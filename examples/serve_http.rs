//! End-to-end gateway demo: boot the OpenAI-compatible HTTP gateway on an
//! ephemeral port, drive it closed-loop over real sockets with the
//! built-in load generator (unary + streaming + chat traffic on keep-alive
//! connections), hot-add a replica at runtime, apply an ingress update
//! through /admin/scale, retire the replica with the drain protocol, and
//! scrape /metrics. Runs against the compiled tiny LM when artifacts
//! exist, the deterministic sim engine otherwise — so this demo works in
//! any environment.

use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::{Engine, EngineConfig, StreamEngine};
use enova::gateway::{loadgen, metrics::parse_exposition, EngineSpawner, Gateway, GatewayConfig};
use enova::runtime::lm::{ExecMode, LmRuntime};
use enova::runtime::{Manifest, PjRt};
use std::sync::Arc;

fn main() -> anyhow::Result<()> {
    let replicas = 2usize;
    let use_lm = Manifest::artifacts_exist();
    let spawner: EngineSpawner = if use_lm {
        Arc::new(|id| {
            let m = Manifest::load(&Manifest::default_dir())?;
            let lm = LmRuntime::load(PjRt::cpu()?, &m, ExecMode::Chained)?;
            let cfg = EngineConfig {
                max_num_seqs: 8,
                max_tokens: 16,
                temperature: 0.7,
            };
            Ok(Box::new(Engine::new(lm, cfg, 100 + id)) as Box<dyn StreamEngine>)
        })
    } else {
        Arc::new(|_id| {
            Ok(Box::new(SimEngine::new(SimEngineConfig {
                max_num_seqs: 8,
                max_tokens: 16,
                ..Default::default()
            })) as Box<dyn StreamEngine>)
        })
    };

    let gw = Gateway::start_scalable(GatewayConfig::default(), spawner, replicas, None)?;
    let addr = gw.addr_string();
    println!(
        "gateway up on http://{addr} ({} engine)",
        if use_lm { "compiled LM" } else { "sim" }
    );

    // one interactive-style exchange first
    let resp = loadgen::post_json(
        &addr,
        "/v1/completions",
        "{\"prompt\": \"what makes serverless LLM serving stable?\", \"max_tokens\": 12}",
    )?;
    println!("\nPOST /v1/completions -> {}", resp.status);
    println!("{}", resp.body_str());

    // closed-loop load: 32 workers mixing unary, streaming and chat,
    // each on one persistent keep-alive connection
    let report = loadgen::run(
        &addr,
        &loadgen::LoadgenConfig {
            concurrency: 32,
            requests_per_worker: 3,
            max_tokens: 8,
            ..Default::default()
        },
    );
    println!("\nloadgen: {}", report.summary());

    // the replica lifecycle the autoscaling supervisor drives: hot-add...
    let added = gw.add_replica()?;
    println!("\nhot-added replica {added}; live set: {:?}", gw.live_replicas());

    // ...reweight through the autoscaler's ingress-update path...
    let resp = loadgen::post_json(
        &addr,
        "/admin/scale",
        &format!(
            "{{\"replicas\": [{{\"id\": 0, \"weight\": 1.0}}, {{\"id\": 1, \"weight\": 0.5}}, \
             {{\"id\": {added}, \"weight\": 2.0}}]}}"
        ),
    )?;
    println!("POST /admin/scale -> {} {}", resp.status, resp.body_str());

    // ...and retire it again: deroute, drain in-flight work, join
    gw.retire_replica(added)?;
    println!("retired replica {added}; live set: {:?}", gw.live_replicas());

    // scrape and summarize the exposition
    let scrape = loadgen::get(&addr, "/metrics")?;
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    println!(
        "\nGET /metrics: {} samples, {} of them per-replica Table II gauges",
        samples.len(),
        samples.iter().filter(|s| s.name.starts_with("enova_replica_")).count()
    );
    for s in samples.iter().filter(|s| s.name == "enova_gateway_requests_total") {
        println!("  {} {:?} = {}", s.name, s.labels, s.value);
    }

    gw.shutdown();
    println!("\ngateway drained and stopped");
    Ok(())
}
