//! End-to-end gateway demo: boot the OpenAI-compatible HTTP gateway on an
//! ephemeral port, drive it closed-loop over real sockets with the
//! built-in load generator (unary + streaming + chat traffic), apply an
//! ingress update through /admin/scale, and scrape /metrics. Runs against
//! the compiled tiny LM when artifacts exist, the deterministic sim
//! engine otherwise — so this demo works in any environment.

use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::{Engine, EngineConfig, StreamEngine};
use enova::gateway::{loadgen, metrics::parse_exposition, EngineFactory, Gateway, GatewayConfig};
use enova::runtime::lm::{ExecMode, LmRuntime};
use enova::runtime::{Manifest, PjRt};

fn main() -> anyhow::Result<()> {
    let replicas = 2u64;
    let use_lm = Manifest::artifacts_exist();
    let factories: Vec<EngineFactory> = (0..replicas)
        .map(|id| -> EngineFactory {
            if use_lm {
                Box::new(move || {
                    let m = Manifest::load(&Manifest::default_dir())?;
                    let lm = LmRuntime::load(PjRt::cpu()?, &m, ExecMode::Chained)?;
                    let cfg = EngineConfig {
                        max_num_seqs: 8,
                        max_tokens: 16,
                        temperature: 0.7,
                    };
                    Ok(Box::new(Engine::new(lm, cfg, 100 + id)) as Box<dyn StreamEngine>)
                })
            } else {
                Box::new(|| {
                    Ok(Box::new(SimEngine::new(SimEngineConfig {
                        max_num_seqs: 8,
                        max_tokens: 16,
                        ..Default::default()
                    })) as Box<dyn StreamEngine>)
                })
            }
        })
        .collect();

    let gw = Gateway::start(GatewayConfig::default(), factories)?;
    let addr = gw.addr_string();
    println!(
        "gateway up on http://{addr} ({} engine)",
        if use_lm { "compiled LM" } else { "sim" }
    );

    // one interactive-style exchange first
    let resp = loadgen::post_json(
        &addr,
        "/v1/completions",
        "{\"prompt\": \"what makes serverless LLM serving stable?\", \"max_tokens\": 12}",
    )?;
    println!("\nPOST /v1/completions -> {}", resp.status);
    println!("{}", resp.body_str());

    // closed-loop load: 32 workers mixing unary, streaming and chat
    let report = loadgen::run(
        &addr,
        &loadgen::LoadgenConfig {
            concurrency: 32,
            requests_per_worker: 3,
            max_tokens: 8,
            ..Default::default()
        },
    );
    println!("\nloadgen: {}", report.summary());

    // the autoscaler's ingress-update path
    let resp = loadgen::post_json(
        &addr,
        "/admin/scale",
        "{\"replicas\": [{\"id\": 0, \"weight\": 1.0}, {\"id\": 1, \"weight\": 0.5}]}",
    )?;
    println!("\nPOST /admin/scale -> {} {}", resp.status, resp.body_str());

    // scrape and summarize the exposition
    let scrape = loadgen::get(&addr, "/metrics")?;
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    println!(
        "\nGET /metrics: {} samples, {} of them per-replica Table II gauges",
        samples.len(),
        samples.iter().filter(|s| s.name.starts_with("enova_replica_")).count()
    );
    for s in samples.iter().filter(|s| s.name == "enova_gateway_requests_total") {
        println!("  {} {:?} = {}", s.name, s.labels, s.value);
    }

    gw.shutdown();
    println!("\ngateway drained and stopped");
    Ok(())
}
