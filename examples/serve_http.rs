//! End-to-end gateway demo: boot the OpenAI-compatible HTTP gateway on an
//! ephemeral port with a warm replica pool, drive it closed-loop over real
//! sockets with the built-in load generator (unary + streaming + chat
//! traffic on keep-alive connections), promote a replica from the warm
//! pool at runtime, apply a live `max_num_seqs`/`gpu_memory`
//! reconfiguration to a running replica, apply an ingress update through
//! /v1/admin/scale, retire a replica (demoting it back to warm), and scrape
//! /metrics. Runs against the compiled tiny LM when the build has the
//! xla-runtime feature and artifacts exist, the deterministic sim engine
//! otherwise — so this demo works in any environment.

use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::{loadgen, metrics::parse_exposition, EngineSpawner, Gateway, GatewayConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

#[cfg(feature = "xla-runtime")]
fn make_spawner() -> (EngineSpawner, &'static str) {
    use enova::engine::{Engine, EngineConfig};
    use enova::runtime::lm::{ExecMode, LmRuntime};
    use enova::runtime::{Manifest, PjRt};
    if Manifest::artifacts_exist() {
        let spawner: EngineSpawner = Arc::new(|id| {
            let m = Manifest::load(&Manifest::default_dir())?;
            let lm = LmRuntime::load(PjRt::cpu()?, &m, ExecMode::Chained)?;
            let cfg = EngineConfig {
                max_num_seqs: 8,
                max_tokens: 16,
                temperature: 0.7,
            };
            Ok(Box::new(Engine::new(lm, cfg, 100 + id)) as Box<dyn StreamEngine>)
        });
        (spawner, "compiled LM")
    } else {
        (sim_spawner(), "sim")
    }
}

#[cfg(not(feature = "xla-runtime"))]
fn make_spawner() -> (EngineSpawner, &'static str) {
    (sim_spawner(), "sim")
}

fn sim_spawner() -> EngineSpawner {
    Arc::new(|_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 8,
            max_tokens: 16,
            ..Default::default()
        })) as Box<dyn StreamEngine>)
    })
}

fn main() -> anyhow::Result<()> {
    let replicas = 2usize;
    let (spawner, kind) = make_spawner();

    let cfg = GatewayConfig {
        warm_pool: 1,
        ..GatewayConfig::default()
    };
    let gw = Gateway::start_scalable(cfg, spawner, replicas, None)?;
    let addr = gw.addr_string();
    println!("gateway up on http://{addr} ({kind} engine, warm pool 1)");

    // one interactive-style exchange first
    let resp = loadgen::post_json(
        &addr,
        "/v1/completions",
        "{\"prompt\": \"what makes serverless LLM serving stable?\", \"max_tokens\": 12}",
    )?;
    println!("\nPOST /v1/completions -> {}", resp.status);
    println!("{}", resp.body_str());

    // closed-loop load: 32 workers mixing unary, streaming and chat,
    // each on one persistent keep-alive connection
    let report = loadgen::run(
        &addr,
        &loadgen::LoadgenConfig {
            concurrency: 32,
            requests_per_worker: 3,
            max_tokens: 8,
            ..Default::default()
        },
    );
    println!("\nloadgen: {}", report.summary());

    // the scenario engine: the same traffic shapes the CI smoke matrix
    // and bench-trend job replay (steady/diurnal/spike/ramp/mixture),
    // here a short open-loop burst with its shape recorded in the report
    let scenario = loadgen::ScenarioConfig {
        kind: loadgen::ScenarioKind::Spike,
        duration: Duration::from_secs(3),
        base_rps: 3.0,
        peak_rps: 12.0,
        seed: 7,
        workers: 8,
        max_tokens: 6,
        ..loadgen::ScenarioConfig::default()
    };
    let sr = loadgen::run_scenario(&addr, &scenario);
    println!(
        "scenario {} ({} offered): {}",
        scenario.kind.name(),
        sr.scenario
            .as_ref()
            .and_then(|j| j.get("offered"))
            .and_then(enova::util::json::Json::as_usize)
            .unwrap_or(0),
        sr.summary()
    );

    // scale-up the way the supervisor does it: the warm pool hides engine
    // init, so promotion is O(route-update)
    let deadline = Instant::now() + Duration::from_secs(60);
    while gw.warm_pool_size() < 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    let t0 = Instant::now();
    let added = gw.add_replica()?;
    println!(
        "\npromoted replica {added} in {:.1}ms (warm pool now {}); live set: {:?}",
        t0.elapsed().as_secs_f64() * 1e3,
        gw.warm_pool_size(),
        gw.live_replicas()
    );

    // the live Fig. 6 knob: mutate a running replica's capacity without a
    // relaunch — in production the supervisor derives this from the live
    // Table II window (§IV-A) with --reconfig
    gw.reconfigure_replica(added, 16, 0.95)?;
    let deadline = Instant::now() + Duration::from_secs(5);
    while Instant::now() < deadline {
        if gw
            .replica_capacities()
            .iter()
            .any(|&(id, cap)| id == added && cap == 16)
        {
            break;
        }
        std::thread::sleep(Duration::from_millis(10));
    }
    println!("reconfigured replica {added} live: capacities {:?}", gw.replica_capacities());

    // ...reweight through the autoscaler's ingress-update path...
    let resp = loadgen::post_json(
        &addr,
        "/v1/admin/scale",
        &format!(
            "{{\"replicas\": [{{\"id\": 0, \"weight\": 1.0}}, {{\"id\": 1, \"weight\": 0.5}}, \
             {{\"id\": {added}, \"weight\": 2.0}}]}}"
        ),
    )?;
    println!("POST /v1/admin/scale -> {} {}", resp.status, resp.body_str());

    // ...and retire it again: demoted back to a warm standby when the
    // pool is below target, drained-then-joined otherwise
    gw.retire_replica(added)?;
    println!(
        "retired replica {added}; live set: {:?}, warm pool {}",
        gw.live_replicas(),
        gw.warm_pool_size()
    );

    // scrape and summarize the exposition
    let scrape = loadgen::get(&addr, "/metrics")?;
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    println!(
        "\nGET /metrics: {} samples, {} of them per-replica Table II gauges",
        samples.len(),
        samples.iter().filter(|s| s.name.starts_with("enova_replica_")).count()
    );
    for s in samples.iter().filter(|s| {
        s.name == "enova_gateway_requests_total"
            || s.name == "enova_gateway_promotion_seconds_count"
            || s.name == "enova_gateway_warm_pool_replicas"
            || s.name == "enova_gateway_reconfigure_events_total"
    }) {
        println!("  {} {:?} = {}", s.name, s.labels, s.value);
    }

    gw.shutdown();
    println!("\ngateway drained and stopped");
    Ok(())
}
