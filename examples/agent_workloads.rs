//! Multi-agent deployment (§IV-A-3 / Fig. 8): embed requests from four
//! agent task families with the compiled embedder, cluster them with
//! modularity maximization, and derive per-community max_tokens — then
//! route fresh requests to their community's configuration.

use enova::clusterer::Communities;
use enova::runtime::embedder::EmbedRuntime;
use enova::runtime::{Manifest, PjRt};
use enova::util::rng::Pcg64;
use enova::workload::corpus::{render_prompt, sample_item, ALL_FAMILIES, ALL_PARADIGMS};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = PjRt::cpu()?;
    let embedder = EmbedRuntime::load(rt, &manifest)?;

    // "historical" requests with observed output lengths
    let mut rng = Pcg64::new(9);
    let mut texts = Vec::new();
    let mut lens = Vec::new();
    for family in ALL_FAMILIES {
        for paradigm in ALL_PARADIGMS {
            for _ in 0..10 {
                texts.push(render_prompt(family, paradigm, &mut rng));
                lens.push(family.sample_output_len(&mut rng));
            }
        }
    }
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let emb = embedder.embed(&refs)?;
    let comms = Communities::fit(&emb, &lens, 0.55, 4096);
    println!("discovered {} communities from {} requests", comms.len(), texts.len());
    for (c, (mt, size)) in comms.max_tokens.iter().zip(&comms.sizes).enumerate() {
        println!("  community {c}: {size} requests, max_tokens {mt}");
    }

    // fresh requests from each family get their community's max_tokens
    println!("\nrouting fresh agent requests:");
    for family in ALL_FAMILIES {
        let item = sample_item(family, &mut rng);
        let e = embedder.embed(&[&item.text])?;
        let (c, mt) = comms.assign(&e[0]).expect("assignment");
        println!("  {:8} → community {c} (max_tokens {mt})", family.name());
    }
    assert!(comms.len() >= 3, "expected ≥3 task communities");
    println!("OK: multi-agent clustering + per-community configuration");
    Ok(())
}
