//! Quickstart: load the compiled tiny LM, serve a handful of prompts
//! through the continuous-batching engine, print completions + timing.
//!
//! ```sh
//! make artifacts && cargo run --release --example quickstart
//! ```

use enova::engine::{Engine, EngineConfig};
use enova::runtime::lm::{ExecMode, LmRuntime};
use enova::runtime::{Manifest, PjRt};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    println!(
        "model: {} params, batch {}, ctx {}, vocab {}",
        manifest.model.param_count,
        manifest.model.batch,
        manifest.model.max_seq,
        manifest.model.vocab
    );
    let rt = PjRt::cpu()?;
    let lm = LmRuntime::load(rt, &manifest, ExecMode::Chained)?;
    let mut engine = Engine::new(
        lm,
        EngineConfig {
            max_num_seqs: 8,
            max_tokens: 32,
            temperature: 0.8,
        },
        42,
    );

    let prompts = [
        "Solve this grade school math problem: a farmer has 12 eggs",
        "Write a python function to merge overlapping intervals",
        "Why do metals conduct electricity?",
        "Read the story about the lost kite and answer the question",
    ];
    for p in prompts {
        engine.submit(p, 32);
    }
    let t0 = std::time::Instant::now();
    let completions = engine.run_to_completion()?;
    let wall = t0.elapsed().as_secs_f64();

    for c in &completions {
        println!(
            "[req {}] {:?} ({} tokens, ttft {:.0}ms, total {:.0}ms, {} output bytes)",
            c.id,
            c.finish_reason,
            c.tokens.len(),
            (c.first_token_at - c.arrival) * 1e3,
            (c.finished_at - c.arrival) * 1e3,
            c.text.len(),
        );
    }
    let tokens: usize = completions.iter().map(|c| c.tokens.len()).sum();
    println!(
        "served {} requests / {} tokens in {:.2}s ({:.0} tok/s on CPU PJRT)",
        completions.len(),
        tokens,
        wall,
        tokens as f64 / wall
    );
    Ok(())
}
