//! End-to-end driver (DESIGN.md §End-to-end validation): deploy TWO real
//! engine replicas of the compiled tiny LM behind the weighted router,
//! replay agent-style requests, record Table II monitoring frames, and
//! report throughput/latency percentiles. Python never runs here.

use enova::engine::{Engine, EngineConfig};
use enova::metrics::Frame;
use enova::router::WeightedRouter;
use enova::runtime::lm::{ExecMode, LmRuntime};
use enova::runtime::{Manifest, PjRt};
use enova::stats::descriptive::quantile;
use enova::tsdb::MetricStore;
use enova::util::rng::Pcg64;
use enova::workload::corpus::{sample_item, ALL_FAMILIES};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let rt = PjRt::cpu()?;

    // replica 1 gets a lower routing weight (pretend it sits on a weaker
    // device — the §IV-A-4 heterogeneous-cluster situation)
    let mut engines: Vec<Engine> = (0..2u64)
        .map(|i| {
            let lm = LmRuntime::load(rt.clone(), &manifest, ExecMode::Chained)?;
            Ok(Engine::new(
                lm,
                EngineConfig { max_num_seqs: 8, max_tokens: 24, temperature: 0.7 },
                100 + i,
            ))
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    let router = WeightedRouter::new(&[(0, 1.0), (1, 0.65)]);

    let mut rng = Pcg64::new(5);
    let n_requests = 60;
    let mut store = MetricStore::new();
    let mut latencies = Vec::new();
    let mut per_replica = vec![0usize; 2];
    let t0 = std::time::Instant::now();
    let (mut submitted, mut completed, mut step) = (0, 0, 0u64);

    while completed < n_requests {
        for _ in 0..4 {
            if submitted < n_requests {
                let fam = ALL_FAMILIES[rng.usize_in(0, 4)];
                let item = sample_item(fam, &mut rng);
                let handle = router.dispatch().expect("replicas");
                per_replica[handle.id as usize] += 1;
                engines[handle.id as usize].submit(&item.text, 24);
                submitted += 1;
            }
        }
        for (ri, engine) in engines.iter_mut().enumerate() {
            for c in engine.step()? {
                latencies.push(c.finished_at - c.arrival);
                completed += 1;
                router.complete(&router.replicas()[ri]);
            }
        }
        step += 1;
        if step % 8 == 0 {
            let t = t0.elapsed().as_secs_f64();
            for (ri, engine) in engines.iter().enumerate() {
                let f: Frame = engine.frame(0.0, 0.0, 0.0);
                f.record(&mut store, &format!("replica-{ri}"), t);
            }
        }
    }
    let wall = t0.elapsed().as_secs_f64();

    println!("served {n_requests} requests in {wall:.2}s across 2 real PJRT replicas");
    println!(
        "routing split: replica-0 {} vs replica-1 {} (weights 1.0 / 0.65)",
        per_replica[0], per_replica[1]
    );
    println!(
        "latency p50 {:.0}ms  p95 {:.0}ms  p99 {:.0}ms",
        quantile(&latencies, 0.5) * 1e3,
        quantile(&latencies, 0.95) * 1e3,
        quantile(&latencies, 0.99) * 1e3,
    );
    let kv = store.window("kv_util", "replica-0", 0.0, wall + 1.0);
    println!(
        "monitoring: {} kv_util samples for replica-0 (max {:.2})",
        kv.len(),
        kv.iter().copied().fold(0.0, f64::max)
    );
    assert!(per_replica[0] > per_replica[1], "router should favor weight 1.0");
    println!("OK: end-to-end cluster serving complete");
    Ok(())
}
