//! Performance detection (§IV-B): calibrate the compiled semi-supervised
//! VAE on the trace trainset, then stream the test fortnight through it,
//! printing detections with their scale-up/down direction.

use enova::detect::dataset::DetectionDataset;
use enova::detect::{EnovaDetector, ScaleDirection};
use enova::runtime::vae::VaeRuntime;
use enova::runtime::{Manifest, PjRt};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load(&Manifest::default_dir())?;
    let ds = DetectionDataset::load(&manifest.detection_dataset)?;
    let rt = PjRt::cpu()?;
    let vae = VaeRuntime::load(rt, &manifest)?;

    let stride = 4;
    let mut rows = Vec::new();
    let mut labels = Vec::new();
    for i in (0..ds.train_rows()).step_by(stride) {
        rows.extend_from_slice(ds.train_row(i));
        labels.push(ds.train_labels[i]);
    }
    let det = EnovaDetector::calibrate_semisupervised(vae, &rows, &labels)?;
    println!("calibrated threshold {:.2} (POT initial {:.2})", det.threshold, det.pot.initial);

    // stream a slice of the test fortnight
    let n = 20_000.min(ds.test_rows());
    let slice = &ds.test[..n * ds.n_features];
    let detections = det.detect(slice)?;
    let mut hits = 0;
    let mut up = 0;
    for (i, d) in detections.iter().enumerate() {
        if d.is_anomaly {
            hits += 1;
            if d.direction == ScaleDirection::Up {
                up += 1;
            }
            if hits <= 8 {
                println!(
                    "  t={i:6} score {:8.2} (thr {:.2}) → {:?} [label={}]",
                    d.kl, d.threshold, d.direction, ds.test_labels[i]
                );
            }
        }
    }
    let true_anoms = ds.test_labels[..n].iter().filter(|&&l| l == 1).count();
    println!("flagged {hits} points over {n} ({} labeled anomalous), {up} scale-up", true_anoms);
    println!("OK: detection loop complete");
    Ok(())
}
