#!/usr/bin/env bash
# CI bench-trend driver: run the loadgen scenarios against an in-process
# forecast-aware gateway (enova bench-gateway) on the release build, emit
# BENCH_gateway.json (p50/p95 latency, shed counts, proactive/reactive
# scale events per scenario), and fail on >20% p95 regression against the
# committed baseline when one exists at rust/benches/BENCH_gateway_baseline.json.
#
# Expects the release binary to be built already:
#   cargo build --release --no-default-features  (or with default features)
set -euo pipefail

cd "$(dirname "$0")/.."
BIN=rust/target/release/enova
OUT="${BENCH_OUT:-BENCH_gateway.json}"
BASELINE="${BENCH_BASELINE:-rust/benches/BENCH_gateway_baseline.json}"
DURATION="${BENCH_DURATION_S:-6}"

if [[ ! -x "$BIN" ]]; then
    echo "release binary missing at $BIN; build it first" >&2
    exit 2
fi

"$BIN" bench-gateway --report "$OUT" --baseline "$BASELINE" \
    --scenarios steady,spike,diurnal --duration-s "$DURATION" \
    --regression-pct "${BENCH_REGRESSION_PCT:-20}"

echo "bench report at $OUT"
