#!/usr/bin/env bash
# CI cluster smoke: the distributed plane, end to end, on one runner.
#
#   1. boot `enova serve-http --cluster` (the coordinator) + two
#      `enova node` processes on the sim engine;
#   2. wait until the coordinator reports both nodes serving
#      (enova_cluster_nodes == 2, asserted on a pre-run scrape);
#   3. replay the `spike` scenario open-loop through the coordinator with
#      `--strict` — any transport error or non-2xx fails the job;
#   4. kill one node mid-run (plain `kill`, no drain — a real death) and
#      require the report to STILL be clean: the coordinator re-routes
#      and backfills on the survivor;
#   5. assert the post-run scrape shows the death (1 healthy node,
#      node_deaths_total moved) and at least one placement.
#
# Artifacts: the loadgen report plus both scrapes. Cleanup runs through
# scripts/smoke_common.sh (one EXIT trap kills and reaps everything).
#
# Expects the release binary to be built already:
#   cargo build --release --no-default-features  (or with default features)
set -euo pipefail

cd "$(dirname "$0")/.."
# shellcheck source=scripts/smoke_common.sh
source scripts/smoke_common.sh

BIN=rust/target/release/enova
PORT="${CLUSTER_PORT:-18500}"
NODE_A_PORT="${CLUSTER_NODE_A_PORT:-18501}"
NODE_B_PORT="${CLUSTER_NODE_B_PORT:-18502}"
REPORT="${CLUSTER_REPORT:-loadgen-cluster-report.json}"
SCRAPE_PRE="${CLUSTER_SCRAPE_PRE:-cluster-scrape-pre.txt}"
SCRAPE_POST="${CLUSTER_SCRAPE_POST:-cluster-scrape-post.txt}"

if [[ ! -x "$BIN" ]]; then
    echo "release binary missing at $BIN; build it first" >&2
    exit 2
fi

start_bg "$BIN" serve-http --cluster --port "$PORT" \
    --heartbeat-ms 100 --node-timeout-beats 3 --dispatch-attempts 4 \
    --forecast --forecast-capacity 5 --forecast-horizon-ms 1000 \
    --scale-interval-ms 200 --cooldown-ms 1000 --max-replicas 4 \
    --max-pending 2048

start_bg "$BIN" node --engine sim --port "$NODE_A_PORT" \
    --coordinator "127.0.0.1:$PORT" --node-id node-a --replicas 1 --warm-pool 1 \
    --gpu-memory 24 --replica-gpu-memory 8 --max-pending 1024 --announce-ms 200

start_bg "$BIN" node --engine sim --port "$NODE_B_PORT" \
    --coordinator "127.0.0.1:$PORT" --node-id node-b --replicas 1 --warm-pool 1 \
    --gpu-memory 24 --replica-gpu-memory 8 --max-pending 1024 --announce-ms 200
NODE_B_PID=$SMOKE_LAST_PID

# coordinator is ready once at least one node serves; then wait until the
# heartbeats have seen both nodes' replicas (nodes flip healthy on join,
# but replica counts only arrive with their first status poll)
wait_http_ok "http://127.0.0.1:$PORT/ready"
REPLICAS=0
for _ in $(seq 1 100); do
    REPLICAS=$(curl -fsS "http://127.0.0.1:$PORT/metrics" \
        | sed -n 's/^enova_cluster_replicas \(.*\)$/\1/p')
    [[ "$REPLICAS" == "2" ]] && break
    sleep 0.1
done
if [[ "$REPLICAS" != "2" ]]; then
    echo "cluster never reached 2 observed replicas (saw ${REPLICAS:-none})" >&2
    exit 1
fi

curl -fsS "http://127.0.0.1:$PORT/metrics" > "$SCRAPE_PRE"
grep -q '^enova_cluster_nodes 2$' "$SCRAPE_PRE"
grep -q '^enova_cluster_replicas 2$' "$SCRAPE_PRE"

# spike through the coordinator; node-b dies mid-run
start_bg "$BIN" loadgen --addr "127.0.0.1:$PORT" --scenario spike \
    --duration-s 8 --base-rps 2 --peak-rps 10 --seed 7 --workers 16 \
    --max-tokens 8 --strict --report "$REPORT"
LOADGEN_PID=$SMOKE_LAST_PID

sleep 4
echo "==> killing node-b (pid $NODE_B_PID) mid-run"
kill "$NODE_B_PID" 2>/dev/null || true

# --strict: the wait propagates loadgen's exit code, so any transport
# error or non-2xx through the node death fails the job here
wait "$LOADGEN_PID"

echo "==> post-run scrape assertions"
curl -fsS "http://127.0.0.1:$PORT/metrics" > "$SCRAPE_POST"
grep -q '^enova_cluster_nodes 1$' "$SCRAPE_POST"
grep -Eq '^enova_cluster_node_deaths_total [1-9]' "$SCRAPE_POST"
PLACEMENTS=$(sed -n 's/^enova_cluster_placement_total{reason="[a-z_]*"} //p' "$SCRAPE_POST" \
    | awk '{s+=$1} END {print s+0}')
if [[ "${PLACEMENTS:-0}" -lt 1 ]]; then
    echo "expected at least one placement, saw ${PLACEMENTS:-0}" >&2
    exit 1
fi

echo "==> versioned admin API smoke (coordinator + surviving node)"
# the coordinator's /v1/admin/status aggregates the fleet; node-a answers
# its own typed advertisement on the same path the heartbeat polls
CLUSTER_STATUS=$(mktemp)
curl -fsS "http://127.0.0.1:$PORT/v1/admin/status" > "$CLUSTER_STATUS"
NODE_STATUS=$(mktemp)
curl -fsS "http://127.0.0.1:$NODE_A_PORT/v1/admin/status" > "$NODE_STATUS"
python3 - "$CLUSTER_STATUS" "$NODE_STATUS" <<'PY'
import json, sys

cluster = json.load(open(sys.argv[1]))
assert cluster["node_id"] == "coordinator", cluster
assert cluster["live_replicas"] >= 1, cluster
node = json.load(open(sys.argv[2]))
assert node["node_id"] == "node-a", node
assert node["live_replicas"] >= 1 and "gpu_memory_free" in node, node
print(f"admin status OK: cluster {cluster['live_replicas']} live, node-a {node['live_replicas']} live")
PY
rm -f "$CLUSTER_STATUS" "$NODE_STATUS"
# weights are a per-process concern: the coordinator refuses with a
# structured error pointing at the node, not a bare 404
curl -sS -X POST --data '{"replicas": [{"id": 0, "weight": 1.0}]}' \
    "http://127.0.0.1:$PORT/v1/admin/scale" | grep -q '"unsupported"'
# the deprecated alias still answers the heartbeat contract
curl -fsS "http://127.0.0.1:$NODE_A_PORT/cluster/status" | grep -q '"node_id"'

echo "==> trace + decision assertions (cross-node traces, flight recorder)"
TRACES="${CLUSTER_TRACES:-cluster-traces.json}"
DECISIONS="${CLUSTER_DECISIONS:-cluster-decisions.json}"
curl -fsS "http://127.0.0.1:$PORT/debug/traces" > "$TRACES"
curl -fsS "http://127.0.0.1:$PORT/debug/decisions" > "$DECISIONS"
# the versioned exports wrap the same recorders in the typed envelope;
# the unversioned paths above stay deprecated aliases with the bare shapes
curl -fsS "http://127.0.0.1:$PORT/v1/debug/traces" \
    | python3 -c "import json,sys; e=json.load(sys.stdin); assert e['api_version']=='v1' and e['kind']=='traces' and e['service']=='coordinator' and e['data']['traces'], e.keys(); print('/v1/debug/traces OK')"
curl -fsS "http://127.0.0.1:$PORT/v1/debug/decisions" \
    | python3 -c "import json,sys; e=json.load(sys.stdin); assert e['api_version']=='v1' and e['kind']=='decisions' and e['data']['decisions'], e.keys(); print('/v1/debug/decisions OK')"
python3 - "$TRACES" "$DECISIONS" <<'PY'
import json, sys

view = json.load(open(sys.argv[1]))
traces = view["traces"]
assert view["recorded"] > 0 and traces, "the run left no traces on the coordinator"
# node-b is dead at scrape time, so only the survivor contributes spans
assert view["nodes_polled"] >= 1, f"no node answered the trace poll: {view['nodes_polled']}"

# at least one merged trace must hold BOTH sides under one trace ID, with
# the full node-side lifecycle (node-b's share died with node-b)
lifecycle = {"admission", "dispatch", "queue_wait", "prefill", "decode"}
cross = 0
for t in traces:
    services = {s["service"] for s in t["spans"]}
    if "coordinator" not in services or not any(x.startswith("node:") for x in services):
        continue
    node_phases = {
        s["name"]
        for s in t["spans"]
        if s["kind"] == "phase" and s["service"].startswith("node:")
    }
    if lifecycle <= node_phases:
        cross += 1
assert cross > 0, "no cross-node trace carried the full lifecycle on the node side"

decisions = json.load(open(sys.argv[2]))["decisions"]
assert decisions, "the decision flight recorder is empty"
placements = [d for d in decisions if d["kind"] == "placement"]
assert placements, f"no placement decision recorded: {decisions}"
for d in placements:
    assert d["attrs"].get("bin_packing"), f"placement without bin-packing snapshot: {d}"
print(f"traces OK: {cross} cross-node traces; {len(placements)} placement decisions recorded")
PY

echo "cluster smoke OK; report at $REPORT ($PLACEMENTS placements, node-b death absorbed, $TRACES + $DECISIONS saved)"
