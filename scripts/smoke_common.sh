# Shared helpers for the CI smoke scripts (gateway_smoke.sh,
# cluster_smoke.sh). Sourced, not executed.
#
# Every background process goes through start_bg so ONE EXIT trap kills
# and reaps them all — a failed assertion (or ctrl-C) never leaves a
# server bound to the port, which used to poison retries on self-hosted
# runners.

SMOKE_PIDS=()
SMOKE_LAST_PID=""

# Run a command in the background and register it for cleanup. The PID is
# exposed via $SMOKE_LAST_PID (not stdout: command substitution would eat
# the server's own output).
start_bg() {
    "$@" &
    SMOKE_LAST_PID=$!
    SMOKE_PIDS+=("$SMOKE_LAST_PID")
}

smoke_cleanup() {
    local pid
    for pid in ${SMOKE_PIDS[@]+"${SMOKE_PIDS[@]}"}; do
        kill "$pid" 2>/dev/null || true
    done
    for pid in ${SMOKE_PIDS[@]+"${SMOKE_PIDS[@]}"}; do
        wait "$pid" 2>/dev/null || true
    done
}
trap smoke_cleanup EXIT

# Poll a URL until it answers 2xx (default 150 x 0.1s).
wait_http_ok() {
    local url=$1 attempts=${2:-150}
    local i
    for i in $(seq 1 "$attempts"); do
        if curl -fsS "$url" >/dev/null 2>&1; then
            return 0
        fi
        sleep 0.1
    done
    echo "timed out waiting for $url" >&2
    return 1
}
