#!/usr/bin/env bash
# Tier-1 verification: the gate every PR must keep green (ROADMAP.md).
# Usage: scripts/tier1.sh [--no-fmt] [--no-default-features]
#
#   --no-default-features  sim-only build (drops the `xla-runtime` feature,
#                          so no xla_extension native lib is needed) — what
#                          the CI `tier1-sim` job runs on stock runners.
set -euo pipefail

SCRIPT_DIR="$(cd "$(dirname "$0")" && pwd)"
cd "$SCRIPT_DIR/../rust"

NO_FMT=0
FEATURES=()
for arg in "$@"; do
    case "$arg" in
        --no-fmt) NO_FMT=1 ;;
        --no-default-features) FEATURES+=("--no-default-features") ;;
        *) echo "unknown option: $arg" >&2; exit 2 ;;
    esac
done

# Reproducible builds: pin the dependency graph and refuse drift. A
# committed lockfile that drifted from Cargo.toml fails here. CI runs the
# guard WITHOUT bootstrap (a missing lockfile hard-fails the job); tier1.sh
# is also the first-run entrypoint for fresh developer environments and the
# offline driver, so it alone opts into bootstrap generation — with the
# guard's loud warning to commit the result.
ENOVA_LOCKFILE_BOOTSTRAP=1 bash "$SCRIPT_DIR/ensure_lockfile.sh"

echo "==> cargo build --release --locked"
cargo build --release --locked ${FEATURES[@]+"${FEATURES[@]}"}

echo "==> cargo test -q --locked"
cargo test -q --locked ${FEATURES[@]+"${FEATURES[@]}"}

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy -- -D warnings"
    cargo clippy --locked ${FEATURES[@]+"${FEATURES[@]}"} -- -D warnings
else
    echo "==> cargo clippy unavailable; skipping lint"
fi

if [[ "$NO_FMT" != "1" ]]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "==> cargo fmt --check"
        cargo fmt --check
    else
        echo "==> cargo fmt unavailable; skipping format check"
    fi
fi

echo "tier-1 OK"
