#!/usr/bin/env bash
# Tier-1 verification: the gate every PR must keep green (ROADMAP.md).
# Usage: scripts/tier1.sh [--no-fmt]
set -euo pipefail

cd "$(dirname "$0")/../rust"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

if cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy -- -D warnings"
    cargo clippy -- -D warnings
else
    echo "==> cargo clippy unavailable; skipping lint"
fi

if [[ "${1:-}" != "--no-fmt" ]]; then
    if cargo fmt --version >/dev/null 2>&1; then
        echo "==> cargo fmt --check"
        cargo fmt --check
    else
        echo "==> cargo fmt unavailable; skipping format check"
    fi
fi

echo "tier-1 OK"
