#!/usr/bin/env bash
# Lockfile guard shared by every CI job.
#
# * rust/Cargo.lock committed (the expected state): verify it matches
#   Cargo.toml with `cargo metadata --locked`, which refuses to update the
#   lockfile — any drift fails the job loudly instead of being silently
#   regenerated away.
# * rust/Cargo.lock absent (a fresh environment before the lockfile has
#   been committed): generate it so this run is still pinned and cache
#   keys stay stable, and warn that it must be committed. The tier1-sim
#   job uploads the generated file as an artifact so committing it is a
#   copy, not a toolchain hunt.
set -euo pipefail

cd "$(dirname "$0")/../rust"

if [[ -f Cargo.lock ]]; then
    echo "==> Cargo.lock present; verifying no drift against Cargo.toml (--locked)"
    if ! cargo metadata --locked --format-version 1 > /dev/null; then
        echo "::error::rust/Cargo.lock is out of date with Cargo.toml." \
             "Run 'cargo generate-lockfile' in rust/ and commit the result." >&2
        exit 1
    fi
else
    echo "::warning::rust/Cargo.lock is missing — generating for this run." \
         "Commit rust/Cargo.lock so every job runs --locked against a pinned graph."
    cargo generate-lockfile
fi
