#!/usr/bin/env bash
# Lockfile guard shared by every CI job and scripts/tier1.sh.
#
# * rust/Cargo.lock committed (the expected state): verify it matches
#   Cargo.toml with `cargo metadata --locked`, which refuses to update the
#   lockfile — any drift fails the job loudly instead of being silently
#   regenerated away.
# * rust/Cargo.lock absent: HARD FAIL. Running `--locked` against a
#   lockfile generated seconds earlier pins nothing, so the old
#   generate-on-missing fallback is gone from CI. The one escape hatch is
#   explicit bootstrap mode (ENOVA_LOCKFILE_BOOTSTRAP=1, what
#   scripts/tier1.sh uses for first-run developer environments): it
#   generates a lockfile for this run and insists you commit it.
set -euo pipefail

cd "$(dirname "$0")/../rust"

if [[ -f Cargo.lock ]]; then
    echo "==> Cargo.lock present; verifying no drift against Cargo.toml (--locked)"
    if ! cargo metadata --locked --format-version 1 > /dev/null; then
        echo "::error::rust/Cargo.lock is out of date with Cargo.toml." \
             "Run 'cargo generate-lockfile' in rust/ and commit the result." >&2
        exit 1
    fi
elif [[ "${ENOVA_LOCKFILE_BOOTSTRAP:-0}" == "1" ]]; then
    echo "::warning::rust/Cargo.lock is missing — bootstrap mode generated one for this" \
         "run only. Commit rust/Cargo.lock so --locked pins a real dependency graph."
    cargo generate-lockfile
else
    echo "::error::rust/Cargo.lock is missing. Run 'cargo generate-lockfile' in rust/" \
         "and commit the result. (Local first run without a lockfile? Re-run with" \
         "ENOVA_LOCKFILE_BOOTSTRAP=1.)" >&2
    exit 1
fi
