#!/usr/bin/env bash
# CI chaos smoke: seeded fault injection against the distributed plane,
# with the circuit breakers as the defense under test.
#
#   1. boot `enova serve-http --cluster` (tight breaker windows) + two
#      `enova node` processes on the sim engine; node-b boots with the
#      seeded injector armed in degrade-and-recover mode (error rate
#      0.25 rising 4x to 1.0 for half of every 2s period);
#   2. assert the chaos admin surface: `GET /v1/admin/chaos` shows the
#      CLI-armed config, `POST /v1/admin/chaos` round-trips it (and
#      re-seeds the injector, so the drill replays deterministically);
#   3. replay the `mixture` scenario through the coordinator with
#      `--strict` — plus seeded adversarial clients (slow-loris writers,
#      mid-stream SSE disconnects) riding alongside — any transport
#      error, non-2xx, or tenant SLO violation fails the job: injected
#      faults must stay invisible to well-formed clients;
#   4. assert the breaker OPENED on node-b during the drill, while the
#      node was never declared dead and no replica was backfilled
#      (derouting is a routing verdict, not a death certificate);
#   5. disarm node-b over the admin API, drive a recovery burst, and
#      assert the breaker CLOSED again through half-open probes;
#   6. assert the typed `/v1/debug/{traces,decisions}` envelopes and
#      their deprecated `/debug/*` aliases serve the same payloads.
#
# Artifacts: the loadgen reports plus both scrapes and the debug exports.
# Cleanup runs through scripts/smoke_common.sh (one EXIT trap kills and
# reaps everything).
#
# Expects the release binary to be built already:
#   cargo build --release --no-default-features  (or with default features)
set -euo pipefail

cd "$(dirname "$0")/.."
# shellcheck source=scripts/smoke_common.sh
source scripts/smoke_common.sh

BIN=rust/target/release/enova
PORT="${CHAOS_PORT:-18600}"
NODE_A_PORT="${CHAOS_NODE_A_PORT:-18601}"
NODE_B_PORT="${CHAOS_NODE_B_PORT:-18602}"
REPORT="${CHAOS_REPORT:-loadgen-chaos-report.json}"
RECOVERY_REPORT="${CHAOS_RECOVERY_REPORT:-loadgen-chaos-recovery.json}"
SCRAPE_DRILL="${CHAOS_SCRAPE_DRILL:-chaos-scrape-drill.txt}"
SCRAPE_POST="${CHAOS_SCRAPE_POST:-chaos-scrape-post.txt}"

if [[ ! -x "$BIN" ]]; then
    echo "release binary missing at $BIN; build it first" >&2
    exit 2
fi

# tight breaker tuning so an 8-second drill exercises the full
# closed -> open -> half-open -> closed cycle
start_bg "$BIN" serve-http --cluster --port "$PORT" \
    --heartbeat-ms 100 --node-timeout-beats 5 --dispatch-attempts 4 \
    --max-pending 2048 \
    --breaker-window 6 --breaker-min-samples 3 --breaker-error-threshold 0.5 \
    --breaker-cooldown-ms 300 --breaker-probes 2

start_bg "$BIN" node --engine sim --port "$NODE_A_PORT" \
    --coordinator "127.0.0.1:$PORT" --node-id node-a --replicas 1 --warm-pool 1 \
    --gpu-memory 24 --replica-gpu-memory 8 --max-pending 1024 --announce-ms 200

# node-b: seeded degrade-and-recover — base error rate 0.25, multiplied
# 4x (to 1.0) for half of every 2s period. Heartbeats are NOT injected,
# so the node looks alive the whole time; only its serving path degrades.
start_bg "$BIN" node --engine sim --port "$NODE_B_PORT" \
    --coordinator "127.0.0.1:$PORT" --node-id node-b --replicas 1 --warm-pool 1 \
    --gpu-memory 24 --replica-gpu-memory 8 --max-pending 1024 --announce-ms 200 \
    --chaos-seed 7 --chaos-error-rate 0.25 \
    --chaos-degrade-period-s 2 --chaos-degrade-duty 0.5 --chaos-degrade-factor 4

wait_http_ok "http://127.0.0.1:$PORT/ready"
REPLICAS=0
for _ in $(seq 1 100); do
    REPLICAS=$(curl -fsS "http://127.0.0.1:$PORT/metrics" \
        | sed -n 's/^enova_cluster_replicas \(.*\)$/\1/p')
    [[ "$REPLICAS" == "2" ]] && break
    sleep 0.1
done
if [[ "$REPLICAS" != "2" ]]; then
    echo "cluster never reached 2 observed replicas (saw ${REPLICAS:-none})" >&2
    exit 1
fi

echo "==> chaos admin surface (typed get/set on the node, refusal on the coordinator)"
CHAOS_VIEW=$(mktemp)
curl -fsS "http://127.0.0.1:$NODE_B_PORT/v1/admin/chaos" > "$CHAOS_VIEW"
python3 - "$CHAOS_VIEW" <<'PY'
import json, sys

v = json.load(open(sys.argv[1]))
assert v["api_version"] == "v1", v
assert v["config"]["error_rate"] == 0.25, v["config"]
assert v["config"]["degrade_period_s"] == 2, v["config"]
assert v["stats"]["armed"] is True, v["stats"]
print(f"chaos GET OK: armed seed={v['config']['seed']} on {v['service']}")
PY
# POST round-trips the same config (and re-seeds the injector's RNG, so
# the drill that follows replays like one armed at boot)
CONFIG=$(python3 -c "import json,sys; print(json.dumps(json.load(open(sys.argv[1]))['config']))" "$CHAOS_VIEW")
curl -fsS -X POST --data "$CONFIG" "http://127.0.0.1:$NODE_B_PORT/v1/admin/chaos" \
    | grep -q '"error_rate":0.25'
rm -f "$CHAOS_VIEW"
# fault injection is node-local: the coordinator refuses with a
# structured error, not a bare 404
curl -sS "http://127.0.0.1:$PORT/v1/admin/chaos" | grep -q '"unsupported"'

echo "==> mixture drill under chaos (--strict) with adversarial clients alongside"
"$BIN" loadgen --addr "127.0.0.1:$PORT" --scenario mixture \
    --duration-s 10 --base-rps 12 --peak-rps 12 --seed 11 --workers 24 \
    --max-tokens 8 --strict --report "$REPORT" \
    --adversarial all --adversarial-clients 2 --chaos-seed 42

echo "==> drill scrape assertions (breaker opened, nobody died, nothing backfilled)"
curl -fsS "http://127.0.0.1:$PORT/metrics" > "$SCRAPE_DRILL"
grep -q '^enova_cluster_nodes 2$' "$SCRAPE_DRILL"
grep -q '^enova_cluster_node_deaths_total 0$' "$SCRAPE_DRILL"
grep -q '^enova_cluster_replicas 2$' "$SCRAPE_DRILL"
grep -q 'enova_cluster_breaker_state{node="node-b"}' "$SCRAPE_DRILL"
OPENS=$(sed -n 's/^enova_cluster_breaker_transitions_total{transition="open"} //p' "$SCRAPE_DRILL")
if [[ "${OPENS:-0}" -lt 1 ]]; then
    echo "the drill never tripped a breaker (opens=${OPENS:-0})" >&2
    exit 1
fi
# the injector actually fired (the zero-error report is retries, not luck)
curl -fsS "http://127.0.0.1:$NODE_B_PORT/v1/admin/chaos" \
    | python3 -c "import json,sys; s=json.load(sys.stdin)['stats']; assert s['injected_errors'] > 0, s; print(f\"injected_errors={s['injected_errors']}\")"

echo "==> disarm node-b and drive the recovery burst"
curl -fsS -X POST --data '{}' "http://127.0.0.1:$NODE_B_PORT/v1/admin/chaos" \
    | grep -q '"armed":false'
"$BIN" loadgen --addr "127.0.0.1:$PORT" --scenario steady \
    --duration-s 4 --base-rps 16 --peak-rps 16 --seed 13 --workers 16 \
    --max-tokens 4 --strict --report "$RECOVERY_REPORT"

echo "==> post-recovery scrape assertions (half-open probed, breaker closed)"
curl -fsS "http://127.0.0.1:$PORT/metrics" > "$SCRAPE_POST"
for transition in open half_open close; do
    COUNT=$(sed -n "s/^enova_cluster_breaker_transitions_total{transition=\"$transition\"} //p" "$SCRAPE_POST")
    if [[ "${COUNT:-0}" -lt 1 ]]; then
        echo "breaker transition '$transition' never fired (count=${COUNT:-0})" >&2
        exit 1
    fi
done
grep -q '^enova_cluster_breaker_state{node="node-b"} 0$' "$SCRAPE_POST"
grep -q '^enova_cluster_nodes 2$' "$SCRAPE_POST"
grep -q '^enova_cluster_node_deaths_total 0$' "$SCRAPE_POST"
grep -q '^enova_cluster_replicas 2$' "$SCRAPE_POST"

echo "==> debug exports: typed /v1/debug/* envelopes + deprecated /debug/* aliases"
TRACES="${CHAOS_TRACES:-chaos-traces.json}"
DECISIONS="${CHAOS_DECISIONS:-chaos-decisions.json}"
curl -fsS "http://127.0.0.1:$PORT/v1/debug/traces" > "$TRACES"
curl -fsS "http://127.0.0.1:$PORT/v1/debug/decisions" > "$DECISIONS"
LEGACY_TRACES=$(mktemp)
LEGACY_DECISIONS=$(mktemp)
curl -fsS "http://127.0.0.1:$PORT/debug/traces" > "$LEGACY_TRACES"
curl -fsS "http://127.0.0.1:$PORT/debug/decisions" > "$LEGACY_DECISIONS"
python3 - "$TRACES" "$DECISIONS" "$LEGACY_TRACES" "$LEGACY_DECISIONS" <<'PY'
import json, sys

traces, decisions, legacy_traces, legacy_decisions = (json.load(open(p)) for p in sys.argv[1:5])
for env, kind in ((traces, "traces"), (decisions, "decisions")):
    assert env["api_version"] == "v1" and env["kind"] == kind, env.keys()
    assert env["service"] == "coordinator", env["service"]
# the envelope's data IS the legacy alias body (same recorder, one level
# of wrapping) — modulo entries recorded between the two scrapes
assert traces["data"]["traces"], "the drill left no traces"
assert legacy_traces["traces"], "legacy alias serves no traces"
assert traces["data"].keys() == legacy_traces.keys(), (traces["data"].keys(), legacy_traces.keys())

ds = decisions["data"]["decisions"]
breaker = {d["reason"] for d in ds if d["kind"] == "breaker"}
assert {"open", "half_open", "close"} <= breaker, f"breaker lifecycle incomplete: {breaker}"
opened = [d for d in ds if d["kind"] == "breaker" and d["reason"] == "open"]
assert all(d["attrs"]["node"] == "node-b" for d in opened), opened
assert all("evidence" in d["attrs"] for d in opened), opened
# a derouted node is NOT a dead node: no backfill placements
assert not [d for d in ds if d["kind"] == "placement" and d["reason"] == "backfill"], ds
assert legacy_decisions["decisions"], "legacy alias serves no decisions"
print(f"debug exports OK: {len(traces['data']['traces'])} traces, "
      f"{len(opened)} breaker opens (all node-b), no backfills")
PY
rm -f "$LEGACY_TRACES" "$LEGACY_DECISIONS"

echo "chaos smoke OK; reports at $REPORT + $RECOVERY_REPORT, scrapes at $SCRAPE_DRILL + $SCRAPE_POST"
