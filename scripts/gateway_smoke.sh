#!/usr/bin/env bash
# CI gateway smoke: boot `enova serve-http` on the deterministic sim
# engine with the forecast-aware supervisor on, drive load, and fail on
# any transport error or non-2xx response (incl. 503) — a gateway at this
# load must serve everything. Writes the loadgen report JSON (uploaded as
# a CI artifact).
#
# SMOKE_SCENARIO selects an open-loop scenario (steady|diurnal|spike|ramp|
# mixture, the CI matrix); unset, the legacy closed-loop burst runs.
#
# Cleanup runs through scripts/smoke_common.sh: every background process
# is killed and reaped on EXIT, success or failure, so a failed assertion
# never leaves a server bound to the port to poison retries.
#
# Expects the release binary to be built already:
#   cargo build --release --no-default-features  (or with default features)
set -euo pipefail

cd "$(dirname "$0")/.."
# shellcheck source=scripts/smoke_common.sh
source scripts/smoke_common.sh

BIN=rust/target/release/enova
PORT="${SMOKE_PORT:-18431}"
SCENARIO="${SMOKE_SCENARIO:-}"
REPORT="${SMOKE_REPORT:-loadgen-report${SCENARIO:+-$SCENARIO}.json}"

if [[ ! -x "$BIN" ]]; then
    echo "release binary missing at $BIN; build it first" >&2
    exit 2
fi

start_bg "$BIN" serve-http --engine sim --port "$PORT" --replicas 2 --warm-pool 1 \
    --autoscale --forecast --max-replicas 3 \
    --scale-interval-ms 200 --forecast-horizon-ms 2000

# wait for readiness (the /ready endpoint is 503 until all replicas built)
wait_http_ok "http://127.0.0.1:$PORT/ready"

if [[ -n "$SCENARIO" ]]; then
    "$BIN" loadgen --addr "127.0.0.1:$PORT" --scenario "$SCENARIO" \
        --duration-s 6 --base-rps 2 --peak-rps 10 --seed 7 --workers 16 \
        --max-tokens 8 --strict --report "$REPORT"
else
    "$BIN" loadgen --addr "127.0.0.1:$PORT" --concurrency 8 --requests 5 \
        --max-tokens 8 --strict --report "$REPORT"
fi

echo "==> smoke scrape sanity"
SCRAPE=$(curl -fsS "http://127.0.0.1:$PORT/metrics")
echo "$SCRAPE" | grep -c '^enova_' >/dev/null
# the forecast surface is live on the scrape
echo "$SCRAPE" | grep -q '^enova_supervisor_forecast_enabled 1'
echo "$SCRAPE" | grep -q '^enova_supervisor_forecast_rps'
echo "$SCRAPE" | grep -q '^enova_supervisor_scale_origin_total{origin="proactive"}'
# the tracing surface is live: phase histograms counted the run
echo "$SCRAPE" | grep -q '^enova_request_phase_seconds_count{phase="admission"}'
echo "$SCRAPE" | grep -Eq '^enova_request_phase_seconds_count\{phase="decode"\} [1-9]'
# the multi-tenant surface is always on the scrape (every unmatched
# request bills the built-in default tenant)
echo "$SCRAPE" | grep -q '^enova_tenant_requests_total{tenant='
echo "$SCRAPE" | grep -q '^enova_tenant_gpu_seconds_total{tenant='
echo "$SCRAPE" | grep -q '^enova_replica_seconds_total'

if [[ "$SCENARIO" == "mixture" ]]; then
    echo "==> tenant smoke (mixture traffic carries tenant identity end to end)"
    # each mixture tenant resolved server-side: admission counters and the
    # cost ledger moved under its own label, with the tier riding along
    for tenant in chat summarize codegen; do
        echo "$SCRAPE" | grep -q "^enova_tenant_requests_total{tenant=\"$tenant\"" \
            || { echo "no admission counter for tenant $tenant" >&2; exit 1; }
    done
    echo "$SCRAPE" | grep -q '^enova_tenant_requests_total{tenant="chat",tier="latency"}'
    echo "$SCRAPE" | grep -q '^enova_tenant_requests_total{tenant="codegen",tier="batch"}'
    # the report graded every tenant against its own SLO budget (--strict
    # above already failed on violations; here we assert grading happened)
    python3 - "$REPORT" <<'PY'
import json, sys

r = json.load(open(sys.argv[1]))
stats = {t["name"]: t for t in r.get("tenant_stats", [])}
assert stats, "mixture report carries no tenant_stats"
for name in ("chat", "summarize", "codegen"):
    assert name in stats, f"tenant {name} missing from the report: {sorted(stats)}"
    assert stats[name]["ok"] > 0, f"tenant {name} completed nothing: {stats[name]}"
assert stats["chat"]["tier"] == "latency" and stats["chat"]["slo_p95_ms"] > 0, stats["chat"]
assert stats["codegen"]["tier"] == "batch" and stats["codegen"]["slo_p95_ms"] == 0, stats["codegen"]
graded = [n for n, t in stats.items() if t["slo_p95_ms"] > 0 and t["ok"] > 0]
assert graded, "no tenant was graded against an SLO budget"
for n in graded:
    assert stats[n]["p95_ms"] <= stats[n]["slo_p95_ms"], f"{n} over budget: {stats[n]}"
print(f"tenant grading OK: {graded} inside their SLO budgets")
PY
fi

echo "==> versioned admin API smoke (/v1/admin/* + deprecated aliases)"
ADMIN_STATUS=$(mktemp)
curl -fsS "http://127.0.0.1:$PORT/v1/admin/status" > "$ADMIN_STATUS"
python3 - "$ADMIN_STATUS" <<'PY'
import json, sys

s = json.load(open(sys.argv[1]))
assert s["live_replicas"] >= 1, s
for key in ("ready", "arrival_rps", "batch_rps", "warm_replicas"):
    assert key in s, f"typed status missing {key}: {s}"
print(f"admin status OK: {s['live_replicas']} live, {s['warm_replicas']} warm")
PY
rm -f "$ADMIN_STATUS"
# v1 errors are the structured {code, message, details} body...
V1_ERR=$(curl -sS -X POST --data '{"replicas": []}' "http://127.0.0.1:$PORT/v1/admin/scale")
echo "$V1_ERR" | grep -q '"invalid_request"'
curl -sS -X POST --data '{}' "http://127.0.0.1:$PORT/v1/admin/scale-up" \
    | grep -q '"not_a_node"'
# ...while the deprecated aliases keep their pre-v1 OpenAI-style envelope
LEGACY_ERR=$(curl -sS -X POST --data '{"replicas": []}' "http://127.0.0.1:$PORT/admin/scale")
echo "$LEGACY_ERR" | grep -q '"error"'

echo "==> trace assertions (every request left a full-lifecycle trace)"
TRACES="${SMOKE_TRACES:-gateway-traces${SCENARIO:+-$SCENARIO}.json}"
curl -fsS "http://127.0.0.1:$PORT/debug/traces" > "$TRACES"
# the versioned path serves the same export inside the typed envelope;
# the unversioned path above stays a deprecated alias with the bare shape
V1_TRACES=$(mktemp)
curl -fsS "http://127.0.0.1:$PORT/v1/debug/traces" > "$V1_TRACES"
python3 - "$V1_TRACES" "$TRACES" <<'PY'
import json, sys

env = json.load(open(sys.argv[1]))
legacy = json.load(open(sys.argv[2]))
assert env["api_version"] == "v1" and env["kind"] == "traces", env.keys()
assert env["data"].keys() == legacy.keys(), (env["data"].keys(), legacy.keys())
assert env["data"]["traces"], "typed trace export is empty"
print(f"/v1/debug/traces OK: typed envelope wraps the legacy shape ({env['service']})")
PY
rm -f "$V1_TRACES"
curl -fsS "http://127.0.0.1:$PORT/v1/debug/decisions" | grep -q '"api_version":"v1"'
curl -fsS "http://127.0.0.1:$PORT/debug/decisions" | grep -q '"decisions"'
python3 - "$TRACES" <<'PY'
import json, sys

view = json.load(open(sys.argv[1]))
traces = view["traces"]
assert view["recorded"] > 0 and traces, "the run left no traces behind"
lifecycle = {"admission", "dispatch", "queue_wait", "prefill", "decode"}
full = 0
for t in traces:
    if t["status"] != 200:
        continue
    phases = {s["name"] for s in t["spans"] if s["kind"] == "phase"}
    missing = lifecycle - phases
    assert not missing, f"trace {t['trace_id']} missing phases {missing}: {t}"
    full += 1
assert full > 0, "no successful trace carried the full lifecycle"
print(f"traces OK: {full} full-lifecycle traces of {len(traces)} recorded")
PY

echo "==> ingress saturation smoke (reactor under a high-concurrency burst)"
# burst well above the steady-state load: every response must still be
# 2xx (no 5xx under saturation), and the reactor must keep its resource
# footprint bounded — connection gauges on /metrics, not one thread per
# connection
SAT_REPORT="${SMOKE_SAT_REPORT:-loadgen-saturation${SCENARIO:+-$SCENARIO}.json}"
"$BIN" loadgen --addr "127.0.0.1:$PORT" --concurrency 32 --requests 8 \
    --max-tokens 2 --strict --report "$SAT_REPORT"

SAT_SCRAPE=$(mktemp)
curl -fsS "http://127.0.0.1:$PORT/metrics" > "$SAT_SCRAPE"
grep -q '^enova_ingress_reactor_mode 1' "$SAT_SCRAPE"
python3 - "$SAT_SCRAPE" <<'PY'
import sys

gauges = {}
for line in open(sys.argv[1]):
    line = line.strip()
    if line.startswith("enova_ingress_"):
        name, value = line.rsplit(None, 1)
        gauges[name] = float(value)
accepted = gauges["enova_ingress_connections_accepted_total"]
open_now = gauges["enova_ingress_connections_open"]
threads = gauges["enova_ingress_handler_threads"]
assert accepted >= 16, f"burst barely registered: accepted={accepted}"
# bounded footprint: the burst is over, so no connection leak beyond the
# /metrics scrape itself, and the handler pool stays at its configured
# size instead of scaling with connection count
assert open_now <= 4, f"connection leak after burst: open={open_now}"
assert threads <= 64, f"handler pool exceeded its bound: threads={threads}"
print(f"saturation OK: accepted={accepted:.0f} open={open_now:.0f} handler_threads={threads:.0f}")
PY
rm -f "$SAT_SCRAPE"

echo "gateway smoke OK; report at $REPORT, traces at $TRACES, saturation at $SAT_REPORT"
