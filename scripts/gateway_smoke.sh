#!/usr/bin/env bash
# CI gateway smoke: boot `enova serve-http` on the deterministic sim
# engine, drive a short closed-loop burst with the built-in loadgen, and
# fail on any transport error or non-2xx response (incl. 503) — a gateway
# at idle load must serve everything. Writes the loadgen report JSON
# (uploaded as a CI artifact).
#
# Expects the release binary to be built already:
#   cargo build --release --no-default-features  (or with default features)
set -euo pipefail

cd "$(dirname "$0")/.."
BIN=rust/target/release/enova
PORT="${SMOKE_PORT:-18431}"
REPORT="${SMOKE_REPORT:-loadgen-report.json}"

if [[ ! -x "$BIN" ]]; then
    echo "release binary missing at $BIN; build it first" >&2
    exit 2
fi

"$BIN" serve-http --engine sim --port "$PORT" --replicas 2 --warm-pool 1 &
SERVER=$!
trap 'kill "$SERVER" 2>/dev/null || true' EXIT

# wait for readiness (the /ready endpoint is 503 until all replicas built)
READY=0
for _ in $(seq 1 150); do
    if curl -fsS "http://127.0.0.1:$PORT/ready" >/dev/null 2>&1; then
        READY=1
        break
    fi
    sleep 0.1
done
if [[ "$READY" != "1" ]]; then
    echo "gateway never became ready on :$PORT" >&2
    exit 1
fi

"$BIN" loadgen --addr "127.0.0.1:$PORT" --concurrency 8 --requests 5 \
    --max-tokens 8 --strict --report "$REPORT"

echo "==> smoke scrape sanity"
curl -fsS "http://127.0.0.1:$PORT/metrics" | grep -c '^enova_' >/dev/null

echo "gateway smoke OK; report at $REPORT"
