#!/usr/bin/env bash
# CI migration smoke: snapshot/restore + live migration on one runner.
#
#   1. boot a coordinator (fast periodic snapshot sweep) + two sim-engine
#      nodes, with --sim-spawn-delay-ms making cold engine init expensive
#      so the snapshot path has something real to beat;
#   2. run steady `--strict` load through the coordinator and, mid-run,
#      drive one live migration node-a -> node-b over POST
#      /v1/admin/migrate — the typed record must come back phase=done,
#      and --strict fails the job on ANY non-2xx during the move;
#   3. assert the route flip is on the flight recorder
#      (/v1/debug/decisions carries a kind=migration entry) and the
#      target's scrape exports promotion_seconds{kind="snapshot"};
#   4. kill the drained source node; the coordinator backfills from its
#      last periodic snapshot, and the backfill's restore_seconds must
#      beat the cold-spawn init floor.
#
# Cleanup runs through scripts/smoke_common.sh (one EXIT trap kills and
# reaps everything). Expects the release binary to be built already.
set -euo pipefail

cd "$(dirname "$0")/.."
# shellcheck source=scripts/smoke_common.sh
source scripts/smoke_common.sh

BIN=rust/target/release/enova
PORT="${MIGRATE_PORT:-18600}"
NODE_A_PORT="${MIGRATE_NODE_A_PORT:-18601}"
NODE_B_PORT="${MIGRATE_NODE_B_PORT:-18602}"
REPORT="${MIGRATE_REPORT:-loadgen-migrate-report.json}"
SCRAPE="${MIGRATE_SCRAPE:-migrate-scrape.txt}"
# artificial sim engine-init cost (ms): what a cold spawn pays and a
# snapshot restore skips
SPAWN_DELAY_MS=150

if [[ ! -x "$BIN" ]]; then
    echo "release binary missing at $BIN; build it first" >&2
    exit 2
fi

start_bg "$BIN" serve-http --cluster --port "$PORT" \
    --heartbeat-ms 100 --node-timeout-beats 3 --dispatch-attempts 4 \
    --scale-interval-ms 200 --cooldown-ms 30000 --max-replicas 6 \
    --snapshot-interval-ms 300 --max-pending 2048

# node-a starts with 2 replicas so its gateway can retire one after the
# restore lands on node-b
start_bg "$BIN" node --engine sim --port "$NODE_A_PORT" \
    --coordinator "127.0.0.1:$PORT" --node-id node-a --replicas 2 \
    --sim-spawn-delay-ms "$SPAWN_DELAY_MS" \
    --gpu-memory 24 --replica-gpu-memory 8 --max-pending 1024 --announce-ms 200
NODE_A_PID=$SMOKE_LAST_PID

start_bg "$BIN" node --engine sim --port "$NODE_B_PORT" \
    --coordinator "127.0.0.1:$PORT" --node-id node-b --replicas 1 \
    --sim-spawn-delay-ms "$SPAWN_DELAY_MS" \
    --gpu-memory 24 --replica-gpu-memory 8 --max-pending 1024 --announce-ms 200

wait_http_ok "http://127.0.0.1:$PORT/ready"
REPLICAS=0
for _ in $(seq 1 100); do
    REPLICAS=$(curl -fsS "http://127.0.0.1:$PORT/metrics" \
        | sed -n 's/^enova_cluster_replicas \(.*\)$/\1/p')
    [[ "$REPLICAS" == "3" ]] && break
    sleep 0.1
done
if [[ "$REPLICAS" != "3" ]]; then
    echo "cluster never reached 3 observed replicas (saw ${REPLICAS:-none})" >&2
    exit 1
fi

# steady strict load through the whole migration: any dropped or non-2xx
# request fails the job at the `wait` below
start_bg "$BIN" loadgen --addr "127.0.0.1:$PORT" --scenario steady \
    --duration-s 8 --base-rps 6 --peak-rps 6 --seed 17 --workers 16 \
    --max-tokens 4 --strict --report "$REPORT"
LOADGEN_PID=$SMOKE_LAST_PID

sleep 2
echo "==> live migration node-a -> node-b under load"
MIGRATION=$(mktemp)
curl -sS -X POST --data '{"source_node": "node-a"}' \
    "http://127.0.0.1:$PORT/v1/admin/migrate" > "$MIGRATION"
python3 - "$MIGRATION" <<'PY'
import json, sys

m = json.load(open(sys.argv[1]))
assert m["phase"] == "done", m
assert m["source_node"] == "node-a" and m["target_node"] == "node-b", m
assert m.get("new_replica_id") is not None, m
t = m["timings"]
assert t["snapshot_seconds"] > 0 and t["restore_seconds"] > 0 and t["retire_seconds"] > 0, t
print(f"migration {m['id']} done: snapshot {t['snapshot_seconds']*1e3:.1f}ms, "
      f"restore {t['restore_seconds']*1e3:.1f}ms, total {t['total_seconds']:.2f}s")
PY
rm -f "$MIGRATION"

wait "$LOADGEN_PID"

echo "==> route flip on the flight recorder, snapshot promotion on the scrape"
curl -fsS "http://127.0.0.1:$PORT/v1/debug/decisions" \
    | python3 -c "
import json, sys
e = json.load(sys.stdin)
assert e['api_version'] == 'v1', e.keys()
ds = e['data']['decisions']
moves = [d for d in ds if d['kind'] == 'migration' and d['reason'] == 'migration']
assert moves, f'no migration decision recorded: {[d[\"kind\"] for d in ds]}'
assert moves[-1]['attrs']['source'] == 'node-a' and moves[-1]['attrs']['target'] == 'node-b', moves[-1]
print('/v1/debug/decisions carries the migration route flip')
"
curl -fsS "http://127.0.0.1:$NODE_B_PORT/metrics" > "$SCRAPE"
grep -Eq 'enova_gateway_promotion_seconds_count\{kind="snapshot"\} [1-9]' "$SCRAPE"
echo "node-b exports promotion_seconds{kind=snapshot}"

echo "==> killing the drained source (pid $NODE_A_PID); backfill restores from its snapshot"
kill "$NODE_A_PID" 2>/dev/null || true
BACKFILL=""
for _ in $(seq 1 100); do
    BACKFILL=$(curl -fsS "http://127.0.0.1:$PORT/v1/admin/migrations" \
        | python3 -c "
import json, sys
ms = json.load(sys.stdin)['migrations']
hits = [m for m in ms if m['reason'] == 'backfill' and m['phase'] == 'done']
print(hits[-1]['timings']['restore_seconds'] if hits else '')
" 2>/dev/null) || BACKFILL=""
    [[ -n "$BACKFILL" ]] && break
    sleep 0.2
done
if [[ -z "$BACKFILL" ]]; then
    echo "coordinator never recorded a snapshot backfill" >&2
    curl -fsS "http://127.0.0.1:$PORT/v1/admin/migrations" >&2 || true
    exit 1
fi
# the whole point: restoring from the frame skips the cold engine-init
python3 -c "
restore = float('$BACKFILL')
floor = $SPAWN_DELAY_MS / 1e3
assert restore < floor, f'backfill restore {restore:.3f}s did not beat the {floor:.3f}s cold init'
print(f'snapshot backfill restored in {restore*1e3:.1f}ms (cold init floor {floor*1e3:.0f}ms)')
"

echo "migrate smoke OK; report at $REPORT, node-b scrape at $SCRAPE"
