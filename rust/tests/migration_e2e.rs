//! End-to-end tests of replica snapshot/restore and live migration: a
//! coordinator and in-process nodes over real sockets. A live migration
//! under steady load drops nothing and lands the capacity on the target;
//! a snapshot restore is measurably faster than a cold spawn in the same
//! run; a dead node is backfilled from its last periodic snapshot; and
//! the whole lifecycle speaks the typed `/v1` control API while the
//! pre-v1 aliases answer with deprecation headers and counters.

use enova::cluster::coordinator::{ClusterPolicy, Coordinator, CoordinatorConfig};
use enova::cluster::node::{NodeConfig, NodeServer};
use enova::cluster::NodeIdentity;
use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::loadgen::{self, run_scenario, LoadgenReport, ScenarioConfig, ScenarioKind};
use enova::gateway::metrics::parse_exposition;
use enova::gateway::{EngineSpawner, GatewayConfig};
use enova::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

fn sim_spawner() -> EngineSpawner {
    Arc::new(|_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 64,
            step_delay: Duration::from_millis(2),
        })) as Box<dyn StreamEngine>)
    })
}

/// A spawner with an artificial engine-init cost, so cold spawns are
/// measurably slower than snapshot restores (which skip the spawner on
/// the sim path entirely).
fn slow_spawner(init: Duration) -> EngineSpawner {
    Arc::new(move |_id| {
        std::thread::sleep(init);
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 64,
            step_delay: Duration::from_millis(2),
        })) as Box<dyn StreamEngine>)
    })
}

fn node_config(id: &str, coordinator: &str, initial_replicas: usize) -> NodeConfig {
    NodeConfig {
        gateway: GatewayConfig {
            max_pending: 1024,
            max_tokens_default: 8,
            monitor_interval: Duration::from_millis(25),
            ..GatewayConfig::default()
        },
        identity: NodeIdentity {
            node_id: id.to_string(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 3,
            replica_capacity_rps: 0.0,
        },
        initial_replicas,
        coordinator: Some(coordinator.to_string()),
        announce_interval: Duration::from_millis(100),
        advertise_addr: None,
    }
}

fn quiet_policy() -> ClusterPolicy {
    ClusterPolicy {
        sample_interval: Duration::from_millis(50),
        detector_scaling: false,
        forecast: None,
        cooldown: Duration::from_secs(30),
        min_replicas: 1,
        max_replicas: 6,
        ..ClusterPolicy::default()
    }
}

fn non_2xx(report: &LoadgenReport) -> usize {
    report
        .status_counts
        .iter()
        .filter(|&(&code, _)| !(200..300).contains(&code))
        .map(|(_, &n)| n)
        .sum()
}

/// The headline: a live migration under steady load. The operator posts
/// `/v1/admin/migrate` mid-run; the replica's capacity moves from the
/// loaded node to the emptier one through snapshot → restore → retire,
/// the loadgen report stays clean (zero transport errors, zero non-2xx —
/// nothing dropped), and the cluster serves from the target afterwards.
#[test]
fn live_migration_under_load_drops_nothing() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(50),
        node_timeout_beats: 4,
        max_pending: 2048,
        // periodic snapshots off: this test exercises the operator API's
        // own capture, not the sweep
        snapshot_interval: Duration::ZERO,
        policy: quiet_policy(),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    // node-a carries 2 replicas (so its gateway can retire one after the
    // restore), node-b has room — the placement pick for the target
    let node_a = NodeServer::start(node_config("node-a", &addr, 2), sim_spawner()).unwrap();
    let node_b = NodeServer::start(node_config("node-b", &addr, 1), sim_spawner()).unwrap();
    assert!(coordinator.wait_for_nodes(2, Duration::from_secs(10)));
    assert!(coordinator.wait_for_replicas(3, Duration::from_secs(10)));

    // steady traffic through the whole migration
    let scn = ScenarioConfig {
        kind: ScenarioKind::Steady,
        duration: Duration::from_secs(6),
        base_rps: 6.0,
        peak_rps: 6.0,
        seed: 17,
        workers: 32,
        max_tokens: 4,
        ..ScenarioConfig::default()
    };
    let loadgen_addr = addr.clone();
    let driver = std::thread::spawn(move || run_scenario(&loadgen_addr, &scn));

    std::thread::sleep(Duration::from_millis(1500));
    let resp = loadgen::post_json(&addr, "/v1/admin/migrate", "{\"source_node\":\"node-a\"}")
        .unwrap();
    assert_eq!(resp.status, 200, "migration landed: {}", resp.body_str());
    let j = resp.json().unwrap();
    assert_eq!(j.get("phase").and_then(Json::as_str), Some("done"));
    assert_eq!(j.get("source_node").and_then(Json::as_str), Some("node-a"));
    assert_eq!(
        j.get("target_node").and_then(Json::as_str),
        Some("node-b"),
        "the placement policy picked the emptier node"
    );
    assert!(j.get("new_replica_id").and_then(Json::as_usize).is_some());
    let timing = |key: &str| j.at(&["timings", key]).and_then(Json::as_f64).unwrap_or(-1.0);
    assert!(timing("snapshot_seconds") > 0.0, "snapshot phase was timed");
    assert!(timing("restore_seconds") > 0.0, "restore phase was timed");
    assert!(timing("retire_seconds") > 0.0, "retire phase was timed");
    assert!(timing("total_seconds") >= timing("restore_seconds"));

    let report = driver.join().unwrap();
    assert_eq!(
        report.errors, 0,
        "zero transport errors through the migration: {}",
        report.summary()
    );
    assert_eq!(
        non_2xx(&report),
        0,
        "zero non-2xx through the migration: {:?}",
        report.status_counts
    );

    // the capacity really moved: node-b grew to 2, node-a drained to 1
    assert!(node_b.gateway().live_replicas().len() >= 2, "target grew");
    assert_eq!(node_a.gateway().live_replicas().len(), 1, "source drained");
    assert!(coordinator.replicas_on("node-b") >= 2, "{:?}", coordinator.nodes());

    // ...and the cluster still serves (from the target among others)
    let ok = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\":\"after\",\"max_tokens\":2}")
        .unwrap();
    assert_eq!(ok.status, 200, "serving after the route flip: {}", ok.body_str());

    // the lifecycle is on the record: the typed list view and the flight
    // recorder both carry the migration
    let list = loadgen::get(&addr, "/v1/admin/migrations").unwrap();
    assert_eq!(list.status, 200);
    let migrations = list.json().unwrap();
    let rows = migrations.get("migrations").and_then(Json::as_arr).unwrap().clone();
    assert!(
        rows.iter().any(|m| m.get("phase").and_then(Json::as_str) == Some("done")
            && m.get("reason").and_then(Json::as_str) == Some("migration")),
        "migration record retained: {}",
        migrations.to_string_compact()
    );
    assert!(
        coordinator
            .decisions()
            .iter()
            .any(|d| d.kind == "migration" && d.reason == "migration"),
        "flight recorder saw the migration"
    );

    coordinator.shutdown();
    node_a.shutdown();
    node_b.shutdown();
}

/// Snapshot restore beats cold spawn in the same run: with an artificial
/// 120ms engine-init cost, a cold `/v1/admin/scale-up` pays it while a
/// restore from a captured frame does not — visible in the
/// `enova_gateway_promotion_seconds{kind}` histogram on the same scrape.
#[test]
fn snapshot_restore_beats_cold_spawn() {
    let node = NodeServer::start(
        NodeConfig {
            identity: NodeIdentity {
                node_id: "solo".into(),
                gpu_memory_total: 32.0,
                replica_gpu_memory: 8.0,
                max_replicas: 4,
                replica_capacity_rps: 0.0,
            },
            initial_replicas: 1,
            coordinator: None,
            ..NodeConfig::default()
        },
        slow_spawner(Duration::from_millis(120)),
    )
    .unwrap();
    let addr = node.addr_string();

    // cold spawn: pays the 120ms engine init
    let up = loadgen::post_json(&addr, "/v1/admin/scale-up", "{}").unwrap();
    assert_eq!(up.status, 200, "{}", up.body_str());
    assert_eq!(node.gateway().promotion_count("cold"), 1);

    // capture a frame from a live replica...
    let cap = loadgen::post_json(&addr, "/v1/admin/snapshots", "{\"action\":\"capture\"}")
        .unwrap();
    assert_eq!(cap.status, 200, "{}", cap.body_str());
    let cap_json = cap.json().unwrap();
    let hex = cap_json
        .get("snapshot_hex")
        .and_then(Json::as_str)
        .expect("capture returns the encoded frame")
        .to_string();
    assert_eq!(cap_json.at(&["info", "engine_kind"]).and_then(Json::as_str), Some("sim"));

    // ...and restore it: a new replica without the engine-init cost
    let body = format!("{{\"action\":\"restore\",\"snapshot_hex\":\"{hex}\"}}");
    let restore = loadgen::post_json(&addr, "/v1/admin/snapshots", &body).unwrap();
    assert_eq!(restore.status, 200, "{}", restore.body_str());
    let restore_json = restore.json().unwrap();
    let promote = restore_json
        .get("promote_seconds")
        .and_then(Json::as_f64)
        .expect("restore reports its promotion latency");
    assert!(promote < 0.120, "restore skipped the init cost: {promote}s");
    assert_eq!(node.gateway().live_replicas().len(), 3);

    // same-run comparison on the histogram: snapshot p95 under cold p50
    let snap_p95 = node.gateway().promotion_quantile("snapshot", 0.95);
    let cold_p50 = node.gateway().promotion_quantile("cold", 0.50);
    assert_eq!(node.gateway().promotion_count("snapshot"), 1);
    assert!(
        snap_p95 < cold_p50,
        "snapshot promotion (p95 {snap_p95}s) beats cold spawn (p50 {cold_p50}s)"
    );

    // the new kind is on the scrape next to warm and cold
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    assert!(
        samples.iter().any(|s| {
            s.name == "enova_gateway_promotion_seconds_count"
                && s.labels.get("kind").map(String::as_str) == Some("snapshot")
                && s.value == 1.0
        }),
        "promotion_seconds{{kind=snapshot}} exported"
    );

    // the capture/restore ledger retained both acts
    let ledger = node.gateway().snapshot_ledger();
    assert!(ledger.len() >= 2, "capture + restore remembered: {ledger:?}");

    node.shutdown();
}

/// Dead-node backfill from the last periodic snapshot: the coordinator's
/// capture sweep keeps a warm frame per node, so when a node dies its
/// capacity is restored on the survivor through the snapshot path —
/// recorded as a `migration` with reason `backfill` in the flight
/// recorder and the migrations history.
#[test]
fn dead_node_backfill_uses_the_snapshot_path() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(50),
        node_timeout_beats: 2,
        max_pending: 2048,
        dispatch_attempts: 4,
        // fast periodic sweep: a frame is stored within the first ticks
        snapshot_interval: Duration::from_millis(200),
        policy: quiet_policy(),
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    let node_a = NodeServer::start(node_config("node-a", &addr, 1), sim_spawner()).unwrap();
    let node_b = NodeServer::start(node_config("node-b", &addr, 1), sim_spawner()).unwrap();
    assert!(coordinator.wait_for_nodes(2, Duration::from_secs(10)));
    assert!(coordinator.wait_for_replicas(2, Duration::from_secs(10)));

    // wait until the sweep has stored at least one frame
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while coordinator.snapshotted_nodes().is_empty() && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(50));
    }
    assert!(
        !coordinator.snapshotted_nodes().is_empty(),
        "the periodic sweep captured a frame"
    );

    // the stored frames are visible on the typed list API
    let list = loadgen::get(&addr, "/v1/admin/snapshots").unwrap();
    assert_eq!(list.status, 200);
    assert!(
        !list.json().unwrap().get("snapshots").and_then(Json::as_arr).unwrap().is_empty(),
        "{}",
        list.body_str()
    );

    node_b.shutdown();
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while coordinator.healthy_nodes() != 1 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(coordinator.healthy_nodes(), 1, "node-b declared dead");
    assert!(
        coordinator.wait_for_replicas(2, Duration::from_secs(10)),
        "backfill restored 2 replicas: {:?}",
        coordinator.nodes()
    );

    // the backfill went through the snapshot path, not a cold spawn: the
    // placement decision says mode=snapshot and the migration view records
    // reason=backfill
    assert!(coordinator.placements_for("backfill") >= 1, "backfill counter moved");
    let decisions = coordinator.decisions();
    let placement = decisions
        .iter()
        .find(|d| d.kind == "placement" && d.reason == "backfill")
        .expect("a backfill placement decision exists");
    assert!(
        placement.attrs.iter().any(|(k, v)| *k == "mode" && v == "snapshot"),
        "backfill restored from a frame: {placement:?}"
    );
    assert!(
        decisions.iter().any(|d| d.kind == "migration" && d.reason == "backfill"),
        "the flight recorder carries the migration view of the backfill"
    );
    assert!(
        coordinator.migrations().iter().any(|m| m.reason == "backfill"),
        "the migrations history carries the backfill: {:?}",
        coordinator.migrations()
    );
    // the survivor observed a snapshot-kind promotion
    assert!(
        node_a.gateway().promotion_count("snapshot") >= 1,
        "the restore landed on the survivor's snapshot histogram"
    );

    coordinator.shutdown();
    node_a.shutdown();
}

/// The control surface is typed end to end: structured requests and
/// `{code, message, details}` errors on `/v1`, `unsupported` where a role
/// cannot answer, and the pre-v1 aliases counted + marked with
/// `Deprecation`/`Sunset` headers — 410 Gone once `--legacy-api off`.
#[test]
fn typed_api_structured_errors_and_deprecated_aliases() {
    let node = NodeServer::start(
        NodeConfig {
            identity: NodeIdentity {
                node_id: "solo".into(),
                gpu_memory_total: 16.0,
                replica_gpu_memory: 8.0,
                max_replicas: 2,
                replica_capacity_rps: 0.0,
            },
            initial_replicas: 1,
            coordinator: None,
            ..NodeConfig::default()
        },
        sim_spawner(),
    )
    .unwrap();
    let addr = node.addr_string();
    let code_of = |resp: &loadgen::HttpResponse| {
        resp.json().unwrap().get("code").and_then(Json::as_str).map(str::to_string)
    };

    // the typed list view, empty at boot
    let list = loadgen::get(&addr, "/v1/admin/snapshots").unwrap();
    assert_eq!(list.status, 200);
    let j = list.json().unwrap();
    assert!(j.get("snapshots").and_then(Json::as_arr).unwrap().is_empty());

    // structured validation errors: unknown action, missing frame, bad frame
    let bad = loadgen::post_json(&addr, "/v1/admin/snapshots", "{\"action\":\"clone\"}").unwrap();
    assert_eq!(bad.status, 400);
    assert_eq!(code_of(&bad).as_deref(), Some("invalid_request"));
    let no_frame =
        loadgen::post_json(&addr, "/v1/admin/snapshots", "{\"action\":\"restore\"}").unwrap();
    assert_eq!(no_frame.status, 400);
    assert_eq!(code_of(&no_frame).as_deref(), Some("invalid_request"));
    let bad_frame = loadgen::post_json(
        &addr,
        "/v1/admin/snapshots",
        "{\"action\":\"restore\",\"snapshot_hex\":\"zz\"}",
    )
    .unwrap();
    assert_eq!(bad_frame.status, 400);
    assert_eq!(code_of(&bad_frame).as_deref(), Some("bad_snapshot"));

    // migration is the coordinator's lifecycle: a node answers the typed
    // refusal, naming its role
    let migrate =
        loadgen::post_json(&addr, "/v1/admin/migrate", "{\"source_node\":\"x\"}").unwrap();
    assert_eq!(migrate.status, 400);
    let mj = migrate.json().unwrap();
    assert_eq!(mj.get("code").and_then(Json::as_str), Some("unsupported"));
    assert_eq!(mj.at(&["details", "role"]).and_then(Json::as_str), Some("node"));

    // a deprecated alias still answers, but marked and counted
    let legacy = loadgen::get(&addr, "/cluster/status").unwrap();
    assert_eq!(legacy.status, 200);
    assert_eq!(legacy.headers.get("deprecation").map(String::as_str), Some("true"));
    assert!(legacy.headers.contains_key("sunset"), "{:?}", legacy.headers);
    assert!(node.gateway().deprecated_hits("/cluster/status") >= 1);
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    assert!(
        samples.iter().any(|s| {
            s.name == "enova_api_deprecated_requests_total"
                && s.labels.get("path").map(String::as_str) == Some("/cluster/status")
                && s.value >= 1.0
        }),
        "deprecated alias hits are exported"
    );
    // the /v1 twin is untouched by the deprecation machinery
    let v1 = loadgen::get(&addr, "/v1/admin/status").unwrap();
    assert_eq!(v1.status, 200);
    assert!(!v1.headers.contains_key("deprecation"));
    node.shutdown();

    // --legacy-api off: the alias is gone (410, structured error, still
    // marked) while the /v1 surface keeps serving
    let strict = NodeServer::start(
        NodeConfig {
            gateway: GatewayConfig {
                legacy_api: false,
                ..GatewayConfig::default()
            },
            identity: NodeIdentity {
                node_id: "strict".into(),
                gpu_memory_total: 16.0,
                replica_gpu_memory: 8.0,
                max_replicas: 2,
                replica_capacity_rps: 0.0,
            },
            initial_replicas: 1,
            coordinator: None,
            ..NodeConfig::default()
        },
        sim_spawner(),
    )
    .unwrap();
    let addr = strict.addr_string();
    let gone = loadgen::get(&addr, "/cluster/status").unwrap();
    assert_eq!(gone.status, 410, "{}", gone.body_str());
    assert_eq!(code_of(&gone).as_deref(), Some("deprecated"));
    assert_eq!(gone.headers.get("deprecation").map(String::as_str), Some("true"));
    assert!(strict.gateway().deprecated_hits("/cluster/status") >= 1);
    let v1 = loadgen::get(&addr, "/v1/admin/status").unwrap();
    assert_eq!(v1.status, 200, "the versioned surface outlives the sunset");
    strict.shutdown();
}
