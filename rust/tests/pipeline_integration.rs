//! Cross-module integration tests: the configuration module driven by real
//! simulator output, cluster routing under recommended weights, the replica
//! planner against the deployer's inventory, and property tests over the
//! simulator's conservation invariants. None of these need artifacts.

use enova::config;
use enova::deployer::{paper_testbed, Deployer};
use enova::metrics::Frame;
use enova::simulator::cluster::ClusterSim;
use enova::simulator::gpu::{A100_80G, RTX4090_24G};
use enova::simulator::modelcard::{LLAMA2_13B, LLAMA2_70B, LLAMA2_7B, MISTRAL_7B};
use enova::simulator::replica::{Replica, ServiceConfig};
use enova::util::prop;
use enova::util::rng::Pcg64;
use enova::workload::arrivals::{poisson_stream, RateProfile};
use enova::workload::corpus::{CorpusMix, ALL_FAMILIES};

fn probe_frames(
    gpu: &'static enova::simulator::gpu::GpuSpec,
    model: &'static enova::simulator::modelcard::ModelCard,
    rps: f64,
    seed: u64,
) -> (Vec<Frame>, Vec<f64>) {
    let space = enova::baselines::ConfigSpace::for_model(gpu, model);
    let cfg = ServiceConfig {
        max_num_seqs: 256,
        gpu_memory: 0.9,
        max_tokens: model.max_model_tokens,
        parallel_size: space.parallel_size,
    };
    let mut rng = Pcg64::new(seed);
    let mix = CorpusMix::uniform(&ALL_FAMILIES);
    let arrivals = poisson_stream(&RateProfile::constant(rps), &mix, 240.0, &mut rng);
    let res = Replica::new(gpu, model, cfg).simulate(arrivals, 300.0);
    (
        res.frames.iter().map(|&(_, f)| f).collect(),
        res.finished.iter().map(|f| f.out_len as f64).collect(),
    )
}

#[test]
fn config_pipeline_orders_devices_and_models() {
    // stronger device ⇒ higher recommended concurrency; bigger model ⇒ lower
    let (fa, la) = probe_frames(&A100_80G, &LLAMA2_7B, 30.0, 1);
    let (fr, lr) = probe_frames(&RTX4090_24G, &LLAMA2_7B, 30.0, 2);
    let (f70, l70) = probe_frames(&A100_80G, &LLAMA2_70B, 30.0, 3);
    let a = config::recommend_for(&A100_80G, &LLAMA2_7B, &fa, &la);
    let r = config::recommend_for(&RTX4090_24G, &LLAMA2_7B, &fr, &lr);
    let s70 = config::recommend_for(&A100_80G, &LLAMA2_70B, &f70, &l70);
    assert!(
        a.max_num_seqs > r.max_num_seqs,
        "A100 {} !> 4090 {}",
        a.max_num_seqs,
        r.max_num_seqs
    );
    assert!(
        s70.max_num_seqs < a.max_num_seqs,
        "70B {} !< 7B {}",
        s70.max_num_seqs,
        a.max_num_seqs
    );
    assert!(s70.parallel_size >= 2);
    assert!(a.parallel_size == 1);
}

#[test]
fn recommended_config_survives_recommended_load() {
    // serving at the estimated n_limit must not melt down
    let (frames, lens) = probe_frames(&A100_80G, &MISTRAL_7B, 25.0, 4);
    let decision = config::determine_max_num_seqs(&frames).expect("decision");
    let cfg = config::recommend_for(&A100_80G, &MISTRAL_7B, &frames, &lens);
    let rep = Replica::new(&A100_80G, &MISTRAL_7B, cfg);
    let mut rng = Pcg64::new(5);
    let mix = CorpusMix::uniform(&ALL_FAMILIES);
    // 0.6× the estimated limit: the recommendation may clamp concurrency
    // below the probe's (KV headroom), so leave margin; a recommendation
    // that cannot even serve 60% of its own capacity estimate is broken.
    let rps = decision.n_limit * 0.6;
    let arrivals = poisson_stream(&RateProfile::constant(rps), &mix, 300.0, &mut rng);
    let issued = arrivals.len();
    let res = rep.simulate(arrivals, 500.0);
    assert!(
        (res.timed_out as f64) < 0.02 * issued as f64,
        "{} timeouts at 0.6×n_limit",
        res.timed_out
    );
    assert!(
        res.finished.len() as f64 > 0.85 * issued as f64,
        "only {}/{} finished",
        res.finished.len(),
        issued
    );
}

#[test]
fn replica_plan_fits_deployer_inventory() {
    let options = vec![
        config::GpuOption {
            gpu: &A100_80G,
            n_limit: 11.0,
            parallel_size: 1,
            inventory: 8,
            gpu_memory: 0.9,
        },
        config::GpuOption {
            gpu: &RTX4090_24G,
            n_limit: 4.0,
            parallel_size: 1,
            inventory: 8,
            gpu_memory: 0.9,
        },
    ];
    let plan = config::determine_replicas(&options, &LLAMA2_7B, 30.0).expect("plan");
    // the deployer must be able to place the whole plan on the testbed
    let mut dep = Deployer::new(paper_testbed());
    let cfgs = [
        ServiceConfig {
            max_num_seqs: 64,
            gpu_memory: 0.9,
            max_tokens: 512,
            parallel_size: 1,
        };
        2
    ];
    for (i, (&n, opt)) in plan.replicas.iter().zip(&options).enumerate() {
        for _ in 0..n {
            let id = dep
                .deploy(&LLAMA2_7B, opt.gpu, cfgs[i], plan.weights[i])
                .expect("placement");
            dep.mark_ready(id).unwrap();
        }
    }
    assert_eq!(
        dep.ready_count(&LLAMA2_7B),
        plan.replicas.iter().sum::<usize>()
    );
    // ingress weights match the plan
    let table = dep.ingress_table(&LLAMA2_7B);
    assert!(table.iter().all(|&(_, w)| w > 0.0 && w <= 1.0));
}

#[test]
fn heterogeneous_cluster_beats_misweighted_cluster() {
    // §IV-A-4: capacity-proportional weights sustain more than inverted ones
    let cfg = ServiceConfig {
        max_num_seqs: 48,
        gpu_memory: 0.9,
        max_tokens: 512,
        parallel_size: 1,
    };
    let make = |w: Vec<f64>| {
        ClusterSim::new(
            vec![
                Replica::new(&A100_80G, &LLAMA2_13B, cfg),
                Replica::new(&RTX4090_24G, &LLAMA2_13B, cfg),
            ],
            w,
        )
    };
    let mut rng = Pcg64::new(6);
    let mix = CorpusMix::uniform(&ALL_FAMILIES);
    let arrivals = poisson_stream(&RateProfile::constant(9.0), &mix, 400.0, &mut rng);
    let issued = arrivals.len();
    let good = make(vec![1.0, 0.4]).simulate(&arrivals, 800.0, 7);
    let bad = make(vec![0.4, 1.0]).simulate(&arrivals, 800.0, 7);
    assert!(
        good.completion_ratio(issued) >= bad.completion_ratio(issued),
        "good {} < bad {}",
        good.completion_ratio(issued),
        bad.completion_ratio(issued)
    );
}

#[test]
fn prop_simulator_conserves_requests() {
    prop::check("finished + timed_out + unserved == issued", 25, |g| {
        let rps = g.f64_in(0.5, 20.0);
        let mns = g.usize_in(4, 96);
        let horizon = g.f64_in(30.0, 150.0);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let arrivals = poisson_stream(&RateProfile::constant(rps), &mix, horizon, &mut rng);
        let issued = arrivals.len();
        let cfg = ServiceConfig {
            max_num_seqs: mns,
            gpu_memory: 0.9,
            max_tokens: 256,
            parallel_size: 1,
        };
        let res = Replica::new(&A100_80G, &LLAMA2_7B, cfg).simulate(arrivals, horizon);
        prop::ensure(
            res.finished.len() + res.timed_out + res.unserved == issued,
            format!(
                "{} + {} + {} != {issued}",
                res.finished.len(),
                res.timed_out,
                res.unserved
            ),
        )
    });
}

#[test]
fn prop_simulator_latency_positive_and_ordered() {
    prop::check("finish ≥ first_token ≥ arrival; out_len ≤ max_tokens", 20, |g| {
        let rps = g.f64_in(0.5, 8.0);
        let max_tokens = g.usize_in(16, 512);
        let mut rng = Pcg64::new(g.rng.next_u64());
        let mix = CorpusMix::uniform(&ALL_FAMILIES);
        let arrivals = poisson_stream(&RateProfile::constant(rps), &mix, 60.0, &mut rng);
        let cfg = ServiceConfig {
            max_num_seqs: 32,
            gpu_memory: 0.9,
            max_tokens,
            parallel_size: 1,
        };
        let res = Replica::new(&A100_80G, &LLAMA2_7B, cfg).simulate(arrivals, 200.0);
        for f in &res.finished {
            prop::ensure(f.finish >= f.first_token, "finish < first_token")?;
            prop::ensure(f.first_token >= f.arrival, "first_token < arrival")?;
            prop::ensure(f.out_len <= max_tokens, "out_len > max_tokens")?;
            prop::ensure(f.out_len >= 1, "empty output")?;
        }
        Ok(())
    });
}

#[test]
fn prop_kv_budget_monotone_in_gpu_memory() {
    prop::check("kv budget grows with gpu_memory", 30, |g| {
        let lo = g.f64_in(0.5, 0.9);
        let hi = (lo + 0.05).min(0.95);
        let mk = |mem: f64| {
            Replica::new(
                &RTX4090_24G,
                &MISTRAL_7B,
                ServiceConfig {
                    max_num_seqs: 32,
                    gpu_memory: mem,
                    max_tokens: 256,
                    parallel_size: 1,
                },
            )
            .kv_budget_bytes()
        };
        prop::ensure(mk(hi) >= mk(lo), "budget not monotone")
    });
}

#[test]
fn prop_weighted_router_never_starves() {
    prop::check("every positive-weight replica gets traffic", 20, |g| {
        let n = g.usize_in(2, 6);
        let weights: Vec<(u64, f64)> = (0..n as u64)
            .map(|i| (i, g.f64_in(0.1, 2.0)))
            .collect();
        let router = enova::router::WeightedRouter::new(&weights);
        for _ in 0..200 {
            router.dispatch();
        }
        for r in router.replicas() {
            prop::ensure(r.dispatched() > 0, format!("replica {} starved", r.id))?;
        }
        Ok(())
    });
}
