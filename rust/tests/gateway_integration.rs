//! Closed-loop integration tests for the HTTP serving gateway, over real
//! sockets against the deterministic sim engine (no artifacts needed):
//! concurrent loadgen round-trips, SSE streaming, Prometheus exposition
//! completeness (all eight Table II columns per replica), admission-control
//! 429s, ingress updates, and malformed-HTTP robustness.

use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::metrics::parse_exposition;
use enova::gateway::{loadgen, EngineFactory, Gateway, GatewayConfig};
use enova::metrics::COLUMNS;
use enova::util::json::Json;
use std::io::{Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn sim_gateway(
    replicas: usize,
    max_pending: usize,
    step_delay_ms: u64,
    engine_max_tokens: usize,
    rate_limit: f64,
    rate_burst: usize,
) -> Gateway {
    let factories: Vec<EngineFactory> = (0..replicas)
        .map(|_| -> EngineFactory {
            Box::new(move || {
                Ok(Box::new(SimEngine::new(SimEngineConfig {
                    max_num_seqs: 8,
                    max_tokens: engine_max_tokens,
                    step_delay: Duration::from_millis(step_delay_ms),
                })) as Box<dyn StreamEngine>)
            })
        })
        .collect();
    Gateway::start(
        GatewayConfig {
            max_pending,
            rate_limit,
            rate_burst,
            max_tokens_default: engine_max_tokens,
            ..Default::default()
        },
        factories,
    )
    .expect("gateway start")
}

#[test]
fn serves_32_concurrent_connections_closed_loop() {
    let gw = sim_gateway(2, 256, 0, 16, 0.0, 64);
    let addr = gw.addr_string();

    let report = loadgen::run(
        &addr,
        &loadgen::LoadgenConfig {
            concurrency: 32,
            requests_per_worker: 2,
            max_tokens: 6,
            stream_every: 2,
            chat_every: 3,
            prompt_prefix: "integration".into(),
        },
    );
    assert_eq!(report.errors, 0, "transport errors: {}", report.summary());
    assert_eq!(report.count(200), 64, "{}", report.summary());
    assert_eq!(report.ok, 64);
    assert!(report.sse_events > 0, "streaming happened");
    assert!(report.completion_tokens > 0);

    gw.shutdown();
}

#[test]
fn unary_and_streamed_completions_agree() {
    let gw = sim_gateway(2, 64, 0, 16, 0.0, 64);
    let addr = gw.addr_string();
    let body = "{\"prompt\": \"same prompt both ways\", \"max_tokens\": 6}";

    // non-streaming
    let unary = loadgen::post_json(&addr, "/v1/completions", body).unwrap();
    assert_eq!(unary.status, 200, "{}", unary.body_str());
    let j = unary.json().unwrap();
    let text = j.at(&["choices"]).unwrap().as_arr().unwrap()[0]
        .get("text")
        .unwrap()
        .as_str()
        .unwrap()
        .to_string();
    assert!(!text.is_empty());
    assert_eq!(
        j.at(&["usage", "completion_tokens"]).unwrap().as_usize(),
        Some(6)
    );
    assert_eq!(
        j.at(&["choices"]).unwrap().as_arr().unwrap()[0]
            .get("finish_reason")
            .unwrap()
            .as_str(),
        Some("length")
    );

    // streaming: multiple SSE events, terminated by [DONE]
    let streamed = loadgen::post_json(
        &addr,
        "/v1/completions",
        "{\"prompt\": \"same prompt both ways\", \"max_tokens\": 6, \"stream\": true}",
    )
    .unwrap();
    assert_eq!(streamed.status, 200);
    assert_eq!(
        streamed.headers.get("content-type").map(String::as_str),
        Some("text/event-stream")
    );
    let events = streamed.sse_data();
    assert!(
        events.len() >= 3,
        "expected multiple SSE events, got {events:?}"
    );
    assert_eq!(events.last().map(String::as_str), Some("[DONE]"));
    let mut concat = String::new();
    let mut finishes = 0;
    for e in events.iter().filter(|e| e.as_str() != "[DONE]") {
        let chunk = Json::parse(e).expect("chunk is JSON");
        let choice = &chunk.at(&["choices"]).unwrap().as_arr().unwrap()[0];
        concat.push_str(choice.get("text").unwrap().as_str().unwrap());
        if choice.get("finish_reason").unwrap().as_str().is_some() {
            finishes += 1;
        }
    }
    assert_eq!(finishes, 1, "exactly one finishing chunk");
    // the sim engine is deterministic per prompt: both paths produce the
    // same text
    assert_eq!(concat, text);

    // chat endpoint round-trip
    let chat = loadgen::post_json(
        &addr,
        "/v1/chat/completions",
        "{\"messages\": [{\"role\": \"user\", \"content\": \"hello there\"}], \"max_tokens\": 4}",
    )
    .unwrap();
    assert_eq!(chat.status, 200);
    let j = chat.json().unwrap();
    let content = j.at(&["choices"]).unwrap().as_arr().unwrap()[0]
        .at(&["message", "content"])
        .unwrap()
        .as_str()
        .unwrap();
    assert!(!content.is_empty());

    gw.shutdown();
}

#[test]
fn metrics_exposition_has_all_table2_columns_per_replica() {
    let gw = sim_gateway(2, 64, 0, 16, 0.0, 64);
    let addr = gw.addr_string();

    // some traffic so gateway counters are non-trivial
    for _ in 0..3 {
        let r = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"m\", \"max_tokens\": 3}")
            .unwrap();
        assert_eq!(r.status, 200);
    }

    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    assert_eq!(scrape.status, 200);
    assert!(scrape
        .headers
        .get("content-type")
        .unwrap()
        .starts_with("text/plain"));
    let samples = parse_exposition(&scrape.body_str()).expect("body parses as exposition");

    for col in COLUMNS {
        for replica in ["replica-0", "replica-1"] {
            assert!(
                samples.iter().any(|s| {
                    s.name == format!("enova_replica_{col}")
                        && s.labels.get("instance").map(String::as_str) == Some(replica)
                }),
                "missing Table II column {col} for {replica}"
            );
        }
    }
    let total: f64 = samples
        .iter()
        .filter(|s| s.name == "enova_gateway_requests_total"
            && s.labels.get("code").map(String::as_str) == Some("200"))
        .map(|s| s.value)
        .sum();
    assert!(total >= 3.0, "request counter saw the traffic");
    assert!(samples
        .iter()
        .any(|s| s.name == "enova_gateway_request_seconds_count" && s.value >= 3.0));
    assert!(samples
        .iter()
        .any(|s| s.name == "enova_gateway_tokens_generated_total" && s.value >= 9.0));

    gw.shutdown();
}

#[test]
fn admission_queue_overflow_returns_429() {
    // 1 replica, capacity 2: hold two slow requests in flight, observe the
    // third rejected deterministically
    let gw = sim_gateway(1, 2, 10, 400, 0.0, 64);
    let addr = gw.addr_string();

    let slow_body = "{\"prompt\": \"slow\", \"max_tokens\": 400}";
    let mut holders = Vec::new();
    for _ in 0..2 {
        let addr = addr.clone();
        holders.push(std::thread::spawn(move || {
            loadgen::post_json(&addr, "/v1/completions", slow_body).unwrap()
        }));
    }

    // wait until both are admitted (inflight gauge == 2)
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let scrape = loadgen::get(&addr, "/metrics").unwrap();
        let samples = parse_exposition(&scrape.body_str()).unwrap();
        let inflight = samples
            .iter()
            .find(|s| s.name == "enova_gateway_inflight_requests")
            .map(|s| s.value)
            .unwrap_or(0.0);
        if inflight >= 2.0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "both slow requests should be admitted, inflight={inflight}"
        );
        std::thread::sleep(Duration::from_millis(5));
    }

    let rejected = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"x\"}").unwrap();
    assert_eq!(rejected.status, 429);
    assert_eq!(
        rejected.headers.get("retry-after").map(String::as_str),
        Some("1")
    );
    let err = rejected.json().unwrap();
    assert_eq!(
        err.at(&["error", "type"]).unwrap().as_str(),
        Some("server_overloaded")
    );

    for h in holders {
        let resp = h.join().unwrap();
        assert_eq!(resp.status, 200, "held requests still complete");
    }

    // capacity freed: the same request is admitted now
    let ok = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"x\", \"max_tokens\": 2}")
        .unwrap();
    assert_eq!(ok.status, 200);

    // and the rejection is visible on the admission counter
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    let samples = parse_exposition(&scrape.body_str()).unwrap();
    assert!(samples.iter().any(|s| {
        s.name == "enova_gateway_admission_rejected_total"
            && s.labels.get("reason").map(String::as_str) == Some("queue_full")
            && s.value >= 1.0
    }));

    gw.shutdown();
}

#[test]
fn rate_limiter_returns_429_after_burst() {
    // burst of 1 and a negligible refill rate: first request passes, the
    // second (sequential, so no race) is rate-limited
    let gw = sim_gateway(1, 64, 0, 8, 1e-6, 1);
    let addr = gw.addr_string();

    let first = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"a\", \"max_tokens\": 2}")
        .unwrap();
    assert_eq!(first.status, 200);
    let second = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"b\", \"max_tokens\": 2}")
        .unwrap();
    assert_eq!(second.status, 429);
    let err = second.json().unwrap();
    assert_eq!(
        err.at(&["error", "type"]).unwrap().as_str(),
        Some("rate_limit_exceeded")
    );

    gw.shutdown();
}

#[test]
fn admin_scale_applies_ingress_updates() {
    let gw = sim_gateway(2, 64, 0, 8, 0.0, 64);
    let addr = gw.addr_string();

    let ok = loadgen::post_json(
        &addr,
        "/admin/scale",
        "{\"replicas\": [{\"id\": 0, \"weight\": 2.0}, {\"id\": 1, \"weight\": 0.5}]}",
    )
    .unwrap();
    assert_eq!(ok.status, 200, "{}", ok.body_str());
    let j = ok.json().unwrap();
    assert_eq!(j.get("routable_replicas").and_then(Json::as_usize), Some(2));

    // traffic still flows after the update
    let r = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"post-scale\", \"max_tokens\": 2}")
        .unwrap();
    assert_eq!(r.status, 200);

    // shrinking the routable set to one replica also works
    let shrink = loadgen::post_json(&addr, "/admin/scale", "{\"replicas\": [{\"id\": 1, \"weight\": 1.0}]}")
        .unwrap();
    assert_eq!(shrink.status, 200);

    // unknown replica ids are rejected
    let bad = loadgen::post_json(&addr, "/admin/scale", "{\"replicas\": [{\"id\": 7, \"weight\": 1.0}]}")
        .unwrap();
    assert_eq!(bad.status, 400);
    assert!(bad.body_str().contains("unknown replica id"));

    // fractional ids must not silently truncate onto another replica
    let frac = loadgen::post_json(&addr, "/admin/scale", "{\"replicas\": [{\"id\": 1.7, \"weight\": 1.0}]}")
        .unwrap();
    assert_eq!(frac.status, 400);

    // duplicate ids would split the router's load accounting
    let dup = loadgen::post_json(
        &addr,
        "/admin/scale",
        "{\"replicas\": [{\"id\": 0, \"weight\": 1.0}, {\"id\": 0, \"weight\": 2.0}]}",
    )
    .unwrap();
    assert_eq!(dup.status, 400);
    assert!(dup.body_str().contains("duplicate"));

    // malformed body
    let bad = loadgen::post_json(&addr, "/admin/scale", "{\"replicas\": []}").unwrap();
    assert_eq!(bad.status, 400);

    gw.shutdown();
}

#[test]
fn health_ready_and_routing_errors() {
    let gw = sim_gateway(1, 64, 0, 8, 0.0, 64);
    let addr = gw.addr_string();

    let h = loadgen::get(&addr, "/healthz").unwrap();
    assert_eq!(h.status, 200);
    assert_eq!(h.json().unwrap().get("status").and_then(Json::as_str), Some("ok"));

    let r = loadgen::get(&addr, "/ready").unwrap();
    assert_eq!(r.status, 200);
    assert_eq!(r.json().unwrap().get("ready").and_then(Json::as_bool), Some(true));

    // wrong method and unknown path
    let m = loadgen::get(&addr, "/v1/completions").unwrap();
    assert_eq!(m.status, 405);
    let nf = loadgen::get(&addr, "/nope").unwrap();
    assert_eq!(nf.status, 404);

    // bad JSON and missing prompt are 4xx with OpenAI-shaped errors
    let bad = loadgen::post_json(&addr, "/v1/completions", "{not json").unwrap();
    assert_eq!(bad.status, 400);
    let missing = loadgen::post_json(&addr, "/v1/completions", "{}").unwrap();
    assert_eq!(missing.status, 400);
    assert!(missing.body_str().contains("prompt"));

    gw.shutdown();
}

/// Raw-socket abuse: the server must answer 4xx (or close), never crash.
#[test]
fn malformed_http_is_4xx_not_panic() {
    let gw = sim_gateway(1, 64, 0, 8, 0.0, 64);
    let addr = gw.addr_string();

    let exchanges: &[(&str, &str)] = &[
        ("GARBAGE LINE\r\n\r\n", "HTTP/1.1 400"),
        ("POST /v1/completions HTTP/1.1\r\n\r\n", "HTTP/1.1 411"),
        (
            "POST /v1/completions HTTP/1.1\r\nContent-Length: 99999999\r\n\r\n",
            "HTTP/1.1 413",
        ),
        (
            "POST /v1/completions HTTP/1.1\r\nContent-Length: oops\r\n\r\n",
            "HTTP/1.1 400",
        ),
        ("GET / HTTP/1.1\r\nno colon here\r\n\r\n", "HTTP/1.1 400"),
    ];
    for (raw, expect) in exchanges {
        let mut s = TcpStream::connect(&addr).unwrap();
        s.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
        s.write_all(raw.as_bytes()).unwrap();
        let mut buf = Vec::new();
        let _ = s.read_to_end(&mut buf);
        let resp = String::from_utf8_lossy(&buf);
        assert!(
            resp.starts_with(expect),
            "sent {raw:?}, expected {expect}, got {resp:?}"
        );
    }

    // the gateway survived all of it
    let h = loadgen::get(&addr, "/healthz").unwrap();
    assert_eq!(h.status, 200);

    gw.shutdown();
}

/// Satellite regression: a multi-request closed loop must reuse sockets
/// (HTTP/1.1 keep-alive), not dial one TCP connection per request.
#[test]
fn closed_loop_reuses_keep_alive_connections() {
    let gw = sim_gateway(2, 256, 0, 16, 0.0, 64);
    let addr = gw.addr_string();

    let report = loadgen::run(
        &addr,
        &loadgen::LoadgenConfig {
            concurrency: 4,
            requests_per_worker: 8,
            max_tokens: 4,
            stream_every: 3, // mix of SSE and unary on the same sockets
            chat_every: 5,
            prompt_prefix: "keep-alive".into(),
        },
    );
    assert_eq!(report.errors, 0, "{}", report.summary());
    assert_eq!(report.count(200), 32, "{}", report.summary());
    assert_eq!(
        report.connections_opened, 4,
        "each worker must hold one socket for its whole sequence: {}",
        report.summary()
    );

    gw.shutdown();
}

/// A single client reuses its connection across unary, SSE and admin
/// exchanges.
#[test]
fn client_reuses_one_socket_across_request_kinds() {
    let gw = sim_gateway(1, 64, 0, 8, 0.0, 64);
    let addr = gw.addr_string();

    let mut client = loadgen::Client::new(&addr);
    let h = client.get("/healthz").unwrap();
    assert_eq!(h.status, 200);
    let unary = client
        .post_json("/v1/completions", "{\"prompt\": \"one socket\", \"max_tokens\": 3}")
        .unwrap();
    assert_eq!(unary.status, 200);
    let streamed = client
        .post_json(
            "/v1/completions",
            "{\"prompt\": \"one socket\", \"max_tokens\": 3, \"stream\": true}",
        )
        .unwrap();
    assert_eq!(streamed.status, 200);
    assert_eq!(streamed.sse_data().last().map(String::as_str), Some("[DONE]"));
    let m = client.get("/metrics").unwrap();
    assert_eq!(m.status, 200);
    assert_eq!(client.connections_opened, 1, "all four exchanges on one socket");

    gw.shutdown();
}

/// Satellite regression: shutdown must fail in-flight jobs with a 503 (and
/// a terminal SSE event for streams) instead of silently dropping them and
/// leaving clients blocked on dead connections.
#[test]
fn shutdown_fails_inflight_requests_with_503() {
    // slow engine: 400 tokens at 20ms/step keeps requests in flight for
    // ~8s, far past the shutdown point
    let gw = sim_gateway(1, 8, 20, 400, 0.0, 64);
    let addr = gw.addr_string();

    let slow_unary = "{\"prompt\": \"hold unary\", \"max_tokens\": 400}";
    let slow_stream = "{\"prompt\": \"hold stream\", \"max_tokens\": 400, \"stream\": true}";
    let unary_thread = {
        let addr = addr.clone();
        std::thread::spawn(move || loadgen::post_json(&addr, "/v1/completions", slow_unary))
    };
    let stream_thread = {
        let addr = addr.clone();
        std::thread::spawn(move || loadgen::post_json(&addr, "/v1/completions", slow_stream))
    };

    // wait until both requests are admitted and running
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let scrape = loadgen::get(&addr, "/metrics").unwrap();
        let samples = parse_exposition(&scrape.body_str()).unwrap();
        let inflight = samples
            .iter()
            .find(|s| s.name == "enova_gateway_inflight_requests")
            .map(|s| s.value)
            .unwrap_or(0.0);
        if inflight >= 2.0 {
            break;
        }
        assert!(Instant::now() < deadline, "requests not admitted, inflight={inflight}");
        std::thread::sleep(Duration::from_millis(5));
    }

    gw.shutdown();

    let unary = unary_thread.join().unwrap().expect("unary got a response");
    assert_eq!(unary.status, 503, "in-flight unary answered, not dropped");
    assert_eq!(
        unary.json().unwrap().at(&["error", "type"]).unwrap().as_str(),
        Some("service_unavailable")
    );

    let streamed = stream_thread.join().unwrap().expect("stream got a response");
    assert_eq!(streamed.status, 200, "SSE head was already out");
    let events = streamed.sse_data();
    assert!(
        events.iter().any(|e| e.contains("service_unavailable")),
        "terminal SSE error event present: {events:?}"
    );
    assert_ne!(
        events.last().map(String::as_str),
        Some("[DONE]"),
        "an interrupted stream must not claim success"
    );
}

/// Satellite regression: jobs that overshoot the queue-time budget are
/// shed with a 503 before ever occupying engine capacity.
#[test]
fn queue_budget_sheds_overdue_jobs_with_503() {
    // one replica with a single engine slot and 30ms steps: the second
    // concurrent request waits in the worker queue behind an ~1.2s run,
    // far past the 100ms budget
    let factories: Vec<EngineFactory> = vec![Box::new(|| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 1,
            max_tokens: 64,
            step_delay: Duration::from_millis(30),
        })) as Box<dyn StreamEngine>)
    })];
    let gw = Gateway::start(
        GatewayConfig {
            max_tokens_default: 64,
            queue_budget: Duration::from_millis(100),
            ..Default::default()
        },
        factories,
    )
    .unwrap();
    let addr = gw.addr_string();

    let hold = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"hog\", \"max_tokens\": 40}")
        })
    };
    // let the hog occupy the only slot
    std::thread::sleep(Duration::from_millis(200));
    let shed = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"late\", \"max_tokens\": 2}")
        .unwrap();
    assert_eq!(shed.status, 503, "queued past budget -> shed: {}", shed.body_str());
    assert!(shed.body_str().contains("queue-time budget"));

    let held = hold.join().unwrap().unwrap();
    assert_eq!(held.status, 200, "the running request was not disturbed");

    // the shed is visible on the scrape
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    let samples = parse_exposition(&scrape.body_str()).unwrap();
    assert!(samples
        .iter()
        .any(|s| s.name == "enova_gateway_queue_shed_total" && s.value >= 1.0));

    gw.shutdown();
}
