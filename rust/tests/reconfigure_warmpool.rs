//! End-to-end tests of live replica reconfiguration (the Fig. 6 knob on
//! the serving path) and the warm replica pool, over real sockets against
//! the deterministic sim engine:
//!
//! * under a workload shift the supervisor's §IV-A recommendation loop
//!   applies a `Reconfigure` that changes a live replica's effective
//!   `max_num_seqs` while every in-flight and queued request still
//!   completes with 200 — nothing is dropped;
//! * an `AddReplica` served from the warm pool routes its first request
//!   and is measurably faster than a cold hot-spawn, asserted via the
//!   `enova_gateway_promotion_seconds` histogram;
//! * retirement demotes to a warm standby (draining in-flight work on its
//!   own schedule) and the standby is reused by the next promotion.

use enova::autoscaler::Action;
use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::metrics::parse_exposition;
use enova::gateway::supervisor::{ReconfigPolicy, SupervisorConfig, Trigger};
use enova::gateway::{loadgen, EngineSpawner, Gateway, GatewayConfig};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn sim_spawner(max_num_seqs: usize, step_delay_ms: u64, init_delay_ms: u64) -> EngineSpawner {
    Arc::new(move |_id| {
        if init_delay_ms > 0 {
            // stands in for real engine init (model load, compile, KV alloc)
            std::thread::sleep(Duration::from_millis(init_delay_ms));
        }
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs,
            max_tokens: 64,
            step_delay: Duration::from_millis(step_delay_ms),
        })) as Box<dyn StreamEngine>)
    })
}

/// The acceptance e2e: a sustained workload shift makes the supervisor's
/// recommendation loop re-derive `max_num_seqs` from the live Table II
/// window and apply it to the running replica — while a closed loop keeps
/// hammering the gateway and observes zero non-200 responses.
#[test]
fn supervisor_reconfigures_live_replica_without_dropping_work() {
    let cfg = GatewayConfig {
        max_pending: 512,
        max_tokens_default: 24,
        monitor_interval: Duration::from_millis(25),
        ..Default::default()
    };
    let sup = SupervisorConfig {
        sample_interval: Duration::from_millis(50),
        // this test exercises the recommender, not the detector
        detector_scaling: false,
        reconfig: Some(ReconfigPolicy {
            interval: Duration::from_millis(200),
            // one verdict per test horizon: hysteresis must not re-fire
            cooldown: Duration::from_secs(3600),
            deadband: 0.2,
            min_max_num_seqs: 4,
            max_max_num_seqs: 16,
            window: 400,
            ..ReconfigPolicy::default()
        }),
        ..Default::default()
    };
    // one 2-slot replica with 5ms steps: 8 closed-loop workers are a
    // sustained shift well past what the initial config serves
    let gw = Gateway::start_scalable(cfg, sim_spawner(2, 5, 0), 1, Some(sup)).unwrap();
    let addr = gw.addr_string();
    assert_eq!(gw.replica_capacities(), vec![(0, 2)]);

    let stop = Arc::new(AtomicBool::new(false));
    let non_200 = Arc::new(AtomicUsize::new(0));
    let completed = Arc::new(AtomicUsize::new(0));
    let mut load = Vec::new();
    for w in 0..8 {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        let non_200 = Arc::clone(&non_200);
        let completed = Arc::clone(&completed);
        load.push(std::thread::spawn(move || {
            let mut client = loadgen::Client::new(&addr);
            let mut k = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let body = format!("{{\"prompt\": \"shift w{w} r{k}\", \"max_tokens\": 24}}");
                match client.post_json("/v1/completions", &body) {
                    Ok(r) if r.status == 200 => {
                        completed.fetch_add(1, Ordering::Relaxed);
                    }
                    Ok(r) => {
                        eprintln!("worker {w} got {}: {}", r.status, r.body_str());
                        non_200.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        eprintln!("worker {w} transport error: {e}");
                        non_200.fetch_add(1, Ordering::Relaxed);
                    }
                }
                k += 1;
            }
        }));
    }

    // the recommendation loop needs a busy window (≥12 busy frames with
    // latency evidence), then one interval tick to act
    let deadline = Instant::now() + Duration::from_secs(60);
    while gw.supervisor_snapshot().reconfigures == 0 {
        assert!(
            Instant::now() < deadline,
            "supervisor never reconfigured; snapshot: {:?}",
            gw.supervisor_snapshot()
        );
        std::thread::sleep(Duration::from_millis(25));
    }

    // the worker applies the mailbox between steps; poll briefly
    let deadline = Instant::now() + Duration::from_secs(10);
    let applied = loop {
        let caps = gw.replica_capacities();
        if let Some(&(_, cap)) = caps.iter().find(|&&(id, _)| id == 0) {
            if cap != 2 {
                break cap;
            }
        }
        assert!(
            Instant::now() < deadline,
            "reconfigure never reached the engine: {:?}",
            gw.replica_capacities()
        );
        std::thread::sleep(Duration::from_millis(10));
    };
    assert!(
        (4..=16).contains(&applied),
        "applied max_num_seqs outside policy bounds: {applied}"
    );
    let snap = gw.supervisor_snapshot();
    assert_eq!(snap.last_max_num_seqs, applied);

    // the event log carries the action with the recommender trigger
    let events = gw.scaling_events();
    let ev = events
        .iter()
        .find(|e| matches!(e.action, Action::Reconfigure { .. }))
        .expect("a Reconfigure event was recorded");
    assert_eq!(ev.trigger, Trigger::Recommender);
    match ev.action {
        Action::Reconfigure {
            max_num_seqs,
            gpu_memory,
        } => {
            assert_eq!(max_num_seqs, applied);
            assert!((0.05..=0.98).contains(&gpu_memory), "{gpu_memory}");
        }
        other => panic!("unexpected action {other:?}"),
    }

    // keep serving through and after the reconfiguration, then stop
    std::thread::sleep(Duration::from_millis(500));
    stop.store(true, Ordering::Relaxed);
    for h in load {
        let _ = h.join();
    }
    assert_eq!(
        non_200.load(Ordering::Relaxed),
        0,
        "requests were dropped or failed across the reconfiguration"
    );
    assert!(
        completed.load(Ordering::Relaxed) > 20,
        "closed loop barely ran: {}",
        completed.load(Ordering::Relaxed)
    );

    // the applied ceiling and the event counters are visible on /metrics
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    let gauge = samples
        .iter()
        .find(|s| {
            s.name == "enova_replica_max_num_seqs"
                && s.labels.get("instance").map(String::as_str) == Some("replica-0")
        })
        .expect("per-replica max_num_seqs gauge");
    assert_eq!(gauge.value, applied as f64);
    assert!(samples
        .iter()
        .any(|s| s.name == "enova_gateway_reconfigure_events_total" && s.value >= 1.0));
    assert!(samples
        .iter()
        .any(|s| s.name == "enova_supervisor_reconfigure_total" && s.value >= 1.0));

    gw.shutdown();
}

/// Warm promotions skip engine init: with a 250ms init delay baked into
/// the spawner, the pooled standby goes live in O(route-update) while the
/// cold spawn pays the full delay — asserted via the promotion-latency
/// histogram on /metrics, per the kind label.
#[test]
fn warm_promotion_beats_cold_spawn_on_the_promotion_metric() {
    let cfg = GatewayConfig {
        max_tokens_default: 8,
        warm_pool: 1,
        ..Default::default()
    };
    let gw = Gateway::start_scalable(cfg, sim_spawner(4, 1, 250), 1, None).unwrap();
    let addr = gw.addr_string();

    // the background filler builds the standby after startup
    let deadline = Instant::now() + Duration::from_secs(30);
    while gw.warm_pool_size() < 1 {
        assert!(Instant::now() < deadline, "warm pool never filled");
        std::thread::sleep(Duration::from_millis(10));
    }
    // /ready counts the standby as built, not as live
    let ready = loadgen::get(&addr, "/ready").unwrap();
    assert_eq!(ready.status, 200, "{}", ready.body_str());
    assert!(ready.body_str().contains("\"replicas\":1"), "{}", ready.body_str());

    // warm promotion: O(route-update)
    let warm_id = gw.add_replica().unwrap();
    assert_eq!(gw.live_replicas().len(), 2);
    assert!(gw.live_replicas().contains(&warm_id));

    // the promoted replica serves its first request
    let ok = loadgen::post_json(
        &addr,
        "/v1/completions",
        "{\"prompt\": \"first request after promotion\", \"max_tokens\": 2}",
    )
    .unwrap();
    assert_eq!(ok.status, 200, "{}", ok.body_str());

    // force at least one cold spawn: while the pool is empty (the refill
    // worker is sleeping through its 250ms init), add_replica pays init
    let deadline = Instant::now() + Duration::from_secs(30);
    while gw.promotion_stats(false).0 == 0 {
        assert!(Instant::now() < deadline, "no cold spawn happened");
        gw.add_replica().unwrap();
    }

    let (warm_count, warm_mean) = gw.promotion_stats(true);
    let (cold_count, cold_mean) = gw.promotion_stats(false);
    assert!(warm_count >= 1 && cold_count >= 1, "{warm_count}/{cold_count}");
    assert!(
        warm_mean < 0.1,
        "warm promotion paid engine init: {warm_mean:.3}s"
    );
    assert!(
        cold_mean >= 0.2,
        "cold spawn skipped engine init: {cold_mean:.3}s"
    );
    assert!(warm_mean < cold_mean, "{warm_mean} !< {cold_mean}");

    // the same comparison via the exposed histogram (the acceptance path)
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    let histo = |name: &str, kind: &str| {
        samples
            .iter()
            .find(|s| s.name == name && s.labels.get("kind").map(String::as_str) == Some(kind))
            .unwrap_or_else(|| panic!("missing {name} kind={kind}"))
            .value
    };
    let warm_metric_mean = histo("enova_gateway_promotion_seconds_sum", "warm")
        / histo("enova_gateway_promotion_seconds_count", "warm");
    let cold_metric_mean = histo("enova_gateway_promotion_seconds_sum", "cold")
        / histo("enova_gateway_promotion_seconds_count", "cold");
    assert!(
        warm_metric_mean < cold_metric_mean,
        "promotion metric does not show the warm advantage: \
         warm {warm_metric_mean:.4}s vs cold {cold_metric_mean:.4}s"
    );

    gw.shutdown();
}

/// Retirement with a below-target pool demotes the replica to a warm
/// standby instead of killing its worker: in-flight work still completes,
/// the id leaves the routable set, and the next promotion reuses it.
#[test]
fn retire_demotes_to_warm_and_next_promotion_reuses_the_standby() {
    let cfg = GatewayConfig {
        max_tokens_default: 64,
        warm_pool: 1,
        ..Default::default()
    };
    // ids 0 (initial) and 1 (first standby) build instantly; any later
    // refill stalls for the whole test, so the pool deterministically
    // stays empty between the promotion and the demote below
    let spawner: EngineSpawner = Arc::new(move |id| {
        if id >= 2 {
            std::thread::sleep(Duration::from_secs(8));
        }
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 64,
            step_delay: Duration::from_millis(10),
        })) as Box<dyn StreamEngine>)
    });
    let gw = Gateway::start_scalable(cfg, spawner, 1, None).unwrap();
    let addr = gw.addr_string();

    let deadline = Instant::now() + Duration::from_secs(10);
    while gw.warm_pool_size() < 1 {
        assert!(Instant::now() < deadline, "warm pool never filled");
        std::thread::sleep(Duration::from_millis(5));
    }
    let added = gw.add_replica().unwrap();
    assert_eq!(gw.live_replicas().len(), 2);

    // park one slow request on each replica, staggered so least-loaded
    // dispatch deterministically fills both
    let slow = "{\"prompt\": \"hold across demote\", \"max_tokens\": 150}";
    let mut holders = Vec::new();
    for round in 1..=2u64 {
        let addr = addr.clone();
        holders.push(std::thread::spawn(move || {
            loadgen::post_json(&addr, "/v1/completions", slow)
        }));
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let busy = gw
                .replica_stats()
                .iter()
                .filter(|&&(_, inflight, _)| inflight >= 1)
                .count();
            if busy as u64 >= round {
                break;
            }
            assert!(Instant::now() < deadline, "round {round} never placed");
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    // demote: returns immediately (no drain-join), worker keeps serving
    let t0 = Instant::now();
    gw.retire_replica(added).unwrap();
    assert!(
        t0.elapsed() < Duration::from_millis(250),
        "demote should not block on drain: {:?}",
        t0.elapsed()
    );
    assert_eq!(gw.live_replicas(), vec![0]);
    assert_eq!(gw.warm_pool_size(), 1);

    // the demoted worker finished its in-flight request — nothing dropped
    for h in holders {
        let resp = h.join().unwrap().unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
    }

    // a demoted id is not weightable through the ingress-update path
    let bad = loadgen::post_json(
        &addr,
        "/admin/scale",
        &format!("{{\"replicas\": [{{\"id\": {added}, \"weight\": 1.0}}]}}"),
    )
    .unwrap();
    assert_eq!(bad.status, 400, "{}", bad.body_str());

    // the next promotion reuses the standby — same id, pool drains
    let again = gw.add_replica().unwrap();
    assert_eq!(again, added, "the warm standby is reused");
    assert_eq!(gw.live_replicas(), vec![0, added]);

    let ok = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"after\", \"max_tokens\": 2}")
        .unwrap();
    assert_eq!(ok.status, 200);

    gw.shutdown();
}
