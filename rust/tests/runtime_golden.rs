//! Cross-language numeric pin: the rust PJRT path must reproduce the
//! golden logits that `python/compile/aot.py` recorded when it lowered the
//! model. This is the end-to-end correctness signal for the whole
//! python → HLO-text → rust → PJRT bridge.
#![cfg(feature = "xla-runtime")]

use enova::runtime::lm::{ExecMode, LmRuntime};
use enova::runtime::{Manifest, PjRt};

fn manifest_or_skip() -> Option<Manifest> {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: run `make artifacts` first");
        return None;
    }
    Some(Manifest::load(&dir).expect("manifest loads"))
}

fn argmax(xs: &[f32]) -> usize {
    xs.iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i)
        .unwrap()
}

fn run_golden(mode: ExecMode) {
    let Some(manifest) = manifest_or_skip() else { return };
    let golden = manifest.golden.clone().expect("golden in manifest");
    let rt = PjRt::cpu().expect("pjrt client");
    let mut lm = LmRuntime::load(rt, &manifest, mode).expect("lm loads");

    lm.prefill(&golden.prompt, golden.slot).expect("prefill");
    let logits = lm.logits(golden.slot).expect("logits");
    assert_eq!(argmax(&logits), golden.prefill_argmax, "prefill argmax");
    for (i, (&got, &want)) in logits
        .iter()
        .zip(&golden.prefill_logits_head)
        .enumerate()
    {
        assert!(
            (got - want).abs() < 1e-3,
            "prefill logit[{i}]: {got} vs {want}"
        );
    }

    let b = lm.spec.batch;
    let mut tokens = vec![0i32; b];
    let mut lens = vec![0i32; b];
    tokens[golden.slot] = golden.decode_token;
    lens[golden.slot] = golden.prompt_len as i32;
    lm.decode(&tokens, &lens).expect("decode");
    let logits = lm.logits(golden.slot).expect("logits");
    assert_eq!(argmax(&logits), golden.decode_argmax, "decode argmax");
    for (i, (&got, &want)) in logits.iter().zip(&golden.decode_logits_head).enumerate() {
        assert!(
            (got - want).abs() < 1e-3,
            "decode logit[{i}]: {got} vs {want}"
        );
    }
}

#[test]
fn golden_chained_buffers() {
    run_golden(ExecMode::Chained);
}

#[test]
fn golden_host_roundtrip() {
    run_golden(ExecMode::HostRoundtrip);
}

#[test]
fn modes_agree_on_longer_generation() {
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = PjRt::cpu().expect("pjrt");
    let mut chained = LmRuntime::load(rt.clone(), &manifest, ExecMode::Chained).unwrap();
    let mut host = LmRuntime::load(rt, &manifest, ExecMode::HostRoundtrip).unwrap();
    let prompt: Vec<i32> = (3..20).collect();
    let b = chained.spec.batch;
    for lm in [&mut chained, &mut host] {
        lm.prefill(&prompt, 0).unwrap();
    }
    let mut c_tokens = Vec::new();
    let mut h_tokens = Vec::new();
    for step in 0..10 {
        for (lm, toks) in [(&mut chained, &mut c_tokens), (&mut host, &mut h_tokens)] {
            let next = argmax(&lm.logits(0).unwrap()) as i32;
            toks.push(next);
            let mut tokens = vec![0i32; b];
            let mut lens = vec![0i32; b];
            tokens[0] = next;
            lens[0] = (prompt.len() + step) as i32;
            lm.decode(&tokens, &lens).unwrap();
        }
    }
    assert_eq!(c_tokens, h_tokens, "greedy decodes diverged between modes");
}

#[test]
fn vae_scores_separate_synthetic_anomaly() {
    use enova::runtime::vae::VaeRuntime;
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = PjRt::cpu().expect("pjrt");
    let vae = VaeRuntime::load(rt, &manifest).expect("vae loads");
    // a plausibly-normal row (light load) vs an absurd overload row
    let normal = vec![240.0, 8.0, 250.0, 0.0, 3.0, 0.6, 0.4, 0.2];
    let anomal = vec![10.0, 120.0, 900.0, 3000.0, 40.0, 0.99, 0.99, 1.0];
    let scores = vae
        .score(&[normal, anomal].concat())
        .expect("scores");
    assert!(scores[1].kl > scores[0].kl * 2.0, "{scores:?}");
}

#[test]
fn embedder_clusters_same_task_texts() {
    use enova::runtime::embedder::EmbedRuntime;
    let Some(manifest) = manifest_or_skip() else { return };
    let rt = PjRt::cpu().expect("pjrt");
    let emb = EmbedRuntime::load(rt, &manifest).expect("embed loads");
    let texts = [
        "write a python function to merge overlapping intervals",
        "write a python function to rotate a matrix in place",
        "solve this grade school math word problem about trains",
    ];
    let vecs = emb.embed(&texts).expect("embed");
    let dot = |a: &[f64], b: &[f64]| -> f64 { a.iter().zip(b).map(|(x, y)| x * y).sum() };
    let same = dot(&vecs[0], &vecs[1]);
    let diff = dot(&vecs[0], &vecs[2]);
    assert!(same > diff + 0.1, "same-task {same} vs cross-task {diff}");
    // unit norm
    for v in &vecs {
        assert!((dot(v, v) - 1.0).abs() < 1e-4);
    }
}
