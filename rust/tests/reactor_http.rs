//! Connection-level e2e tests for the sharded reactor ingress, over raw
//! sockets against the deterministic sim engine: slow-loris partial
//! header reads must not occupy handler threads, request pipelining on
//! one keep-alive connection, a client vanishing mid-SSE-stream must not
//! destabilize the gateway, and a draining shutdown must answer every
//! dispatched request (zero transport failures).

use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::metrics::parse_exposition;
use enova::gateway::{loadgen, EngineFactory, Gateway, GatewayConfig, IngressMode};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

fn sim_gateway(
    replicas: usize,
    max_pending: usize,
    step_delay_ms: u64,
    engine_max_tokens: usize,
) -> Gateway {
    let factories: Vec<EngineFactory> = (0..replicas)
        .map(|_| -> EngineFactory {
            Box::new(move || {
                Ok(Box::new(SimEngine::new(SimEngineConfig {
                    max_num_seqs: 8,
                    max_tokens: engine_max_tokens,
                    step_delay: Duration::from_millis(step_delay_ms),
                })) as Box<dyn StreamEngine>)
            })
        })
        .collect();
    Gateway::start(
        GatewayConfig {
            max_pending,
            max_tokens_default: engine_max_tokens,
            ingress: IngressMode::Reactor,
            ..Default::default()
        },
        factories,
    )
    .expect("gateway start")
}

/// One HTTP/1.1 response off a buffered raw socket: status, the
/// Content-Length body (or chunked frames drained to the terminal chunk).
fn read_one_response(r: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut status_line = String::new();
    r.read_line(&mut status_line).expect("status line");
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|c| c.parse().ok())
        .unwrap_or_else(|| panic!("bad status line {status_line:?}"));
    let mut content_length: Option<usize> = None;
    let mut chunked = false;
    loop {
        let mut line = String::new();
        r.read_line(&mut line).expect("header line");
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim();
            if name == "content-length" {
                content_length = value.parse().ok();
            } else if name == "transfer-encoding" && value.eq_ignore_ascii_case("chunked") {
                chunked = true;
            }
        }
    }
    let mut body = Vec::new();
    if chunked {
        loop {
            let mut size_line = String::new();
            r.read_line(&mut size_line).expect("chunk size");
            let size = usize::from_str_radix(size_line.trim(), 16).expect("hex chunk size");
            if size == 0 {
                let mut blank = String::new();
                let _ = r.read_line(&mut blank);
                break;
            }
            let mut chunk = vec![0u8; size + 2];
            r.read_exact(&mut chunk).expect("chunk body");
            chunk.truncate(size);
            body.extend_from_slice(&chunk);
        }
    } else if let Some(n) = content_length {
        body.resize(n, 0);
        r.read_exact(&mut body).expect("body");
    }
    (status, String::from_utf8_lossy(&body).into_owned())
}

/// A client dribbling its request head a few bytes at a time (slow loris)
/// must neither be dropped nor pin a handler thread: while it dribbles,
/// other clients get served at full speed, and once its request finally
/// completes it is answered normally.
#[test]
fn slow_loris_partial_headers_dont_block_serving() {
    let gw = sim_gateway(1, 64, 0, 8);
    let addr = gw.addr_string();

    let loris = {
        let addr = addr.clone();
        std::thread::spawn(move || {
            let body = "{\"prompt\": \"loris\", \"max_tokens\": 2}";
            let head = format!(
                "POST /v1/completions HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n",
                body.len()
            );
            let stream = TcpStream::connect(&addr).unwrap();
            stream.set_nodelay(true).unwrap();
            let mut w = &stream;
            // 3-byte pieces, 20ms apart: the head alone takes ~700ms
            for piece in head.as_bytes().chunks(3) {
                w.write_all(piece).unwrap();
                w.flush().unwrap();
                std::thread::sleep(Duration::from_millis(20));
            }
            w.write_all(body.as_bytes()).unwrap();
            w.flush().unwrap();
            let mut r = BufReader::new(stream);
            read_one_response(&mut r)
        })
    };

    // while the loris dribbles, the gateway serves others immediately
    let t0 = Instant::now();
    for i in 0..5 {
        let resp = loadgen::post_json(
            &addr,
            "/v1/completions",
            &format!("{{\"prompt\": \"fast {i}\", \"max_tokens\": 2}}"),
        )
        .unwrap();
        assert_eq!(resp.status, 200, "{}", resp.body_str());
    }
    assert!(
        t0.elapsed() < Duration::from_millis(600),
        "full-speed requests stalled behind a slow-loris connection: {:?}",
        t0.elapsed()
    );

    let (status, body) = loris.join().expect("loris thread");
    assert_eq!(status, 200, "loris answered once complete: {body}");
    gw.shutdown();
}

/// Two requests written back-to-back on one keep-alive connection before
/// reading anything: both must be answered, in order, on that connection.
#[test]
fn pipelined_requests_on_one_connection() {
    let gw = sim_gateway(1, 64, 0, 8);
    let addr = gw.addr_string();

    let body_a = "{\"prompt\": \"pipeline a\", \"max_tokens\": 2}";
    let body_b = "{\"prompt\": \"pipeline b\", \"max_tokens\": 3}";
    let mut wire = String::new();
    for body in [body_a, body_b] {
        wire.push_str(&format!(
            "POST /v1/completions HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
            body.len()
        ));
    }
    let stream = TcpStream::connect(&addr).unwrap();
    stream
        .set_read_timeout(Some(Duration::from_secs(10)))
        .unwrap();
    let mut w = &stream;
    w.write_all(wire.as_bytes()).unwrap();
    w.flush().unwrap();

    let mut r = BufReader::new(stream);
    let (status_a, resp_a) = read_one_response(&mut r);
    let (status_b, resp_b) = read_one_response(&mut r);
    assert_eq!(status_a, 200, "{resp_a}");
    assert_eq!(status_b, 200, "{resp_b}");
    // responses come back in request order: token budgets tell them apart
    let tokens = |raw: &str| {
        enova::util::json::Json::parse(raw)
            .unwrap_or_else(|e| panic!("non-JSON response {raw:?}: {e}"))
            .at(&["usage", "completion_tokens"])
            .and_then(enova::util::json::Json::as_usize)
    };
    assert_eq!(tokens(&resp_a), Some(2), "first response answers the first request");
    assert_eq!(tokens(&resp_b), Some(3), "second response answers the second request");
    gw.shutdown();
}

/// A client that vanishes mid-SSE-stream must not wedge the gateway: the
/// handler notices the dead socket, the connection gauge returns to zero,
/// and new requests keep being served.
#[test]
fn client_disconnect_mid_sse_stream_is_contained() {
    // slow-ish stream so the disconnect lands mid-flight
    let gw = sim_gateway(1, 64, 10, 200);
    let addr = gw.addr_string();

    {
        let body = "{\"prompt\": \"abandoned\", \"max_tokens\": 200, \"stream\": true}";
        let stream = TcpStream::connect(&addr).unwrap();
        stream
            .set_read_timeout(Some(Duration::from_secs(5)))
            .unwrap();
        let mut w = &stream;
        w.write_all(
            format!(
                "POST /v1/completions HTTP/1.1\r\nHost: {addr}\r\nContent-Type: application/json\r\nContent-Length: {}\r\n\r\n{body}",
                body.len()
            )
            .as_bytes(),
        )
        .unwrap();
        w.flush().unwrap();
        // read just the head + first bytes of the stream, then vanish
        let mut first = [0u8; 64];
        let mut r = &stream;
        let n = r.read(&mut first).unwrap();
        assert!(n > 0, "stream started before disconnect");
        drop(stream);
    }

    // the gateway keeps serving new work afterwards
    let resp = loadgen::post_json(
        &addr,
        "/v1/completions",
        "{\"prompt\": \"after disconnect\", \"max_tokens\": 2}",
    )
    .unwrap();
    assert_eq!(resp.status, 200, "{}", resp.body_str());

    // and the abandoned connection is reaped: open connections drain to 0
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let metrics = loadgen::get(&addr, "/metrics").unwrap();
        let samples = parse_exposition(&metrics.body_str()).unwrap();
        let open = samples
            .iter()
            .find(|s| s.name == "enova_ingress_connections_open")
            .map(|s| s.value)
            .unwrap_or(-1.0);
        // the /metrics connection itself is not kept open by loadgen::get
        if open == 0.0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "abandoned SSE connection never reaped, open={open}"
        );
        std::thread::sleep(Duration::from_millis(50));
    }
    gw.shutdown();
}

/// Draining shutdown: requests already dispatched when shutdown begins
/// are all answered with a well-formed response (200 if they finish, 503
/// with a terminal event if shed) — never a torn connection.
#[test]
fn draining_shutdown_answers_every_inflight_request() {
    // slow engine keeps requests in flight across the shutdown point
    let gw = sim_gateway(2, 64, 20, 300);
    let addr = gw.addr_string();

    let mut clients = Vec::new();
    for i in 0..6 {
        let addr = addr.clone();
        let stream = i % 2 == 1;
        clients.push(std::thread::spawn(move || {
            let body = format!(
                "{{\"prompt\": \"drain {i}\", \"max_tokens\": 300, \"stream\": {stream}}}"
            );
            loadgen::post_json(&addr, "/v1/completions", &body)
        }));
    }

    // wait until the fleet is actually in flight
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let metrics = loadgen::get(&addr, "/metrics").unwrap();
        let samples = parse_exposition(&metrics.body_str()).unwrap();
        let inflight = samples
            .iter()
            .find(|s| s.name == "enova_gateway_inflight_requests")
            .map(|s| s.value)
            .unwrap_or(0.0);
        if inflight >= 4.0 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "requests not admitted, inflight={inflight}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }

    gw.shutdown();

    // zero in-flight transport failures: every client got a well-formed
    // HTTP response — completed (200) or shed with a 503
    for c in clients {
        let resp = c
            .join()
            .expect("client thread")
            .expect("well-formed response across draining shutdown");
        assert!(
            resp.status == 200 || resp.status == 503,
            "unexpected status {} across drain",
            resp.status
        );
    }
}

/// The reactor path advertises itself and its connection accounting on
/// `/metrics`.
#[test]
fn reactor_exports_ingress_gauges() {
    let gw = sim_gateway(1, 64, 0, 8);
    let addr = gw.addr_string();
    let resp = loadgen::post_json(
        &addr,
        "/v1/completions",
        "{\"prompt\": \"gauge\", \"max_tokens\": 2}",
    )
    .unwrap();
    assert_eq!(resp.status, 200);

    let metrics = loadgen::get(&addr, "/metrics").unwrap();
    let samples = parse_exposition(&metrics.body_str()).unwrap();
    let find = |name: &str| {
        samples
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing {name}"))
            .value
    };
    assert_eq!(find("enova_ingress_reactor_mode"), 1.0);
    assert!(find("enova_ingress_connections_accepted_total") >= 2.0);
    assert!(find("enova_ingress_handler_threads") >= 1.0);
    gw.shutdown();
}
