//! End-to-end tests of the distributed tracing plane: a coordinator and
//! two in-process nodes over real sockets. Every proxied request must
//! leave ONE trace whose coordinator-side and node-side spans share a
//! trace ID, whose node-side lifecycle phases partition the node timeline
//! (durations sum to ≈ the measured latency), and a node death mid-run
//! must leave `cause=node_death` retry spans plus a matching backfill
//! entry in the decision flight recorder — all with zero non-2xx.

use enova::cluster::coordinator::{ClusterPolicy, Coordinator, CoordinatorConfig};
use enova::cluster::node::{NodeConfig, NodeServer};
use enova::cluster::NodeIdentity;
use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::loadgen::{self, run_scenario, LoadgenReport, ScenarioConfig, ScenarioKind};
use enova::gateway::metrics::parse_exposition;
use enova::gateway::{EngineSpawner, GatewayConfig};
use enova::trace::SpanKind;
use enova::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

fn sim_spawner() -> EngineSpawner {
    Arc::new(|_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 64,
            step_delay: Duration::from_millis(2),
        })) as Box<dyn StreamEngine>)
    })
}

fn node_config(id: &str, coordinator: &str, initial_replicas: usize) -> NodeConfig {
    NodeConfig {
        gateway: GatewayConfig {
            max_pending: 1024,
            max_tokens_default: 8,
            monitor_interval: Duration::from_millis(25),
            warm_pool: 1,
            ..GatewayConfig::default()
        },
        identity: NodeIdentity {
            node_id: id.to_string(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 3,
            replica_capacity_rps: 0.0,
        },
        initial_replicas,
        coordinator: Some(coordinator.to_string()),
        announce_interval: Duration::from_millis(100),
        advertise_addr: None,
    }
}

fn non_2xx(report: &LoadgenReport) -> usize {
    report
        .status_counts
        .iter()
        .filter(|&(&code, _)| !(200..300).contains(&code))
        .map(|(_, &n)| n)
        .sum()
}

/// The lifecycle phases every served request must record node-side.
const LIFECYCLE_PHASES: [&str; 5] = ["admission", "dispatch", "queue_wait", "prefill", "decode"];

/// The headline tracing behavior: a spike through the 2-node cluster
/// leaves, for every request, one trace whose coordinator-side and
/// node-side spans share a trace ID (visible in the coordinator's
/// aggregated `/debug/traces`), and whose node-side phase durations sum
/// to within 10% of that request's measured latency.
#[test]
fn cross_node_traces_share_one_id_and_phases_partition_the_latency() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(50),
        node_timeout_beats: 4,
        max_pending: 2048,
        policy: ClusterPolicy {
            // tracing is the subject here; scaling loops stay off
            detector_scaling: false,
            forecast: None,
            ..ClusterPolicy::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    let node_a = NodeServer::start(node_config("node-a", &addr, 1), sim_spawner()).unwrap();
    let node_b = NodeServer::start(node_config("node-b", &addr, 1), sim_spawner()).unwrap();
    assert!(coordinator.wait_for_nodes(2, Duration::from_secs(10)));
    assert!(coordinator.wait_for_replicas(2, Duration::from_secs(10)));

    let scn = ScenarioConfig {
        kind: ScenarioKind::Spike,
        duration: Duration::from_secs(8),
        base_rps: 2.0,
        peak_rps: 12.0,
        spike_start: 0.3,
        spike_len: 0.5,
        seed: 7,
        workers: 48,
        max_tokens: 4,
        ..ScenarioConfig::default()
    };
    let report = run_scenario(&addr, &scn);
    assert_eq!(report.errors, 0, "strict: no transport errors: {}", report.summary());
    assert_eq!(non_2xx(&report), 0, "strict: zero non-2xx: {:?}", report.status_counts);
    // the scenario streams every 4th request, so SSE timing percentiles
    // are real measurements, not zero-fills
    assert!(report.ttft_p50_ms > 0.0, "TTFT measured: {}", report.summary());
    assert!(report.itl_p50_ms > 0.0, "inter-token gaps measured: {}", report.summary());

    // ---- the coordinator's aggregated view: both sides of every trace
    let scrape = loadgen::get(&addr, "/debug/traces").unwrap();
    assert_eq!(scrape.status, 200);
    let view = scrape.json().unwrap();
    let traces = view.get("traces").and_then(Json::as_arr).expect("traces array");
    assert!(!traces.is_empty(), "the spike left traces behind");
    assert!(
        view.get("nodes_polled").and_then(Json::as_usize) == Some(2),
        "both nodes contributed spans: {}",
        view.to_string_compact()
    );
    // the same export under the versioned API: typed envelope with the
    // legacy payload verbatim under `data`; the old path stays an alias
    let v1 = loadgen::get(&addr, "/v1/debug/traces").unwrap();
    assert_eq!(v1.status, 200);
    let envelope = v1.json().unwrap();
    assert_eq!(envelope.get("api_version").and_then(Json::as_str), Some("v1"));
    assert_eq!(envelope.get("kind").and_then(Json::as_str), Some("traces"));
    assert_eq!(envelope.get("service").and_then(Json::as_str), Some("coordinator"));
    assert_eq!(
        envelope.at(&["data", "traces"]).and_then(Json::as_arr).map(<[Json]>::len),
        Some(traces.len()),
        "typed export carries the same trace payload"
    );
    // node gateways serve the same envelope
    let node_v1 = loadgen::get(&node_a.addr_string(), "/v1/debug/traces").unwrap().json().unwrap();
    assert_eq!(node_v1.get("api_version").and_then(Json::as_str), Some("v1"));
    assert_eq!(node_v1.get("kind").and_then(Json::as_str), Some("traces"));
    assert!(
        node_v1.at(&["data", "traces"]).and_then(Json::as_arr).is_some(),
        "node-side typed export: {}",
        node_v1.to_string_compact()
    );
    let mut cross_node = 0usize;
    for t in traces {
        let spans = t.get("spans").and_then(Json::as_arr).expect("spans array");
        let service_of =
            |sp: &Json| sp.get("service").and_then(Json::as_str).unwrap_or("").to_string();
        let has_coord = spans.iter().any(|sp| service_of(sp) == "coordinator");
        let has_node = spans.iter().any(|sp| service_of(sp).starts_with("node:"));
        assert!(has_coord, "coordinator spans present: {}", t.to_string_compact());
        if !has_node {
            continue; // a 429/edge case without a node hop would be legal
        }
        cross_node += 1;
        // one trace ID spans both services — and the node side carries
        // the full request lifecycle
        for phase in LIFECYCLE_PHASES {
            assert!(
                spans.iter().any(|sp| {
                    sp.get("kind").and_then(Json::as_str) == Some("phase")
                        && sp.get("name").and_then(Json::as_str) == Some(phase)
                        && service_of(sp).starts_with("node:")
                }),
                "phase {phase} missing node-side: {}",
                t.to_string_compact()
            );
        }
    }
    assert!(
        cross_node * 10 >= traces.len() * 9,
        "nearly every trace crossed to a node: {cross_node}/{}",
        traces.len()
    );

    // ---- node-side records: phases partition the measured latency
    for node in [&node_a, &node_b] {
        let node_view = loadgen::get(&node.addr_string(), "/debug/traces").unwrap().json().unwrap();
        let node_traces = node_view.get("traces").and_then(Json::as_arr).expect("traces");
        assert!(!node_traces.is_empty(), "node kept traces");
        for t in node_traces {
            let total = t.get("total_seconds").and_then(Json::as_f64).unwrap();
            let phase_sum = t.get("phase_seconds_total").and_then(Json::as_f64).unwrap();
            assert!(
                (phase_sum - total).abs() <= total * 0.10,
                "phase sum {phase_sum:.6}s within 10% of latency {total:.6}s: {}",
                t.to_string_compact()
            );
        }
    }

    // ---- the phase histograms made it to the node scrape
    let exposition = loadgen::get(&node_a.addr_string(), "/metrics").unwrap();
    let samples = parse_exposition(&exposition.body_str()).expect("valid exposition");
    for phase in LIFECYCLE_PHASES {
        let count: f64 = samples
            .iter()
            .filter(|s| {
                s.name == "enova_request_phase_seconds_count"
                    && s.labels.get("phase").map(String::as_str) == Some(phase)
            })
            .map(|s| s.value)
            .sum();
        assert!(count > 0.0, "phase {phase} histogram counted requests");
    }
    assert!(
        samples.iter().any(|s| s.name == "enova_gateway_ttft_seconds_count" && s.value > 0.0),
        "TTFT histogram moved"
    );

    coordinator.shutdown();
    node_a.shutdown();
    node_b.shutdown();
}

/// Kill a node mid-run: the affected requests re-dispatch (zero non-2xx),
/// each re-dispatch leaves a `cause=node_death` retry span on its trace,
/// and the decision flight recorder holds the matching backfill placement
/// with its bin-packing cause snapshot.
#[test]
fn node_death_leaves_retry_spans_and_a_backfill_decision() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        // slow death detection a little so in-flight traffic actually
        // hits the dead node and exercises the retry path
        heartbeat_interval: Duration::from_millis(250),
        node_timeout_beats: 3,
        max_pending: 2048,
        dispatch_attempts: 4,
        policy: ClusterPolicy {
            sample_interval: Duration::from_millis(50),
            detector_scaling: false,
            forecast: None,
            cooldown: Duration::from_secs(30),
            min_replicas: 1,
            max_replicas: 4,
            ..ClusterPolicy::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    let node_a = NodeServer::start(node_config("node-a", &addr, 1), sim_spawner()).unwrap();
    let node_b = NodeServer::start(node_config("node-b", &addr, 1), sim_spawner()).unwrap();
    assert!(coordinator.wait_for_nodes(2, Duration::from_secs(10)));
    assert!(coordinator.wait_for_replicas(2, Duration::from_secs(10)));

    let scn = ScenarioConfig {
        kind: ScenarioKind::Steady,
        duration: Duration::from_secs(6),
        base_rps: 12.0,
        peak_rps: 12.0,
        seed: 13,
        workers: 32,
        max_tokens: 4,
        ..ScenarioConfig::default()
    };
    let loadgen_addr = addr.clone();
    let driver = std::thread::spawn(move || run_scenario(&loadgen_addr, &scn));

    std::thread::sleep(Duration::from_millis(2000));
    node_b.shutdown();

    let report = driver.join().unwrap();
    assert_eq!(report.errors, 0, "strict through the death: {}", report.summary());
    assert_eq!(non_2xx(&report), 0, "zero non-2xx: {:?}", report.status_counts);

    let deadline = std::time::Instant::now() + Duration::from_secs(8);
    while coordinator.healthy_nodes() != 1 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(coordinator.healthy_nodes(), 1, "node-b declared dead");
    assert!(
        coordinator.wait_for_replicas(2, Duration::from_secs(8)),
        "backfill restored 2 replicas: {:?}",
        coordinator.nodes()
    );

    // ---- the retried requests carry the cause on their traces
    let death_retries: Vec<_> = coordinator
        .traces()
        .into_iter()
        .filter(|t| {
            t.spans.iter().any(|sp| {
                sp.kind == SpanKind::Retry
                    && sp.attrs.iter().any(|(k, v)| *k == "cause" && v == "node_death")
            })
        })
        .collect();
    assert!(
        !death_retries.is_empty(),
        "at least one trace recorded a node_death retry span"
    );
    for t in &death_retries {
        assert_eq!(t.status, 200, "the retried request still succeeded");
        let proxies = t.spans.iter().filter(|sp| sp.kind == SpanKind::Proxy).count();
        assert!(proxies >= 2, "a failed and a successful attempt: {t:?}");
    }

    // ---- and the flight recorder explains the backfill that followed
    let backfill = coordinator
        .decisions()
        .into_iter()
        .find(|d| d.kind == "placement" && d.reason == "backfill")
        .expect("a backfill decision was recorded");
    assert_eq!(backfill.service, "coordinator");
    assert!(
        backfill.attrs.iter().any(|(k, v)| *k == "node" && v == "node-a"),
        "backfill chose the survivor: {backfill:?}"
    );
    assert!(
        backfill.attrs.iter().any(|(k, v)| *k == "bin_packing" && v.contains("node-a")),
        "the bin-packing inputs were snapshotted: {backfill:?}"
    );

    // the same entry is served over HTTP
    let over_http = loadgen::get(&addr, "/debug/decisions").unwrap();
    assert_eq!(over_http.status, 200);
    let body = over_http.json().unwrap();
    let decisions = body.get("decisions").and_then(Json::as_arr).expect("decisions array");
    assert!(
        decisions.iter().any(|d| d.get("reason").and_then(Json::as_str) == Some("backfill")),
        "backfill visible at /debug/decisions: {}",
        body.to_string_compact()
    );

    // and under the versioned path, wrapped in the typed envelope
    let v1 = loadgen::get(&addr, "/v1/debug/decisions").unwrap();
    assert_eq!(v1.status, 200);
    let envelope = v1.json().unwrap();
    assert_eq!(envelope.get("api_version").and_then(Json::as_str), Some("v1"));
    assert_eq!(envelope.get("kind").and_then(Json::as_str), Some("decisions"));
    assert_eq!(envelope.get("service").and_then(Json::as_str), Some("coordinator"));
    assert!(
        envelope
            .at(&["data", "decisions"])
            .and_then(Json::as_arr)
            .map(|ds| ds
                .iter()
                .any(|d| d.get("reason").and_then(Json::as_str) == Some("backfill")))
            .unwrap_or(false),
        "backfill visible at /v1/debug/decisions: {}",
        envelope.to_string_compact()
    );

    coordinator.shutdown();
    node_a.shutdown();
}
