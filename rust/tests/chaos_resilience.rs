//! End-to-end chaos drills: a coordinator and two in-process nodes over
//! real sockets, with the seeded fault injector armed on one node. The
//! contract under test is the PR's headline invariant — injected faults
//! stay invisible to clients: unary 500s are retried away (zero
//! client-visible non-2xx, no double-commit), a severed SSE stream ends
//! in exactly one terminal error event on a cleanly closed chunked body,
//! and a slow-but-alive node trips its circuit breaker and recovers
//! through half-open without ever being declared dead or backfilled.
//! The typed `/v1/debug/*` and `/v1/admin/chaos` surfaces are asserted
//! along the way.

use enova::chaos::ChaosConfig;
use enova::cluster::coordinator::{ClusterPolicy, Coordinator, CoordinatorConfig};
use enova::cluster::node::{NodeConfig, NodeServer};
use enova::cluster::pool::BreakerConfig;
use enova::cluster::NodeIdentity;
use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::loadgen::{self, run_scenario, LoadgenReport, ScenarioConfig, ScenarioKind};
use enova::gateway::metrics::parse_exposition;
use enova::gateway::{EngineSpawner, GatewayConfig};
use enova::trace::SpanKind;
use enova::util::json::{num, obj, s, Json};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn sim_spawner() -> EngineSpawner {
    Arc::new(|_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 64,
            step_delay: Duration::from_millis(2),
        })) as Box<dyn StreamEngine>)
    })
}

/// A node whose wrapped gateway boots with the given chaos config armed
/// (pass `ChaosConfig::default()` for a clean node).
fn node_config(id: &str, coordinator: &str, chaos: ChaosConfig) -> NodeConfig {
    NodeConfig {
        gateway: GatewayConfig {
            max_pending: 1024,
            max_tokens_default: 8,
            monitor_interval: Duration::from_millis(25),
            warm_pool: 1,
            chaos,
            ..GatewayConfig::default()
        },
        identity: NodeIdentity {
            node_id: id.to_string(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 3,
            replica_capacity_rps: 0.0,
        },
        initial_replicas: 1,
        coordinator: Some(coordinator.to_string()),
        announce_interval: Duration::from_millis(100),
        advertise_addr: None,
    }
}

fn non_2xx(report: &LoadgenReport) -> usize {
    report
        .status_counts
        .iter()
        .filter(|&(&code, _)| !(200..300).contains(&code))
        .map(|(_, &n)| n)
        .sum()
}

fn completion_body(max_tokens: usize, stream: bool) -> String {
    obj([
        ("prompt", s("chaos drill")),
        ("max_tokens", num(max_tokens as f64)),
        ("stream", Json::Bool(stream)),
    ])
    .to_string_compact()
}

/// Sum of a labelled counter over a parsed exposition.
fn counter(samples: &[enova::gateway::metrics::Sample], name: &str, label: (&str, &str)) -> f64 {
    samples
        .iter()
        .filter(|s| s.name == name && s.labels.get(label.0).map(String::as_str) == Some(label.1))
        .map(|s| s.value)
        .sum()
}

/// Every request injected with a 500 on the chaos node re-dispatches to
/// the healthy node: the client sees zero non-2xx and every request
/// commits exactly one response. The chaos admin surface answers typed
/// on the node and refuses typed on the coordinator.
#[test]
fn injected_errors_are_retried_away_without_double_commit() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(50),
        node_timeout_beats: 4,
        max_pending: 2048,
        dispatch_attempts: 4,
        policy: ClusterPolicy {
            detector_scaling: false,
            forecast: None,
            ..ClusterPolicy::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    let node_a =
        NodeServer::start(node_config("node-a", &addr, ChaosConfig::default()), sim_spawner())
            .unwrap();
    // node-b fails EVERY request it is dispatched — the worst case for
    // the retry path, and a guaranteed breaker trip
    let node_b = NodeServer::start(
        node_config(
            "node-b",
            &addr,
            ChaosConfig {
                seed: 1234,
                error_rate: 1.0,
                ..ChaosConfig::default()
            },
        ),
        sim_spawner(),
    )
    .unwrap();
    assert!(coordinator.wait_for_nodes(2, Duration::from_secs(10)));
    assert!(coordinator.wait_for_replicas(2, Duration::from_secs(10)));

    // enough concurrency that least-loaded routing regularly lands on
    // node-b (an idle tie always picks the first node)
    let scn = ScenarioConfig {
        kind: ScenarioKind::Steady,
        duration: Duration::from_secs(5),
        base_rps: 24.0,
        peak_rps: 24.0,
        seed: 21,
        workers: 32,
        max_tokens: 4,
        ..ScenarioConfig::default()
    };
    let report = run_scenario(&addr, &scn);
    assert_eq!(report.errors, 0, "no transport errors under chaos: {}", report.summary());
    assert_eq!(non_2xx(&report), 0, "zero client-visible non-2xx: {:?}", report.status_counts);
    // exactly one committed response per offered request — a retried
    // unary never double-commits (completions are stateless server-side,
    // and a response was never started on the failed attempt)
    assert_eq!(
        report.status_counts.get(&200).copied().unwrap_or(0),
        report.requests,
        "every request committed exactly one 200: {:?}",
        report.status_counts
    );

    // the retries are visible on the traces: a shed_500 retry span on
    // node-b followed by a successful proxy attempt elsewhere
    let retried: Vec<_> = coordinator
        .traces()
        .into_iter()
        .filter(|t| {
            t.spans.iter().any(|sp| {
                sp.kind == SpanKind::Retry
                    && sp.attrs.iter().any(|(k, v)| *k == "cause" && v == "shed_500")
                    && sp.attrs.iter().any(|(k, v)| *k == "node" && v == "node-b")
            })
        })
        .collect();
    assert!(!retried.is_empty(), "at least one trace recorded an injected-500 retry");
    for t in &retried {
        assert_eq!(t.status, 200, "the retried request still succeeded: {t:?}");
        let proxies = t.spans.iter().filter(|sp| sp.kind == SpanKind::Proxy).count();
        assert!(proxies >= 2, "a failed and a successful attempt: {t:?}");
    }

    // chaos is node-local state: the node answers the typed surface...
    let chaos_view = loadgen::get(&node_b.addr_string(), "/v1/admin/chaos").unwrap();
    assert_eq!(chaos_view.status, 200);
    let body = chaos_view.json().unwrap();
    assert_eq!(body.get("api_version").and_then(Json::as_str), Some("v1"));
    assert_eq!(body.at(&["config", "error_rate"]).and_then(Json::as_f64), Some(1.0));
    assert!(
        body.at(&["stats", "injected_errors"]).and_then(Json::as_f64).unwrap_or(0.0) > 0.0,
        "the injector counted its faults: {}",
        body.to_string_compact()
    );
    // ...and the coordinator refuses it with a structured error
    let refused = loadgen::get(&addr, "/v1/admin/chaos").unwrap();
    assert_eq!(refused.status, 400);
    let err = refused.json().unwrap();
    assert_eq!(err.get("code").and_then(Json::as_str), Some("unsupported"));

    // runtime disarm round-trips through the same endpoint: an empty
    // body means "all defaults", and all-defaults is disarmed
    let disarmed = loadgen::post_json(&node_b.addr_string(), "/v1/admin/chaos", "{}").unwrap();
    assert_eq!(disarmed.status, 200);
    let body = disarmed.json().unwrap();
    assert_eq!(body.at(&["config", "error_rate"]).and_then(Json::as_f64), Some(0.0));
    assert_eq!(body.at(&["stats", "armed"]), Some(&Json::Bool(false)));

    coordinator.shutdown();
    node_a.shutdown();
    node_b.shutdown();
}

/// Arm mid-stream SSE aborts on one node at runtime (through the typed
/// chaos API), then stream through the coordinator: a severed upstream
/// yields exactly ONE terminal `service_unavailable` event as the last
/// data event of a cleanly closed chunked 200 — never a torn client
/// socket, never a second error event.
#[test]
fn severed_sse_streams_end_in_one_terminal_error_event() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(50),
        node_timeout_beats: 4,
        max_pending: 2048,
        dispatch_attempts: 4,
        policy: ClusterPolicy {
            detector_scaling: false,
            forecast: None,
            ..ClusterPolicy::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    let node_a =
        NodeServer::start(node_config("node-a", &addr, ChaosConfig::default()), sim_spawner())
            .unwrap();
    let node_b =
        NodeServer::start(node_config("node-b", &addr, ChaosConfig::default()), sim_spawner())
            .unwrap();
    assert!(coordinator.wait_for_nodes(2, Duration::from_secs(10)));
    assert!(coordinator.wait_for_replicas(2, Duration::from_secs(10)));

    // arm BOTH nodes at runtime so the behavior is routing-independent:
    // every stream is severed after at least one event, with no clean
    // close on the node side
    for node_addr in [node_a.addr_string(), node_b.addr_string()] {
        let armed = loadgen::post_json(
            &node_addr,
            "/v1/admin/chaos",
            &obj([("seed", num(99.0)), ("sse_abort_rate", num(1.0))]).to_string_compact(),
        )
        .unwrap();
        assert_eq!(armed.status, 200);
        let body = armed.json().unwrap();
        assert_eq!(body.at(&["config", "sse_abort_rate"]).and_then(Json::as_f64), Some(1.0));
    }

    for _ in 0..10 {
        let resp = loadgen::post_json(&addr, "/v1/completions", &completion_body(8, true))
            .expect("a severed upstream must not tear the client socket");
        assert_eq!(resp.status, 200, "the stream already committed 200: {}", resp.body_str());
        let events = resp.sse_data();
        assert!(!events.is_empty(), "at least one event relayed: {}", resp.body_str());
        let errors = events.iter().filter(|e| e.contains("service_unavailable")).count();
        assert_eq!(errors, 1, "exactly one terminal error event: {events:?}");
        assert!(
            events.last().unwrap().contains("service_unavailable"),
            "the error event terminates the stream: {events:?}"
        );
        assert!(
            !events.iter().any(|e| e.trim() == "[DONE]"),
            "a severed stream must not also claim completion: {events:?}"
        );
    }

    // a severed stream committed a 200 before dying — it is the client's
    // problem to surface, not a node-health verdict: the breaker stays
    // closed and nobody is declared dead or backfilled
    assert_eq!(coordinator.healthy_nodes(), 2);
    assert!(
        !coordinator.decisions().iter().any(|d| d.kind == "breaker"),
        "SSE aborts after commit must not trip the breaker"
    );

    coordinator.shutdown();
    node_a.shutdown();
    node_b.shutdown();
}

/// A slow-but-alive node (seeded latency spikes, heartbeats unaffected)
/// trips its circuit breaker on the latency window, keeps its replicas
/// and registration the whole time, and — once the chaos is disarmed —
/// recovers through half-open probes back to closed. No death, no
/// backfill, no replica flapping.
#[test]
fn slow_node_trips_the_breaker_and_recovers_through_half_open() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(50),
        node_timeout_beats: 8,
        max_pending: 2048,
        dispatch_attempts: 4,
        breaker: BreakerConfig {
            enabled: true,
            window: 8,
            min_samples: 4,
            error_threshold: 0.5,
            latency_threshold: Duration::from_millis(120),
            cooldown: Duration::from_millis(400),
            half_open_probes: 2,
        },
        policy: ClusterPolicy {
            detector_scaling: false,
            forecast: None,
            ..ClusterPolicy::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    let node_a =
        NodeServer::start(node_config("node-a", &addr, ChaosConfig::default()), sim_spawner())
            .unwrap();
    // node-b answers everything — ~300ms late: alive by every health
    // check, useless on the serving path
    let node_b = NodeServer::start(
        node_config(
            "node-b",
            &addr,
            ChaosConfig {
                seed: 7,
                latency_rate: 1.0,
                latency_ms: 300.0,
                latency_sigma: 0.1,
                tail_ratio: 0.0,
                max_delay_ms: 600.0,
                ..ChaosConfig::default()
            },
        ),
        sim_spawner(),
    )
    .unwrap();
    assert!(coordinator.wait_for_nodes(2, Duration::from_secs(10)));
    assert!(coordinator.wait_for_replicas(2, Duration::from_secs(10)));

    // enough offered load that the least-loaded scan regularly overflows
    // onto node-b (idle ties always pick the first node) and its latency
    // window fills past min_samples
    let scn = ScenarioConfig {
        kind: ScenarioKind::Steady,
        duration: Duration::from_secs(4),
        base_rps: 24.0,
        peak_rps: 24.0,
        seed: 31,
        workers: 32,
        max_tokens: 4,
        ..ScenarioConfig::default()
    };
    let report = run_scenario(&addr, &scn);
    assert_eq!(report.errors, 0, "slow is not broken: {}", report.summary());
    assert_eq!(non_2xx(&report), 0, "zero non-2xx through the slow node: {:?}", report.status_counts);

    // the breaker opened on latency evidence, attributed to node-b
    let opened = coordinator
        .decisions()
        .into_iter()
        .find(|d| d.kind == "breaker" && d.reason == "open")
        .expect("the latency window tripped the breaker");
    assert!(
        opened.attrs.iter().any(|(k, v)| *k == "node" && v == "node-b"),
        "the slow node was the one derouted: {opened:?}"
    );
    // ...but it is a routing verdict, not a death certificate
    assert_eq!(coordinator.healthy_nodes(), 2, "node-b never declared dead");
    assert!(
        coordinator.wait_for_replicas(2, Duration::from_secs(2)),
        "replica counts untouched: {:?}",
        coordinator.nodes()
    );
    assert!(
        !coordinator
            .decisions()
            .iter()
            .any(|d| d.kind == "placement" && d.reason == "backfill"),
        "a derouted node is not backfilled"
    );

    // cure the node, then drive probes until the breaker closes again.
    // Traffic drives the state machine — and it must be CONCURRENT: an
    // idle-tie pick always lands on node-a, so only overlapping requests
    // reach node-b and spend its half-open probe budget.
    let cured = loadgen::post_json(&node_b.addr_string(), "/v1/admin/chaos", "{}").unwrap();
    assert_eq!(cured.status, 200);
    let deadline = Instant::now() + Duration::from_secs(10);
    let recovered = loop {
        let batch: Vec<_> = (0..4)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    loadgen::post_json(&addr, "/v1/completions", &completion_body(4, false))
                })
            })
            .collect();
        for h in batch {
            let resp = h.join().unwrap().expect("probe traffic flows");
            assert!((200..300).contains(&resp.status), "probes stay 2xx: {}", resp.status);
        }
        let scrape = loadgen::get(&addr, "/metrics").unwrap();
        let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
        let closes =
            counter(&samples, "enova_cluster_breaker_transitions_total", ("transition", "close"));
        let state = counter(&samples, "enova_cluster_breaker_state", ("node", "node-b"));
        if closes > 0.0 && state == 0.0 {
            break true;
        }
        if Instant::now() >= deadline {
            break false;
        }
        std::thread::sleep(Duration::from_millis(50));
    };
    assert!(recovered, "the cured node closed its breaker within the deadline");

    // the full open → half-open → close cycle is on the scrape...
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    for transition in ["open", "half_open", "close"] {
        assert!(
            counter(
                &samples,
                "enova_cluster_breaker_transitions_total",
                ("transition", transition)
            ) > 0.0,
            "transition {transition} counted"
        );
    }
    // ...and narrated in the flight recorder, served typed over HTTP
    let v1 = loadgen::get(&addr, "/v1/debug/decisions").unwrap();
    assert_eq!(v1.status, 200);
    let envelope = v1.json().unwrap();
    assert_eq!(envelope.get("api_version").and_then(Json::as_str), Some("v1"));
    let decisions = envelope
        .at(&["data", "decisions"])
        .and_then(Json::as_arr)
        .expect("decisions array in the typed envelope");
    for reason in ["open", "half_open", "close"] {
        assert!(
            decisions.iter().any(|d| {
                d.get("kind").and_then(Json::as_str) == Some("breaker")
                    && d.get("reason").and_then(Json::as_str) == Some(reason)
            }),
            "breaker {reason} recorded: {}",
            envelope.to_string_compact()
        );
    }

    // still two healthy nodes, still two replicas: recovery flapped nothing
    assert_eq!(coordinator.healthy_nodes(), 2);
    assert!(coordinator.wait_for_replicas(2, Duration::from_secs(2)));

    coordinator.shutdown();
    node_a.shutdown();
    node_b.shutdown();
}
