//! End-to-end tests of forecast-aware proactive scaling on the live
//! gateway: under a `diurnal` scenario the proactive planner pre-promotes
//! warm replicas *before* the ramp peak (the reactive detector only ever
//! reacts after it), and p95 time-in-queue under the same seeded traffic
//! beats the reactive-only baseline.

use enova::autoscaler::Action;
use enova::detect::ScaleDirection;
use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::loadgen::{run_scenario, ScenarioConfig, ScenarioKind};
use enova::gateway::supervisor::{ForecastPolicy, SupervisorConfig, Trigger};
use enova::gateway::{EngineSpawner, Gateway, GatewayConfig};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn sim_spawner(max_num_seqs: usize, step_delay_ms: u64) -> EngineSpawner {
    Arc::new(move |_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs,
            max_tokens: 64,
            step_delay: Duration::from_millis(step_delay_ms),
        })) as Box<dyn StreamEngine>)
    })
}

fn diurnal(seed: u64, peak_rps: f64, max_tokens: usize, workers: usize) -> ScenarioConfig {
    ScenarioConfig {
        kind: ScenarioKind::Diurnal,
        duration: Duration::from_secs(8),
        base_rps: 2.0,
        peak_rps,
        seed,
        workers,
        max_tokens,
        ..ScenarioConfig::default()
    }
}

/// The headline behavior: a predictable diurnal ramp makes the planner
/// promote warm standbys ahead of the peak — proactive counter over zero,
/// promotions dominated by `kind=warm`, and the scale event strictly
/// earlier than the λ(t) maximum.
#[test]
fn diurnal_forecast_prepromotes_warm_before_peak() {
    let cfg = GatewayConfig {
        max_pending: 1024,
        max_tokens_default: 8,
        monitor_interval: Duration::from_millis(25),
        warm_pool: 1,
        ..Default::default()
    };
    let sup = SupervisorConfig {
        sample_interval: Duration::from_millis(50),
        cooldown: Duration::from_millis(500),
        min_replicas: 1,
        max_replicas: 3,
        // this test must prove the *proactive* path: reactive loops off
        detector_scaling: false,
        reconfig: None,
        forecast: Some(ForecastPolicy {
            // 20 x 50ms = a one-second lead on demand
            horizon_steps: 20,
            season_steps: 0,
            err_budget: 50.0,
            replica_capacity_rps: 30.0,
            headroom: 0.0,
            min_warm: 1,
            trough_scale_down: false,
        }),
        ..Default::default()
    };
    let gw = Gateway::start_scalable(cfg, sim_spawner(4, 5), 1, Some(sup)).unwrap();
    let gw_t0 = Instant::now();
    let addr = gw.addr_string();
    let snap = gw.supervisor_snapshot();
    assert!(snap.enabled && snap.forecast_enabled);

    // base 2 rps climbing to 60 rps at t=4s: demand crosses the 30 rps
    // per-replica capacity around t≈2s, so a one-second-lead forecast
    // must fire well before the peak
    let scn = diurnal(7, 60.0, 4, 48);
    let scenario_offset = gw_t0.elapsed().as_secs_f64();
    let report = run_scenario(&addr, &scn);
    let peak_at = scenario_offset + scn.peak_time_secs();

    assert_eq!(report.errors, 0, "no transport errors: {}", report.summary());
    let non_2xx: usize = report
        .status_counts
        .iter()
        .filter(|&(&code, _)| !(200..300).contains(&code))
        .map(|(_, &n)| n)
        .sum();
    assert_eq!(non_2xx, 0, "clean run: {:?}", report.status_counts);

    let snap = gw.supervisor_snapshot();
    assert!(
        snap.proactive_events >= 1,
        "proactive scale-up counter must move: {snap:?}"
    );
    assert_eq!(snap.reactive_events, 0, "reactive loops were off: {snap:?}");
    assert!(gw.live_replicas().len() >= 2, "capacity was added: {:?}", gw.live_replicas());

    // every promotion came out of the warm pool (the standby is rebuilt
    // in the background between promotions)
    let (warm_promotions, warm_mean) = gw.promotion_stats(true);
    let (cold_promotions, _) = gw.promotion_stats(false);
    assert!(warm_promotions >= 1, "warm promotions observed");
    assert!(
        warm_promotions >= cold_promotions,
        "promotion histogram dominated by kind=warm: {warm_promotions} warm vs \
         {cold_promotions} cold"
    );
    assert!(
        warm_mean < 1.0,
        "warm promotion is O(route-update), not engine init: {warm_mean:.3}s"
    );

    // the first proactive event fired before the ramp peak
    let events = gw.scaling_events();
    let ev = events
        .iter()
        .find(|e| e.trigger == Trigger::Forecast)
        .expect("a forecast-triggered event exists");
    assert_eq!(ev.direction, ScaleDirection::Up);
    assert_eq!(ev.action, Action::AddReplica);
    assert!(
        ev.at < peak_at,
        "pre-promotion at t={:.2}s must precede the peak at t={:.2}s",
        ev.at,
        peak_at
    );

    gw.shutdown();
}

/// One run of the comparison harness: same gateway shape, same seeded
/// diurnal traffic; only the forecast policy differs. Returns the p95
/// time-in-queue estimate and the supervisor snapshot.
fn run_diurnal(forecast: bool, seed: u64) -> (f64, enova::gateway::supervisor::SupervisorSnapshot) {
    let cfg = GatewayConfig {
        max_pending: 2048,
        max_tokens_default: 8,
        monitor_interval: Duration::from_millis(25),
        warm_pool: 1,
        ..Default::default()
    };
    let sup = SupervisorConfig {
        sample_interval: Duration::from_millis(50),
        // a deliberately laggy reactive loop — the cold-start chase the
        // paper's motivation describes: ~2s calibration, then patience
        calib_samples: 40,
        patience: 4,
        cooldown: Duration::from_secs(2),
        min_replicas: 1,
        max_replicas: 3,
        // the queue guard is a reactive shortcut; disable it in both runs
        // so the comparison isolates forecast-vs-detector
        queue_wait_budget: Duration::from_secs(3600),
        detector_scaling: true,
        reconfig: None,
        forecast: forecast.then(|| ForecastPolicy {
            horizon_steps: 20,
            season_steps: 0,
            err_budget: 10.0,
            replica_capacity_rps: 20.0,
            headroom: 0.1,
            min_warm: 1,
            trough_scale_down: false,
        }),
    };
    // two 10ms-step slots ≈ 25 rps per replica at 8 tokens: one replica
    // is far under the 60 rps peak, so the baseline *must* queue
    let gw = Gateway::start_scalable(cfg, sim_spawner(2, 10), 1, Some(sup)).unwrap();
    let addr = gw.addr_string();
    let report = run_scenario(&addr, &diurnal(seed, 60.0, 8, 64));
    assert_eq!(report.errors, 0, "no transport errors: {}", report.summary());
    let p95 = gw.queue_wait_quantile(0.95);
    let snap = gw.supervisor_snapshot();
    gw.shutdown();
    (p95, snap)
}

/// Identical seeds, identical gateways: the forecast-driven run keeps p95
/// time-in-queue at or below the reactive-only baseline, because capacity
/// arrives before the peak instead of after the detector notices it.
#[test]
fn forecast_p95_queue_wait_beats_reactive_baseline_at_same_seed() {
    let seed = 1234;
    let (reactive_p95, reactive_snap) = run_diurnal(false, seed);
    let (forecast_p95, forecast_snap) = run_diurnal(true, seed);

    assert_eq!(
        reactive_snap.proactive_events, 0,
        "baseline has no proactive planner: {reactive_snap:?}"
    );
    assert!(
        forecast_snap.proactive_events >= 1,
        "forecast run pre-promoted: {forecast_snap:?}"
    );
    assert!(
        reactive_p95 >= 0.05,
        "the baseline must actually queue under the peak (p95 {reactive_p95:.3}s)"
    );
    assert!(
        forecast_p95 <= reactive_p95,
        "proactive p95 time-in-queue ({forecast_p95:.3}s) must not exceed the reactive-only \
         baseline ({reactive_p95:.3}s)"
    );
}
