//! End-to-end tests of the distributed serving plane: a coordinator and
//! two in-process nodes over real sockets. Under a `spike` scenario the
//! cluster supervisor's scale-up is *placed* on the less-loaded node
//! (spread anti-affinity), and killing a node mid-run sheds nothing — the
//! coordinator re-routes in-flight traffic to the survivor and backfills
//! the lost replica there.

use enova::cluster::coordinator::{ClusterPolicy, Coordinator, CoordinatorConfig};
use enova::cluster::node::{NodeConfig, NodeServer};
use enova::cluster::NodeIdentity;
use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::loadgen::{self, run_scenario, LoadgenReport, ScenarioConfig, ScenarioKind};
use enova::gateway::metrics::parse_exposition;
use enova::gateway::supervisor::ForecastPolicy;
use enova::gateway::{EngineSpawner, GatewayConfig};
use enova::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

fn sim_spawner() -> EngineSpawner {
    Arc::new(|_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 64,
            step_delay: Duration::from_millis(2),
        })) as Box<dyn StreamEngine>)
    })
}

fn node_config(id: &str, coordinator: &str, initial_replicas: usize) -> NodeConfig {
    NodeConfig {
        gateway: GatewayConfig {
            max_pending: 1024,
            max_tokens_default: 8,
            monitor_interval: Duration::from_millis(25),
            warm_pool: 1,
            ..GatewayConfig::default()
        },
        identity: NodeIdentity {
            node_id: id.to_string(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 3,
            replica_capacity_rps: 0.0,
        },
        initial_replicas,
        coordinator: Some(coordinator.to_string()),
        announce_interval: Duration::from_millis(100),
        advertise_addr: None,
    }
}

fn non_2xx(report: &LoadgenReport) -> usize {
    report
        .status_counts
        .iter()
        .filter(|&(&code, _)| !(200..300).contains(&code))
        .map(|(_, &n)| n)
        .sum()
}

/// The headline placement behavior: a spike drives the forecast planner
/// over per-replica capacity, and the resulting scale-up lands on the
/// *emptier* node (node-b with 1 replica, not node-a with 2) — spread
/// anti-affinity over free gpu_memory, decided at the coordinator.
#[test]
fn spike_scale_up_lands_on_the_emptier_node() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(50),
        node_timeout_beats: 4,
        max_pending: 2048,
        policy: ClusterPolicy {
            sample_interval: Duration::from_millis(50),
            cooldown: Duration::from_millis(400),
            min_replicas: 1,
            max_replicas: 6,
            // this test must prove the *placement* of proactive
            // decisions; the reactive detector stays off
            detector_scaling: false,
            forecast: Some(ForecastPolicy {
                horizon_steps: 4,
                season_steps: 0,
                err_budget: 50.0,
                replica_capacity_rps: 6.0,
                headroom: 0.0,
                min_warm: 0,
                trough_scale_down: false,
            }),
            ..ClusterPolicy::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    // node-a carries 2 replicas, node-b only 1: the next placement must
    // prefer node-b
    let node_a = NodeServer::start(node_config("node-a", &addr, 2), sim_spawner()).unwrap();
    let node_b = NodeServer::start(node_config("node-b", &addr, 1), sim_spawner()).unwrap();
    assert!(
        coordinator.wait_for_nodes(2, Duration::from_secs(10)),
        "both nodes registered and serving"
    );
    assert!(
        coordinator.wait_for_replicas(3, Duration::from_secs(10)),
        "heartbeats observed all 3 initial replicas"
    );

    let scn = ScenarioConfig {
        kind: ScenarioKind::Spike,
        duration: Duration::from_secs(8),
        base_rps: 2.0,
        peak_rps: 30.0,
        spike_start: 0.3,
        spike_len: 0.5,
        seed: 7,
        workers: 48,
        max_tokens: 4,
        ..ScenarioConfig::default()
    };
    let report = run_scenario(&addr, &scn);
    assert_eq!(report.errors, 0, "no transport errors: {}", report.summary());
    assert_eq!(non_2xx(&report), 0, "clean run: {:?}", report.status_counts);

    // the spike produced at least one placement, and the first landed on
    // the emptier node
    let placements = coordinator.placements();
    let first_up = placements
        .iter()
        .find(|p| p.up)
        .expect("the spike forced at least one placement");
    assert_eq!(first_up.node_id, "node-b", "spread anti-affinity: {placements:?}");
    assert_eq!(first_up.reason, "forecast", "the proactive planner placed it");
    assert!(
        coordinator.replicas_on("node-b") >= 2,
        "node-b grew: {:?}",
        coordinator.nodes()
    );
    assert!(node_b.gateway().live_replicas().len() >= 2, "the node really scaled");

    // the coordinator's scrape speaks the cluster vocabulary
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    assert_eq!(scrape.status, 200);
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    let value = |name: &str| {
        samples
            .iter()
            .find(|s| s.name == name)
            .unwrap_or_else(|| panic!("missing {name}"))
            .value
    };
    assert_eq!(value("enova_cluster_nodes"), 2.0);
    let placement_total: f64 = samples
        .iter()
        .filter(|s| s.name == "enova_cluster_placement_total")
        .map(|s| s.value)
        .sum();
    assert!(placement_total >= 1.0, "placement counter moved");
    for node in ["node-a", "node-b"] {
        assert!(
            samples.iter().any(|s| s.name == "enova_cluster_replicas_per_node"
                && s.labels.get("node").map(String::as_str) == Some(node)),
            "missing per-node replica gauge for {node}"
        );
    }

    coordinator.shutdown();
    node_a.shutdown();
    node_b.shutdown();
}

/// Kill a node mid-run: the loadgen report still shows zero transport
/// errors and zero non-2xx (unary requests re-dispatch to the survivor),
/// the coordinator declares the node dead, and the lost replica is
/// backfilled on the surviving node.
#[test]
fn killing_a_node_mid_run_sheds_nothing() {
    let coordinator = Coordinator::start(CoordinatorConfig {
        heartbeat_interval: Duration::from_millis(50),
        node_timeout_beats: 2,
        max_pending: 2048,
        dispatch_attempts: 4,
        policy: ClusterPolicy {
            sample_interval: Duration::from_millis(50),
            // reactive/proactive loops off: this test isolates routing,
            // death detection and backfill
            detector_scaling: false,
            forecast: None,
            cooldown: Duration::from_secs(30),
            min_replicas: 1,
            max_replicas: 4,
            ..ClusterPolicy::default()
        },
        ..CoordinatorConfig::default()
    })
    .unwrap();
    let addr = coordinator.addr_string();

    let node_a = NodeServer::start(node_config("node-a", &addr, 1), sim_spawner()).unwrap();
    let node_b = NodeServer::start(node_config("node-b", &addr, 1), sim_spawner()).unwrap();
    assert!(coordinator.wait_for_nodes(2, Duration::from_secs(10)));
    assert!(coordinator.wait_for_replicas(2, Duration::from_secs(10)));

    // steady traffic through the whole incident
    let scn = ScenarioConfig {
        kind: ScenarioKind::Steady,
        duration: Duration::from_secs(6),
        base_rps: 6.0,
        peak_rps: 6.0,
        seed: 13,
        workers: 32,
        max_tokens: 4,
        ..ScenarioConfig::default()
    };
    let loadgen_addr = addr.clone();
    let driver = std::thread::spawn(move || run_scenario(&loadgen_addr, &scn));

    // kill node-b a third of the way in
    std::thread::sleep(Duration::from_millis(2000));
    node_b.shutdown();

    let report = driver.join().unwrap();
    assert_eq!(
        report.errors, 0,
        "zero transport errors through the node death: {}",
        report.summary()
    );
    assert_eq!(
        non_2xx(&report),
        0,
        "zero non-2xx through the node death: {:?}",
        report.status_counts
    );

    // the coordinator noticed the death...
    let deadline = std::time::Instant::now() + Duration::from_secs(5);
    while coordinator.healthy_nodes() != 1 && std::time::Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(coordinator.healthy_nodes(), 1, "node-b declared dead");
    // ...and backfilled the lost replica on the survivor
    assert!(
        coordinator.wait_for_replicas(2, Duration::from_secs(5)),
        "backfill restored 2 replicas: {:?}",
        coordinator.nodes()
    );
    assert!(coordinator.placements_for("backfill") >= 1, "backfill counter moved");
    let backfill = coordinator
        .placements()
        .into_iter()
        .find(|p| p.reason == "backfill")
        .expect("a backfill placement event exists");
    assert_eq!(backfill.node_id, "node-a", "backfill landed on the survivor");
    assert!(
        node_a.gateway().live_replicas().len() >= 2,
        "the survivor really grew: {:?}",
        node_a.gateway().live_replicas()
    );

    coordinator.shutdown();
    node_a.shutdown();
}

/// The node control surface stands alone: status is a parseable
/// advertisement, scale-up adds a live replica (and accounts memory),
/// scale-down drains the newest, and the last replica is refused with a
/// 409 — placement invariants enforced at the node boundary too.
#[test]
fn node_control_surface_scales_and_refuses_the_floor() {
    let node = NodeServer::start(
        NodeConfig {
            identity: NodeIdentity {
                node_id: "solo".into(),
                gpu_memory_total: 16.0,
                replica_gpu_memory: 8.0,
                max_replicas: 2,
                replica_capacity_rps: 0.0,
            },
            initial_replicas: 1,
            coordinator: None,
            ..NodeConfig::default()
        },
        sim_spawner(),
    )
    .unwrap();
    let addr = node.addr_string();

    let status = loadgen::get(&addr, "/cluster/status").unwrap();
    assert_eq!(status.status, 200);
    let j = status.json().unwrap();
    assert_eq!(j.get("node_id").and_then(Json::as_str), Some("solo"));
    assert_eq!(j.get("live_replicas").and_then(Json::as_usize), Some(1));
    assert_eq!(j.get("gpu_memory_free").and_then(Json::as_f64), Some(8.0));

    // scale up to the ceiling
    let up = loadgen::post_json(&addr, "/cluster/scale-up", "{}").unwrap();
    assert_eq!(up.status, 200, "{}", up.body_str());
    assert_eq!(node.gateway().live_replicas().len(), 2);
    let full = loadgen::post_json(&addr, "/cluster/scale-up", "{}").unwrap();
    assert_eq!(full.status, 409, "at the ceiling: {}", full.body_str());
    let status = loadgen::get(&addr, "/cluster/status").unwrap();
    assert_eq!(
        status.json().unwrap().get("gpu_memory_free").and_then(Json::as_f64),
        Some(0.0),
        "memory accounting followed the scale-up"
    );

    // drain back down; the floor is refused
    let down = loadgen::post_json(&addr, "/cluster/scale-down", "{}").unwrap();
    assert_eq!(down.status, 200, "{}", down.body_str());
    assert_eq!(node.gateway().live_replicas().len(), 1);
    let floor = loadgen::post_json(&addr, "/cluster/scale-down", "{}").unwrap();
    assert_eq!(floor.status, 409, "last replica refused: {}", floor.body_str());

    // a non-node gateway hides the control surface entirely (404), which
    // this node does not
    let missing = loadgen::get(&addr, "/cluster/nope").unwrap();
    assert_eq!(missing.status, 404);

    node.shutdown();
}
