//! End-to-end tests of multi-tenant SLO- and cost-aware serving on the
//! live gateway: a latency-tier tenant rides the fast lane past a batch
//! tenant's saturation, the per-tenant GPU-seconds ledger stays consistent
//! with the gateway-wide replica-seconds meter and the `/metrics` scrape,
//! the cost-aware trough scale-down retires paid-for capacity earlier than
//! the keep-everything baseline, and the versioned `/v1/admin/*` control
//! surface answers typed JSON while the deprecated aliases keep working.

use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::loadgen::{self, Client};
use enova::gateway::metrics::parse_exposition;
use enova::gateway::supervisor::{ForecastPolicy, SupervisorConfig};
use enova::gateway::{EngineSpawner, Gateway, GatewayConfig};
use enova::util::json::Json;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn sim_spawner(max_num_seqs: usize, step_delay_ms: u64) -> EngineSpawner {
    Arc::new(move |_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs,
            max_tokens: 64,
            step_delay: Duration::from_millis(step_delay_ms),
        })) as Box<dyn StreamEngine>)
    })
}

fn tenant_header(tenant: &str) -> String {
    format!("x-enova-tenant: {tenant}\r\n")
}

fn p95(mut xs: Vec<f64>) -> f64 {
    assert!(!xs.is_empty(), "no samples to take a p95 of");
    xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
    xs[((xs.len() as f64 * 0.95) as usize).min(xs.len() - 1)]
}

/// The headline multi-tenant behavior: one 2-slot replica saturated by a
/// batch tenant's closed loop, while a latency tenant's probes arrive on
/// the side. The fast lane lets `chat` overtake the queued `codegen`
/// backlog, so its p95 stays far below the batch tenant's.
#[test]
fn latency_tenant_holds_slo_under_batch_saturation() {
    let cfg = GatewayConfig {
        max_pending: 1024,
        max_tokens_default: 8,
        monitor_interval: Duration::from_millis(25),
        ..Default::default()
    };
    let gw = Gateway::start_scalable(cfg, sim_spawner(2, 10), 1, None).unwrap();
    let addr = gw.addr_string();
    let body = r#"{"prompt": "tenants", "max_tokens": 8}"#;

    // 12 closed-loop batch workers against 2 engine slots with 10ms steps:
    // a standing slow-lane backlog for the whole probe window
    let stop = Arc::new(AtomicBool::new(false));
    let mut workers = Vec::new();
    for _ in 0..12 {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        workers.push(std::thread::spawn(move || {
            let mut client = Client::new(&addr);
            let hdr = tenant_header("codegen");
            let mut lat_ms = Vec::new();
            let mut non_200 = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let t0 = Instant::now();
                match client.request_headed("POST", "/v1/completions", Some(body), &hdr) {
                    Ok(r) if r.status == 200 => lat_ms.push(t0.elapsed().as_secs_f64() * 1e3),
                    Ok(_) => non_200 += 1,
                    Err(_) => non_200 += 1,
                }
            }
            (lat_ms, non_200)
        }));
    }

    // let the backlog build, then probe as the latency tenant
    std::thread::sleep(Duration::from_millis(600));
    let mut probe = Client::new(&addr);
    let hdr = tenant_header("chat");
    let mut chat_ms = Vec::new();
    for _ in 0..60 {
        let t0 = Instant::now();
        let r = probe.request_headed("POST", "/v1/completions", Some(body), &hdr).unwrap();
        assert_eq!(r.status, 200, "latency tenant never shed: {}", r.body_str());
        chat_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        std::thread::sleep(Duration::from_millis(30));
    }
    stop.store(true, Ordering::Relaxed);
    let mut batch_ms = Vec::new();
    for w in workers {
        let (lat, non_200) = w.join().unwrap();
        assert_eq!(non_200, 0, "nothing shed: headroom covers both tenants");
        batch_ms.extend(lat);
    }

    let chat_p95 = p95(chat_ms);
    let batch_p95 = p95(batch_ms);
    assert!(
        chat_p95 < batch_p95,
        "fast lane: chat p95 {chat_p95:.0}ms must undercut batch p95 {batch_p95:.0}ms"
    );
    assert!(
        chat_p95 < 1500.0,
        "latency tier stays responsive under batch saturation: p95 {chat_p95:.0}ms"
    );

    // the tiers really were resolved from the header, not defaulted
    let snaps = gw.tenant_snapshots();
    let by_id = |id: &str| snaps.iter().find(|s| s.id == id).unwrap().clone();
    assert!(by_id("chat").admitted >= 60);
    assert!(by_id("codegen").admitted as usize >= 12);
    assert_eq!(by_id("default").admitted, 0, "every request carried a tenant");

    gw.shutdown();
}

/// Cost-ledger consistency, driven strictly sequentially so billed
/// submit→completion windows never overlap: every active tenant accrues
/// GPU-seconds, their sum never exceeds the gateway's replica-seconds
/// meter, and the `/metrics` scrape tells the same story as the in-process
/// snapshots.
#[test]
fn tenant_cost_ledger_is_consistent_with_replica_seconds_and_metrics() {
    let cfg = GatewayConfig {
        max_pending: 256,
        max_tokens_default: 8,
        monitor_interval: Duration::from_millis(25),
        ..Default::default()
    };
    let gw = Gateway::start_scalable(cfg, sim_spawner(4, 2), 1, None).unwrap();
    let addr = gw.addr_string();
    let body = r#"{"prompt": "ledger", "max_tokens": 8}"#;

    let mut client = Client::new(&addr);
    for _ in 0..30 {
        for tenant in ["chat", "codegen"] {
            let r = client
                .request_headed("POST", "/v1/completions", Some(body), &tenant_header(tenant))
                .unwrap();
            assert_eq!(r.status, 200, "{}", r.body_str());
        }
    }
    // a few monitoring flushes so the replica-seconds integrator and the
    // metric gauges catch up with the last completion
    std::thread::sleep(Duration::from_millis(150));

    let snaps = gw.tenant_snapshots();
    let by_id = |id: &str| snaps.iter().find(|s| s.id == id).unwrap().clone();
    assert_eq!(by_id("chat").admitted, 30);
    assert_eq!(by_id("codegen").admitted, 30);
    assert!(by_id("chat").gpu_seconds > 0.0, "chat accrued GPU time");
    assert!(by_id("codegen").gpu_seconds > 0.0, "codegen accrued GPU time");

    let billed: f64 = snaps.iter().map(|s| s.gpu_seconds).sum();
    let ran = gw.replica_seconds();
    assert!(ran > 0.0, "the replica-seconds meter moved");
    assert!(
        billed <= ran + 0.1,
        "sequential billing cannot exceed replica wall-clock: {billed:.3}s billed vs \
         {ran:.3}s run"
    );

    // the scrape speaks the same ledger
    let scrape = loadgen::get(&addr, "/metrics").unwrap();
    assert_eq!(scrape.status, 200);
    let samples = parse_exposition(&scrape.body_str()).expect("valid exposition");
    let tenant_sample = |name: &str, tenant: &str| {
        samples
            .iter()
            .find(|s| {
                s.name == name && s.labels.get("tenant").map(String::as_str) == Some(tenant)
            })
            .unwrap_or_else(|| panic!("missing {name}{{tenant=\"{tenant}\"}}"))
    };
    let chat_requests = tenant_sample("enova_tenant_requests_total", "chat");
    assert_eq!(chat_requests.value, 30.0);
    assert_eq!(
        chat_requests.labels.get("tier").map(String::as_str),
        Some("latency"),
        "the tier label rides along"
    );
    assert!(tenant_sample("enova_tenant_gpu_seconds_total", "chat").value > 0.0);
    assert!(tenant_sample("enova_tenant_gpu_seconds_total", "codegen").value > 0.0);
    let meter = samples
        .iter()
        .find(|s| s.name == "enova_replica_seconds_total")
        .expect("missing enova_replica_seconds_total");
    assert!(meter.value > 0.0);

    gw.shutdown();
}

/// One run of the trough comparison: 3 live replicas, light steady
/// latency-tier traffic far under per-replica capacity, reactive loops
/// off, forecast on. Only `trough_scale_down` differs between runs.
fn run_trough(trough: bool) -> (f64, u64, usize) {
    let cfg = GatewayConfig {
        max_pending: 1024,
        max_tokens_default: 8,
        monitor_interval: Duration::from_millis(25),
        ..Default::default()
    };
    let sup = SupervisorConfig {
        sample_interval: Duration::from_millis(50),
        cooldown: Duration::from_millis(300),
        min_replicas: 1,
        max_replicas: 3,
        // this test must prove the *trough* path: reactive loops off
        detector_scaling: false,
        queue_wait_budget: Duration::from_secs(3600),
        reconfig: None,
        forecast: Some(ForecastPolicy {
            horizon_steps: 4,
            season_steps: 0,
            err_budget: 50.0,
            replica_capacity_rps: 30.0,
            headroom: 0.0,
            min_warm: 0,
            trough_scale_down: trough,
        }),
        ..Default::default()
    };
    let gw = Gateway::start_scalable(cfg, sim_spawner(4, 2), 3, Some(sup)).unwrap();
    let addr = gw.addr_string();
    assert_eq!(gw.live_replicas().len(), 3);

    // ~20 rps of latency-tier traffic against 3 x 30 rps of capacity: a
    // standing trough both forecast views agree on
    let mut client = Client::new(&addr);
    let hdr = tenant_header("chat");
    let body = r#"{"prompt": "trough", "max_tokens": 8}"#;
    let deadline = Instant::now() + Duration::from_secs(4);
    while Instant::now() < deadline {
        let r = client.request_headed("POST", "/v1/completions", Some(body), &hdr).unwrap();
        assert_eq!(r.status, 200, "{}", r.body_str());
        std::thread::sleep(Duration::from_millis(25));
    }
    std::thread::sleep(Duration::from_millis(150));

    let snap = gw.supervisor_snapshot();
    let replica_seconds = gw.replica_seconds();
    let live = gw.live_replicas().len();
    gw.shutdown();
    (replica_seconds, snap.trough_events, live)
}

/// The cost story of the trough scale-down: with both forecast views
/// agreeing demand fits fewer replicas, the trough run retires capacity
/// the baseline keeps paying for — strictly fewer replica-seconds over the
/// same traffic, while serving every request.
#[test]
fn trough_scale_down_spends_fewer_replica_seconds_than_keeping_capacity() {
    let (base_rs, base_troughs, base_live) = run_trough(false);
    let (trough_rs, troughs, live) = run_trough(true);

    assert_eq!(base_troughs, 0, "baseline never trough-retires");
    assert_eq!(base_live, 3, "baseline keeps all paid-for capacity");
    assert!(troughs >= 1, "the trough counter moved");
    assert!(live < 3, "the trough run really retired: {live} live");
    assert!(
        trough_rs < base_rs,
        "trough run must be cheaper: {trough_rs:.2} vs {base_rs:.2} replica-seconds"
    );
}

/// The versioned control surface on a plain gateway: `/v1/admin/status`
/// and `/v1/admin/scale` answer the typed JSON bodies from
/// `cluster::proto`, errors carry `{code, message, details}`, node-only
/// endpoints refuse with a structured 404 — and every deprecated alias
/// still answers its pre-v1 contract.
#[test]
fn versioned_admin_api_answers_typed_json_and_aliases_still_work() {
    let cfg = GatewayConfig {
        max_pending: 256,
        max_tokens_default: 8,
        monitor_interval: Duration::from_millis(25),
        ..Default::default()
    };
    let gw = Gateway::start_scalable(cfg, sim_spawner(2, 2), 2, None).unwrap();
    let addr = gw.addr_string();

    // GET /v1/admin/status: the typed NodeStatus advertisement
    let status = loadgen::get(&addr, "/v1/admin/status").unwrap();
    assert_eq!(status.status, 200);
    let j = status.json().unwrap();
    assert_eq!(j.get("live_replicas").and_then(Json::as_usize), Some(2));
    assert!(j.get("arrival_rps").is_some(), "status advertises arrival_rps");
    assert!(j.get("batch_rps").is_some(), "status advertises batch_rps");
    assert!(j.get("ready").is_some(), "status advertises readiness");

    // POST /v1/admin/scale: typed request in, typed response out
    let ok = loadgen::post_json(
        &addr,
        "/v1/admin/scale",
        r#"{"replicas": [{"id": 0, "weight": 2.0}, {"id": 1, "weight": 1.0}]}"#,
    )
    .unwrap();
    assert_eq!(ok.status, 200, "{}", ok.body_str());
    let j = ok.json().unwrap();
    assert_eq!(j.get("routable_replicas").and_then(Json::as_usize), Some(2));
    assert_eq!(j.get("applied").and_then(Json::as_arr).map(Vec::len), Some(2));

    // a v1 validation failure is the structured {code, message, details}
    let bad = loadgen::post_json(&addr, "/v1/admin/scale", r#"{"replicas": []}"#).unwrap();
    assert_eq!(bad.status, 400);
    let j = bad.json().unwrap();
    assert_eq!(j.get("code").and_then(Json::as_str), Some("invalid_request"));
    assert!(j.get("message").and_then(Json::as_str).is_some());

    let unknown = loadgen::post_json(
        &addr,
        "/v1/admin/scale",
        r#"{"replicas": [{"id": 99, "weight": 1.0}]}"#,
    )
    .unwrap();
    assert_eq!(unknown.status, 400);
    let j = unknown.json().unwrap();
    assert_eq!(j.get("code").and_then(Json::as_str), Some("unknown_replica"));

    // the same failure on the deprecated alias keeps the OpenAI-style
    // envelope its existing callers parse
    let legacy_unknown = loadgen::post_json(
        &addr,
        "/admin/scale",
        r#"{"replicas": [{"id": 99, "weight": 1.0}]}"#,
    )
    .unwrap();
    assert_eq!(legacy_unknown.status, 400);
    let j = legacy_unknown.json().unwrap();
    assert!(j.get("error").is_some(), "legacy alias keeps the error envelope");
    assert!(j.get("code").is_none(), "legacy alias does not leak the v1 shape");

    // node-only surface off node mode: a structured 404 on v1
    let not_node = loadgen::post_json(&addr, "/v1/admin/scale-up", "{}").unwrap();
    assert_eq!(not_node.status, 404);
    let j = not_node.json().unwrap();
    assert_eq!(j.get("code").and_then(Json::as_str), Some("not_a_node"));

    // the deprecated aliases still answer their pre-v1 contracts
    let legacy_ok = loadgen::post_json(
        &addr,
        "/admin/scale",
        r#"{"replicas": [{"id": 0, "weight": 1.0}, {"id": 1, "weight": 1.0}]}"#,
    )
    .unwrap();
    assert_eq!(legacy_ok.status, 200, "{}", legacy_ok.body_str());
    assert_eq!(
        legacy_ok.json().unwrap().get("routable_replicas").and_then(Json::as_usize),
        Some(2)
    );
    let legacy_status = loadgen::get(&addr, "/cluster/status").unwrap();
    assert_eq!(legacy_status.status, 404, "status alias stays node-only off node mode");

    gw.shutdown();
}
