//! End-to-end tests of the closed autoscaling loop on the live gateway,
//! over real sockets against the deterministic sim engine: sustained
//! overload → the detector fires → an engine worker is hot-spawned and
//! receives traffic → retirement drains without dropping in-flight work.

use enova::autoscaler::Action;
use enova::detect::ScaleDirection;
use enova::engine::sim::{SimEngine, SimEngineConfig};
use enova::engine::StreamEngine;
use enova::gateway::supervisor::{SupervisorConfig, Trigger};
use enova::gateway::{loadgen, EngineSpawner, Gateway, GatewayConfig};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

fn sim_spawner(max_num_seqs: usize, step_delay_ms: u64) -> EngineSpawner {
    Arc::new(move |_id| {
        Ok(Box::new(SimEngine::new(SimEngineConfig {
            max_num_seqs,
            max_tokens: 64,
            step_delay: Duration::from_millis(step_delay_ms),
        })) as Box<dyn StreamEngine>)
    })
}

/// The full live loop, deterministically: calibrate on healthy traffic,
/// overload, watch the detector hot-spawn a replica that then serves
/// traffic, and verify p95 TTFT recovers within the test horizon.
#[test]
fn overload_triggers_detector_scale_up_and_ttft_recovers() {
    let cfg = GatewayConfig {
        max_pending: 512,
        max_tokens_default: 16,
        monitor_interval: Duration::from_millis(25),
        ..Default::default()
    };
    let sup = SupervisorConfig {
        sample_interval: Duration::from_millis(50),
        calib_samples: 20,
        patience: 2,
        cooldown: Duration::from_secs(2),
        min_replicas: 1,
        max_replicas: 3,
        // out of the way: this test must prove the *detector* path
        queue_wait_budget: Duration::from_secs(3600),
        detector_scaling: true,
        reconfig: None,
        forecast: None,
    };
    let gw = Gateway::start_scalable(cfg, sim_spawner(2, 10), 1, Some(sup)).unwrap();
    let addr = gw.addr_string();
    assert!(gw.supervisor_snapshot().enabled);

    // phase 1 — calibration: light sequential traffic gives the detector
    // a healthy baseline with natural frame variance
    let mut client = loadgen::Client::new(&addr);
    let deadline = Instant::now() + Duration::from_secs(30);
    while !gw.supervisor_snapshot().calibrated {
        let r = client
            .post_json("/v1/completions", "{\"prompt\": \"calibration\", \"max_tokens\": 2}")
            .unwrap();
        assert_eq!(r.status, 200);
        assert!(Instant::now() < deadline, "supervisor never calibrated");
        std::thread::sleep(Duration::from_millis(20));
    }
    assert_eq!(gw.live_replicas(), vec![0], "healthy traffic must not scale");

    // phase 2 — sustained overload: 16 closed-loop workers against one
    // 2-slot engine with 10ms steps pushes n^p far outside calibration
    let stop = Arc::new(AtomicBool::new(false));
    let mut load = Vec::new();
    for w in 0..16 {
        let addr = addr.clone();
        let stop = Arc::clone(&stop);
        load.push(std::thread::spawn(move || {
            let mut client = loadgen::Client::new(&addr);
            let mut k = 0usize;
            while !stop.load(Ordering::Relaxed) {
                let body =
                    format!("{{\"prompt\": \"overload w{w} r{k}\", \"max_tokens\": 24}}");
                let _ = client.post_json("/v1/completions", &body);
                k += 1;
            }
        }));
    }

    let deadline = Instant::now() + Duration::from_secs(60);
    while gw.live_replicas().len() < 2 {
        assert!(
            Instant::now() < deadline,
            "no scale-up within the horizon; snapshot: {:?}",
            gw.supervisor_snapshot()
        );
        std::thread::sleep(Duration::from_millis(25));
    }
    let events = gw.scaling_events();
    assert!(!events.is_empty());
    let ev = &events[0];
    assert_eq!(ev.direction, ScaleDirection::Up);
    assert_eq!(ev.action, Action::AddReplica);
    assert_eq!(ev.trigger, Trigger::Detector, "detector, not the queue guard");
    assert!(ev.energy > ev.threshold, "{ev:?}");
    assert!(ev.replicas_after >= 2);

    // the hot-spawned worker receives traffic
    let new_id = ev.replica_id;
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let served = gw
            .replica_stats()
            .iter()
            .any(|&(id, _, dispatched)| id == new_id && dispatched > 0);
        if served {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "hot-added replica {new_id} never dispatched to: {:?}",
            gw.replica_stats()
        );
        std::thread::sleep(Duration::from_millis(25));
    }

    // ...and its Table II frames appear on the scrape
    let scrape = client.get("/metrics").unwrap();
    assert!(scrape
        .body_str()
        .contains(&format!("instance=\"replica-{new_id}\"")));

    stop.store(true, Ordering::Relaxed);
    for h in load {
        let _ = h.join();
    }

    // phase 3 — recovery: with the scaled-out set and the burst over, p95
    // TTFT (~= unary latency at max_tokens 1) is back to interactive
    let mut lat: Vec<f64> = Vec::new();
    for k in 0..20 {
        let t0 = Instant::now();
        let r = client
            .post_json(
                "/v1/completions",
                &format!("{{\"prompt\": \"probe {k}\", \"max_tokens\": 1}}"),
            )
            .unwrap();
        assert_eq!(r.status, 200);
        lat.push(t0.elapsed().as_secs_f64());
    }
    lat.sort_by(f64::total_cmp);
    let p95 = lat[(lat.len() * 95 / 100).min(lat.len() - 1)];
    assert!(p95 < 2.0, "p95 TTFT did not recover within the horizon: {p95:.3}s");

    gw.shutdown();
}

/// Replica lifecycle without the supervisor: hot-add serves traffic, and
/// the retire path drains without dropping an in-flight request. Also the
/// /admin/scale regression: a retired id is rejected with a 400 naming it.
#[test]
fn hot_add_then_drain_retire_without_dropping_inflight() {
    let gw = Gateway::start_scalable(
        GatewayConfig {
            max_tokens_default: 64,
            ..Default::default()
        },
        sim_spawner(4, 10),
        1,
        None,
    )
    .unwrap();
    let addr = gw.addr_string();
    assert_eq!(gw.live_replicas(), vec![0]);

    let added = gw.add_replica().unwrap();
    assert_eq!(added, 1);
    assert_eq!(gw.live_replicas(), vec![0, 1]);
    let ready = loadgen::get(&addr, "/ready").unwrap();
    assert_eq!(ready.status, 200, "{}", ready.body_str());
    assert!(ready.body_str().contains("\"replicas\":2"));

    // park one slow request on each replica, staggered so least-loaded
    // dispatch deterministically picks the idle one the second time
    let slow = "{\"prompt\": \"hold during retire\", \"max_tokens\": 150}";
    let mut holders = Vec::new();
    for round in 1..=2u64 {
        let addr = addr.clone();
        holders.push(std::thread::spawn(move || {
            loadgen::post_json(&addr, "/v1/completions", slow)
        }));
        let deadline = Instant::now() + Duration::from_secs(10);
        loop {
            let stats = gw.replica_stats();
            let busy = stats.iter().filter(|&&(_, inflight, _)| inflight >= 1).count();
            if busy as u64 >= round {
                break;
            }
            assert!(Instant::now() < deadline, "round {round} not placed: {stats:?}");
            std::thread::sleep(Duration::from_millis(5));
        }
    }
    let stats = gw.replica_stats();
    assert!(
        stats.iter().all(|&(_, inflight, _)| inflight == 1),
        "one held request per replica: {stats:?}"
    );

    // retire the busy new replica: blocks until its in-flight request
    // finished — nothing is dropped
    gw.retire_replica(added).unwrap();
    assert_eq!(gw.live_replicas(), vec![0]);
    for h in holders {
        let resp = h.join().unwrap().unwrap();
        assert_eq!(resp.status, 200, "drained, not dropped: {}", resp.body_str());
        let tokens = resp
            .json()
            .unwrap()
            .at(&["usage", "completion_tokens"])
            .and_then(enova::util::json::Json::as_usize);
        assert_eq!(tokens, Some(64), "the drained request ran to completion");
    }

    // satellite regression: the ingress-update path validates ids against
    // live workers and names the unknown ones
    let bad = loadgen::post_json(
        &addr,
        "/admin/scale",
        "{\"replicas\": [{\"id\": 0, \"weight\": 1.0}, {\"id\": 1, \"weight\": 1.0}]}",
    )
    .unwrap();
    assert_eq!(bad.status, 400, "retired replica must not be weightable");
    let msg = bad.body_str();
    assert!(msg.contains("unknown replica ids [1]"), "names the dead id: {msg}");
    assert!(msg.contains("live replicas are [0]"), "names the live set: {msg}");

    // several unknown ids are all named
    let bad2 = loadgen::post_json(
        &addr,
        "/admin/scale",
        "{\"replicas\": [{\"id\": 5, \"weight\": 1.0}, {\"id\": 9, \"weight\": 1.0}]}",
    )
    .unwrap();
    assert_eq!(bad2.status, 400);
    assert!(bad2.body_str().contains("unknown replica ids [5, 9]"), "{}", bad2.body_str());

    // the survivor still serves
    let ok = loadgen::post_json(&addr, "/v1/completions", "{\"prompt\": \"after\", \"max_tokens\": 2}")
        .unwrap();
    assert_eq!(ok.status, 200);

    // retiring the last routable replica is refused
    assert!(gw.retire_replica(0).is_err());

    gw.shutdown();
}

/// A gateway started with fixed factories (no spawner) cannot hot-add and
/// says so instead of panicking.
#[test]
fn fixed_gateway_has_no_hot_add() {
    use enova::gateway::EngineFactory;
    let factories: Vec<EngineFactory> = vec![Box::new(|| {
        Ok(Box::new(SimEngine::new(SimEngineConfig::default())) as Box<dyn StreamEngine>)
    })];
    let gw = Gateway::start(GatewayConfig::default(), factories).unwrap();
    let err = gw.add_replica().unwrap_err().to_string();
    assert!(err.contains("spawner"), "{err}");
    gw.shutdown();
}
