//! Seeded, deterministic fault injection for the serving path.
//!
//! Real fleets do not fail cleanly: nodes return elevated error rates,
//! grow log-normal/Pareto latency tails, and oscillate between degraded
//! and healthy without ever dying. This module makes those failure modes
//! reproducible: a [`ChaosInjector`] sits on a node's serving path and —
//! keyed off a single `--chaos-seed` — injects errors, latency spikes and
//! mid-stream SSE aborts from a [`crate::util::rng::Pcg64`] stream, plus
//! a wall-clock degrade-and-recover square wave that multiplies the
//! injection rates while "degraded". Every knob is runtime-mutable via
//! the typed `POST /v1/admin/chaos` endpoint (see
//! [`crate::cluster::proto`]), so chaos-smoke can toggle faults without
//! restarting processes.
//!
//! Determinism: given a seed, the sequence of draws is bit-for-bit
//! reproducible. Concurrent requests contend for one mutex-guarded
//! generator, so the *assignment* of draws to requests can vary with
//! scheduling — but the multiset of injected faults over N decisions is
//! fixed by the seed, which is what the chaos invariant tests rely on.

use crate::util::json::{num, obj, Json};
use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// The full injection configuration — plain data, JSON-serializable, and
/// the body of the `/v1/admin/chaos` get/set surface. All-zero (the
/// default) means chaos is disarmed and the injector is a no-op.
#[derive(Debug, Clone, PartialEq)]
pub struct ChaosConfig {
    /// seed for the deterministic draw stream; re-seeding with the same
    /// value replays the same fault sequence
    pub seed: u64,
    /// probability in [0,1] that a request is failed with an injected
    /// 500 before reaching an engine
    pub error_rate: f64,
    /// probability in [0,1] that a request is delayed by a sampled spike
    pub latency_rate: f64,
    /// median of the log-normal spike body, in milliseconds
    pub latency_ms: f64,
    /// log-scale sigma of the spike body (0.5 ≈ mild skew, 1.5 ≈ heavy)
    pub latency_sigma: f64,
    /// probability in [0,1] that a spike additionally draws a
    /// generalized-Pareto tail excess (the "Pareto tail" of the fault
    /// model)
    pub tail_ratio: f64,
    /// GPD shape ξ of the tail excess (heavier as ξ → 1)
    pub tail_xi: f64,
    /// GPD scale of the tail excess, in milliseconds
    pub tail_scale_ms: f64,
    /// hard cap on any injected delay, in milliseconds (0 = 10s default)
    pub max_delay_ms: f64,
    /// probability in [0,1] that a streaming response is aborted
    /// mid-stream (socket torn down after ≥1 SSE event, no clean close)
    pub sse_abort_rate: f64,
    /// period of the degrade-and-recover square wave, in seconds
    /// (0 = no cycling)
    pub degrade_period_s: f64,
    /// fraction of each period spent degraded, in [0,1]
    pub degrade_duty: f64,
    /// multiplier applied to error/latency/abort rates while degraded
    pub degrade_factor: f64,
}

impl Default for ChaosConfig {
    fn default() -> Self {
        ChaosConfig {
            seed: 0,
            error_rate: 0.0,
            latency_rate: 0.0,
            latency_ms: 200.0,
            latency_sigma: 0.8,
            tail_ratio: 0.1,
            tail_xi: 0.4,
            tail_scale_ms: 500.0,
            max_delay_ms: 0.0,
            sse_abort_rate: 0.0,
            degrade_period_s: 0.0,
            degrade_duty: 0.0,
            degrade_factor: 4.0,
        }
    }
}

impl ChaosConfig {
    /// Whether this config injects anything at all (directly or via the
    /// degrade cycle).
    pub fn armed(&self) -> bool {
        self.error_rate > 0.0
            || self.latency_rate > 0.0
            || self.sse_abort_rate > 0.0
            || (self.degrade_period_s > 0.0 && self.degrade_duty > 0.0)
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("seed", num(self.seed as f64)),
            ("error_rate", num(self.error_rate)),
            ("latency_rate", num(self.latency_rate)),
            ("latency_ms", num(self.latency_ms)),
            ("latency_sigma", num(self.latency_sigma)),
            ("tail_ratio", num(self.tail_ratio)),
            ("tail_xi", num(self.tail_xi)),
            ("tail_scale_ms", num(self.tail_scale_ms)),
            ("max_delay_ms", num(self.max_delay_ms)),
            ("sse_abort_rate", num(self.sse_abort_rate)),
            ("degrade_period_s", num(self.degrade_period_s)),
            ("degrade_duty", num(self.degrade_duty)),
            ("degrade_factor", num(self.degrade_factor)),
        ])
    }

    /// Parse a config from JSON. Absent fields keep their defaults, so a
    /// `POST /v1/admin/chaos` body only names the knobs it changes.
    /// Rejects out-of-range probabilities and negative magnitudes.
    pub fn from_json(v: &Json) -> Result<ChaosConfig, String> {
        let mut cfg = ChaosConfig::default();
        let f = |key: &str, dst: &mut f64| -> Result<(), String> {
            if let Some(x) = v.get(key) {
                *dst = x.as_f64().ok_or_else(|| format!("{key} must be a number"))?;
            }
            Ok(())
        };
        if let Some(x) = v.get("seed") {
            cfg.seed = x.as_f64().ok_or("seed must be a number")? as u64;
        }
        f("error_rate", &mut cfg.error_rate)?;
        f("latency_rate", &mut cfg.latency_rate)?;
        f("latency_ms", &mut cfg.latency_ms)?;
        f("latency_sigma", &mut cfg.latency_sigma)?;
        f("tail_ratio", &mut cfg.tail_ratio)?;
        f("tail_xi", &mut cfg.tail_xi)?;
        f("tail_scale_ms", &mut cfg.tail_scale_ms)?;
        f("max_delay_ms", &mut cfg.max_delay_ms)?;
        f("sse_abort_rate", &mut cfg.sse_abort_rate)?;
        f("degrade_period_s", &mut cfg.degrade_period_s)?;
        f("degrade_duty", &mut cfg.degrade_duty)?;
        f("degrade_factor", &mut cfg.degrade_factor)?;
        for (key, p) in [
            ("error_rate", cfg.error_rate),
            ("latency_rate", cfg.latency_rate),
            ("tail_ratio", cfg.tail_ratio),
            ("sse_abort_rate", cfg.sse_abort_rate),
            ("degrade_duty", cfg.degrade_duty),
        ] {
            if !(0.0..=1.0).contains(&p) {
                return Err(format!("{key} must be in [0,1] (got {p})"));
            }
        }
        for (key, x) in [
            ("latency_ms", cfg.latency_ms),
            ("latency_sigma", cfg.latency_sigma),
            ("tail_scale_ms", cfg.tail_scale_ms),
            ("max_delay_ms", cfg.max_delay_ms),
            ("degrade_period_s", cfg.degrade_period_s),
            ("degrade_factor", cfg.degrade_factor),
        ] {
            if !x.is_finite() || x < 0.0 {
                return Err(format!("{key} must be a finite non-negative number (got {x})"));
            }
        }
        Ok(cfg)
    }
}

/// One injection verdict for one request, drawn in a fixed order so the
/// stream is seed-deterministic regardless of which faults fire.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ChaosDecision {
    /// fail the request with an injected 500 before dispatch
    pub fail: bool,
    /// sleep this long before dispatch (zero = no spike)
    pub delay: Duration,
    /// tear the socket down mid-stream after ≥1 SSE event (streaming
    /// requests only; ignored on the unary path)
    pub abort_sse: bool,
}

impl ChaosDecision {
    pub const NONE: ChaosDecision = ChaosDecision {
        fail: false,
        delay: Duration::ZERO,
        abort_sse: false,
    };
}

/// The runtime-mutable injector one gateway/node owns. Cheap when
/// disarmed: a single relaxed atomic load per request.
pub struct ChaosInjector {
    cfg: Mutex<ChaosConfig>,
    rng: Mutex<Pcg64>,
    /// phase origin of the degrade square wave; reset on every set_config
    epoch: Mutex<Instant>,
    armed: AtomicBool,
    /// bumped on every set_config, so operators can correlate scrapes
    generation: AtomicU64,
    pub injected_errors: AtomicU64,
    pub injected_delays: AtomicU64,
    pub injected_aborts: AtomicU64,
    pub injected_delay_ms: AtomicU64,
}

impl ChaosInjector {
    pub fn new(cfg: ChaosConfig) -> Self {
        let armed = cfg.armed();
        ChaosInjector {
            rng: Mutex::new(Pcg64::new(cfg.seed)),
            cfg: Mutex::new(cfg),
            epoch: Mutex::new(Instant::now()),
            armed: AtomicBool::new(armed),
            generation: AtomicU64::new(0),
            injected_errors: AtomicU64::new(0),
            injected_delays: AtomicU64::new(0),
            injected_aborts: AtomicU64::new(0),
            injected_delay_ms: AtomicU64::new(0),
        }
    }

    pub fn config(&self) -> ChaosConfig {
        self.cfg.lock().unwrap().clone()
    }

    pub fn armed(&self) -> bool {
        self.armed.load(Ordering::Relaxed)
    }

    pub fn generation(&self) -> u64 {
        self.generation.load(Ordering::Relaxed)
    }

    /// Replace the live config. Reseeds the draw stream from the new
    /// seed and restarts the degrade cycle at its healthy phase, so a
    /// set is a reproducible experiment boundary.
    pub fn set_config(&self, cfg: ChaosConfig) {
        *self.rng.lock().unwrap() = Pcg64::new(cfg.seed);
        *self.epoch.lock().unwrap() = Instant::now();
        self.armed.store(cfg.armed(), Ordering::Relaxed);
        *self.cfg.lock().unwrap() = cfg;
        self.generation.fetch_add(1, Ordering::Relaxed);
    }

    /// Whether the degrade square wave is currently in its degraded
    /// phase (the first `duty` fraction of every period).
    pub fn degraded_now(&self) -> bool {
        let cfg = self.cfg.lock().unwrap();
        if cfg.degrade_period_s <= 0.0 || cfg.degrade_duty <= 0.0 {
            return false;
        }
        let elapsed = self.epoch.lock().unwrap().elapsed().as_secs_f64();
        let phase = (elapsed / cfg.degrade_period_s).fract();
        phase < cfg.degrade_duty
    }

    /// Draw one injection verdict. Draw order is fixed (error, latency
    /// gate, spike body, tail gate, tail excess, sse gate) so the stream
    /// stays aligned with the seed whatever the outcomes are.
    pub fn decide(&self) -> ChaosDecision {
        if !self.armed() {
            return ChaosDecision::NONE;
        }
        let cfg = self.config();
        let boost = if self.degraded_now() { cfg.degrade_factor.max(1.0) } else { 1.0 };
        let mut rng = self.rng.lock().unwrap();
        let fail = rng.f64() < (cfg.error_rate * boost).min(1.0);
        let spike = rng.f64() < (cfg.latency_rate * boost).min(1.0);
        // always burn the body/tail draws so the stream position does
        // not depend on the gates' outcomes
        let mu = cfg.latency_ms.max(0.0).max(1e-9).ln();
        let mut delay_ms = rng.lognormal(mu, cfg.latency_sigma.max(0.0));
        let tail = rng.f64() < cfg.tail_ratio;
        let excess = rng.gpd(cfg.tail_xi, cfg.tail_scale_ms.max(0.0));
        let abort_sse = rng.f64() < (cfg.sse_abort_rate * boost).min(1.0);
        drop(rng);
        if tail {
            delay_ms += excess;
        }
        let cap = if cfg.max_delay_ms > 0.0 { cfg.max_delay_ms } else { 10_000.0 };
        delay_ms = delay_ms.min(cap);
        let delay = if spike {
            Duration::from_secs_f64(delay_ms.max(0.0) / 1e3)
        } else {
            Duration::ZERO
        };
        if fail {
            self.injected_errors.fetch_add(1, Ordering::Relaxed);
        }
        if spike {
            self.injected_delays.fetch_add(1, Ordering::Relaxed);
            self.injected_delay_ms.fetch_add(delay_ms as u64, Ordering::Relaxed);
        }
        if abort_sse {
            self.injected_aborts.fetch_add(1, Ordering::Relaxed);
        }
        ChaosDecision { fail, delay, abort_sse }
    }

    /// Counters + live state, embedded in the `/v1/admin/chaos` response.
    pub fn stats_json(&self) -> Json {
        obj([
            ("armed", Json::Bool(self.armed())),
            ("degraded", Json::Bool(self.degraded_now())),
            ("generation", num(self.generation() as f64)),
            (
                "injected_errors",
                num(self.injected_errors.load(Ordering::Relaxed) as f64),
            ),
            (
                "injected_delays",
                num(self.injected_delays.load(Ordering::Relaxed) as f64),
            ),
            (
                "injected_aborts",
                num(self.injected_aborts.load(Ordering::Relaxed) as f64),
            ),
            (
                "injected_delay_ms",
                num(self.injected_delay_ms.load(Ordering::Relaxed) as f64),
            ),
        ])
    }
}

impl std::fmt::Debug for ChaosInjector {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("ChaosInjector")
            .field("cfg", &self.config())
            .field("armed", &self.armed())
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed_cfg() -> ChaosConfig {
        ChaosConfig {
            seed: 42,
            error_rate: 0.3,
            latency_rate: 0.2,
            latency_ms: 50.0,
            latency_sigma: 0.5,
            sse_abort_rate: 0.1,
            ..ChaosConfig::default()
        }
    }

    #[test]
    fn disarmed_is_a_noop() {
        let inj = ChaosInjector::new(ChaosConfig::default());
        assert!(!inj.armed());
        for _ in 0..100 {
            assert_eq!(inj.decide(), ChaosDecision::NONE);
        }
        assert_eq!(inj.injected_errors.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn same_seed_same_decisions() {
        let a = ChaosInjector::new(armed_cfg());
        let b = ChaosInjector::new(armed_cfg());
        for _ in 0..500 {
            assert_eq!(a.decide(), b.decide());
        }
        // set_config reseeds: a's stream restarts from the beginning,
        // matching a freshly built injector draw-for-draw
        a.set_config(armed_cfg());
        let replayed: Vec<ChaosDecision> = (0..200).map(|_| a.decide()).collect();
        let fresh = ChaosInjector::new(armed_cfg());
        let expect: Vec<ChaosDecision> = (0..200).map(|_| fresh.decide()).collect();
        assert_eq!(replayed, expect);
    }

    #[test]
    fn rates_are_roughly_honored() {
        let inj = ChaosInjector::new(armed_cfg());
        let n = 20_000;
        let mut fails = 0usize;
        let mut spikes = 0usize;
        for _ in 0..n {
            let d = inj.decide();
            if d.fail {
                fails += 1;
            }
            if !d.delay.is_zero() {
                spikes += 1;
                assert!(d.delay <= Duration::from_secs(10));
            }
        }
        let fail_rate = fails as f64 / n as f64;
        let spike_rate = spikes as f64 / n as f64;
        assert!((fail_rate - 0.3).abs() < 0.02, "fail rate {fail_rate}");
        assert!((spike_rate - 0.2).abs() < 0.02, "spike rate {spike_rate}");
    }

    #[test]
    fn degrade_cycle_boosts_rates() {
        let cfg = ChaosConfig {
            seed: 7,
            error_rate: 0.1,
            degrade_period_s: 3600.0, // degraded phase covers the whole test
            degrade_duty: 0.99,
            degrade_factor: 5.0,
            ..ChaosConfig::default()
        };
        let inj = ChaosInjector::new(cfg);
        assert!(inj.degraded_now());
        let n = 10_000;
        let fails = (0..n).filter(|_| inj.decide().fail).count();
        let rate = fails as f64 / n as f64;
        assert!((rate - 0.5).abs() < 0.03, "boosted fail rate {rate}");
    }

    #[test]
    fn degrade_requires_period_and_duty() {
        let inj = ChaosInjector::new(ChaosConfig {
            degrade_period_s: 10.0,
            degrade_duty: 0.0,
            ..ChaosConfig::default()
        });
        assert!(!inj.degraded_now());
        assert!(!inj.armed());
    }

    #[test]
    fn config_json_roundtrip() {
        let cfg = ChaosConfig {
            seed: 99,
            error_rate: 0.25,
            latency_rate: 0.5,
            latency_ms: 120.0,
            latency_sigma: 1.1,
            tail_ratio: 0.2,
            tail_xi: 0.3,
            tail_scale_ms: 400.0,
            max_delay_ms: 2000.0,
            sse_abort_rate: 0.05,
            degrade_period_s: 20.0,
            degrade_duty: 0.5,
            degrade_factor: 3.0,
        };
        let wire = cfg.to_json().to_string_compact();
        let back = ChaosConfig::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(cfg, back);
    }

    #[test]
    fn partial_config_keeps_defaults() {
        let v = Json::parse(r#"{"error_rate":0.5,"seed":3}"#).unwrap();
        let cfg = ChaosConfig::from_json(&v).unwrap();
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.error_rate, 0.5);
        assert_eq!(cfg.latency_ms, ChaosConfig::default().latency_ms);
    }

    #[test]
    fn config_validation_rejects_bad_values() {
        for body in [
            r#"{"error_rate":1.5}"#,
            r#"{"latency_rate":-0.1}"#,
            r#"{"latency_ms":-5}"#,
            r#"{"degrade_duty":2}"#,
            r#"{"error_rate":"lots"}"#,
        ] {
            let v = Json::parse(body).unwrap();
            assert!(ChaosConfig::from_json(&v).is_err(), "accepted {body}");
        }
    }

    #[test]
    fn set_config_updates_armed_and_generation() {
        let inj = ChaosInjector::new(ChaosConfig::default());
        assert!(!inj.armed());
        inj.set_config(armed_cfg());
        assert!(inj.armed());
        assert_eq!(inj.generation(), 1);
        inj.set_config(ChaosConfig::default());
        assert!(!inj.armed());
        assert_eq!(inj.generation(), 2);
    }
}
