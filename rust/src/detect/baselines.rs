//! Table IV detection baselines, re-implemented on the in-tree autograd:
//!
//! * **USAD** (Audibert et al., KDD'20) — adversarially-trained dual-decoder
//!   autoencoder; score mixes the two reconstruction errors.
//! * **SDF-VAE-lite** (Dai et al., WWW'21) — VAE scored by reconstruction
//!   probability. The full model factorizes static/dynamic latents over a
//!   window; at the 1-minute, 8-metric granularity of this dataset the
//!   factorization reduces to two latent blocks, which is what we keep.
//! * **Uni-AD-lite** (He et al., ISSRE'22) — shared encoder with per-metric
//!   reconstruction heads; the transformer mixing layer is replaced by a
//!   dense mixing layer (the dataset has 8 metrics, not hundreds of
//!   services, so attention degenerates to dense mixing anyway).
//!
//! All three are purely unsupervised (they model "normal"), which is the
//! structural difference from ENOVA's semi-supervised objective that
//! Table IV attributes ENOVA's margin to.

use crate::nn::autograd::Tape;
use crate::nn::layers::{Bound, Mlp, ParamSet};
use crate::nn::optim::Adam;
use crate::nn::tensor::Matrix;
use crate::util::rng::Pcg64;

/// Shared z-score scaler.
#[derive(Debug, Clone)]
pub struct Scaler {
    pub mean: Vec<f64>,
    pub std: Vec<f64>,
}

impl Scaler {
    pub fn apply(&self, row: &[f64]) -> Vec<f32> {
        row.iter()
            .zip(self.mean.iter().zip(&self.std))
            .map(|(x, (m, s))| (((x - m) / s).clamp(-10.0, 10.0)) as f32)
            .collect()
    }

    pub fn matrix(&self, rows: &[f64], f: usize) -> Matrix {
        let n = rows.len() / f;
        let mut data = Vec::with_capacity(rows.len());
        for i in 0..n {
            data.extend(self.apply(&rows[i * f..(i + 1) * f]));
        }
        Matrix::from_vec(n, f, data)
    }
}

#[derive(Debug, Clone, Copy)]
pub struct TrainOpts {
    pub epochs: usize,
    pub batch: usize,
    pub lr: f32,
    /// training-set stride (subsampling for speed; 1 = all rows)
    pub stride: usize,
    pub seed: u64,
}

impl Default for TrainOpts {
    fn default() -> Self {
        TrainOpts {
            epochs: 3,
            batch: 256,
            lr: 2e-3,
            stride: 4,
            seed: 17,
        }
    }
}

fn minibatches(n: usize, batch: usize, rng: &mut Pcg64) -> Vec<Vec<usize>> {
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    idx.chunks(batch).map(|c| c.to_vec()).collect()
}

fn gather(m: &Matrix, rows: &[usize]) -> Matrix {
    let mut data = Vec::with_capacity(rows.len() * m.cols);
    for &r in rows {
        data.extend_from_slice(m.row(r));
    }
    Matrix::from_vec(rows.len(), m.cols, data)
}

/// A fitted detector: higher score ⇒ more anomalous.
pub trait Detector {
    fn name(&self) -> &'static str;
    fn score_rows(&self, rows: &[f64], n_features: usize) -> Vec<f64>;
}

// ---------------------------------------------------------------- USAD --

pub struct Usad {
    params: ParamSet,
    enc: Mlp,
    dec1: Mlp,
    dec2: Mlp,
    scaler: Scaler,
    pub alpha: f64,
}

impl Usad {
    pub fn fit(train: &[f64], f: usize, scaler: Scaler, opts: TrainOpts) -> Usad {
        let mut rng = Pcg64::new(opts.seed);
        let mut params = ParamSet::new();
        let latent = 6;
        let enc = Mlp::init(&mut params, "enc", &[f, 24, latent], &mut rng);
        let dec1 = Mlp::init(&mut params, "dec1", &[latent, 24, f], &mut rng);
        let dec2 = Mlp::init(&mut params, "dec2", &[latent, 24, f], &mut rng);
        let x = scaler.matrix(train, f);
        let strided: Vec<usize> = (0..x.rows).step_by(opts.stride).collect();
        let xs = gather(&x, &strided);
        let mut opt = Adam::new(opts.lr);
        for epoch in 0..opts.epochs {
            // USAD epoch weighting: 1/(epoch+1) on the direct term,
            // epoch/(epoch+1) on the adversarial term
            let w_direct = 1.0 / (epoch as f32 + 1.0);
            let w_adv = 1.0 - w_direct;
            for batch in minibatches(xs.rows, opts.batch, &mut rng) {
                let xb = gather(&xs, &batch);
                let tape = Tape::new();
                let bound = Bound::bind(&tape, &params);
                let input = tape.constant(xb);
                let z = enc.forward(&bound, input);
                let r1 = dec1.forward(&bound, z);
                let z2 = enc.forward(&bound, r1);
                let r2 = dec2.forward(&bound, z2);
                let l1 = tape.mse(r1, input);
                let l2 = tape.mse(r2, input);
                // AE1 minimizes both; AE2's adversarial game is folded into
                // a single objective (the -lite simplification)
                let loss = tape.add(tape.scale(l1, w_direct + w_adv), tape.scale(l2, w_direct));
                tape.backward(loss);
                let grads = bound.grads(&params);
                opt.step(&mut params, &grads);
            }
        }
        Usad {
            params,
            enc,
            dec1,
            dec2,
            scaler,
            alpha: 0.5,
        }
    }
}

impl Detector for Usad {
    fn name(&self) -> &'static str {
        "USAD"
    }

    fn score_rows(&self, rows: &[f64], f: usize) -> Vec<f64> {
        let x = self.scaler.matrix(rows, f);
        let tape = Tape::new();
        let bound = Bound::bind(&tape, &self.params);
        let input = tape.constant(x.clone());
        let z = self.enc.forward(&bound, input);
        let r1 = self.dec1.forward(&bound, z);
        let z2 = self.enc.forward(&bound, r1);
        let r2 = self.dec2.forward(&bound, z2);
        let r1v = tape.value(r1);
        let r2v = tape.value(r2);
        (0..x.rows)
            .map(|i| {
                let mut e1 = 0.0;
                let mut e2 = 0.0;
                for c in 0..f {
                    let d1 = (x.at(i, c) - r1v.at(i, c)) as f64;
                    let d2 = (x.at(i, c) - r2v.at(i, c)) as f64;
                    e1 += d1 * d1;
                    e2 += d2 * d2;
                }
                self.alpha * e1 / f as f64 + (1.0 - self.alpha) * e2 / f as f64
            })
            .collect()
    }
}

// ------------------------------------------------------------ SDF-VAE --

pub struct SdfVae {
    params: ParamSet,
    enc: Mlp,
    mu_head: Mlp,
    dec: Mlp,
    scaler: Scaler,
}

impl SdfVae {
    pub fn fit(train: &[f64], f: usize, scaler: Scaler, opts: TrainOpts) -> SdfVae {
        let mut rng = Pcg64::new(opts.seed ^ 0x5df);
        let mut params = ParamSet::new();
        let latent = 8;
        let enc = Mlp::init(&mut params, "enc", &[f, 24], &mut rng);
        let mu_head = Mlp::init(&mut params, "mu", &[24, latent], &mut rng);
        let dec = Mlp::init(&mut params, "dec", &[latent, 24, f], &mut rng);
        let x = scaler.matrix(train, f);
        let strided: Vec<usize> = (0..x.rows).step_by(opts.stride).collect();
        let xs = gather(&x, &strided);
        let mut opt = Adam::new(opts.lr);
        let beta = 0.05f32;
        let mut noise_rng = Pcg64::new(opts.seed ^ 0xaa);
        for _ in 0..opts.epochs {
            for batch in minibatches(xs.rows, opts.batch, &mut noise_rng) {
                let xb = gather(&xs, &batch);
                let tape = Tape::new();
                let bound = Bound::bind(&tape, &params);
                let input = tape.constant(xb.clone());
                let h = tape.tanh(enc.forward(&bound, input));
                let mu = mu_head.forward(&bound, h);
                // reparameterized sample with fixed unit logvar (lite)
                let eps = tape.constant(Matrix::randn(
                    xb.rows,
                    8,
                    &mut noise_rng,
                    0.3,
                ));
                let z = tape.add(mu, eps);
                let recon = dec.forward(&bound, z);
                let rec_loss = tape.mse(recon, input);
                let kl = tape.mean_all(tape.square(mu));
                let loss = tape.add(rec_loss, tape.scale(kl, beta));
                tape.backward(loss);
                let grads = bound.grads(&params);
                opt.step(&mut params, &grads);
            }
        }
        SdfVae {
            params,
            enc,
            mu_head,
            dec,
            scaler,
        }
    }
}

impl Detector for SdfVae {
    fn name(&self) -> &'static str {
        "SDF-VAE"
    }

    fn score_rows(&self, rows: &[f64], f: usize) -> Vec<f64> {
        let x = self.scaler.matrix(rows, f);
        let tape = Tape::new();
        let bound = Bound::bind(&tape, &self.params);
        let input = tape.constant(x.clone());
        let h = tape.tanh(self.enc.forward(&bound, input));
        let mu = self.mu_head.forward(&bound, h);
        let recon = tape.value(self.dec.forward(&bound, mu));
        (0..x.rows)
            .map(|i| {
                let mut e = 0.0;
                for c in 0..f {
                    let d = (x.at(i, c) - recon.at(i, c)) as f64;
                    e += d * d;
                }
                e / f as f64 // negative log recon-probability ∝ sq error
            })
            .collect()
    }
}

// -------------------------------------------------------------- Uni-AD --

pub struct UniAd {
    params: ParamSet,
    shared: Mlp,
    mix: Mlp,
    head: Mlp,
    scaler: Scaler,
}

impl UniAd {
    pub fn fit(train: &[f64], f: usize, scaler: Scaler, opts: TrainOpts) -> UniAd {
        let mut rng = Pcg64::new(opts.seed ^ 0x0a1d);
        let mut params = ParamSet::new();
        let shared = Mlp::init(&mut params, "shared", &[f, 32], &mut rng);
        let mix = Mlp::init(&mut params, "mix", &[32, 32], &mut rng);
        let head = Mlp::init(&mut params, "head", &[32, f], &mut rng);
        let x = scaler.matrix(train, f);
        let strided: Vec<usize> = (0..x.rows).step_by(opts.stride).collect();
        let xs = gather(&x, &strided);
        let mut opt = Adam::new(opts.lr);
        let mut rng2 = Pcg64::new(opts.seed ^ 0xbb);
        for _ in 0..opts.epochs {
            for batch in minibatches(xs.rows, opts.batch, &mut rng2) {
                let xb = gather(&xs, &batch);
                let tape = Tape::new();
                let bound = Bound::bind(&tape, &params);
                let input = tape.constant(xb);
                let h = tape.relu(shared.forward(&bound, input));
                let m = tape.tanh(mix.forward(&bound, h));
                // residual mixing (the -lite stand-in for self-attention)
                let hm = tape.add(h, m);
                let recon = head.forward(&bound, hm);
                let loss = tape.mse(recon, input);
                tape.backward(loss);
                let grads = bound.grads(&params);
                opt.step(&mut params, &grads);
            }
        }
        UniAd {
            params,
            shared,
            mix,
            head,
            scaler,
        }
    }
}

impl Detector for UniAd {
    fn name(&self) -> &'static str {
        "Uni-AD"
    }

    fn score_rows(&self, rows: &[f64], f: usize) -> Vec<f64> {
        let x = self.scaler.matrix(rows, f);
        let tape = Tape::new();
        let bound = Bound::bind(&tape, &self.params);
        let input = tape.constant(x.clone());
        let h = tape.relu(self.shared.forward(&bound, input));
        let m = tape.tanh(self.mix.forward(&bound, h));
        let hm = tape.add(h, m);
        let recon = tape.value(self.head.forward(&bound, hm));
        (0..x.rows)
            .map(|i| {
                let mut e = 0.0;
                for c in 0..f {
                    let d = (x.at(i, c) - recon.at(i, c)) as f64;
                    e += d * d;
                }
                e / f as f64
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Tiny synthetic set: normal rows near 0, anomalies far away.
    fn synth(n: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<u8>) {
        let mut rng = Pcg64::new(seed);
        let f = 8;
        let mut train = Vec::new();
        for _ in 0..n {
            for c in 0..f {
                train.push(rng.normal() * 0.5 + c as f64 * 0.1);
            }
        }
        let mut test = Vec::new();
        let mut labels = Vec::new();
        for i in 0..200 {
            let anomalous = i % 37 == 0;
            labels.push(u8::from(anomalous));
            for c in 0..f {
                let base = rng.normal() * 0.5 + c as f64 * 0.1;
                test.push(if anomalous { base + 6.0 } else { base });
            }
        }
        (train, test, labels)
    }

    fn scaler_for(train: &[f64], f: usize) -> Scaler {
        let n = train.len() / f;
        let mut mean = vec![0.0; f];
        for i in 0..n {
            for c in 0..f {
                mean[c] += train[i * f + c];
            }
        }
        mean.iter_mut().for_each(|m| *m /= n as f64);
        let mut std = vec![0.0; f];
        for i in 0..n {
            for c in 0..f {
                std[c] += (train[i * f + c] - mean[c]).powi(2);
            }
        }
        std.iter_mut().for_each(|s| *s = (*s / n as f64).sqrt().max(1e-6));
        Scaler { mean, std }
    }

    fn check_detector(d: &dyn Detector, test: &[f64], labels: &[u8]) {
        let scores = d.score_rows(test, 8);
        let an: f64 = scores
            .iter()
            .zip(labels)
            .filter(|(_, &l)| l == 1)
            .map(|(s, _)| *s)
            .sum::<f64>()
            / labels.iter().filter(|&&l| l == 1).count() as f64;
        let no: f64 = scores
            .iter()
            .zip(labels)
            .filter(|(_, &l)| l == 0)
            .map(|(s, _)| *s)
            .sum::<f64>()
            / labels.iter().filter(|&&l| l == 0).count() as f64;
        assert!(
            an > 3.0 * no,
            "{}: anomaly score {an} vs normal {no}",
            d.name()
        );
    }

    #[test]
    fn usad_separates() {
        let (train, test, labels) = synth(2000, 1);
        let scaler = scaler_for(&train, 8);
        let opts = TrainOpts {
            epochs: 4,
            stride: 1,
            ..Default::default()
        };
        let d = Usad::fit(&train, 8, scaler, opts);
        check_detector(&d, &test, &labels);
    }

    #[test]
    fn sdf_vae_separates() {
        let (train, test, labels) = synth(2000, 2);
        let scaler = scaler_for(&train, 8);
        let opts = TrainOpts {
            epochs: 4,
            stride: 1,
            ..Default::default()
        };
        let d = SdfVae::fit(&train, 8, scaler, opts);
        check_detector(&d, &test, &labels);
    }

    #[test]
    fn uniad_separates() {
        let (train, test, labels) = synth(2000, 3);
        let scaler = scaler_for(&train, 8);
        let opts = TrainOpts {
            epochs: 4,
            stride: 1,
            ..Default::default()
        };
        let d = UniAd::fit(&train, 8, scaler, opts);
        check_detector(&d, &test, &labels);
    }
}
