//! Point-adjusted detection evaluation (Xu et al. / the paper's §VI-B):
//! if any point inside a contiguous anomalous segment is flagged, the whole
//! segment counts as detected (no false positives added for its points).

#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Prf {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    pub tp: usize,
    pub fp: usize,
    pub fn_: usize,
}

/// Apply point-adjustment to binary predictions given ground-truth labels.
pub fn point_adjust(labels: &[u8], preds: &[bool]) -> Vec<bool> {
    assert_eq!(labels.len(), preds.len());
    let mut adjusted = preds.to_vec();
    let mut i = 0;
    while i < labels.len() {
        if labels[i] == 1 {
            let start = i;
            while i < labels.len() && labels[i] == 1 {
                i += 1;
            }
            if preds[start..i].iter().any(|&p| p) {
                for a in adjusted[start..i].iter_mut() {
                    *a = true;
                }
            }
        } else {
            i += 1;
        }
    }
    adjusted
}

pub fn prf(labels: &[u8], preds: &[bool]) -> Prf {
    let adjusted = point_adjust(labels, preds);
    let mut tp = 0;
    let mut fp = 0;
    let mut fn_ = 0;
    for (&l, &p) in labels.iter().zip(&adjusted) {
        match (l, p) {
            (1, true) => tp += 1,
            (0, true) => fp += 1,
            (1, false) => fn_ += 1,
            _ => {}
        }
    }
    let precision = if tp + fp > 0 {
        tp as f64 / (tp + fp) as f64
    } else {
        0.0
    };
    let recall = if tp + fn_ > 0 {
        tp as f64 / (tp + fn_) as f64
    } else {
        0.0
    };
    let f1 = if precision + recall > 0.0 {
        2.0 * precision * recall / (precision + recall)
    } else {
        0.0
    };
    Prf {
        precision,
        recall,
        f1,
        tp,
        fp,
        fn_,
    }
}

/// Evaluate at a fixed threshold.
pub fn prf_at(labels: &[u8], scores: &[f64], threshold: f64) -> Prf {
    let preds: Vec<bool> = scores.iter().map(|&s| s > threshold).collect();
    prf(labels, &preds)
}

/// Best-F1 threshold search over score quantiles (standard protocol for
/// the unsupervised baselines, which publish no thresholding rule).
pub fn best_f1(labels: &[u8], scores: &[f64]) -> (f64, Prf) {
    let mut sorted = scores.to_vec();
    sorted.sort_by(f64::total_cmp);
    let mut best = (f64::INFINITY, prf_at(labels, scores, f64::INFINITY));
    for i in 0..200 {
        let q = 0.95 + 0.05 * (i as f64 / 200.0);
        let thr = crate::stats::descriptive::quantile_sorted(&sorted, q);
        let p = prf_at(labels, scores, thr);
        if p.f1 > best.1.f1 {
            best = (thr, p);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn point_adjust_fills_segments() {
        let labels = [0, 1, 1, 1, 0, 1, 1, 0];
        let preds = [false, false, true, false, false, false, false, false];
        let adj = point_adjust(&labels, &preds);
        assert_eq!(
            adj,
            [false, true, true, true, false, false, false, false]
        );
    }

    #[test]
    fn perfect_detection() {
        let labels = [0, 1, 1, 0, 0];
        let preds = [false, true, false, false, false];
        let p = prf(&labels, &preds);
        assert_eq!(p.precision, 1.0);
        assert_eq!(p.recall, 1.0);
        assert_eq!(p.f1, 1.0);
    }

    #[test]
    fn false_positives_hurt_precision() {
        let labels = [0, 0, 0, 1, 1];
        let preds = [true, true, false, true, false];
        let p = prf(&labels, &preds);
        assert!((p.precision - 0.5).abs() < 1e-9); // 2 tp (adjusted), 2 fp
        assert_eq!(p.recall, 1.0);
    }

    #[test]
    fn best_f1_finds_separating_threshold() {
        let labels: Vec<u8> = (0..100).map(|i| u8::from(i >= 90)).collect();
        let scores: Vec<f64> = (0..100)
            .map(|i| if i >= 90 { 10.0 + i as f64 } else { i as f64 * 0.01 })
            .collect();
        let (thr, p) = best_f1(&labels, &scores);
        assert!(p.f1 > 0.99, "{p:?} at {thr}");
    }
}
