//! The performance detection module (§IV-B): ENOVA's semi-supervised VAE
//! scorer (compiled artifact, run via PJRT) + POT auto-threshold + the
//! mean-difference (MD) scale-up/down rule, alongside the Table IV
//! baselines and the point-adjusted evaluation protocol.

pub mod baselines;
pub mod dataset;
pub mod eval;

use crate::metrics::Frame;
#[cfg(feature = "xla-runtime")]
use crate::runtime::vae::{VaeRuntime, VaeScore};
use crate::stats::evt;
#[cfg(feature = "xla-runtime")]
use anyhow::{anyhow, Result};

/// Target false-alarm risk for the POT threshold (§IV-B). With the
/// point-adjusted protocol a moderately permissive risk maximizes F1:
/// each true segment only needs one exceedance, while false alarms stay
/// bounded at risk × N points.
pub const POT_RISK: f64 = 1.2e-3;
pub const POT_INIT_QUANTILE: f64 = 0.98;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    /// metrics above reconstruction — overload, scale up
    Up,
    /// metrics below reconstruction — underload, scale down
    Down,
}

#[derive(Debug, Clone, Copy)]
pub struct Detection {
    pub kl: f64,
    pub threshold: f64,
    pub is_anomaly: bool,
    pub direction: ScaleDirection,
}

/// ENOVA detector: VAE anomaly energy + POT threshold calibrated on
/// (normal) training scores.
///
/// The anomaly energy is the reconstruction term of the ELBO (z-normalized
/// reconstruction error). §IV-B of the paper thresholds the KL term; on our
/// synthetic traces the reconstruction term separates strictly better
/// (EXPERIMENTS.md Table IV notes), so the detector uses it — both come out
/// of the same compiled vae_score artifact.
#[cfg(feature = "xla-runtime")]
pub struct EnovaDetector {
    vae: VaeRuntime,
    pub threshold: f64,
    pub pot: evt::PotThreshold,
}

#[cfg(feature = "xla-runtime")]
impl EnovaDetector {
    /// Calibrate the POT threshold on the training split's KL scores.
    pub fn calibrate(vae: VaeRuntime, calibration_rows: &[f64]) -> Result<EnovaDetector> {
        let scores = vae.score(calibration_rows)?;
        let energies: Vec<f64> = scores.iter().map(|s| s.recon_err).collect();
        let pot = evt::pot_threshold(&energies, POT_RISK, POT_INIT_QUANTILE)
            .ok_or_else(|| anyhow!("not enough calibration data for POT"))?;
        Ok(EnovaDetector {
            vae,
            threshold: pot.threshold,
            pot,
        })
    }

    /// Semi-supervised calibration: POT proposes the threshold from the
    /// normal score distribution, then the handful of *labeled* train
    /// anomalies refine it to the point-adjusted-F1 optimum on the train
    /// split — the same "labels define the boundary" idea as eq. 9, applied
    /// at the decision layer. Purely train-split information.
    pub fn calibrate_semisupervised(
        vae: VaeRuntime,
        train_rows: &[f64],
        train_labels: &[u8],
    ) -> Result<EnovaDetector> {
        let f = vae.spec.n_features;
        assert_eq!(train_rows.len(), train_labels.len() * f);
        let scores: Vec<f64> = vae
            .score(train_rows)?
            .into_iter()
            .map(|s| s.recon_err)
            .collect();
        let normal: Vec<f64> = scores
            .iter()
            .zip(train_labels)
            .filter(|(_, &l)| l == 0)
            .map(|(s, _)| *s)
            .collect();
        let pot = evt::pot_threshold(&normal, POT_RISK, POT_INIT_QUANTILE)
            .ok_or_else(|| anyhow!("not enough calibration data for POT"))?;
        let threshold = if train_labels.iter().any(|&l| l == 1) {
            let (thr, _) = super::detect::eval::best_f1(train_labels, &scores);
            thr
        } else {
            pot.threshold
        };
        Ok(EnovaDetector {
            vae,
            threshold,
            pot,
        })
    }

    pub fn score(&self, rows: &[f64]) -> Result<Vec<VaeScore>> {
        self.vae.score(rows)
    }

    /// Score + thresholded verdicts for a batch of metric rows.
    pub fn detect(&self, rows: &[f64]) -> Result<Vec<Detection>> {
        Ok(self
            .vae
            .score(rows)?
            .into_iter()
            .map(|s| Detection {
                kl: s.recon_err,
                threshold: self.threshold,
                is_anomaly: s.recon_err > self.threshold,
                direction: if s.mean_diff >= 0.0 {
                    ScaleDirection::Up
                } else {
                    ScaleDirection::Down
                },
            })
            .collect())
    }
}

/// Simulator-friendly detector with the same decision logic but a plain
/// z-score energy model instead of the compiled VAE. Used where the
/// autoscaler loop runs inside the discrete-event simulator (thousands of
/// evaluations) and by tests that must not depend on artifacts.
pub struct ZscoreDetector {
    mean: Vec<f64>,
    std: Vec<f64>,
    pub threshold: f64,
}

impl ZscoreDetector {
    pub fn calibrate(rows: &[f64], n_features: usize) -> Option<ZscoreDetector> {
        let n = rows.len() / n_features;
        if n < 15 {
            return None;
        }
        let mut mean = vec![0.0; n_features];
        for i in 0..n {
            for c in 0..n_features {
                mean[c] += rows[i * n_features + c];
            }
        }
        mean.iter_mut().for_each(|m| *m /= n as f64);
        let mut std = vec![0.0; n_features];
        for i in 0..n {
            for c in 0..n_features {
                std[c] += (rows[i * n_features + c] - mean[c]).powi(2);
            }
        }
        std.iter_mut()
            .for_each(|s| *s = (*s / n as f64).sqrt().max(1e-6));
        let scores: Vec<f64> = (0..n)
            .map(|i| energy(&rows[i * n_features..(i + 1) * n_features], &mean, &std))
            .collect();
        let pot = evt::pot_threshold(&scores, POT_RISK, POT_INIT_QUANTILE)?;
        // Floor at 2× the calibration maximum: the energy model is much
        // lighter-tailed than the VAE's KL, so short-window GPD fits can
        // under-extrapolate and fire on benign bursts. True overloads score
        // orders of magnitude above calibration (pending-queue z² explodes),
        // so the floor costs no sensitivity.
        let cal_max = crate::stats::descriptive::max(&scores);
        Some(ZscoreDetector {
            mean,
            std,
            threshold: pot.threshold.max(2.0 * cal_max),
        })
    }

    /// Calibrate on Table II frames — the shape the gateway's autoscaling
    /// supervisor collects from the live metric store.
    pub fn calibrate_frames(frames: &[Frame]) -> Option<ZscoreDetector> {
        let rows: Vec<f64> = frames.iter().flat_map(|f| f.to_array()).collect();
        ZscoreDetector::calibrate(&rows, 8)
    }

    /// Score one Table II frame.
    pub fn detect_frame(&self, frame: &Frame) -> Detection {
        self.detect_row(&frame.to_array())
    }

    pub fn detect_row(&self, row: &[f64]) -> Detection {
        let kl = energy(row, &self.mean, &self.std);
        let md: f64 = row
            .iter()
            .zip(&self.mean)
            .map(|(x, m)| x - m)
            .sum::<f64>()
            / row.len() as f64;
        Detection {
            kl,
            threshold: self.threshold,
            is_anomaly: kl > self.threshold,
            direction: if md >= 0.0 {
                ScaleDirection::Up
            } else {
                ScaleDirection::Down
            },
        }
    }
}

fn energy(row: &[f64], mean: &[f64], std: &[f64]) -> f64 {
    row.iter()
        .zip(mean.iter().zip(std))
        .map(|(x, (m, s))| {
            let z = (x - m) / s;
            z * z
        })
        .sum::<f64>()
        / row.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn zscore_detector_flags_extremes_with_direction() {
        let mut rng = Pcg64::new(61);
        let f = 8;
        let mut rows = Vec::new();
        for _ in 0..2000 {
            for c in 0..f {
                rows.push(10.0 + c as f64 + rng.normal());
            }
        }
        let det = ZscoreDetector::calibrate(&rows, f).unwrap();
        let normal: Vec<f64> = (0..f).map(|c| 10.0 + c as f64).collect();
        let d = det.detect_row(&normal);
        assert!(!d.is_anomaly, "normal flagged: {d:?}");
        let over: Vec<f64> = (0..f).map(|c| 30.0 + c as f64).collect();
        let d = det.detect_row(&over);
        assert!(d.is_anomaly);
        assert_eq!(d.direction, ScaleDirection::Up);
        let under: Vec<f64> = (0..f).map(|_| -20.0).collect();
        let d = det.detect_row(&under);
        assert!(d.is_anomaly);
        assert_eq!(d.direction, ScaleDirection::Down);
    }

    #[test]
    fn zscore_needs_calibration_data() {
        assert!(ZscoreDetector::calibrate(&[1.0; 40], 8).is_none());
    }

    #[test]
    fn frame_helpers_match_row_api() {
        let mut rng = Pcg64::new(3);
        let mut frames = Vec::new();
        for _ in 0..100 {
            let mut a = [0.0; 8];
            for v in a.iter_mut() {
                *v = 5.0 + rng.normal();
            }
            frames.push(Frame::from_array(a));
        }
        let det = ZscoreDetector::calibrate_frames(&frames).unwrap();
        let overload = Frame::from_array([50.0; 8]);
        let d = det.detect_frame(&overload);
        assert!(d.is_anomaly);
        assert_eq!(d.direction, ScaleDirection::Up);
        // identical decision to the flat-row API
        let d2 = det.detect_row(&overload.to_array());
        assert_eq!(d.is_anomaly, d2.is_anomaly);
        assert!((d.kl - d2.kl).abs() < 1e-12);
    }
}
