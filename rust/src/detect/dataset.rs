//! Loader for `artifacts/detection_dataset.csv` — the synthetic 4-week,
//! 16-instance labeled metric traces (written by python/compile/traces.py;
//! both the rust baselines and the ENOVA VAE see exactly this data).

use crate::metrics;
use anyhow::{bail, Context, Result};
use std::path::Path;

#[derive(Debug, Default, Clone)]
pub struct DetectionDataset {
    pub n_features: usize,
    /// row-major feature matrix
    pub train: Vec<f64>,
    pub train_labels: Vec<u8>,
    pub test: Vec<f64>,
    pub test_labels: Vec<u8>,
    pub train_instances: Vec<u16>,
    pub test_instances: Vec<u16>,
}

impl DetectionDataset {
    pub fn load(path: &Path) -> Result<DetectionDataset> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading {}", path.display()))?;
        let mut lines = text.lines();
        let header = lines.next().context("empty csv")?;
        let cols: Vec<&str> = header.split(',').collect();
        if cols.len() < 4 || cols[0] != "instance" || cols[1] != "split" || cols[2] != "label" {
            bail!("unexpected header: {header}");
        }
        let feature_names = &cols[3..];
        if feature_names != metrics::COLUMNS {
            bail!("metric column mismatch: {feature_names:?}");
        }
        let f = feature_names.len();
        let mut ds = DetectionDataset {
            n_features: f,
            ..Default::default()
        };
        for (lineno, line) in lines.enumerate() {
            if line.is_empty() {
                continue;
            }
            let mut it = line.split(',');
            let inst: u16 = it.next().context("inst")?.parse()?;
            let split: u8 = it.next().context("split")?.parse()?;
            let label: u8 = it.next().context("label")?.parse()?;
            let (vals, labels, insts) = if split == 0 {
                (&mut ds.train, &mut ds.train_labels, &mut ds.train_instances)
            } else {
                (&mut ds.test, &mut ds.test_labels, &mut ds.test_instances)
            };
            for (k, tok) in it.enumerate() {
                if k >= f {
                    bail!("row {lineno}: too many columns");
                }
                vals.push(tok.parse::<f64>().with_context(|| format!("row {lineno}"))?);
            }
            labels.push(label);
            insts.push(inst);
        }
        if ds.train.len() != ds.train_labels.len() * f
            || ds.test.len() != ds.test_labels.len() * f
        {
            bail!("ragged csv");
        }
        Ok(ds)
    }

    pub fn train_rows(&self) -> usize {
        self.train_labels.len()
    }

    pub fn test_rows(&self) -> usize {
        self.test_labels.len()
    }

    pub fn train_row(&self, i: usize) -> &[f64] {
        &self.train[i * self.n_features..(i + 1) * self.n_features]
    }

    pub fn test_row(&self, i: usize) -> &[f64] {
        &self.test[i * self.n_features..(i + 1) * self.n_features]
    }

    /// Per-feature mean/std over the train split (the normalization every
    /// detector shares).
    pub fn train_scaler(&self) -> (Vec<f64>, Vec<f64>) {
        let f = self.n_features;
        let n = self.train_rows().max(1) as f64;
        let mut mean = vec![0.0; f];
        for i in 0..self.train_rows() {
            for (m, x) in mean.iter_mut().zip(self.train_row(i)) {
                *m += x;
            }
        }
        for m in mean.iter_mut() {
            *m /= n;
        }
        let mut var = vec![0.0; f];
        for i in 0..self.train_rows() {
            for ((v, x), m) in var.iter_mut().zip(self.train_row(i)).zip(&mean) {
                *v += (x - m) * (x - m);
            }
        }
        let std = var.into_iter().map(|v| (v / n).sqrt().max(1e-6)).collect();
        (mean, std)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Write;

    fn tiny_csv() -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("enova_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("d.csv");
        let mut f = std::fs::File::create(&path).unwrap();
        writeln!(
            f,
            "instance,split,label,{}",
            crate::metrics::COLUMNS.join(",")
        )
        .unwrap();
        for i in 0..10 {
            let split = if i < 6 { 0 } else { 1 };
            let label = u8::from(i == 8);
            writeln!(
                f,
                "0,{split},{label},{},2,3,0,4.5,0.5,0.6,0.1",
                i as f64
            )
            .unwrap();
        }
        path
    }

    #[test]
    fn loads_and_splits() {
        let ds = DetectionDataset::load(&tiny_csv()).unwrap();
        assert_eq!(ds.train_rows(), 6);
        assert_eq!(ds.test_rows(), 4);
        assert_eq!(ds.test_labels, vec![0, 0, 1, 0]);
        assert_eq!(ds.train_row(2)[0], 2.0);
        let (mean, std) = ds.train_scaler();
        assert_eq!(mean.len(), 8);
        assert!((mean[0] - 2.5).abs() < 1e-9);
        assert!(std.iter().all(|&s| s > 0.0));
    }

    #[test]
    fn rejects_bad_header() {
        let dir = std::env::temp_dir().join("enova_test_ds");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.csv");
        std::fs::write(&path, "a,b,c\n1,2,3\n").unwrap();
        assert!(DetectionDataset::load(&path).is_err());
    }
}
