//! Request task clustering (§IV-A-3): cosine-similarity request graph +
//! modularity-maximizing community detection (eq. 7, Louvain-style), then
//! per-community output-length KDE for `max_tokens`, and centroid
//! assignment for new requests.
//!
//! Embeddings come from [`crate::runtime::embedder`] in production; the
//! algorithms here are embedding-agnostic (unit vectors in).

use crate::config::determine_max_tokens;

pub fn cosine(a: &[f64], b: &[f64]) -> f64 {
    let mut dot = 0.0;
    let mut na = 0.0;
    let mut nb = 0.0;
    for (x, y) in a.iter().zip(b) {
        dot += x * y;
        na += x * x;
        nb += y * y;
    }
    if na <= 0.0 || nb <= 0.0 {
        0.0
    } else {
        dot / (na * nb).sqrt()
    }
}

/// Weighted undirected request graph: edge (i,j) when cosine ≥ threshold.
pub struct RequestGraph {
    pub n: usize,
    /// adjacency: (neighbor, weight)
    pub adj: Vec<Vec<(usize, f64)>>,
    pub total_weight: f64, // m in eq. 7
}

impl RequestGraph {
    pub fn build(embeddings: &[Vec<f64>], threshold: f64) -> RequestGraph {
        let n = embeddings.len();
        let mut adj = vec![Vec::new(); n];
        let mut total = 0.0;
        for i in 0..n {
            for j in i + 1..n {
                let w = cosine(&embeddings[i], &embeddings[j]);
                if w >= threshold {
                    adj[i].push((j, w));
                    adj[j].push((i, w));
                    total += w;
                }
            }
        }
        RequestGraph {
            n,
            adj,
            total_weight: total,
        }
    }

    pub fn degree(&self, i: usize) -> f64 {
        self.adj[i].iter().map(|&(_, w)| w).sum()
    }
}

/// Louvain phase-1 (local moving) iterated to a fixed point: maximizes the
/// modularity objective of eq. 7. Returns a community id per node.
pub fn louvain(graph: &RequestGraph) -> Vec<usize> {
    let n = graph.n;
    let m2 = (2.0 * graph.total_weight).max(1e-12);
    let mut community: Vec<usize> = (0..n).collect();
    let degrees: Vec<f64> = (0..n).map(|i| graph.degree(i)).collect();
    let mut comm_degree: Vec<f64> = degrees.clone();

    let mut improved = true;
    let mut rounds = 0;
    while improved && rounds < 32 {
        improved = false;
        rounds += 1;
        for i in 0..n {
            let current = community[i];
            // weights from i into each neighboring community
            let mut into: std::collections::BTreeMap<usize, f64> = Default::default();
            for &(j, w) in &graph.adj[i] {
                *into.entry(community[j]).or_default() += w;
            }
            // detach i
            comm_degree[current] -= degrees[i];
            let base = into.get(&current).copied().unwrap_or(0.0)
                - degrees[i] * comm_degree[current] / m2;
            let mut best = (current, base);
            for (&c, &w_in) in &into {
                if c == current {
                    continue;
                }
                let gain = w_in - degrees[i] * comm_degree[c] / m2;
                if gain > best.1 + 1e-12 {
                    best = (c, gain);
                }
            }
            community[i] = best.0;
            comm_degree[best.0] += degrees[i];
            if best.0 != current {
                improved = true;
            }
        }
    }
    relabel(&mut community);
    community
}

fn relabel(community: &mut [usize]) {
    let mut map = std::collections::BTreeMap::new();
    for c in community.iter_mut() {
        let next = map.len();
        *c = *map.entry(*c).or_insert(next);
    }
}

/// Modularity Q of an assignment (eq. 7), for tests/diagnostics.
pub fn modularity(graph: &RequestGraph, community: &[usize]) -> f64 {
    let m2 = (2.0 * graph.total_weight).max(1e-12);
    let n_comms = community.iter().copied().max().map(|c| c + 1).unwrap_or(0);
    let mut within = vec![0.0; n_comms];
    let mut degree = vec![0.0; n_comms];
    for i in 0..graph.n {
        degree[community[i]] += graph.degree(i);
        for &(j, w) in &graph.adj[i] {
            if community[j] == community[i] {
                within[community[i]] += w; // counts each edge twice
            }
        }
    }
    (0..n_comms)
        .map(|c| within[c] / m2 - (degree[c] / m2).powi(2))
        .sum()
}

/// A fitted clustering: centroids + per-community max_tokens.
#[derive(Debug, Clone)]
pub struct Communities {
    pub centroids: Vec<Vec<f64>>,
    pub max_tokens: Vec<usize>,
    pub sizes: Vec<usize>,
}

impl Communities {
    /// Fit from embeddings + the observed output lengths of each request.
    pub fn fit(
        embeddings: &[Vec<f64>],
        output_lens: &[usize],
        threshold: f64,
        fallback_max_tokens: usize,
    ) -> Communities {
        assert_eq!(embeddings.len(), output_lens.len());
        let graph = RequestGraph::build(embeddings, threshold);
        let assign = louvain(&graph);
        let n_comms = assign.iter().copied().max().map(|c| c + 1).unwrap_or(0);
        let dim = embeddings.first().map(|e| e.len()).unwrap_or(0);
        let mut centroids = vec![vec![0.0; dim]; n_comms];
        let mut sizes = vec![0usize; n_comms];
        let mut lens: Vec<Vec<f64>> = vec![Vec::new(); n_comms];
        for (i, &c) in assign.iter().enumerate() {
            sizes[c] += 1;
            lens[c].push(output_lens[i] as f64);
            for (acc, x) in centroids[c].iter_mut().zip(&embeddings[i]) {
                *acc += x;
            }
        }
        for (c, centroid) in centroids.iter_mut().enumerate() {
            let norm: f64 = centroid.iter().map(|x| x * x).sum::<f64>().sqrt();
            if norm > 0.0 {
                for x in centroid.iter_mut() {
                    *x /= norm;
                }
            }
            let _ = c;
        }
        let max_tokens = lens
            .iter()
            .map(|l| determine_max_tokens(l).unwrap_or(fallback_max_tokens))
            .collect();
        Communities {
            centroids,
            max_tokens,
            sizes,
        }
    }

    /// Assign a new request to the nearest centroid; returns (community,
    /// its max_tokens).
    pub fn assign(&self, embedding: &[f64]) -> Option<(usize, usize)> {
        let (mut best, mut best_sim) = (None, -1.0);
        for (c, centroid) in self.centroids.iter().enumerate() {
            let s = cosine(embedding, centroid);
            if s > best_sim {
                best_sim = s;
                best = Some(c);
            }
        }
        best.map(|c| (c, self.max_tokens[c]))
    }

    pub fn len(&self) -> usize {
        self.centroids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.centroids.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    /// Synthetic unit embeddings around k well-separated anchors.
    fn synth(k: usize, per: usize, seed: u64) -> (Vec<Vec<f64>>, Vec<usize>) {
        let mut rng = Pcg64::new(seed);
        let dim = 16;
        let anchors: Vec<Vec<f64>> = (0..k)
            .map(|c| {
                let mut v = vec![0.0; dim];
                v[c * 3] = 1.0;
                v[c * 3 + 1] = 0.5;
                v
            })
            .collect();
        let mut out = Vec::new();
        let mut labels = Vec::new();
        for (c, anchor) in anchors.iter().enumerate() {
            for _ in 0..per {
                let mut v: Vec<f64> = anchor
                    .iter()
                    .map(|&a| a + rng.normal() * 0.08)
                    .collect();
                let n: f64 = v.iter().map(|x| x * x).sum::<f64>().sqrt();
                v.iter_mut().for_each(|x| *x /= n);
                out.push(v);
                labels.push(c);
            }
        }
        (out, labels)
    }

    #[test]
    fn louvain_recovers_planted_communities() {
        let (emb, labels) = synth(4, 25, 1);
        let graph = RequestGraph::build(&emb, 0.7);
        let assign = louvain(&graph);
        // same-label pairs should share communities, cross-label shouldn't
        let mut agree = 0;
        let mut total = 0;
        for i in 0..emb.len() {
            for j in i + 1..emb.len() {
                let same_label = labels[i] == labels[j];
                let same_comm = assign[i] == assign[j];
                if same_label == same_comm {
                    agree += 1;
                }
                total += 1;
            }
        }
        let rand_index = agree as f64 / total as f64;
        assert!(rand_index > 0.95, "rand index {rand_index}");
        let q = modularity(&graph, &assign);
        assert!(q > 0.5, "modularity {q}");
    }

    #[test]
    fn louvain_beats_trivial_assignment() {
        let (emb, _) = synth(3, 20, 2);
        let graph = RequestGraph::build(&emb, 0.7);
        let assign = louvain(&graph);
        let trivial: Vec<usize> = vec![0; emb.len()];
        assert!(modularity(&graph, &assign) > modularity(&graph, &trivial) + 0.2);
    }

    #[test]
    fn communities_fit_and_assign() {
        let (emb, labels) = synth(3, 30, 3);
        let mut rng = Pcg64::new(4);
        // community 0 writes long outputs, others short
        let lens: Vec<usize> = labels
            .iter()
            .map(|&l| {
                if l == 0 {
                    (600.0 + rng.normal() * 60.0) as usize
                } else {
                    (80.0 + rng.normal() * 10.0) as usize
                }
            })
            .collect();
        let comms = Communities::fit(&emb, &lens, 0.7, 1024);
        assert!(comms.len() >= 3, "found {} communities", comms.len());
        // a fresh point near anchor 0 should inherit the long max_tokens
        let (c0, mt0) = comms.assign(&emb[0]).unwrap();
        assert!(mt0 > 400, "community {c0} max_tokens {mt0}");
        let (_, mt1) = comms.assign(&emb[emb.len() - 1]).unwrap();
        assert!(mt1 < 200, "short community got {mt1}");
    }

    #[test]
    fn empty_and_degenerate() {
        let comms = Communities::fit(&[], &[], 0.7, 512);
        assert!(comms.is_empty());
        assert!(comms.assign(&[1.0, 0.0]).is_none());
    }
}
