//! Hand-rolled HTTP/1.1 request parsing and response writing (hyper is not
//! in the offline crate set). Deliberately small: enough of RFC 9112 for an
//! OpenAI-style JSON API — start line, headers, Content-Length bodies,
//! keep-alive. Every malformed input maps to a 4xx [`HttpError`], never a
//! panic; bounded line/header/body limits keep a hostile peer from forcing
//! unbounded allocation.
//!
//! Parsing is *incremental and resumable* ([`RequestParser`]): bytes are
//! fed in as they arrive off a nonblocking socket and the parser suspends
//! mid-line, mid-headers or mid-body without losing state — what the
//! reactor's connection state machines are built on. The blocking
//! [`read_request`] is a thin loop over the same state machine, so both
//! ingress paths share one grammar.

use std::collections::BTreeMap;
use std::io::{BufRead, Write};

/// Longest accepted start/header line, bytes.
pub const MAX_LINE_BYTES: usize = 16 * 1024;
/// Most headers accepted per request.
pub const MAX_HEADERS: usize = 128;

#[derive(Debug, Clone, PartialEq)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError {
            status,
            message: message.into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// path with the query string stripped
    pub path: String,
    pub query: Option<String>,
    pub version: String,
    /// header names lowercased
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// HTTP/1.1 defaults to keep-alive unless the client asks to close.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) => !v.eq_ignore_ascii_case("close"),
            None => self.version != "HTTP/1.0",
        }
    }

    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::new(400, "request body is not valid UTF-8"))
    }
}

/// What one [`RequestParser::poll`] step produced.
#[derive(Debug)]
pub enum Poll {
    /// A complete request was parsed off the buffered bytes.
    Ready(Request),
    /// The buffered bytes don't hold a complete request yet; feed more.
    NeedMore,
    /// The peer sent only stray blank lines — close the connection
    /// cleanly, exactly like the legacy blocking path did.
    Close,
}

/// A partially-parsed request head, carried across `NeedMore` suspensions.
#[derive(Debug)]
struct Partial {
    method: String,
    path: String,
    query: Option<String>,
    version: String,
    headers: BTreeMap<String, String>,
}

impl Partial {
    fn into_request(self, body: Vec<u8>) -> Request {
        Request {
            method: self.method,
            path: self.path,
            query: self.query,
            version: self.version,
            headers: self.headers,
            body,
        }
    }
}

#[derive(Debug)]
enum ParseState {
    StartLine,
    Headers(Partial),
    Body(Partial, usize),
}

/// Incremental, resumable HTTP/1.1 request parser: [`feed`] bytes as they
/// arrive, [`poll`] for complete requests. Suspends losslessly at any byte
/// boundary (mid-line, mid-headers, mid-body), so a nonblocking reactor
/// can park a connection between readable events — and a slow-loris peer
/// holds a buffer, not a thread. Enforces the same limits and maps to the
/// same [`HttpError`]s as the blocking [`read_request`], which is now a
/// thin loop over this state machine. After an `Err` the parser is
/// poisoned; close the connection (every caller already does).
///
/// [`feed`]: RequestParser::feed
/// [`poll`]: RequestParser::poll
#[derive(Debug)]
pub struct RequestParser {
    max_body_bytes: usize,
    buf: Vec<u8>,
    /// parse cursor: `buf[..pos]` is consumed, `buf[pos..]` pending
    pos: usize,
    state: ParseState,
    /// stray blank lines tolerated before a start line (capped at 4)
    blanks: usize,
}

impl RequestParser {
    pub fn new(max_body_bytes: usize) -> RequestParser {
        RequestParser {
            max_body_bytes,
            buf: Vec::new(),
            pos: 0,
            state: ParseState::StartLine,
            blanks: 0,
        }
    }

    /// Append bytes read off the wire. Cheap; parsing happens in `poll`.
    pub fn feed(&mut self, bytes: &[u8]) {
        self.buf.extend_from_slice(bytes);
    }

    /// True when the parser sits cleanly between requests with nothing
    /// buffered — the only state in which an idle connection may be
    /// reaped without losing a request in flight.
    pub fn is_clean(&self) -> bool {
        matches!(self.state, ParseState::StartLine) && self.pos >= self.buf.len()
    }

    /// True once the head is complete and body bytes are being awaited.
    pub fn in_body(&self) -> bool {
        matches!(self.state, ParseState::Body(..))
    }

    /// Unconsumed bytes (pipelined follow-up requests), surrendered so the
    /// connection can move between threads; the parser resets to clean.
    pub fn take_leftover(&mut self) -> Vec<u8> {
        let rest = self.buf.split_off(self.pos);
        self.buf.clear();
        self.pos = 0;
        self.state = ParseState::StartLine;
        rest
    }

    /// The peer half-closed. `Ok(())` iff the close is clean (between
    /// requests); mid-request EOF maps to the legacy 400s.
    pub fn eof(&self) -> Result<(), HttpError> {
        let mid_line = self.pos < self.buf.len();
        match &self.state {
            ParseState::StartLine if !mid_line => Ok(()),
            ParseState::StartLine => Err(HttpError::new(400, "truncated request line")),
            ParseState::Headers(_) if mid_line => {
                Err(HttpError::new(400, "truncated request line"))
            }
            ParseState::Headers(_) => Err(HttpError::new(400, "EOF inside headers")),
            ParseState::Body(..) => Err(HttpError::new(400, "truncated body")),
        }
    }

    /// Advance the state machine as far as the buffered bytes allow.
    pub fn poll(&mut self) -> Result<Poll, HttpError> {
        loop {
            match std::mem::replace(&mut self.state, ParseState::StartLine) {
                ParseState::StartLine => match self.take_line()? {
                    None => return Ok(Poll::NeedMore),
                    Some(l) if l.is_empty() => {
                        // tolerate a few stray blank lines between
                        // pipelined requests; a peer sending only blanks
                        // gets a clean close
                        self.blanks += 1;
                        if self.blanks >= 4 {
                            self.compact();
                            return Ok(Poll::Close);
                        }
                    }
                    Some(l) => self.state = ParseState::Headers(parse_start_line(&l)?),
                },
                ParseState::Headers(mut p) => match self.take_line()? {
                    None => {
                        self.state = ParseState::Headers(p);
                        return Ok(Poll::NeedMore);
                    }
                    Some(l) if l.is_empty() => match self.body_len(&p)? {
                        0 => {
                            self.finish_one();
                            return Ok(Poll::Ready(p.into_request(Vec::new())));
                        }
                        len => self.state = ParseState::Body(p, len),
                    },
                    Some(l) => {
                        push_header(&mut p, &l)?;
                        self.state = ParseState::Headers(p);
                    }
                },
                ParseState::Body(p, len) => {
                    if self.buf.len() - self.pos < len {
                        self.state = ParseState::Body(p, len);
                        return Ok(Poll::NeedMore);
                    }
                    let body = self.buf[self.pos..self.pos + len].to_vec();
                    self.pos += len;
                    self.finish_one();
                    return Ok(Poll::Ready(p.into_request(body)));
                }
            }
        }
    }

    /// One `\n`-terminated line off the buffer (`\r` trimmed), or `None`
    /// when no full line is buffered yet. Bounded: an unterminated run
    /// longer than [`MAX_LINE_BYTES`] is a 431 without waiting for the
    /// newline, so a hostile peer cannot force unbounded buffering.
    fn take_line(&mut self) -> Result<Option<String>, HttpError> {
        let avail = &self.buf[self.pos..];
        let Some(idx) = avail.iter().position(|&b| b == b'\n') else {
            if avail.len() > MAX_LINE_BYTES {
                return Err(HttpError::new(431, "header line too long"));
            }
            return Ok(None);
        };
        if idx > MAX_LINE_BYTES {
            return Err(HttpError::new(431, "header line too long"));
        }
        let mut line = avail[..idx].to_vec();
        if line.last() == Some(&b'\r') {
            line.pop();
        }
        self.pos += idx + 1;
        String::from_utf8(line)
            .map(Some)
            .map_err(|_| HttpError::new(400, "header line is not valid UTF-8"))
    }

    /// Body length once the head is complete, enforcing the framing rules.
    fn body_len(&self, p: &Partial) -> Result<usize, HttpError> {
        // Transfer-Encoding is rejected outright — including alongside a
        // Content-Length, where honoring either header invites request
        // smuggling / connection desync (RFC 9112 §6.1)
        if p.headers.contains_key("transfer-encoding") {
            return Err(HttpError::new(501, "chunked request bodies not supported"));
        }
        match p.headers.get("content-length") {
            Some(v) => {
                let len: usize = v
                    .parse()
                    .map_err(|_| HttpError::new(400, format!("bad Content-Length: {v:?}")))?;
                if len > self.max_body_bytes {
                    return Err(HttpError::new(
                        413,
                        format!("body of {len} bytes exceeds limit of {}", self.max_body_bytes),
                    ));
                }
                Ok(len)
            }
            None if matches!(p.method.as_str(), "POST" | "PUT" | "PATCH") => {
                Err(HttpError::new(411, "Content-Length required"))
            }
            None => Ok(0),
        }
    }

    /// A request completed: drop its consumed bytes, keep any pipelined
    /// tail, rearm for the next request.
    fn finish_one(&mut self) {
        self.compact();
        self.blanks = 0;
    }

    fn compact(&mut self) {
        self.buf.drain(..self.pos);
        self.pos = 0;
    }
}

fn parse_start_line(start: &str) -> Result<Partial, HttpError> {
    let mut parts = start.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if v.starts_with("HTTP/") => (m, t, v),
        _ => return Err(HttpError::new(400, format!("malformed start line: {start:?}"))),
    };
    if !method.chars().all(|c| c.is_ascii_uppercase()) {
        return Err(HttpError::new(400, "malformed method"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };
    Ok(Partial {
        method: method.to_string(),
        path,
        query,
        version: version.to_string(),
        headers: BTreeMap::new(),
    })
}

fn push_header(p: &mut Partial, line: &str) -> Result<(), HttpError> {
    let (name, value) = line
        .split_once(':')
        .ok_or_else(|| HttpError::new(400, format!("malformed header: {line:?}")))?;
    if name.trim().is_empty() {
        return Err(HttpError::new(400, "empty header name"));
    }
    p.headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
    if p.headers.len() > MAX_HEADERS {
        return Err(HttpError::new(431, "too many headers"));
    }
    Ok(())
}

/// Parse one request off the wire. `Ok(None)` = connection closed cleanly
/// between requests (keep-alive loop should just exit). A thin blocking
/// loop over [`RequestParser`], so both ingress paths share one grammar.
pub fn read_request<R: BufRead>(
    r: &mut R,
    max_body_bytes: usize,
) -> Result<Option<Request>, HttpError> {
    let mut parser = RequestParser::new(max_body_bytes);
    loop {
        match parser.poll()? {
            Poll::Ready(req) => return Ok(Some(req)),
            Poll::Close => return Ok(None),
            Poll::NeedMore => {}
        }
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            // timeouts / resets: a truncated body is reported, anything
            // earlier drops the connection silently (legacy behavior)
            Err(_) if parser.in_body() => {
                return Err(HttpError::new(400, "truncated body"));
            }
            Err(_) => return Ok(None),
        };
        if chunk.is_empty() {
            return parser.eof().map(|_| None);
        }
        let n = chunk.len();
        parser.feed(chunk);
        r.consume(n);
    }
}

pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        410 => "Gone",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    pub extra_headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn prometheus(body: String) -> Response {
        Response {
            status: 200,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: body.into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Response {
        self.extra_headers.push((name.to_string(), value.into()));
        self
    }

    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        for (name, value) in &self.extra_headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>, HttpError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()), 1024)
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            "POST /v1/completions?probe=1 HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.query.as_deref(), Some("probe=1"));
        assert_eq!(req.body, b"abcd");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.keep_alive());
    }

    #[test]
    fn keep_alive_pipelining() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\nGET /ready HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes().to_vec());
        let a = read_request(&mut cur, 1024).unwrap().unwrap();
        assert_eq!(a.path, "/healthz");
        assert!(a.keep_alive());
        let b = read_request(&mut cur, 1024).unwrap().unwrap();
        assert_eq!(b.path, "/ready");
        assert!(!b.keep_alive());
        assert!(read_request(&mut cur, 1024).unwrap().is_none());
    }

    #[test]
    fn malformed_start_line_is_400() {
        for raw in [
            "NOT-HTTP\r\n\r\n",
            "GET\r\n\r\n",
            "GET / HTTP/1.1 extra\r\n\r\n",
            "get / HTTP/1.1\r\n\r\n",
            "GET / FTP/1.1\r\n\r\n",
        ] {
            let err = parse(raw).unwrap_err();
            assert_eq!(err.status, 400, "{raw:?} -> {err:?}");
        }
    }

    #[test]
    fn missing_content_length_is_411() {
        let err = parse("POST /v1/completions HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 411);
    }

    #[test]
    fn bad_content_length_is_400() {
        let err = parse("POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn oversized_body_is_413_not_panic() {
        let err = parse("POST / HTTP/1.1\r\nContent-Length: 999999\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 413);
    }

    #[test]
    fn truncated_body_is_400() {
        let err = parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn transfer_encoding_is_rejected_even_with_content_length() {
        // honoring either header when both are present invites smuggling
        let err = parse(
            "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 4\r\n\r\n4\r\nab",
        )
        .unwrap_err();
        assert_eq!(err.status, 501);
        let err = parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 501);
    }

    #[test]
    fn oversized_header_line_is_431() {
        let raw = format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(MAX_LINE_BYTES + 10));
        let err = parse(&raw).unwrap_err();
        assert_eq!(err.status, 431);
    }

    #[test]
    fn header_without_colon_is_400() {
        let err = parse("GET / HTTP/1.1\r\nbroken header\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn eof_is_clean_none() {
        assert!(parse("").unwrap().is_none());
        assert!(parse("\r\n\r\n").unwrap().is_none());
    }

    #[test]
    fn incremental_parser_survives_byte_at_a_time_feed() {
        // slow-loris shape: the whole request dribbles in one byte per
        // feed; the parser suspends and resumes without losing state
        let raw = "POST /v1/completions HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd";
        let mut parser = RequestParser::new(1024);
        let mut got = None;
        for (i, b) in raw.as_bytes().iter().enumerate() {
            parser.feed(&[*b]);
            match parser.poll().unwrap() {
                Poll::Ready(req) => {
                    assert_eq!(i, raw.len() - 1, "completed only on the last byte");
                    got = Some(req);
                }
                Poll::NeedMore => {}
                Poll::Close => panic!("spurious close"),
            }
        }
        let req = got.expect("request completed");
        assert_eq!(req.method, "POST");
        assert_eq!(req.body, b"abcd");
        assert!(parser.is_clean());
    }

    #[test]
    fn incremental_parser_pipelines_and_surrenders_leftover() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\nGET /ready HTTP/1.1\r\n\r\n";
        let mut parser = RequestParser::new(1024);
        parser.feed(raw.as_bytes());
        let first = match parser.poll().unwrap() {
            Poll::Ready(req) => req,
            other => panic!("expected first request, got {other:?}"),
        };
        assert_eq!(first.path, "/healthz");
        assert!(!parser.is_clean(), "pipelined tail still buffered");
        // a connection moving to another thread takes its tail along...
        let leftover = parser.take_leftover();
        assert!(parser.is_clean());
        // ...and a fresh parser resumes exactly where this one stopped
        let mut resumed = RequestParser::new(1024);
        resumed.feed(&leftover);
        match resumed.poll().unwrap() {
            Poll::Ready(req) => assert_eq!(req.path, "/ready"),
            other => panic!("expected second request, got {other:?}"),
        }
        assert!(matches!(resumed.poll().unwrap(), Poll::NeedMore));
    }

    #[test]
    fn incremental_parser_eof_maps_to_legacy_errors() {
        // clean between requests
        assert!(RequestParser::new(1024).eof().is_ok());
        // mid start line
        let mut p = RequestParser::new(1024);
        p.feed(b"GET /hea");
        assert!(matches!(p.poll().unwrap(), Poll::NeedMore));
        assert_eq!(p.eof().unwrap_err().status, 400);
        // between headers
        let mut p = RequestParser::new(1024);
        p.feed(b"GET / HTTP/1.1\r\nHost: x\r\n");
        assert!(matches!(p.poll().unwrap(), Poll::NeedMore));
        assert_eq!(p.eof().unwrap_err().message, "EOF inside headers");
        // mid body
        let mut p = RequestParser::new(1024);
        p.feed(b"POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc");
        assert!(matches!(p.poll().unwrap(), Poll::NeedMore));
        assert!(p.in_body());
        assert_eq!(p.eof().unwrap_err().message, "truncated body");
    }

    #[test]
    fn incremental_parser_bounds_unterminated_lines() {
        let mut p = RequestParser::new(1024);
        p.feed("x".repeat(MAX_LINE_BYTES + 1).as_bytes());
        assert_eq!(p.poll().unwrap_err().status, 431);
    }

    #[test]
    fn response_writes_well_formed_http() {
        let mut out = Vec::new();
        Response::json(429, "{}".into())
            .with_header("Retry-After", "1")
            .write_to(&mut out, false)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }
}
