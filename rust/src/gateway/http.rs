//! Hand-rolled HTTP/1.1 request parsing and response writing (hyper is not
//! in the offline crate set). Deliberately small: enough of RFC 9112 for an
//! OpenAI-style JSON API — start line, headers, Content-Length bodies,
//! keep-alive. Every malformed input maps to a 4xx [`HttpError`], never a
//! panic; bounded line/header/body limits keep a hostile peer from forcing
//! unbounded allocation.

use std::collections::BTreeMap;
use std::io::{BufRead, Write};

/// Longest accepted start/header line, bytes.
pub const MAX_LINE_BYTES: usize = 16 * 1024;
/// Most headers accepted per request.
pub const MAX_HEADERS: usize = 128;

#[derive(Debug, Clone, PartialEq)]
pub struct HttpError {
    pub status: u16,
    pub message: String,
}

impl HttpError {
    pub fn new(status: u16, message: impl Into<String>) -> HttpError {
        HttpError {
            status,
            message: message.into(),
        }
    }
}

#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    /// path with the query string stripped
    pub path: String,
    pub query: Option<String>,
    pub version: String,
    /// header names lowercased
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// HTTP/1.1 defaults to keep-alive unless the client asks to close.
    pub fn keep_alive(&self) -> bool {
        match self.header("connection") {
            Some(v) => !v.eq_ignore_ascii_case("close"),
            None => self.version != "HTTP/1.0",
        }
    }

    pub fn body_str(&self) -> Result<&str, HttpError> {
        std::str::from_utf8(&self.body)
            .map_err(|_| HttpError::new(400, "request body is not valid UTF-8"))
    }
}

/// Read one line (terminated by `\n`, `\r` trimmed) without unbounded
/// buffering. `Ok(None)` means clean EOF before any byte.
fn read_line_limited<R: BufRead>(r: &mut R, cap: usize) -> Result<Option<String>, HttpError> {
    let mut line: Vec<u8> = Vec::new();
    loop {
        let chunk = match r.fill_buf() {
            Ok(c) => c,
            // timeouts / resets: drop the connection silently
            Err(_) => return Ok(None),
        };
        if chunk.is_empty() {
            // EOF: mid-line EOF is a truncated request
            if line.is_empty() {
                return Ok(None);
            }
            return Err(HttpError::new(400, "truncated request line"));
        }
        if let Some(pos) = chunk.iter().position(|&b| b == b'\n') {
            line.extend_from_slice(&chunk[..pos]);
            r.consume(pos + 1);
            break;
        }
        line.extend_from_slice(chunk);
        let n = chunk.len();
        r.consume(n);
        if line.len() > cap {
            return Err(HttpError::new(431, "header line too long"));
        }
    }
    if line.len() > cap {
        return Err(HttpError::new(431, "header line too long"));
    }
    if line.last() == Some(&b'\r') {
        line.pop();
    }
    String::from_utf8(line)
        .map(Some)
        .map_err(|_| HttpError::new(400, "header line is not valid UTF-8"))
}

/// Parse one request off the wire. `Ok(None)` = connection closed cleanly
/// between requests (keep-alive loop should just exit).
pub fn read_request<R: BufRead>(
    r: &mut R,
    max_body_bytes: usize,
) -> Result<Option<Request>, HttpError> {
    // tolerate a few stray blank lines between pipelined requests
    let mut start = String::new();
    for _ in 0..4 {
        match read_line_limited(r, MAX_LINE_BYTES)? {
            None => return Ok(None),
            Some(l) if l.is_empty() => continue,
            Some(l) => {
                start = l;
                break;
            }
        }
    }
    if start.is_empty() {
        return Ok(None);
    }

    let mut parts = start.split_whitespace();
    let (method, target, version) = match (parts.next(), parts.next(), parts.next(), parts.next())
    {
        (Some(m), Some(t), Some(v), None) if v.starts_with("HTTP/") => (m, t, v),
        _ => return Err(HttpError::new(400, format!("malformed start line: {start:?}"))),
    };
    if !method.chars().all(|c| c.is_ascii_uppercase()) {
        return Err(HttpError::new(400, "malformed method"));
    }
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), Some(q.to_string())),
        None => (target.to_string(), None),
    };

    let mut headers = BTreeMap::new();
    loop {
        let line = match read_line_limited(r, MAX_LINE_BYTES)? {
            None => return Err(HttpError::new(400, "EOF inside headers")),
            Some(l) => l,
        };
        if line.is_empty() {
            break;
        }
        let (name, value) = line
            .split_once(':')
            .ok_or_else(|| HttpError::new(400, format!("malformed header: {line:?}")))?;
        if name.trim().is_empty() {
            return Err(HttpError::new(400, "empty header name"));
        }
        headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        if headers.len() > MAX_HEADERS {
            return Err(HttpError::new(431, "too many headers"));
        }
    }

    let has_body_method = matches!(method, "POST" | "PUT" | "PATCH");
    // Transfer-Encoding is rejected outright — including alongside a
    // Content-Length, where honoring either header invites request
    // smuggling / connection desync (RFC 9112 §6.1)
    if headers.contains_key("transfer-encoding") {
        return Err(HttpError::new(501, "chunked request bodies not supported"));
    }
    let body = match headers.get("content-length") {
        Some(v) => {
            let len: usize = v
                .parse()
                .map_err(|_| HttpError::new(400, format!("bad Content-Length: {v:?}")))?;
            if len > max_body_bytes {
                return Err(HttpError::new(
                    413,
                    format!("body of {len} bytes exceeds limit of {max_body_bytes}"),
                ));
            }
            let mut buf = vec![0u8; len];
            std::io::Read::read_exact(r, &mut buf)
                .map_err(|_| HttpError::new(400, "truncated body"))?;
            buf
        }
        None if has_body_method => {
            return Err(HttpError::new(411, "Content-Length required"));
        }
        None => Vec::new(),
    };

    Ok(Some(Request {
        method: method.to_string(),
        path,
        query,
        version: version.to_string(),
        headers,
        body,
    }))
}

pub fn status_text(status: u16) -> &'static str {
    match status {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        409 => "Conflict",
        411 => "Length Required",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        431 => "Request Header Fields Too Large",
        500 => "Internal Server Error",
        501 => "Not Implemented",
        502 => "Bad Gateway",
        503 => "Service Unavailable",
        504 => "Gateway Timeout",
        _ => "Unknown",
    }
}

#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub content_type: &'static str,
    pub body: Vec<u8>,
    pub extra_headers: Vec<(String, String)>,
}

impl Response {
    pub fn json(status: u16, body: String) -> Response {
        Response {
            status,
            content_type: "application/json",
            body: body.into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn text(status: u16, body: impl Into<String>) -> Response {
        Response {
            status,
            content_type: "text/plain; charset=utf-8",
            body: body.into().into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn prometheus(body: String) -> Response {
        Response {
            status: 200,
            content_type: "text/plain; version=0.0.4; charset=utf-8",
            body: body.into_bytes(),
            extra_headers: Vec::new(),
        }
    }

    pub fn with_header(mut self, name: &str, value: impl Into<String>) -> Response {
        self.extra_headers.push((name.to_string(), value.into()));
        self
    }

    pub fn write_to<W: Write>(&self, w: &mut W, keep_alive: bool) -> std::io::Result<()> {
        let mut head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: {}\r\n",
            self.status,
            status_text(self.status),
            self.content_type,
            self.body.len(),
            if keep_alive { "keep-alive" } else { "close" },
        );
        for (name, value) in &self.extra_headers {
            head.push_str(name);
            head.push_str(": ");
            head.push_str(value);
            head.push_str("\r\n");
        }
        head.push_str("\r\n");
        w.write_all(head.as_bytes())?;
        w.write_all(&self.body)?;
        w.flush()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn parse(raw: &str) -> Result<Option<Request>, HttpError> {
        read_request(&mut Cursor::new(raw.as_bytes().to_vec()), 1024)
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            "POST /v1/completions?probe=1 HTTP/1.1\r\nHost: x\r\nContent-Length: 4\r\n\r\nabcd",
        )
        .unwrap()
        .unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/v1/completions");
        assert_eq!(req.query.as_deref(), Some("probe=1"));
        assert_eq!(req.body, b"abcd");
        assert_eq!(req.header("host"), Some("x"));
        assert!(req.keep_alive());
    }

    #[test]
    fn keep_alive_pipelining() {
        let raw = "GET /healthz HTTP/1.1\r\n\r\nGET /ready HTTP/1.1\r\nConnection: close\r\n\r\n";
        let mut cur = Cursor::new(raw.as_bytes().to_vec());
        let a = read_request(&mut cur, 1024).unwrap().unwrap();
        assert_eq!(a.path, "/healthz");
        assert!(a.keep_alive());
        let b = read_request(&mut cur, 1024).unwrap().unwrap();
        assert_eq!(b.path, "/ready");
        assert!(!b.keep_alive());
        assert!(read_request(&mut cur, 1024).unwrap().is_none());
    }

    #[test]
    fn malformed_start_line_is_400() {
        for raw in [
            "NOT-HTTP\r\n\r\n",
            "GET\r\n\r\n",
            "GET / HTTP/1.1 extra\r\n\r\n",
            "get / HTTP/1.1\r\n\r\n",
            "GET / FTP/1.1\r\n\r\n",
        ] {
            let err = parse(raw).unwrap_err();
            assert_eq!(err.status, 400, "{raw:?} -> {err:?}");
        }
    }

    #[test]
    fn missing_content_length_is_411() {
        let err = parse("POST /v1/completions HTTP/1.1\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 411);
    }

    #[test]
    fn bad_content_length_is_400() {
        let err = parse("POST / HTTP/1.1\r\nContent-Length: nope\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn oversized_body_is_413_not_panic() {
        let err = parse("POST / HTTP/1.1\r\nContent-Length: 999999\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 413);
    }

    #[test]
    fn truncated_body_is_400() {
        let err = parse("POST / HTTP/1.1\r\nContent-Length: 10\r\n\r\nabc").unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn transfer_encoding_is_rejected_even_with_content_length() {
        // honoring either header when both are present invites smuggling
        let err = parse(
            "POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\nContent-Length: 4\r\n\r\n4\r\nab",
        )
        .unwrap_err();
        assert_eq!(err.status, 501);
        let err = parse("POST / HTTP/1.1\r\nTransfer-Encoding: chunked\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 501);
    }

    #[test]
    fn oversized_header_line_is_431() {
        let raw = format!("GET /{} HTTP/1.1\r\n\r\n", "x".repeat(MAX_LINE_BYTES + 10));
        let err = parse(&raw).unwrap_err();
        assert_eq!(err.status, 431);
    }

    #[test]
    fn header_without_colon_is_400() {
        let err = parse("GET / HTTP/1.1\r\nbroken header\r\n\r\n").unwrap_err();
        assert_eq!(err.status, 400);
    }

    #[test]
    fn eof_is_clean_none() {
        assert!(parse("").unwrap().is_none());
        assert!(parse("\r\n\r\n").unwrap().is_none());
    }

    #[test]
    fn response_writes_well_formed_http() {
        let mut out = Vec::new();
        Response::json(429, "{}".into())
            .with_header("Retry-After", "1")
            .write_to(&mut out, false)
            .unwrap();
        let text = String::from_utf8(out).unwrap();
        assert!(text.starts_with("HTTP/1.1 429 Too Many Requests\r\n"));
        assert!(text.contains("Content-Length: 2\r\n"));
        assert!(text.contains("Retry-After: 1\r\n"));
        assert!(text.contains("Connection: close\r\n"));
        assert!(text.ends_with("\r\n\r\n{}"));
    }
}
