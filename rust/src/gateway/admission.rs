//! Gateway-side admission control — the queueing-model guardrails of the
//! paper's §III: a token-bucket rate limiter smooths arrival bursts and a
//! bounded in-flight gate caps queued + running requests, so overload turns
//! into fast 429s at the edge instead of unbounded engine queues (the
//! t^p blow-up ENOVA's detector would otherwise have to catch downstream).

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Classic token bucket: `rate` tokens/s refill, `burst` capacity.
#[derive(Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    /// seconds since `epoch` at the last refill (kept as f64 so tests can
    /// drive time deterministically through [`TokenBucket::try_take_at`])
    last: f64,
    epoch: Instant,
}

impl TokenBucket {
    pub fn new(rate: f64, burst: usize) -> TokenBucket {
        let burst = (burst.max(1)) as f64;
        TokenBucket {
            rate: rate.max(0.0),
            burst,
            tokens: burst,
            last: 0.0,
            epoch: Instant::now(),
        }
    }

    /// Take one token at an explicit clock reading (test seam).
    ///
    /// `last` is clamped to be monotonic: a non-monotonic clock reading
    /// (NTP step, test-driven time) must not rewind it, or the span it
    /// rewound over would be refilled a second time on the next call —
    /// minting free tokens.
    pub fn try_take_at(&mut self, now_secs: f64) -> bool {
        let dt = (now_secs - self.last).max(0.0);
        self.last = self.last.max(now_secs);
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    pub fn try_take(&mut self) -> bool {
        let now = self.epoch.elapsed().as_secs_f64();
        self.try_take_at(now)
    }
}

/// Bounded count of requests inside the serving pipeline (engine pending +
/// running). Acquire before dispatch; the returned permit releases on drop.
#[derive(Debug)]
pub struct AdmissionGate {
    cap: usize,
    inflight: AtomicUsize,
}

impl AdmissionGate {
    pub fn new(cap: usize) -> Arc<AdmissionGate> {
        Arc::new(AdmissionGate {
            cap: cap.max(1),
            inflight: AtomicUsize::new(0),
        })
    }

    pub fn try_acquire(gate: &Arc<AdmissionGate>) -> Option<AdmissionPermit> {
        gate.inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                if v < gate.cap {
                    Some(v + 1)
                } else {
                    None
                }
            })
            .ok()?;
        Some(AdmissionPermit {
            gate: Arc::clone(gate),
        })
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[derive(Debug)]
pub struct AdmissionPermit {
    gate: Arc<AdmissionGate>,
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        self.gate.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

// ---------------------------------------------------------------------------
// Multi-tenant admission: SLO tiers, per-tenant budgets, and the cost ledger.
//
// ENOVA's premise is that *diverse co-located applications* on shared GPUs
// degrade service quality unless the stack understands them individually
// (§I); SageServe and DeepServe (PAPERS.md) both split heterogeneous
// workloads into latency-sensitive and batch lanes. The types below give
// every request a tenant identity resolved at ingress, and give every
// tenant an SLO tier, optional private token bucket, queue-time budget,
// a GPU-seconds cost ledger, and a non-consuming arrival-rate sample the
// supervisor's per-tenant forecasters read.
// ---------------------------------------------------------------------------

/// Service-level tier of a tenant. `Latency` and `Standard` ride the fast
/// lane of the worker queues; `Batch` rides the slow lane and never blocks
/// the other two.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum SloTier {
    /// interactive traffic: strictest queue budgets, fast lane, placement
    /// anti-affinity from batch-heavy replicas
    Latency,
    /// the default tier: fast lane, default budgets
    Standard,
    /// throughput traffic: slow lane, shed last, no placement privileges
    Batch,
}

impl SloTier {
    pub fn parse(s: &str) -> Option<SloTier> {
        match s.trim().to_ascii_lowercase().as_str() {
            "latency" => Some(SloTier::Latency),
            "standard" => Some(SloTier::Standard),
            "batch" => Some(SloTier::Batch),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            SloTier::Latency => "latency",
            SloTier::Standard => "standard",
            SloTier::Batch => "batch",
        }
    }

    /// Fast-lane membership: everything except batch.
    pub fn is_fast(self) -> bool {
        !matches!(self, SloTier::Batch)
    }
}

/// Static configuration of one tenant (from `enova.toml` or built-in
/// defaults). Zero-valued limits mean "inherit the gateway-wide setting".
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub id: String,
    pub tier: SloTier,
    /// API keys (`Authorization: Bearer <key>`) that resolve to this tenant
    pub api_keys: Vec<String>,
    /// private token-bucket refill rate in req/s; 0 disables the bucket
    pub rate_limit: f64,
    /// private token-bucket burst; only meaningful with `rate_limit > 0`
    pub rate_burst: usize,
    /// per-tenant queue-time budget in ms; 0 inherits the gateway default
    pub queue_budget_ms: u64,
}

impl TenantSpec {
    pub fn new(id: &str, tier: SloTier) -> TenantSpec {
        TenantSpec {
            id: id.to_string(),
            tier,
            api_keys: Vec::new(),
            rate_limit: 0.0,
            rate_burst: 0,
            queue_budget_ms: 0,
        }
    }
}

/// Seconds of history the arrival-rate ring keeps per tenant.
const RATE_RING_SECS: usize = 32;

/// Fixed ring of per-second arrival counts. Unlike the forecaster feed
/// (which consumes counter deltas), reading a rate here does not consume
/// anything, so `/metrics` and `/cluster/status` can both sample it.
#[derive(Debug)]
struct RateRing {
    counts: [u32; RATE_RING_SECS],
    /// absolute second index the head slot corresponds to
    head: u64,
}

impl RateRing {
    fn new() -> RateRing {
        RateRing {
            counts: [0; RATE_RING_SECS],
            head: 0,
        }
    }

    fn advance(&mut self, sec: u64) {
        if sec <= self.head {
            return;
        }
        let steps = (sec - self.head).min(RATE_RING_SECS as u64);
        for i in 1..=steps {
            let idx = ((self.head + i) % RATE_RING_SECS as u64) as usize;
            self.counts[idx] = 0;
        }
        self.head = sec;
    }

    fn mark(&mut self, sec: u64) {
        self.advance(sec);
        let idx = (self.head % RATE_RING_SECS as u64) as usize;
        self.counts[idx] = self.counts[idx].saturating_add(1);
    }

    /// Mean arrivals/s over the trailing `window` seconds ending at `sec`
    /// (inclusive of the current second).
    fn rate(&mut self, sec: u64, window: u64) -> f64 {
        self.advance(sec);
        let w = window.clamp(1, RATE_RING_SECS as u64 - 1);
        let mut total = 0u64;
        for i in 0..w {
            let idx = ((self.head + RATE_RING_SECS as u64 - i) % RATE_RING_SECS as u64) as usize;
            total += self.counts[idx] as u64;
        }
        total as f64 / w as f64
    }
}

/// Point-in-time view of one tenant for `/metrics` and `/cluster/status`.
#[derive(Debug, Clone)]
pub struct TenantSnapshot {
    pub id: String,
    pub tier: SloTier,
    pub admitted: u64,
    pub rejected: u64,
    pub gpu_seconds: f64,
    pub arrival_rps: f64,
}

/// Live per-tenant state: counters, the private bucket, the cost ledger,
/// and the arrival-rate ring. Shared via `Arc` between the ingress path
/// (resolution + admission), the worker loop (cost crediting) and the
/// supervisor (forecaster feed).
#[derive(Debug)]
pub struct TenantState {
    pub spec: TenantSpec,
    bucket: Option<Mutex<TokenBucket>>,
    admitted: AtomicU64,
    rejected: AtomicU64,
    /// GPU busy time credited at request completion, in microseconds
    gpu_micros: AtomicU64,
    rate: Mutex<RateRing>,
    started: Instant,
}

impl TenantState {
    fn new(spec: TenantSpec) -> Arc<TenantState> {
        let bucket = (spec.rate_limit > 0.0)
            .then(|| Mutex::new(TokenBucket::new(spec.rate_limit, spec.rate_burst.max(1))));
        Arc::new(TenantState {
            spec,
            bucket,
            admitted: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            gpu_micros: AtomicU64::new(0),
            rate: Mutex::new(RateRing::new()),
            started: Instant::now(),
        })
    }

    pub fn id(&self) -> &str {
        &self.spec.id
    }

    pub fn tier(&self) -> SloTier {
        self.spec.tier
    }

    /// Per-tenant token bucket; vacuously true for unthrottled tenants.
    pub fn try_admit(&self) -> bool {
        match &self.bucket {
            Some(b) => b.lock().unwrap().try_take(),
            None => true,
        }
    }

    fn now_sec(&self) -> u64 {
        self.started.elapsed().as_secs()
    }

    pub fn note_admitted(&self) {
        self.note_admitted_at(self.now_sec());
    }

    /// Test seam: record an admission at an explicit second.
    pub fn note_admitted_at(&self, sec: u64) {
        self.admitted.fetch_add(1, Ordering::Relaxed);
        self.rate.lock().unwrap().mark(sec);
    }

    pub fn note_rejected(&self) {
        self.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Credit GPU busy time (submit → completion) to the cost ledger.
    pub fn credit_gpu(&self, secs: f64) {
        if secs.is_finite() && secs > 0.0 {
            self.gpu_micros
                .fetch_add((secs * 1e6).round() as u64, Ordering::Relaxed);
        }
    }

    pub fn gpu_seconds(&self) -> f64 {
        self.gpu_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn admitted_total(&self) -> u64 {
        self.admitted.load(Ordering::Relaxed)
    }

    pub fn rejected_total(&self) -> u64 {
        self.rejected.load(Ordering::Relaxed)
    }

    /// Trailing mean arrival rate over `window_secs` (non-consuming).
    pub fn arrival_rps(&self, window_secs: u64) -> f64 {
        self.arrival_rps_at(self.now_sec(), window_secs)
    }

    /// Test seam: rate read at an explicit second.
    pub fn arrival_rps_at(&self, sec: u64, window_secs: u64) -> f64 {
        self.rate.lock().unwrap().rate(sec, window_secs)
    }

    /// This tenant's queue-time budget, or the gateway default when unset.
    pub fn queue_budget(&self, default: Duration) -> Duration {
        if self.spec.queue_budget_ms > 0 {
            Duration::from_millis(self.spec.queue_budget_ms)
        } else {
            default
        }
    }

    pub fn snapshot(&self) -> TenantSnapshot {
        TenantSnapshot {
            id: self.spec.id.clone(),
            tier: self.spec.tier,
            admitted: self.admitted_total(),
            rejected: self.rejected_total(),
            gpu_seconds: self.gpu_seconds(),
            arrival_rps: self.arrival_rps(5),
        }
    }
}

/// Tenant id every unmatched request resolves to.
pub const DEFAULT_TENANT: &str = "default";

/// Immutable registry of tenants, resolved once per request at ingress.
/// Unknown tenants never fail a request — they fall back to the built-in
/// `default` standard-tier tenant so admission semantics for anonymous
/// traffic are unchanged.
#[derive(Debug)]
pub struct TenantRegistry {
    tenants: BTreeMap<String, Arc<TenantState>>,
    by_key: BTreeMap<String, String>,
}

impl TenantRegistry {
    pub fn new(specs: Vec<TenantSpec>) -> Arc<TenantRegistry> {
        let mut tenants = BTreeMap::new();
        let mut by_key = BTreeMap::new();
        for spec in specs {
            if spec.id.is_empty() || tenants.contains_key(&spec.id) {
                continue;
            }
            for key in &spec.api_keys {
                if !key.is_empty() {
                    by_key.entry(key.clone()).or_insert_with(|| spec.id.clone());
                }
            }
            tenants.insert(spec.id.clone(), TenantState::new(spec));
        }
        tenants
            .entry(DEFAULT_TENANT.to_string())
            .or_insert_with(|| TenantState::new(TenantSpec::new(DEFAULT_TENANT, SloTier::Standard)));
        Arc::new(TenantRegistry { tenants, by_key })
    }

    /// The built-in registry: the three mixture-scenario tenants mapped to
    /// their natural tiers (chat is interactive, summarize is ordinary,
    /// codegen is throughput), plus the `default` fallback.
    pub fn with_defaults() -> Arc<TenantRegistry> {
        TenantRegistry::new(vec![
            TenantSpec::new("chat", SloTier::Latency),
            TenantSpec::new("summarize", SloTier::Standard),
            TenantSpec::new("codegen", SloTier::Batch),
        ])
    }

    /// Resolve a request to a tenant. Precedence: explicit `x-enova-tenant`
    /// header, then API key (`Authorization: Bearer`), then the optional
    /// body hint (OpenAI `user` field), then the default tenant. Unknown
    /// ids and keys fall through rather than erroring.
    pub fn resolve(
        &self,
        header: Option<&str>,
        api_key: Option<&str>,
        hint: Option<&str>,
    ) -> Arc<TenantState> {
        if let Some(t) = header.map(str::trim).and_then(|h| self.tenants.get(h)) {
            return Arc::clone(t);
        }
        if let Some(t) = api_key
            .and_then(|k| self.by_key.get(k.trim()))
            .and_then(|id| self.tenants.get(id))
        {
            return Arc::clone(t);
        }
        if let Some(t) = hint.map(str::trim).and_then(|h| self.tenants.get(h)) {
            return Arc::clone(t);
        }
        self.default_tenant()
    }

    pub fn get(&self, id: &str) -> Option<Arc<TenantState>> {
        self.tenants.get(id).map(Arc::clone)
    }

    pub fn default_tenant(&self) -> Arc<TenantState> {
        Arc::clone(&self.tenants[DEFAULT_TENANT])
    }

    /// All tenants in stable (id-sorted) order.
    pub fn all(&self) -> Vec<Arc<TenantState>> {
        self.tenants.values().map(Arc::clone).collect()
    }

    pub fn snapshots(&self) -> Vec<TenantSnapshot> {
        self.tenants.values().map(|t| t.snapshot()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_enforces_rate_and_burst() {
        let mut b = TokenBucket::new(2.0, 3);
        // burst drains first
        assert!(b.try_take_at(0.0));
        assert!(b.try_take_at(0.0));
        assert!(b.try_take_at(0.0));
        assert!(!b.try_take_at(0.0), "burst exhausted");
        // 0.5s at 2/s refills exactly one token
        assert!(b.try_take_at(0.5));
        assert!(!b.try_take_at(0.5));
        // refill caps at burst
        assert!(b.try_take_at(100.0));
        assert!(b.try_take_at(100.0));
        assert!(b.try_take_at(100.0));
        assert!(!b.try_take_at(100.0));
    }

    #[test]
    fn bucket_tolerates_clock_going_backwards() {
        let mut b = TokenBucket::new(1.0, 1);
        assert!(b.try_take_at(10.0));
        assert!(!b.try_take_at(5.0)); // negative dt must not mint tokens
        // the rewind must not have reset `last`: only 0.5s really elapsed
        // since the take at t=10, so no token yet — the pre-clamp bug
        // refilled [5.0, 10.5] here and handed out a free token
        assert!(!b.try_take_at(10.5));
        assert!(b.try_take_at(11.0)); // a full second since t=10
    }

    #[test]
    fn bucket_never_mints_tokens_from_clock_rewinds() {
        // property: over any clock walk (forwards and backwards), grants
        // never exceed burst + rate × furthest-forward-progress
        crate::util::prop::check("token bucket monotonic refill", 300, |g| {
            let rate = g.f64_in(0.5, 50.0);
            let burst = g.usize_in(1, 16);
            let mut b = TokenBucket::new(rate, burst);
            let mut now = 0.0f64;
            let mut hi = 0.0f64;
            let mut granted = 0usize;
            let steps = g.usize_in(1, 200);
            for _ in 0..steps {
                now = (now + g.f64_in(-2.0, 2.0)).max(0.0);
                hi = hi.max(now);
                if b.try_take_at(now) {
                    granted += 1;
                }
            }
            let budget = burst as f64 + rate * hi + 1e-6;
            if granted as f64 <= budget {
                Ok(())
            } else {
                Err(format!(
                    "granted {granted} tokens > budget {budget:.3} \
                     (rate {rate:.3}, burst {burst}, furthest clock {hi:.3})"
                ))
            }
        });
    }

    #[test]
    fn tier_parse_roundtrip() {
        for tier in [SloTier::Latency, SloTier::Standard, SloTier::Batch] {
            assert_eq!(SloTier::parse(tier.as_str()), Some(tier));
        }
        assert_eq!(SloTier::parse(" LATENCY "), Some(SloTier::Latency));
        assert_eq!(SloTier::parse("gold"), None);
        assert!(SloTier::Latency.is_fast());
        assert!(SloTier::Standard.is_fast());
        assert!(!SloTier::Batch.is_fast());
    }

    #[test]
    fn registry_resolves_header_key_hint_then_default() {
        let mut vip = TenantSpec::new("vip", SloTier::Latency);
        vip.api_keys = vec!["sk-vip-1".to_string()];
        let reg = TenantRegistry::new(vec![vip, TenantSpec::new("bulk", SloTier::Batch)]);

        // header wins over everything
        let t = reg.resolve(Some("bulk"), Some("sk-vip-1"), Some("vip"));
        assert_eq!(t.id(), "bulk");
        assert_eq!(t.tier(), SloTier::Batch);
        // API key when no header
        assert_eq!(reg.resolve(None, Some("sk-vip-1"), None).id(), "vip");
        // body hint when neither
        assert_eq!(reg.resolve(None, None, Some("bulk")).id(), "bulk");
        // unknown everything falls back to the default standard tenant
        let t = reg.resolve(Some("nobody"), Some("sk-stale"), Some("ghost"));
        assert_eq!(t.id(), DEFAULT_TENANT);
        assert_eq!(t.tier(), SloTier::Standard);
        // whitespace around ids is tolerated
        assert_eq!(reg.resolve(Some(" vip "), None, None).id(), "vip");
    }

    #[test]
    fn registry_always_has_a_default_tenant() {
        let reg = TenantRegistry::new(Vec::new());
        assert_eq!(reg.default_tenant().id(), DEFAULT_TENANT);
        // built-in mixture tenants map to their natural tiers
        let reg = TenantRegistry::with_defaults();
        assert_eq!(reg.get("chat").unwrap().tier(), SloTier::Latency);
        assert_eq!(reg.get("summarize").unwrap().tier(), SloTier::Standard);
        assert_eq!(reg.get("codegen").unwrap().tier(), SloTier::Batch);
        assert_eq!(reg.all().len(), 4, "three tenants plus the fallback");
    }

    #[test]
    fn per_tenant_bucket_throttles_only_its_owner() {
        let mut throttled = TenantSpec::new("small", SloTier::Standard);
        throttled.rate_limit = 1.0;
        throttled.rate_burst = 2;
        let reg = TenantRegistry::new(vec![throttled]);
        let small = reg.get("small").unwrap();
        assert!(small.try_admit());
        assert!(small.try_admit());
        assert!(!small.try_admit(), "burst of 2 exhausted");
        // the default tenant has no private bucket and is never throttled
        let default = reg.default_tenant();
        for _ in 0..100 {
            assert!(default.try_admit());
        }
    }

    #[test]
    fn ledger_and_counters_accumulate() {
        let reg = TenantRegistry::with_defaults();
        let t = reg.get("chat").unwrap();
        t.note_admitted_at(0);
        t.note_admitted_at(0);
        t.note_rejected();
        t.credit_gpu(0.5);
        t.credit_gpu(1.25);
        t.credit_gpu(f64::NAN); // poison is ignored
        t.credit_gpu(-3.0);
        assert_eq!(t.admitted_total(), 2);
        assert_eq!(t.rejected_total(), 1);
        assert!((t.gpu_seconds() - 1.75).abs() < 1e-6, "{}", t.gpu_seconds());
        let snap = t.snapshot();
        assert_eq!(snap.id, "chat");
        assert_eq!(snap.tier, SloTier::Latency);
        assert_eq!(snap.admitted, 2);
        assert!((snap.gpu_seconds - 1.75).abs() < 1e-6);
    }

    #[test]
    fn rate_ring_tracks_trailing_arrivals() {
        let reg = TenantRegistry::with_defaults();
        let t = reg.get("summarize").unwrap();
        // 3 arrivals/s for seconds 10..15
        for sec in 10..15 {
            for _ in 0..3 {
                t.note_admitted_at(sec);
            }
        }
        let rps = t.arrival_rps_at(14, 5);
        assert!((rps - 3.0).abs() < 0.61, "trailing rate ~3: {rps}");
        // a long quiet gap decays the rate to zero
        let rps = t.arrival_rps_at(200, 5);
        assert!(rps.abs() < 1e-9, "stale window decays: {rps}");
        // clock going backwards must not panic or corrupt the ring
        t.note_admitted_at(100);
        t.note_admitted_at(50);
        assert!(t.arrival_rps_at(100, 5) >= 0.0);
    }

    #[test]
    fn queue_budget_inherits_default_when_unset() {
        let mut strict = TenantSpec::new("strict", SloTier::Latency);
        strict.queue_budget_ms = 40;
        let reg = TenantRegistry::new(vec![strict]);
        let default_budget = Duration::from_millis(500);
        assert_eq!(
            reg.get("strict").unwrap().queue_budget(default_budget),
            Duration::from_millis(40)
        );
        assert_eq!(
            reg.default_tenant().queue_budget(default_budget),
            default_budget
        );
    }

    #[test]
    fn gate_caps_inflight_and_releases_on_drop() {
        let gate = AdmissionGate::new(2);
        let a = AdmissionGate::try_acquire(&gate).unwrap();
        let b = AdmissionGate::try_acquire(&gate).unwrap();
        assert!(AdmissionGate::try_acquire(&gate).is_none(), "over capacity");
        assert_eq!(gate.inflight(), 2);
        drop(a);
        assert_eq!(gate.inflight(), 1);
        let c = AdmissionGate::try_acquire(&gate).unwrap();
        assert!(AdmissionGate::try_acquire(&gate).is_none());
        drop(b);
        drop(c);
        assert_eq!(gate.inflight(), 0);
    }
}
