//! Gateway-side admission control — the queueing-model guardrails of the
//! paper's §III: a token-bucket rate limiter smooths arrival bursts and a
//! bounded in-flight gate caps queued + running requests, so overload turns
//! into fast 429s at the edge instead of unbounded engine queues (the
//! t^p blow-up ENOVA's detector would otherwise have to catch downstream).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Classic token bucket: `rate` tokens/s refill, `burst` capacity.
#[derive(Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    /// seconds since `epoch` at the last refill (kept as f64 so tests can
    /// drive time deterministically through [`TokenBucket::try_take_at`])
    last: f64,
    epoch: Instant,
}

impl TokenBucket {
    pub fn new(rate: f64, burst: usize) -> TokenBucket {
        let burst = (burst.max(1)) as f64;
        TokenBucket {
            rate: rate.max(0.0),
            burst,
            tokens: burst,
            last: 0.0,
            epoch: Instant::now(),
        }
    }

    /// Take one token at an explicit clock reading (test seam).
    ///
    /// `last` is clamped to be monotonic: a non-monotonic clock reading
    /// (NTP step, test-driven time) must not rewind it, or the span it
    /// rewound over would be refilled a second time on the next call —
    /// minting free tokens.
    pub fn try_take_at(&mut self, now_secs: f64) -> bool {
        let dt = (now_secs - self.last).max(0.0);
        self.last = self.last.max(now_secs);
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    pub fn try_take(&mut self) -> bool {
        let now = self.epoch.elapsed().as_secs_f64();
        self.try_take_at(now)
    }
}

/// Bounded count of requests inside the serving pipeline (engine pending +
/// running). Acquire before dispatch; the returned permit releases on drop.
#[derive(Debug)]
pub struct AdmissionGate {
    cap: usize,
    inflight: AtomicUsize,
}

impl AdmissionGate {
    pub fn new(cap: usize) -> Arc<AdmissionGate> {
        Arc::new(AdmissionGate {
            cap: cap.max(1),
            inflight: AtomicUsize::new(0),
        })
    }

    pub fn try_acquire(gate: &Arc<AdmissionGate>) -> Option<AdmissionPermit> {
        gate.inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                if v < gate.cap {
                    Some(v + 1)
                } else {
                    None
                }
            })
            .ok()?;
        Some(AdmissionPermit {
            gate: Arc::clone(gate),
        })
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[derive(Debug)]
pub struct AdmissionPermit {
    gate: Arc<AdmissionGate>,
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        self.gate.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_enforces_rate_and_burst() {
        let mut b = TokenBucket::new(2.0, 3);
        // burst drains first
        assert!(b.try_take_at(0.0));
        assert!(b.try_take_at(0.0));
        assert!(b.try_take_at(0.0));
        assert!(!b.try_take_at(0.0), "burst exhausted");
        // 0.5s at 2/s refills exactly one token
        assert!(b.try_take_at(0.5));
        assert!(!b.try_take_at(0.5));
        // refill caps at burst
        assert!(b.try_take_at(100.0));
        assert!(b.try_take_at(100.0));
        assert!(b.try_take_at(100.0));
        assert!(!b.try_take_at(100.0));
    }

    #[test]
    fn bucket_tolerates_clock_going_backwards() {
        let mut b = TokenBucket::new(1.0, 1);
        assert!(b.try_take_at(10.0));
        assert!(!b.try_take_at(5.0)); // negative dt must not mint tokens
        // the rewind must not have reset `last`: only 0.5s really elapsed
        // since the take at t=10, so no token yet — the pre-clamp bug
        // refilled [5.0, 10.5] here and handed out a free token
        assert!(!b.try_take_at(10.5));
        assert!(b.try_take_at(11.0)); // a full second since t=10
    }

    #[test]
    fn bucket_never_mints_tokens_from_clock_rewinds() {
        // property: over any clock walk (forwards and backwards), grants
        // never exceed burst + rate × furthest-forward-progress
        crate::util::prop::check("token bucket monotonic refill", 300, |g| {
            let rate = g.f64_in(0.5, 50.0);
            let burst = g.usize_in(1, 16);
            let mut b = TokenBucket::new(rate, burst);
            let mut now = 0.0f64;
            let mut hi = 0.0f64;
            let mut granted = 0usize;
            let steps = g.usize_in(1, 200);
            for _ in 0..steps {
                now = (now + g.f64_in(-2.0, 2.0)).max(0.0);
                hi = hi.max(now);
                if b.try_take_at(now) {
                    granted += 1;
                }
            }
            let budget = burst as f64 + rate * hi + 1e-6;
            if granted as f64 <= budget {
                Ok(())
            } else {
                Err(format!(
                    "granted {granted} tokens > budget {budget:.3} \
                     (rate {rate:.3}, burst {burst}, furthest clock {hi:.3})"
                ))
            }
        });
    }

    #[test]
    fn gate_caps_inflight_and_releases_on_drop() {
        let gate = AdmissionGate::new(2);
        let a = AdmissionGate::try_acquire(&gate).unwrap();
        let b = AdmissionGate::try_acquire(&gate).unwrap();
        assert!(AdmissionGate::try_acquire(&gate).is_none(), "over capacity");
        assert_eq!(gate.inflight(), 2);
        drop(a);
        assert_eq!(gate.inflight(), 1);
        let c = AdmissionGate::try_acquire(&gate).unwrap();
        assert!(AdmissionGate::try_acquire(&gate).is_none());
        drop(b);
        drop(c);
        assert_eq!(gate.inflight(), 0);
    }
}
