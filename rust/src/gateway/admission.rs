//! Gateway-side admission control — the queueing-model guardrails of the
//! paper's §III: a token-bucket rate limiter smooths arrival bursts and a
//! bounded in-flight gate caps queued + running requests, so overload turns
//! into fast 429s at the edge instead of unbounded engine queues (the
//! t^p blow-up ENOVA's detector would otherwise have to catch downstream).

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::Instant;

/// Classic token bucket: `rate` tokens/s refill, `burst` capacity.
#[derive(Debug)]
pub struct TokenBucket {
    rate: f64,
    burst: f64,
    tokens: f64,
    /// seconds since `epoch` at the last refill (kept as f64 so tests can
    /// drive time deterministically through [`TokenBucket::try_take_at`])
    last: f64,
    epoch: Instant,
}

impl TokenBucket {
    pub fn new(rate: f64, burst: usize) -> TokenBucket {
        let burst = (burst.max(1)) as f64;
        TokenBucket {
            rate: rate.max(0.0),
            burst,
            tokens: burst,
            last: 0.0,
            epoch: Instant::now(),
        }
    }

    /// Take one token at an explicit clock reading (test seam).
    pub fn try_take_at(&mut self, now_secs: f64) -> bool {
        let dt = (now_secs - self.last).max(0.0);
        self.last = now_secs;
        self.tokens = (self.tokens + dt * self.rate).min(self.burst);
        if self.tokens >= 1.0 {
            self.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    pub fn try_take(&mut self) -> bool {
        let now = self.epoch.elapsed().as_secs_f64();
        self.try_take_at(now)
    }
}

/// Bounded count of requests inside the serving pipeline (engine pending +
/// running). Acquire before dispatch; the returned permit releases on drop.
#[derive(Debug)]
pub struct AdmissionGate {
    cap: usize,
    inflight: AtomicUsize,
}

impl AdmissionGate {
    pub fn new(cap: usize) -> Arc<AdmissionGate> {
        Arc::new(AdmissionGate {
            cap: cap.max(1),
            inflight: AtomicUsize::new(0),
        })
    }

    pub fn try_acquire(gate: &Arc<AdmissionGate>) -> Option<AdmissionPermit> {
        gate.inflight
            .fetch_update(Ordering::AcqRel, Ordering::Acquire, |v| {
                if v < gate.cap {
                    Some(v + 1)
                } else {
                    None
                }
            })
            .ok()?;
        Some(AdmissionPermit {
            gate: Arc::clone(gate),
        })
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Acquire)
    }

    pub fn capacity(&self) -> usize {
        self.cap
    }
}

#[derive(Debug)]
pub struct AdmissionPermit {
    gate: Arc<AdmissionGate>,
}

impl Drop for AdmissionPermit {
    fn drop(&mut self) {
        self.gate.inflight.fetch_sub(1, Ordering::AcqRel);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_enforces_rate_and_burst() {
        let mut b = TokenBucket::new(2.0, 3);
        // burst drains first
        assert!(b.try_take_at(0.0));
        assert!(b.try_take_at(0.0));
        assert!(b.try_take_at(0.0));
        assert!(!b.try_take_at(0.0), "burst exhausted");
        // 0.5s at 2/s refills exactly one token
        assert!(b.try_take_at(0.5));
        assert!(!b.try_take_at(0.5));
        // refill caps at burst
        assert!(b.try_take_at(100.0));
        assert!(b.try_take_at(100.0));
        assert!(b.try_take_at(100.0));
        assert!(!b.try_take_at(100.0));
    }

    #[test]
    fn bucket_tolerates_clock_going_backwards() {
        let mut b = TokenBucket::new(1.0, 1);
        assert!(b.try_take_at(10.0));
        assert!(!b.try_take_at(5.0)); // negative dt must not mint tokens
    }

    #[test]
    fn gate_caps_inflight_and_releases_on_drop() {
        let gate = AdmissionGate::new(2);
        let a = AdmissionGate::try_acquire(&gate).unwrap();
        let b = AdmissionGate::try_acquire(&gate).unwrap();
        assert!(AdmissionGate::try_acquire(&gate).is_none(), "over capacity");
        assert_eq!(gate.inflight(), 2);
        drop(a);
        assert_eq!(gate.inflight(), 1);
        let c = AdmissionGate::try_acquire(&gate).unwrap();
        assert!(AdmissionGate::try_acquire(&gate).is_none());
        drop(b);
        drop(c);
        assert_eq!(gate.inflight(), 0);
    }
}
