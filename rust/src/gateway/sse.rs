//! Server-Sent Events over HTTP/1.1 chunked transfer encoding — the
//! OpenAI streaming wire format (`Content-Type: text/event-stream`, one
//! `data: <json>\n\n` event per token chunk, terminated by `data: [DONE]`).
//! Each SSE event is flushed as its own HTTP chunk so clients see tokens
//! the moment the engine samples them.

use std::io::Write;

/// Writes the response head that switches the connection into SSE mode.
/// After this, the body must be produced exclusively through
/// [`ChunkedWriter`] / [`SseWriter`].
pub fn write_sse_head<W: Write>(w: &mut W) -> std::io::Result<()> {
    w.write_all(
        b"HTTP/1.1 200 OK\r\n\
          Content-Type: text/event-stream\r\n\
          Cache-Control: no-cache\r\n\
          Transfer-Encoding: chunked\r\n\
          Connection: keep-alive\r\n\
          \r\n",
    )?;
    w.flush()
}

/// RFC 9112 §7.1 chunked body framing: `<hex len>\r\n<payload>\r\n`,
/// terminated by a zero-length chunk.
pub struct ChunkedWriter<W: Write> {
    w: W,
    finished: bool,
}

impl<W: Write> ChunkedWriter<W> {
    pub fn new(w: W) -> ChunkedWriter<W> {
        ChunkedWriter { w, finished: false }
    }

    /// The underlying writer — for response-head bytes that must precede
    /// the chunked body (the cluster coordinator's SSE relay writes the
    /// head lazily, only once the upstream produced its first chunk).
    pub fn inner_mut(&mut self) -> &mut W {
        &mut self.w
    }

    pub fn write_chunk(&mut self, payload: &[u8]) -> std::io::Result<()> {
        if payload.is_empty() || self.finished {
            return Ok(()); // empty chunk would terminate the body early
        }
        write!(self.w, "{:x}\r\n", payload.len())?;
        self.w.write_all(payload)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminating zero chunk; the connection can keep serving afterwards.
    pub fn finish(&mut self) -> std::io::Result<()> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

pub struct SseWriter<W: Write> {
    chunks: ChunkedWriter<W>,
    pub events_written: usize,
}

impl<W: Write> SseWriter<W> {
    pub fn new(w: W) -> SseWriter<W> {
        SseWriter {
            chunks: ChunkedWriter::new(w),
            events_written: 0,
        }
    }

    /// One `data:` event. `data` must not contain newlines (the gateway
    /// only ever sends single-line JSON payloads).
    pub fn event(&mut self, data: &str) -> std::io::Result<()> {
        debug_assert!(!data.contains('\n'), "multi-line SSE payload");
        let framed = format!("data: {data}\n\n");
        self.events_written += 1;
        self.chunks.write_chunk(framed.as_bytes())
    }

    /// The OpenAI stream terminator followed by the chunked-body
    /// terminator.
    pub fn done(&mut self) -> std::io::Result<()> {
        self.event("[DONE]")?;
        self.chunks.finish()
    }

    /// Abort the body without the `[DONE]` marker (error mid-stream).
    pub fn finish(&mut self) -> std::io::Result<()> {
        self.chunks.finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunk_framing_is_rfc9112() {
        let mut buf = Vec::new();
        let mut w = ChunkedWriter::new(&mut buf);
        w.write_chunk(b"hello").unwrap();
        w.write_chunk(b"0123456789abcdef").unwrap(); // 16 bytes -> "10"
        w.finish().unwrap();
        assert_eq!(
            String::from_utf8(buf).unwrap(),
            "5\r\nhello\r\n10\r\n0123456789abcdef\r\n0\r\n\r\n"
        );
    }

    #[test]
    fn finish_is_idempotent_and_blocks_further_chunks() {
        let mut buf = Vec::new();
        let mut w = ChunkedWriter::new(&mut buf);
        w.finish().unwrap();
        w.finish().unwrap();
        w.write_chunk(b"late").unwrap();
        assert_eq!(buf, b"0\r\n\r\n");
    }

    #[test]
    fn sse_events_and_done_marker() {
        let mut buf = Vec::new();
        let mut w = SseWriter::new(&mut buf);
        w.event(r#"{"token":"a"}"#).unwrap();
        w.done().unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.contains("data: {\"token\":\"a\"}\n\n"));
        assert!(text.contains("data: [DONE]\n\n"));
        assert!(text.ends_with("0\r\n\r\n"));
        assert_eq!(w.events_written, 2);
    }

    #[test]
    fn sse_head_declares_event_stream() {
        let mut buf = Vec::new();
        write_sse_head(&mut buf).unwrap();
        let text = String::from_utf8(buf).unwrap();
        assert!(text.starts_with("HTTP/1.1 200 OK\r\n"));
        assert!(text.contains("Content-Type: text/event-stream\r\n"));
        assert!(text.contains("Transfer-Encoding: chunked\r\n"));
    }
}
