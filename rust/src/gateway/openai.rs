//! OpenAI-compatible request parsing and response building on top of
//! [`crate::util::json`]. Covers the subset the serving engine implements:
//! `/v1/completions` and `/v1/chat/completions`, streaming or not, with
//! usage accounting. Chat messages are flattened into a single prompt —
//! the tiny byte-level LM has no chat template.

use crate::engine::FinishReason;
use crate::util::json::{num, obj, s, Json};
use std::time::{SystemTime, UNIX_EPOCH};

pub const DEFAULT_MODEL: &str = "enova-tiny-lm";

/// Normalized parameters shared by both completion endpoints.
#[derive(Debug, Clone, PartialEq)]
pub struct CompletionParams {
    pub prompt: String,
    pub max_tokens: usize,
    pub stream: bool,
    pub model: String,
    /// OpenAI's end-user identifier; the gateway treats it as a tenant
    /// hint of last resort (header and API key take precedence)
    pub user: Option<String>,
}

fn opt_usize(j: &Json, key: &str) -> Result<Option<usize>, String> {
    match j.get(key) {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_f64()
            .filter(|x| *x >= 1.0 && x.fract() == 0.0)
            .map(|x| Some(x as usize))
            .ok_or_else(|| format!("\"{key}\" must be a positive integer")),
    }
}

fn common(j: &Json, prompt: String, default_max: usize) -> Result<CompletionParams, String> {
    Ok(CompletionParams {
        prompt,
        max_tokens: opt_usize(j, "max_tokens")?.unwrap_or(default_max),
        stream: match j.get("stream") {
            None | Some(Json::Null) => false,
            Some(v) => v.as_bool().ok_or("\"stream\" must be a boolean")?,
        },
        model: j
            .get("model")
            .and_then(Json::as_str)
            .unwrap_or(DEFAULT_MODEL)
            .to_string(),
        user: j.get("user").and_then(Json::as_str).map(str::to_string),
    })
}

/// `POST /v1/completions` body. `prompt` may be a string or a one-element
/// array of strings (the OpenAI SDK sends both).
pub fn parse_completion(j: &Json, default_max: usize) -> Result<CompletionParams, String> {
    let prompt = match j.get("prompt") {
        Some(Json::Str(p)) => p.clone(),
        Some(Json::Arr(items)) => match items.first() {
            Some(Json::Str(p)) if items.len() == 1 => p.clone(),
            _ => return Err("\"prompt\" array must hold exactly one string".into()),
        },
        Some(_) => return Err("\"prompt\" must be a string".into()),
        None => return Err("missing required field \"prompt\"".into()),
    };
    common(j, prompt, default_max)
}

/// `POST /v1/chat/completions` body: messages flattened role-tagged into
/// one prompt, ending with the assistant cue.
pub fn parse_chat(j: &Json, default_max: usize) -> Result<CompletionParams, String> {
    let messages = j
        .get("messages")
        .and_then(Json::as_arr)
        .ok_or("missing required field \"messages\"")?;
    if messages.is_empty() {
        return Err("\"messages\" must not be empty".into());
    }
    let mut prompt = String::new();
    for m in messages {
        let role = m
            .get("role")
            .and_then(Json::as_str)
            .ok_or("each message needs a string \"role\"")?;
        let content = m
            .get("content")
            .and_then(Json::as_str)
            .ok_or("each message needs a string \"content\"")?;
        prompt.push_str(role);
        prompt.push_str(": ");
        prompt.push_str(content);
        prompt.push('\n');
    }
    prompt.push_str("assistant:");
    common(j, prompt, default_max)
}

fn created() -> f64 {
    SystemTime::now()
        .duration_since(UNIX_EPOCH)
        .map(|d| d.as_secs() as f64)
        .unwrap_or(0.0)
}

fn usage(prompt_tokens: usize, completion_tokens: usize) -> Json {
    obj([
        ("prompt_tokens", num(prompt_tokens as f64)),
        ("completion_tokens", num(completion_tokens as f64)),
        ("total_tokens", num((prompt_tokens + completion_tokens) as f64)),
    ])
}

/// Non-streaming `/v1/completions` response.
pub fn completion_body(
    req_id: &str,
    model: &str,
    text: &str,
    finish: FinishReason,
    prompt_tokens: usize,
    completion_tokens: usize,
) -> Json {
    obj([
        ("id", s(req_id)),
        ("object", s("text_completion")),
        ("created", num(created())),
        ("model", s(model)),
        (
            "choices",
            Json::Arr(vec![obj([
                ("index", num(0.0)),
                ("text", s(text)),
                ("finish_reason", s(finish.as_str())),
                ("logprobs", Json::Null),
            ])]),
        ),
        ("usage", usage(prompt_tokens, completion_tokens)),
    ])
}

/// Non-streaming `/v1/chat/completions` response.
pub fn chat_body(
    req_id: &str,
    model: &str,
    text: &str,
    finish: FinishReason,
    prompt_tokens: usize,
    completion_tokens: usize,
) -> Json {
    obj([
        ("id", s(req_id)),
        ("object", s("chat.completion")),
        ("created", num(created())),
        ("model", s(model)),
        (
            "choices",
            Json::Arr(vec![obj([
                ("index", num(0.0)),
                (
                    "message",
                    obj([("role", s("assistant")), ("content", s(text))]),
                ),
                ("finish_reason", s(finish.as_str())),
            ])]),
        ),
        ("usage", usage(prompt_tokens, completion_tokens)),
    ])
}

/// One streamed token chunk for either endpoint. `finish` is only set on
/// the last content-carrying chunk.
pub fn stream_chunk(
    req_id: &str,
    model: &str,
    delta_text: &str,
    finish: Option<FinishReason>,
    chat: bool,
) -> Json {
    let finish_json = match finish {
        Some(f) => s(f.as_str()),
        None => Json::Null,
    };
    let choice = if chat {
        obj([
            ("index", num(0.0)),
            ("delta", obj([("content", s(delta_text))])),
            ("finish_reason", finish_json),
        ])
    } else {
        obj([
            ("index", num(0.0)),
            ("text", s(delta_text)),
            ("finish_reason", finish_json),
        ])
    };
    obj([
        ("id", s(req_id)),
        (
            "object",
            s(if chat {
                "chat.completion.chunk"
            } else {
                "text_completion"
            }),
        ),
        ("created", num(created())),
        ("model", s(model)),
        ("choices", Json::Arr(vec![choice])),
    ])
}

/// First chunk of a chat stream: the assistant role announcement.
pub fn chat_role_chunk(req_id: &str, model: &str) -> Json {
    obj([
        ("id", s(req_id)),
        ("object", s("chat.completion.chunk")),
        ("created", num(created())),
        ("model", s(model)),
        (
            "choices",
            Json::Arr(vec![obj([
                ("index", num(0.0)),
                ("delta", obj([("role", s("assistant"))])),
                ("finish_reason", Json::Null),
            ])]),
        ),
    ])
}

/// OpenAI-shaped error envelope.
pub fn error_body(kind: &str, message: &str) -> Json {
    obj([(
        "error",
        obj([
            ("message", s(message)),
            ("type", s(kind)),
            ("param", Json::Null),
            ("code", Json::Null),
        ]),
    )])
}

/// Compact (single-line) rendering for SSE payloads and response bodies.
pub fn to_wire(j: &Json) -> String {
    j.to_string_compact()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_completion_request() {
        let j = Json::parse(r#"{"prompt": "hi", "max_tokens": 4, "stream": true}"#).unwrap();
        let p = parse_completion(&j, 64).unwrap();
        assert_eq!(p.prompt, "hi");
        assert_eq!(p.max_tokens, 4);
        assert!(p.stream);
        assert_eq!(p.model, DEFAULT_MODEL);

        let arr = Json::parse(r#"{"prompt": ["only one"]}"#).unwrap();
        assert_eq!(parse_completion(&arr, 64).unwrap().prompt, "only one");
    }

    #[test]
    fn user_field_is_optional_and_carried_through() {
        let j = Json::parse(r#"{"prompt": "hi", "user": "tenant-7"}"#).unwrap();
        assert_eq!(parse_completion(&j, 64).unwrap().user.as_deref(), Some("tenant-7"));
        let j = Json::parse(r#"{"prompt": "hi"}"#).unwrap();
        assert_eq!(parse_completion(&j, 64).unwrap().user, None);
        // a non-string user is ignored, not an error (OpenAI tolerates it)
        let j = Json::parse(r#"{"prompt": "hi", "user": 9}"#).unwrap();
        assert_eq!(parse_completion(&j, 64).unwrap().user, None);
    }

    #[test]
    fn rejects_bad_completion_requests() {
        for body in [
            r#"{}"#,
            r#"{"prompt": 5}"#,
            r#"{"prompt": ["a", "b"]}"#,
            r#"{"prompt": "x", "max_tokens": -1}"#,
            r#"{"prompt": "x", "max_tokens": 2.9}"#,
            r#"{"prompt": "x", "stream": "yes"}"#,
        ] {
            let j = Json::parse(body).unwrap();
            assert!(parse_completion(&j, 64).is_err(), "{body}");
        }
    }

    #[test]
    fn chat_flattens_messages() {
        let j = Json::parse(
            r#"{"messages": [{"role": "system", "content": "be brief"},
                             {"role": "user", "content": "hello"}]}"#,
        )
        .unwrap();
        let p = parse_chat(&j, 32).unwrap();
        assert_eq!(p.prompt, "system: be brief\nuser: hello\nassistant:");
        assert_eq!(p.max_tokens, 32);

        let bad = Json::parse(r#"{"messages": []}"#).unwrap();
        assert!(parse_chat(&bad, 32).is_err());
        let bad2 = Json::parse(r#"{"messages": [{"role": "user"}]}"#).unwrap();
        assert!(parse_chat(&bad2, 32).is_err());
    }

    #[test]
    fn bodies_roundtrip_as_json() {
        let b = completion_body("cmpl-1", "m", "out", FinishReason::MaxTokens, 3, 7);
        let parsed = Json::parse(&to_wire(&b)).unwrap();
        assert_eq!(
            parsed.at(&["choices"]).unwrap().as_arr().unwrap()[0]
                .get("text")
                .unwrap()
                .as_str(),
            Some("out")
        );
        assert_eq!(
            parsed.at(&["usage", "total_tokens"]).unwrap().as_usize(),
            Some(10)
        );

        let c = chat_body("chatcmpl-1", "m", "hi", FinishReason::Eos, 1, 2);
        let parsed = Json::parse(&to_wire(&c)).unwrap();
        assert_eq!(
            parsed.at(&["choices"]).unwrap().as_arr().unwrap()[0]
                .at(&["message", "content"])
                .unwrap()
                .as_str(),
            Some("hi")
        );
    }

    #[test]
    fn wire_format_is_single_line_and_preserves_strings() {
        let j = obj([("a", s("x y\nz \" q")), ("b", Json::Arr(vec![num(1.0)]))]);
        let wire = to_wire(&j);
        assert!(!wire.contains('\n'));
        assert_eq!(Json::parse(&wire).unwrap(), j);
    }

    #[test]
    fn stream_chunk_shapes() {
        let chat = stream_chunk("id", "m", "tok", None, true);
        let t = to_wire(&chat);
        assert!(t.contains("chat.completion.chunk"));
        assert!(t.contains("\"content\":\"tok\""));
        let fin = stream_chunk("id", "m", "", Some(FinishReason::MaxTokens), false);
        assert!(to_wire(&fin).contains("\"finish_reason\":\"length\""));
    }
}
