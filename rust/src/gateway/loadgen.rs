//! Self-contained HTTP client + closed-loop load generator that drives the
//! gateway over real sockets — the integration-test harness and the
//! `examples/serve_http.rs` demo driver. The client understands exactly
//! what the gateway emits: Content-Length bodies and chunked SSE streams.
//!
//! The closed loop runs on persistent HTTP/1.1 keep-alive connections
//! multiplexed over a shared [`ConnPool`]: workers check sockets out per
//! exchange and park them back on clean framing boundaries, so attainable
//! attack rates are not capped by per-request TCP handshakes and the
//! socket count tracks peak concurrency, not worker count.
//! [`LoadgenReport::connections_opened`] lets tests assert the reuse.
//!
//! Beyond the closed loop, [`run_scenario`] is an *open-loop* scenario
//! engine: named arrival-pattern generators (`steady`, `diurnal`, `spike`,
//! `ramp` and a multi-tenant `mixture` of heterogeneous prompt/output
//! lengths, matching the paper's co-located-applications setting) produce
//! a seeded non-homogeneous Poisson schedule that a worker pool replays
//! against the gateway in real time. Each scenario emits its shape
//! parameters into the JSON report, so a CI artifact says exactly what
//! traffic produced its numbers.
//!
//! For chaos drills, [`run_adversarial`] adds deliberately *misbehaving*
//! clients alongside the well-formed load: slow-loris writers that drip
//! a request head byte-by-byte, and streaming readers that sever the
//! socket mid-SSE. Both are seeded ([`AdversarialConfig::seed`]) so a
//! failing CI run replays bit-identically.

use crate::util::json::{num, obj, s, Json};
use crate::util::rng::Pcg64;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    /// header names lowercased
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }

    pub fn json(&self) -> Result<Json> {
        Json::parse(&self.body_str()).map_err(|e| anyhow!("response is not JSON: {e}"))
    }

    /// The `data:` payloads of an SSE body, in order (including `[DONE]`).
    pub fn sse_data(&self) -> Vec<String> {
        self.body_str()
            .split("\n\n")
            .filter_map(|event| event.trim().strip_prefix("data: ").map(str::to_string))
            .collect()
    }
}

/// One chunk of an RFC 9112 chunked body; `None` is the terminal zero
/// chunk (with its trailers consumed). Shared with the cluster
/// coordinator's SSE relay, which forwards chunk-by-chunk instead of
/// buffering.
pub(crate) fn read_chunk<R: BufRead>(r: &mut R) -> Result<Option<Vec<u8>>> {
    let mut size_line = String::new();
    r.read_line(&mut size_line)?;
    let size_str = size_line.trim().split(';').next().unwrap_or("").trim();
    let size = usize::from_str_radix(size_str, 16)
        .with_context(|| format!("bad chunk size line {size_line:?}"))?;
    if size == 0 {
        // trailers (we send none) up to the blank line
        loop {
            let mut trailer = String::new();
            if r.read_line(&mut trailer)? == 0 || trailer.trim().is_empty() {
                break;
            }
        }
        return Ok(None);
    }
    let mut chunk = vec![0u8; size];
    r.read_exact(&mut chunk)?;
    let mut crlf = [0u8; 2];
    r.read_exact(&mut crlf)?;
    Ok(Some(chunk))
}

/// Drain a chunked body, optionally recording the wall-clock arrival of
/// every chunk — the gateway writes one SSE event per chunk, so these
/// instants are per-token timestamps (TTFT and inter-token gaps).
fn read_chunked_timed<R: BufRead>(
    r: &mut R,
    mut chunk_times: Option<&mut Vec<Instant>>,
) -> Result<Vec<u8>> {
    let mut body = Vec::new();
    while let Some(chunk) = read_chunk(r)? {
        if let Some(times) = chunk_times.as_mut() {
            times.push(Instant::now());
        }
        body.extend_from_slice(&chunk);
    }
    Ok(body)
}

/// The request head for one exchange. `close` asks the server to close
/// the connection after responding; omitted, HTTP/1.1 defaults to
/// keep-alive. `extra` is a pre-rendered block of additional header
/// lines, each `Name: value\r\n` (e.g. the `x-enova-tenant` identity).
fn request_head(
    method: &str,
    path: &str,
    addr: &str,
    body: Option<&str>,
    close: bool,
    extra: &str,
) -> String {
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nAccept: */*\r\n{extra}");
    if close {
        head.push_str("Connection: close\r\n");
    }
    if let Some(b) = body {
        head.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            b.len()
        ));
    }
    head.push_str("\r\n");
    head
}

/// Read one response off the stream. The `BufReader` is scoped to this
/// exchange: the gateway never pushes unsolicited bytes, and both
/// Content-Length and chunked bodies are exactly delimited, so no buffered
/// bytes are lost when it drops — which is what makes keep-alive reuse of
/// the bare `TcpStream` safe.
/// Parse one response head (status line + headers, names lowercased) off
/// the stream, leaving the body unread — shared by the buffered client
/// below and the cluster coordinator's proxy, which branches on the head
/// before deciding to buffer or relay.
pub(crate) fn read_response_head<R: BufRead>(
    r: &mut R,
) -> Result<(u16, BTreeMap<String, String>)> {
    let mut status_line = String::new();
    if r.read_line(&mut status_line)? == 0 {
        bail!("EOF before status line");
    }
    let mut parts = status_line.split_whitespace();
    let proto = parts.next().unwrap_or("");
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad status line {status_line:?}"))?;
    if !proto.starts_with("HTTP/") {
        bail!("bad status line {status_line:?}");
    }
    let mut headers = BTreeMap::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            bail!("EOF inside response headers");
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }
    Ok((status, headers))
}

fn read_response(stream: &TcpStream) -> Result<HttpResponse> {
    read_response_timed(stream, None)
}

fn read_response_timed(
    stream: &TcpStream,
    chunk_times: Option<&mut Vec<Instant>>,
) -> Result<HttpResponse> {
    let mut r = BufReader::new(stream);
    let (status, headers) = read_response_head(&mut r)?;

    let body = if headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false)
    {
        read_chunked_timed(&mut r, chunk_times)?
    } else if let Some(len) = headers.get("content-length") {
        let len: usize = len.parse().context("bad Content-Length in response")?;
        let mut buf = vec![0u8; len];
        r.read_exact(&mut buf)?;
        buf
    } else {
        // no framing: the peer signals the end by closing
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        buf
    };

    Ok(HttpResponse {
        status,
        headers,
        body,
    })
}

/// One blocking HTTP/1.1 exchange on a fresh connection
/// (`Connection: close`). For request sequences, prefer [`Client`].
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> Result<HttpResponse> {
    request_headed(addr, method, path, body, timeout, "")
}

/// [`request`] with a pre-rendered extra header block (each line
/// `Name: value\r\n`) — how a caller sends a tenant identity.
pub fn request_headed(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
    extra: &str,
) -> Result<HttpResponse> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.set_nodelay(true)?;

    let mut w = &stream;
    w.write_all(request_head(method, path, addr, body, true, extra).as_bytes())?;
    if let Some(b) = body {
        w.write_all(b.as_bytes())?;
    }
    w.flush()?;
    read_response(&stream)
}

pub fn get(addr: &str, path: &str) -> Result<HttpResponse> {
    request(addr, "GET", path, None, Duration::from_secs(30))
}

pub fn post_json(addr: &str, path: &str, body: &str) -> Result<HttpResponse> {
    request(addr, "POST", path, Some(body), Duration::from_secs(60))
}

/// Idle keep-alive connections parked in a [`ConnPool`] beyond this cap
/// are closed instead of checked in.
const POOL_MAX_IDLE: usize = 32;

/// Thread-safe pool of idle keep-alive connections to one address,
/// shareable across loadgen workers: a worker that finishes an exchange
/// parks its socket here, and any worker's next request reuses it instead
/// of dialing. Every dial is counted, so a closed loop over a shared pool
/// still reports how many sockets it really opened.
pub struct ConnPool {
    addr: String,
    timeout: Duration,
    idle: Mutex<Vec<TcpStream>>,
    dials: AtomicUsize,
}

impl ConnPool {
    pub fn new(addr: &str) -> ConnPool {
        ConnPool {
            addr: addr.to_string(),
            timeout: Duration::from_secs(60),
            idle: Mutex::new(Vec::new()),
            dials: AtomicUsize::new(0),
        }
    }

    /// Pop an idle pooled socket (`true` = reused) or dial a fresh one.
    fn checkout(&self) -> Result<(TcpStream, bool)> {
        if let Some(stream) = self.idle.lock().unwrap().pop() {
            return Ok((stream, true));
        }
        Ok((self.dial()?, false))
    }

    fn dial(&self) -> Result<TcpStream> {
        let stream =
            TcpStream::connect(&self.addr).with_context(|| format!("connect {}", self.addr))?;
        stream.set_read_timeout(Some(self.timeout))?;
        stream.set_write_timeout(Some(self.timeout))?;
        stream.set_nodelay(true)?;
        self.dials.fetch_add(1, Ordering::Relaxed);
        Ok(stream)
    }

    fn checkin(&self, stream: TcpStream) {
        let mut idle = self.idle.lock().unwrap();
        if idle.len() < POOL_MAX_IDLE {
            idle.push(stream);
        }
    }

    /// Total sockets dialed through this pool over its lifetime.
    pub fn connections_opened(&self) -> usize {
        self.dials.load(Ordering::Relaxed)
    }

    /// Idle sockets currently parked.
    pub fn idle_count(&self) -> usize {
        self.idle.lock().unwrap().len()
    }
}

/// Persistent HTTP/1.1 client over a (possibly shared) connection pool:
/// each exchange checks a keep-alive socket out of the pool — dialing only
/// when none is idle — and parks it back on a clean framing boundary, so
/// concurrent workers multiplex a small set of sockets instead of owning
/// one each. A socket that turns out stale on send (the server reaped it
/// while idle) is replaced by a *fresh dial* and the request retried once.
/// Counts this client's dials so the integration suite can assert reuse.
pub struct Client {
    addr: String,
    pool: Arc<ConnPool>,
    stream: Option<TcpStream>,
    /// whether `stream` came out of the pool rather than a fresh dial —
    /// gates the stale-socket retry
    reused: bool,
    /// sockets dialed by this client (every pool dial is attributed to
    /// exactly one client, so per-worker counts sum to the pool total)
    pub connections_opened: usize,
}

impl Client {
    pub fn new(addr: &str) -> Client {
        Client::with_pool(Arc::new(ConnPool::new(addr)))
    }

    /// A client multiplexing over a shared pool.
    pub fn with_pool(pool: Arc<ConnPool>) -> Client {
        Client {
            addr: pool.addr.clone(),
            pool,
            stream: None,
            reused: false,
            connections_opened: 0,
        }
    }

    fn connect(&mut self, force_fresh: bool) -> Result<()> {
        if self.stream.is_none() {
            let (stream, reused) = if force_fresh {
                (self.pool.dial()?, false)
            } else {
                self.pool.checkout()?
            };
            if !reused {
                self.connections_opened += 1;
            }
            self.reused = reused;
            self.stream = Some(stream);
        }
        Ok(())
    }

    /// One exchange on the persistent connection. Only a *stale-socket*
    /// failure on a reused connection (the server closed an idle
    /// keep-alive socket: reset/EOF before any response byte) redials and
    /// retries once. Timeouts and mid-response failures are returned as
    /// errors — blindly retrying would re-execute a non-idempotent POST
    /// whose first copy may still be running on the server.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<HttpResponse> {
        self.request_inner(method, path, body, None, "")
    }

    /// [`Client::request`] with a pre-rendered extra header block (each
    /// line `Name: value\r\n`) — how the scenario engine sends the
    /// `x-enova-tenant` identity.
    pub fn request_headed(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra: &str,
    ) -> Result<HttpResponse> {
        self.request_inner(method, path, body, None, extra)
    }

    /// [`Client::request`] that also records the arrival instant of every
    /// chunk of a chunked (SSE) response body into `chunk_times` — the
    /// raw material for TTFT and inter-token-latency percentiles.
    pub fn request_timed(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        chunk_times: &mut Vec<Instant>,
    ) -> Result<HttpResponse> {
        self.request_inner(method, path, body, Some(chunk_times), "")
    }

    /// [`Client::request_timed`] with an extra header block.
    pub fn request_timed_headed(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        extra: &str,
        chunk_times: &mut Vec<Instant>,
    ) -> Result<HttpResponse> {
        self.request_inner(method, path, body, Some(chunk_times), extra)
    }

    fn request_inner(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        mut chunk_times: Option<&mut Vec<Instant>>,
        extra: &str,
    ) -> Result<HttpResponse> {
        match self.try_request(method, path, body, chunk_times.as_mut().map(|t| &mut **t), false, extra)
        {
            Ok(resp) => Ok(resp),
            Err(e) => {
                let was_reused = self.reused;
                self.stream = None;
                if was_reused && stale_socket_error(&e) {
                    if let Some(times) = chunk_times.as_mut() {
                        times.clear();
                    }
                    // retry on a guaranteed-fresh dial: popping another
                    // pooled socket could hand us a second stale one
                    self.try_request(method, path, body, chunk_times, true, extra)
                } else {
                    Err(e)
                }
            }
        }
    }

    fn try_request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
        chunk_times: Option<&mut Vec<Instant>>,
        force_fresh: bool,
        extra: &str,
    ) -> Result<HttpResponse> {
        self.connect(force_fresh)?;
        let resp = {
            let stream = self.stream.as_ref().expect("connected above");
            let mut w = stream;
            w.write_all(request_head(method, path, &self.addr, body, false, extra).as_bytes())?;
            if let Some(b) = body {
                w.write_all(b.as_bytes())?;
            }
            w.flush()?;
            read_response_timed(stream, chunk_times)?
        };
        // honor the server's wish to close; an unframed body also means
        // the connection is done
        let close = resp
            .headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false);
        let unframed = !resp.headers.contains_key("content-length")
            && !resp.headers.contains_key("transfer-encoding");
        if close || unframed {
            self.stream = None;
        } else if let Some(stream) = self.stream.take() {
            // clean framing boundary: park the socket for any worker
            self.pool.checkin(stream);
        }
        Ok(resp)
    }

    pub fn get(&mut self, path: &str) -> Result<HttpResponse> {
        self.request("GET", path, None)
    }

    pub fn post_json(&mut self, path: &str, body: &str) -> Result<HttpResponse> {
        self.request("POST", path, Some(body))
    }
}

/// True for failures that mean the server closed a previously-idle
/// keep-alive socket — reset/abort/broken pipe, or EOF before any status
/// byte ([`read_response_head`]'s "EOF before status line"). A timeout or
/// an error after response bytes arrived is NOT stale: the request may
/// well be executing server-side, so a retry would duplicate it.
fn stale_socket_error(e: &anyhow::Error) -> bool {
    for cause in e.chain() {
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            return matches!(
                io.kind(),
                std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::UnexpectedEof
            );
        }
    }
    e.to_string().contains("EOF before status line")
}

/// Closed-loop driver configuration: `concurrency` workers each issue
/// `requests_per_worker` sequential requests on one keep-alive connection.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub concurrency: usize,
    pub requests_per_worker: usize,
    pub max_tokens: usize,
    /// every k-th request of a worker streams (0 = never)
    pub stream_every: usize,
    /// every k-th request goes to /v1/chat/completions (0 = never)
    pub chat_every: usize,
    pub prompt_prefix: String,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            concurrency: 8,
            requests_per_worker: 4,
            max_tokens: 8,
            stream_every: 2,
            chat_every: 3,
            prompt_prefix: "benchmark this serving gateway".into(),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct LoadgenReport {
    pub requests: usize,
    pub ok: usize,
    /// transport-level failures (connect/read errors)
    pub errors: usize,
    pub status_counts: BTreeMap<u16, usize>,
    pub sse_events: usize,
    pub completion_tokens: usize,
    /// TCP connections dialed across all workers; == concurrency when
    /// keep-alive reuse held for every request
    pub connections_opened: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    pub p99_ms: f64,
    /// time-to-first-token over streamed 200s, from request send to the
    /// first SSE chunk on the wire (0 when nothing streamed)
    pub ttft_p50_ms: f64,
    pub ttft_p95_ms: f64,
    pub ttft_p99_ms: f64,
    /// inter-token latency: gaps between consecutive SSE content chunks
    pub itl_p50_ms: f64,
    pub itl_p95_ms: f64,
    pub itl_p99_ms: f64,
    pub elapsed_secs: f64,
    /// shape parameters of the scenario that generated this report
    /// (open-loop runs only)
    pub scenario: Option<Json>,
    /// per-tenant outcome lines (mixture scenarios only): latency
    /// percentiles and shed counts per co-located application, each
    /// carrying its tier and p95 SLO budget so `--strict` can grade them
    pub tenant_stats: Vec<TenantStat>,
}

/// Per-tenant slice of a scenario report.
#[derive(Debug, Clone, Default)]
pub struct TenantStat {
    pub name: String,
    /// SLO tier label of the tenant spec ("latency" | "standard" | "batch")
    pub tier: String,
    pub requests: usize,
    pub ok: usize,
    /// 429 + 503 responses — admission rejections and shed load
    pub shed: usize,
    pub p50_ms: f64,
    pub p95_ms: f64,
    /// p95 budget from the tenant spec; 0 = ungraded
    pub slo_p95_ms: f64,
}

impl LoadgenReport {
    pub fn count(&self, status: u16) -> usize {
        self.status_counts.get(&status).copied().unwrap_or(0)
    }

    /// The full report as JSON — what `enova loadgen --report FILE`
    /// writes and the CI gateway-smoke job uploads as its artifact.
    pub fn to_json(&self) -> Json {
        let statuses = Json::Obj(
            self.status_counts
                .iter()
                .map(|(code, n)| (code.to_string(), num(*n as f64)))
                .collect(),
        );
        let mut j = obj([
            ("requests", num(self.requests as f64)),
            ("ok", num(self.ok as f64)),
            ("errors", num(self.errors as f64)),
            ("status_counts", statuses),
            ("sse_events", num(self.sse_events as f64)),
            ("completion_tokens", num(self.completion_tokens as f64)),
            ("connections_opened", num(self.connections_opened as f64)),
            ("p50_ms", num(self.p50_ms)),
            ("p95_ms", num(self.p95_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("ttft_p50_ms", num(self.ttft_p50_ms)),
            ("ttft_p95_ms", num(self.ttft_p95_ms)),
            ("ttft_p99_ms", num(self.ttft_p99_ms)),
            ("itl_p50_ms", num(self.itl_p50_ms)),
            ("itl_p95_ms", num(self.itl_p95_ms)),
            ("itl_p99_ms", num(self.itl_p99_ms)),
            ("elapsed_secs", num(self.elapsed_secs)),
            (
                "requests_per_sec",
                num(self.requests as f64 / self.elapsed_secs.max(1e-9)),
            ),
        ]);
        if let (Json::Obj(m), Some(scn)) = (&mut j, &self.scenario) {
            m.insert("scenario".to_string(), scn.clone());
        }
        if !self.tenant_stats.is_empty() {
            let stats = Json::Arr(
                self.tenant_stats
                    .iter()
                    .map(|t| {
                        obj([
                            ("name", s(&t.name)),
                            ("tier", s(&t.tier)),
                            ("requests", num(t.requests as f64)),
                            ("ok", num(t.ok as f64)),
                            ("shed", num(t.shed as f64)),
                            ("p50_ms", num(t.p50_ms)),
                            ("p95_ms", num(t.p95_ms)),
                            ("slo_p95_ms", num(t.slo_p95_ms)),
                        ])
                    })
                    .collect(),
            );
            if let Json::Obj(m) = &mut j {
                m.insert("tenant_stats".to_string(), stats);
            }
        }
        j
    }

    /// Graded per-tenant SLO check: every tenant with a non-zero p95
    /// budget and at least one completed request must be inside it.
    /// Returns one human-readable line per violation (empty = pass).
    pub fn slo_violations(&self) -> Vec<String> {
        self.tenant_stats
            .iter()
            .filter(|t| t.slo_p95_ms > 0.0 && t.ok > 0 && t.p95_ms > t.slo_p95_ms)
            .map(|t| {
                format!(
                    "tenant {} ({}): p95 {:.1}ms over its {:.0}ms SLO budget",
                    t.name, t.tier, t.p95_ms, t.slo_p95_ms
                )
            })
            .collect()
    }

    pub fn summary(&self) -> String {
        let mut out = format!(
            "{} requests in {:.2}s ({:.1} req/s) over {} connections: {} ok, {} errors, \
             statuses {:?}, {} completion tokens, {} SSE events, p50 {:.1}ms p95 {:.1}ms \
             p99 {:.1}ms, ttft p50 {:.1}ms p95 {:.1}ms, itl p50 {:.1}ms p95 {:.1}ms",
            self.requests,
            self.elapsed_secs,
            self.requests as f64 / self.elapsed_secs.max(1e-9),
            self.connections_opened,
            self.ok,
            self.errors,
            self.status_counts,
            self.completion_tokens,
            self.sse_events,
            self.p50_ms,
            self.p95_ms,
            self.p99_ms,
            self.ttft_p50_ms,
            self.ttft_p95_ms,
            self.itl_p50_ms,
            self.itl_p95_ms,
        );
        for t in &self.tenant_stats {
            out.push_str(&format!(
                "\n  tenant {} ({}): {} requests, {} ok, {} shed, p50 {:.1}ms p95 {:.1}ms{}",
                t.name,
                t.tier,
                t.requests,
                t.ok,
                t.shed,
                t.p50_ms,
                t.p95_ms,
                if t.slo_p95_ms > 0.0 {
                    format!(" (SLO {:.0}ms)", t.slo_p95_ms)
                } else {
                    String::new()
                },
            ));
        }
        out
    }
}

struct OneResult {
    status: Option<u16>,
    latency: Duration,
    sse_events: usize,
    completion_tokens: usize,
    /// streamed 200s only: send → first SSE chunk, in seconds
    ttft: Option<f64>,
    /// streamed 200s only: gaps between consecutive content chunks
    inter_token_gaps: Vec<f64>,
    /// tenant the request was issued as (mixture scenarios only)
    tenant: Option<String>,
}

fn one_request(client: &mut Client, cfg: &LoadgenConfig, worker: usize, k: usize) -> OneResult {
    let stream = cfg.stream_every != 0 && (worker + k) % cfg.stream_every == 0;
    let chat = cfg.chat_every != 0 && (worker + k) % cfg.chat_every == 0;
    let prompt = format!("{} w{worker} r{k}", cfg.prompt_prefix);
    exchange(client, &prompt, cfg.max_tokens, stream, chat, None)
}

/// One completion exchange (unary or streaming, completion or chat) with
/// the same accounting the closed loop and the scenario engine share.
/// `tenant` rides as an `x-enova-tenant` header, so the gateway's
/// admission layer resolves the request to that tenant's SLO tier and
/// budgets.
fn exchange(
    client: &mut Client,
    prompt: &str,
    max_tokens: usize,
    stream: bool,
    chat: bool,
    tenant: Option<&str>,
) -> OneResult {
    // build through util::json so arbitrary prompt content is escaped
    let body = if chat {
        obj([
            (
                "messages",
                Json::Arr(vec![obj([("role", s("user")), ("content", s(prompt))])]),
            ),
            ("max_tokens", num(max_tokens as f64)),
            ("stream", Json::Bool(stream)),
        ])
    } else {
        obj([
            ("prompt", s(prompt)),
            ("max_tokens", num(max_tokens as f64)),
            ("stream", Json::Bool(stream)),
        ])
    }
    .to_string_compact();
    let path = if chat {
        "/v1/chat/completions"
    } else {
        "/v1/completions"
    };
    let extra = match tenant {
        Some(name) => format!("x-enova-tenant: {name}\r\n"),
        None => String::new(),
    };
    let t0 = Instant::now();
    let mut chunk_times: Vec<Instant> = Vec::new();
    let result = if stream {
        client.request_timed_headed("POST", path, Some(&body), &extra, &mut chunk_times)
    } else {
        client.request_headed("POST", path, Some(&body), &extra)
    };
    match result {
        Err(_) => OneResult {
            status: None,
            latency: t0.elapsed(),
            sse_events: 0,
            completion_tokens: 0,
            ttft: None,
            inter_token_gaps: Vec::new(),
            tenant: tenant.map(str::to_string),
        },
        Ok(resp) => {
            let mut sse_events = 0;
            let mut completion_tokens = 0;
            let mut ttft = None;
            let mut inter_token_gaps = Vec::new();
            if resp.status == 200 {
                if stream {
                    let events = resp.sse_data();
                    sse_events = events.len();
                    completion_tokens = events
                        .iter()
                        .filter(|e| e.as_str() != "[DONE]")
                        .filter(|e| {
                            Json::parse(e)
                                .ok()
                                .and_then(|j| {
                                    j.get("choices")?.as_arr()?.first().map(|c| {
                                        c.get("text").is_some()
                                            || c.at(&["delta", "content"]).is_some()
                                    })
                                })
                                .unwrap_or(false)
                        })
                        .count();
                    ttft = chunk_times
                        .first()
                        .map(|t| t.saturating_duration_since(t0).as_secs_f64());
                    // gaps between consecutive *content* chunks; the
                    // trailing [DONE] flush is excluded when the
                    // one-event-per-chunk alignment holds
                    let content_times: Vec<Instant> = if chunk_times.len() == events.len() {
                        events
                            .iter()
                            .zip(&chunk_times)
                            .filter(|(e, _)| e.as_str() != "[DONE]")
                            .map(|(_, t)| *t)
                            .collect()
                    } else {
                        chunk_times.clone()
                    };
                    inter_token_gaps = content_times
                        .windows(2)
                        .map(|w| w[1].saturating_duration_since(w[0]).as_secs_f64())
                        .collect();
                } else if let Ok(j) = resp.json() {
                    completion_tokens = j
                        .at(&["usage", "completion_tokens"])
                        .and_then(Json::as_usize)
                        .unwrap_or(0);
                }
            }
            OneResult {
                status: Some(resp.status),
                latency: t0.elapsed(),
                sse_events,
                completion_tokens,
                ttft,
                inter_token_gaps,
                tenant: tenant.map(str::to_string),
            }
        }
    }
}

/// Sorted per-request samples that become the report's percentile lines.
#[derive(Default)]
struct LatencySamples {
    latencies_ms: Vec<f64>,
    ttft_ms: Vec<f64>,
    inter_token_ms: Vec<f64>,
    /// tenant name → (requests, ok, shed, sorted ok-latencies in ms)
    tenants: BTreeMap<String, TenantSamples>,
}

#[derive(Default)]
struct TenantSamples {
    requests: usize,
    ok: usize,
    shed: usize,
    latencies_ms: Vec<f64>,
}

/// Fold a stream of per-request results into a report; returns the sorted
/// sample lists alongside for the percentile fill-in.
fn collect_results(rx: mpsc::Receiver<OneResult>) -> (LoadgenReport, LatencySamples) {
    let mut report = LoadgenReport::default();
    let mut samples = LatencySamples::default();
    for r in rx {
        report.requests += 1;
        let latency_ms = r.latency.as_secs_f64() * 1e3;
        match r.status {
            None => report.errors += 1,
            Some(code) => {
                *report.status_counts.entry(code).or_insert(0) += 1;
                if code == 200 {
                    report.ok += 1;
                    samples.latencies_ms.push(latency_ms);
                }
            }
        }
        if let Some(name) = &r.tenant {
            let t = samples.tenants.entry(name.clone()).or_default();
            t.requests += 1;
            match r.status {
                Some(200) => {
                    t.ok += 1;
                    t.latencies_ms.push(latency_ms);
                }
                Some(429) | Some(503) => t.shed += 1,
                _ => {}
            }
        }
        if let Some(ttft) = r.ttft {
            samples.ttft_ms.push(ttft * 1e3);
        }
        samples
            .inter_token_ms
            .extend(r.inter_token_gaps.iter().map(|g| g * 1e3));
        report.sse_events += r.sse_events;
        report.completion_tokens += r.completion_tokens;
    }
    samples.latencies_ms.sort_by(f64::total_cmp);
    samples.ttft_ms.sort_by(f64::total_cmp);
    samples.inter_token_ms.sort_by(f64::total_cmp);
    for t in samples.tenants.values_mut() {
        t.latencies_ms.sort_by(f64::total_cmp);
    }
    (report, samples)
}

fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let idx = ((sorted.len() - 1) as f64 * q).round() as usize;
    sorted[idx]
}

fn fill_percentiles(report: &mut LoadgenReport, samples: &LatencySamples) {
    report.p50_ms = percentile(&samples.latencies_ms, 0.50);
    report.p95_ms = percentile(&samples.latencies_ms, 0.95);
    report.p99_ms = percentile(&samples.latencies_ms, 0.99);
    report.ttft_p50_ms = percentile(&samples.ttft_ms, 0.50);
    report.ttft_p95_ms = percentile(&samples.ttft_ms, 0.95);
    report.ttft_p99_ms = percentile(&samples.ttft_ms, 0.99);
    report.itl_p50_ms = percentile(&samples.inter_token_ms, 0.50);
    report.itl_p95_ms = percentile(&samples.inter_token_ms, 0.95);
    report.itl_p99_ms = percentile(&samples.inter_token_ms, 0.99);
}

/// Run the closed loop against `addr` and aggregate a report.
pub fn run(addr: &str, cfg: &LoadgenConfig) -> LoadgenReport {
    let t0 = Instant::now();
    let (tx, rx) = mpsc::channel::<OneResult>();
    let (conn_tx, conn_rx) = mpsc::channel::<usize>();
    let mut handles = Vec::new();
    let pool = Arc::new(ConnPool::new(addr));
    for worker in 0..cfg.concurrency {
        let tx = tx.clone();
        let conn_tx = conn_tx.clone();
        let cfg = cfg.clone();
        let pool = Arc::clone(&pool);
        handles.push(std::thread::spawn(move || {
            let mut client = Client::with_pool(pool);
            for k in 0..cfg.requests_per_worker {
                let _ = tx.send(one_request(&mut client, &cfg, worker, k));
            }
            let _ = conn_tx.send(client.connections_opened);
        }));
    }
    drop(tx);
    drop(conn_tx);

    let (mut report, samples) = collect_results(rx);
    report.connections_opened = conn_rx.iter().sum();
    for h in handles {
        let _ = h.join();
    }
    report.elapsed_secs = t0.elapsed().as_secs_f64();
    fill_percentiles(&mut report, &samples);
    report
}

/// Named arrival-pattern generators for the scenario engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScenarioKind {
    /// constant rate at `base_rps`
    Steady,
    /// raised-cosine day: starts at `base_rps`, peaks at `peak_rps` half a
    /// period in, returns to base — the predictable ramp a forecaster
    /// should get ahead of
    Diurnal,
    /// flat base with a rectangular burst to `peak_rps` — the shape a
    /// purely reactive loop handles least badly
    Spike,
    /// linear climb from `base_rps` to `peak_rps` over the whole run
    Ramp,
    /// steady aggregate rate split across heterogeneous co-located tenants
    /// (different prompt lengths, output budgets and streaming habits)
    Mixture,
}

impl ScenarioKind {
    pub const ALL: [ScenarioKind; 5] = [
        ScenarioKind::Steady,
        ScenarioKind::Diurnal,
        ScenarioKind::Spike,
        ScenarioKind::Ramp,
        ScenarioKind::Mixture,
    ];

    pub fn parse(name: &str) -> Option<ScenarioKind> {
        match name {
            "steady" => Some(ScenarioKind::Steady),
            "diurnal" => Some(ScenarioKind::Diurnal),
            "spike" => Some(ScenarioKind::Spike),
            "ramp" => Some(ScenarioKind::Ramp),
            "mixture" => Some(ScenarioKind::Mixture),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            ScenarioKind::Steady => "steady",
            ScenarioKind::Diurnal => "diurnal",
            ScenarioKind::Spike => "spike",
            ScenarioKind::Ramp => "ramp",
            ScenarioKind::Mixture => "mixture",
        }
    }
}

/// One co-located application in a `mixture` scenario. The names line up
/// with the gateway's built-in tenant registry, so requests issued as
/// these tenants resolve to real SLO tiers and budgets server-side.
#[derive(Debug, Clone)]
pub struct TenantSpec {
    pub name: String,
    /// SLO tier label ("latency" | "standard" | "batch") — sent for
    /// report grading only; the *server's* registry decides the real tier
    pub tier: String,
    /// share of the aggregate arrival rate (normalized over all tenants)
    pub weight: f64,
    /// approximate prompt length in words
    pub prompt_words: usize,
    /// per-request completion budget
    pub max_tokens: usize,
    /// whether this tenant's requests stream
    pub stream: bool,
    /// p95 end-to-end latency budget in ms graded by `--strict`; 0 =
    /// ungraded (batch tenants have throughput, not latency, SLOs)
    pub slo_p95_ms: f64,
}

/// The paper's co-location setting in miniature: an interactive chat app,
/// a long-prompt/short-output summarizer, and a short-prompt/long-output
/// code generator sharing one gateway — one tenant per SLO tier.
pub fn default_tenants() -> Vec<TenantSpec> {
    vec![
        TenantSpec {
            name: "chat".into(),
            tier: "latency".into(),
            weight: 0.5,
            prompt_words: 24,
            max_tokens: 16,
            stream: true,
            slo_p95_ms: 5_000.0,
        },
        TenantSpec {
            name: "summarize".into(),
            tier: "standard".into(),
            weight: 0.3,
            prompt_words: 120,
            max_tokens: 6,
            stream: false,
            slo_p95_ms: 10_000.0,
        },
        TenantSpec {
            name: "codegen".into(),
            tier: "batch".into(),
            weight: 0.2,
            prompt_words: 40,
            max_tokens: 32,
            stream: false,
            slo_p95_ms: 0.0,
        },
    ]
}

/// Shape parameters of one open-loop scenario run.
#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    pub kind: ScenarioKind,
    pub duration: Duration,
    pub base_rps: f64,
    pub peak_rps: f64,
    /// diurnal period; `ZERO` means one full period per run
    pub period: Duration,
    /// spike window start/length as fractions of the duration
    pub spike_start: f64,
    pub spike_len: f64,
    /// seeds the Poisson schedule and tenant assignment — identical seeds
    /// replay identical offered load
    pub seed: u64,
    /// dispatcher pool size (upper bound on in-flight requests)
    pub workers: usize,
    /// completion budget for non-mixture scenarios
    pub max_tokens: usize,
    /// co-located applications (used by `mixture`)
    pub tenants: Vec<TenantSpec>,
}

impl Default for ScenarioConfig {
    fn default() -> Self {
        ScenarioConfig {
            kind: ScenarioKind::Steady,
            duration: Duration::from_secs(10),
            base_rps: 2.0,
            peak_rps: 8.0,
            period: Duration::ZERO,
            spike_start: 0.5,
            spike_len: 0.2,
            seed: 42,
            workers: 32,
            max_tokens: 8,
            tenants: default_tenants(),
        }
    }
}

/// Safety cap on a generated schedule, so a typo'd rate cannot allocate
/// an unbounded arrival list.
const MAX_SCHEDULED_ARRIVALS: usize = 250_000;

/// One scheduled request of a scenario run.
#[derive(Debug, Clone)]
struct Arrival {
    /// seconds into the run
    at: f64,
    prompt: String,
    max_tokens: usize,
    stream: bool,
    chat: bool,
    /// tenant identity the request is issued as (mixture only)
    tenant: Option<String>,
}

impl ScenarioConfig {
    fn duration_secs(&self) -> f64 {
        self.duration.as_secs_f64().max(1e-9)
    }

    fn period_secs(&self) -> f64 {
        if self.period.is_zero() {
            self.duration_secs()
        } else {
            self.period.as_secs_f64().max(1e-9)
        }
    }

    /// Arrival intensity λ(t) in requests/second at `t` seconds into the
    /// run.
    pub fn rate_at(&self, t: f64) -> f64 {
        let d = self.duration_secs();
        let base = self.base_rps.max(0.0);
        let peak = self.peak_rps.max(base);
        match self.kind {
            ScenarioKind::Steady | ScenarioKind::Mixture => base,
            ScenarioKind::Diurnal => {
                let p = self.period_secs();
                let phase = 2.0 * std::f64::consts::PI * (t / p);
                base + (peak - base) * 0.5 * (1.0 - phase.cos())
            }
            ScenarioKind::Spike => {
                let s0 = self.spike_start.clamp(0.0, 1.0) * d;
                let s1 = (self.spike_start + self.spike_len).clamp(0.0, 1.0) * d;
                if t >= s0 && t < s1 {
                    peak
                } else {
                    base
                }
            }
            ScenarioKind::Ramp => base + (peak - base) * (t / d).clamp(0.0, 1.0),
        }
    }

    /// Seconds into the run at which λ(t) first peaks — what a proactive
    /// gateway must beat.
    pub fn peak_time_secs(&self) -> f64 {
        let d = self.duration_secs();
        match self.kind {
            ScenarioKind::Steady | ScenarioKind::Mixture => 0.0,
            ScenarioKind::Diurnal => (self.period_secs() / 2.0).min(d),
            ScenarioKind::Spike => self.spike_start.clamp(0.0, 1.0) * d,
            ScenarioKind::Ramp => d,
        }
    }

    /// Shape parameters as JSON — embedded in the report so every
    /// artifact names the traffic that produced it.
    pub fn to_json(&self, offered: usize) -> Json {
        let mut j = obj([
            ("kind", s(self.kind.name())),
            ("duration_secs", num(self.duration_secs())),
            ("base_rps", num(self.base_rps)),
            ("peak_rps", num(self.peak_rps)),
            ("period_secs", num(self.period_secs())),
            ("spike_start", num(self.spike_start)),
            ("spike_len", num(self.spike_len)),
            ("seed", num(self.seed as f64)),
            ("workers", num(self.workers as f64)),
            ("max_tokens", num(self.max_tokens as f64)),
            ("peak_time_secs", num(self.peak_time_secs())),
            ("offered", num(offered as f64)),
            ("offered_rps", num(offered as f64 / self.duration_secs())),
        ]);
        if self.kind == ScenarioKind::Mixture {
            let tenants = Json::Arr(
                self.tenants
                    .iter()
                    .map(|t| {
                        obj([
                            ("name", s(&t.name)),
                            ("tier", s(&t.tier)),
                            ("weight", num(t.weight)),
                            ("prompt_words", num(t.prompt_words as f64)),
                            ("max_tokens", num(t.max_tokens as f64)),
                            ("stream", Json::Bool(t.stream)),
                            ("slo_p95_ms", num(t.slo_p95_ms)),
                        ])
                    })
                    .collect(),
            );
            if let Json::Obj(m) = &mut j {
                m.insert("tenants".to_string(), tenants);
            }
        }
        j
    }

    /// The seeded arrival schedule: non-homogeneous Poisson by thinning,
    /// with per-arrival request bodies (tenant-assigned for `mixture`).
    fn arrivals(&self) -> Vec<Arrival> {
        let d = self.duration_secs();
        // every shape is bounded by max(base, peak), so thinning against
        // that envelope is exact even for sub-sample-width spikes
        let lambda_max = self.base_rps.max(self.peak_rps).max(1e-9);
        let mut rng = Pcg64::new(self.seed);
        let total_weight: f64 = self.tenants.iter().map(|t| t.weight.max(0.0)).sum();
        let mut out = Vec::new();
        let mut t = 0.0;
        let mut i = 0usize;
        loop {
            t += rng.exponential(lambda_max);
            if t >= d || out.len() >= MAX_SCHEDULED_ARRIVALS {
                break;
            }
            // thinning: accept with probability λ(t)/λ_max
            if rng.f64() > self.rate_at(t) / lambda_max {
                continue;
            }
            let arrival = if self.kind == ScenarioKind::Mixture && total_weight > 0.0 {
                let mut pick = rng.f64() * total_weight;
                let mut chosen = &self.tenants[self.tenants.len() - 1];
                for tenant in &self.tenants {
                    pick -= tenant.weight.max(0.0);
                    if pick <= 0.0 {
                        chosen = tenant;
                        break;
                    }
                }
                Arrival {
                    at: t,
                    prompt: filler_prompt(&chosen.name, i, chosen.prompt_words),
                    max_tokens: chosen.max_tokens,
                    stream: chosen.stream,
                    chat: false,
                    tenant: Some(chosen.name.clone()),
                }
            } else {
                Arrival {
                    at: t,
                    prompt: format!("scenario {} req {i}", self.kind.name()),
                    max_tokens: self.max_tokens,
                    stream: i % 4 == 0,
                    chat: i % 3 == 0,
                    tenant: None,
                }
            };
            out.push(arrival);
            i += 1;
        }
        out
    }
}

/// Deterministic prompt of roughly `words` words for a tenant.
fn filler_prompt(tenant: &str, i: usize, words: usize) -> String {
    let mut p = format!("tenant {tenant} request {i}");
    for w in 0..words.saturating_sub(3) {
        p.push_str(if w % 2 == 0 { " serve" } else { " tokens" });
    }
    p
}

/// Replay a scenario's arrival schedule against `addr` in real time: a
/// scheduler thread paces the seeded offsets, a pool of `workers`
/// keep-alive clients issues the requests. Open loop: latency is measured
/// from the *scheduled arrival time*, so a saturated worker pool or a
/// slow gateway shows up as latency — never as a silently slower attack
/// rate.
pub fn run_scenario(addr: &str, cfg: &ScenarioConfig) -> LoadgenReport {
    let arrivals = cfg.arrivals();
    let offered = arrivals.len();
    let (tx, rx) = mpsc::channel::<OneResult>();
    let (conn_tx, conn_rx) = mpsc::channel::<usize>();
    let (job_tx, job_rx) = mpsc::channel::<(Arrival, Instant)>();
    let job_rx = Arc::new(Mutex::new(job_rx));
    let mut handles = Vec::new();
    let pool = Arc::new(ConnPool::new(addr));
    for _ in 0..cfg.workers.max(1) {
        let tx = tx.clone();
        let conn_tx = conn_tx.clone();
        let job_rx = Arc::clone(&job_rx);
        let pool = Arc::clone(&pool);
        handles.push(std::thread::spawn(move || {
            let mut client = Client::with_pool(pool);
            loop {
                let job = job_rx.lock().unwrap().recv();
                match job {
                    Ok((a, due)) => {
                        let mut r = exchange(
                            &mut client,
                            &a.prompt,
                            a.max_tokens,
                            a.stream,
                            a.chat,
                            a.tenant.as_deref(),
                        );
                        // open-loop latency: from the scheduled arrival,
                        // including any wait for a free worker
                        r.latency = due.elapsed().max(r.latency);
                        let _ = tx.send(r);
                    }
                    Err(_) => break,
                }
            }
            let _ = conn_tx.send(client.connections_opened);
        }));
    }
    drop(tx);
    drop(conn_tx);

    let t0 = Instant::now();
    for a in arrivals {
        let due = t0 + Duration::from_secs_f64(a.at.max(0.0));
        let wait = due.saturating_duration_since(Instant::now());
        if !wait.is_zero() {
            std::thread::sleep(wait);
        }
        if job_tx.send((a, due)).is_err() {
            break;
        }
    }
    drop(job_tx);

    let (mut report, samples) = collect_results(rx);
    report.connections_opened = conn_rx.iter().sum();
    for h in handles {
        let _ = h.join();
    }
    report.elapsed_secs = t0.elapsed().as_secs_f64();
    fill_percentiles(&mut report, &samples);
    fill_tenant_stats(&mut report, &samples, &cfg.tenants);
    report.scenario = Some(cfg.to_json(offered));
    report
}

/// Turn the per-tenant sample accumulators into report lines, attaching
/// each tenant's tier and SLO budget from the scenario's specs. Tenants
/// that sent no requests (zero weight, or a non-mixture run) are omitted.
fn fill_tenant_stats(report: &mut LoadgenReport, samples: &LatencySamples, specs: &[TenantSpec]) {
    report.tenant_stats = samples
        .tenants
        .iter()
        .map(|(name, t)| {
            let spec = specs.iter().find(|sp| &sp.name == name);
            TenantStat {
                name: name.clone(),
                tier: spec.map(|sp| sp.tier.clone()).unwrap_or_default(),
                requests: t.requests,
                ok: t.ok,
                shed: t.shed,
                p50_ms: percentile(&t.latencies_ms, 0.50),
                p95_ms: percentile(&t.latencies_ms, 0.95),
                slo_p95_ms: spec.map(|sp| sp.slo_p95_ms).unwrap_or(0.0),
            }
        })
        .collect();
}

// ---------------------------------------------------------------------------
// Adversarial clients
// ---------------------------------------------------------------------------

/// A deliberately misbehaving client persona for chaos drills.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdversarialKind {
    /// drip the request head and body a few bytes at a time with seeded
    /// pauses — the classic slow-loris connection squatter
    SlowLoris,
    /// start a streaming completion, read a few SSE chunks, then sever
    /// the socket mid-stream without a clean close
    SseDisconnect,
}

impl AdversarialKind {
    pub const ALL: [AdversarialKind; 2] =
        [AdversarialKind::SlowLoris, AdversarialKind::SseDisconnect];

    pub fn parse(name: &str) -> Option<AdversarialKind> {
        match name {
            "slow-loris" => Some(AdversarialKind::SlowLoris),
            "sse-disconnect" => Some(AdversarialKind::SseDisconnect),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            AdversarialKind::SlowLoris => "slow-loris",
            AdversarialKind::SseDisconnect => "sse-disconnect",
        }
    }
}

/// Parse a comma-separated persona list (`slow-loris,sse-disconnect`).
/// An empty string selects every persona.
pub fn parse_adversarial_list(list: &str) -> Result<Vec<AdversarialKind>> {
    let names: Vec<&str> = list
        .split(',')
        .map(str::trim)
        .filter(|n| !n.is_empty())
        .collect();
    if names.is_empty() {
        return Ok(AdversarialKind::ALL.to_vec());
    }
    names
        .iter()
        .map(|n| {
            AdversarialKind::parse(n).ok_or_else(|| {
                anyhow!(
                    "unknown adversarial persona {n:?} (expected one of: {})",
                    AdversarialKind::ALL.map(|k| k.name()).join(", ")
                )
            })
        })
        .collect()
}

/// Shape of one adversarial run: `clients` misbehaving connections loop
/// over the selected personas until `duration` elapses.
#[derive(Debug, Clone)]
pub struct AdversarialConfig {
    pub kinds: Vec<AdversarialKind>,
    pub clients: usize,
    pub duration: Duration,
    /// seeds every persona's byte pacing and disconnect points —
    /// identical seeds replay identical misbehavior
    pub seed: u64,
    pub max_tokens: usize,
}

impl Default for AdversarialConfig {
    fn default() -> Self {
        AdversarialConfig {
            kinds: AdversarialKind::ALL.to_vec(),
            clients: 4,
            duration: Duration::from_secs(10),
            seed: 42,
            max_tokens: 8,
        }
    }
}

/// Outcome counters of an adversarial run. "Defended" outcomes (the
/// server cutting a loris, shedding with 4xx) are successes for the
/// server; `errors` counts only transport failures on *our* side before
/// the misbehavior even started.
#[derive(Debug, Clone, Default)]
pub struct AdversarialReport {
    pub slow_loris_sent: usize,
    /// the server waited out the drip and answered with a status
    pub slow_loris_answered: usize,
    /// the server severed the connection mid-drip (defense engaged)
    pub slow_loris_cut: usize,
    pub sse_attempts: usize,
    /// streams we actually walked away from mid-flight
    pub sse_abandoned: usize,
    pub sse_chunks_consumed: usize,
    pub errors: usize,
}

impl AdversarialReport {
    fn merge(&mut self, other: &AdversarialReport) {
        self.slow_loris_sent += other.slow_loris_sent;
        self.slow_loris_answered += other.slow_loris_answered;
        self.slow_loris_cut += other.slow_loris_cut;
        self.sse_attempts += other.sse_attempts;
        self.sse_abandoned += other.sse_abandoned;
        self.sse_chunks_consumed += other.sse_chunks_consumed;
        self.errors += other.errors;
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("slow_loris_sent", num(self.slow_loris_sent as f64)),
            ("slow_loris_answered", num(self.slow_loris_answered as f64)),
            ("slow_loris_cut", num(self.slow_loris_cut as f64)),
            ("sse_attempts", num(self.sse_attempts as f64)),
            ("sse_abandoned", num(self.sse_abandoned as f64)),
            ("sse_chunks_consumed", num(self.sse_chunks_consumed as f64)),
            ("errors", num(self.errors as f64)),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "adversarial: {} loris ({} answered, {} cut), {} sse streams \
             ({} abandoned after {} chunks), {} errors",
            self.slow_loris_sent,
            self.slow_loris_answered,
            self.slow_loris_cut,
            self.sse_attempts,
            self.sse_abandoned,
            self.sse_chunks_consumed,
            self.errors,
        )
    }
}

enum SlowLorisOutcome {
    Answered(u16),
    Cut,
}

/// One slow-loris exchange: a valid unary completion whose bytes arrive
/// 1–3 at a time with seeded sub-10ms pauses. A server that tears the
/// socket down mid-drip reports as `Cut`; one that waits us out and
/// answers reports its status.
fn slow_loris_once(addr: &str, rng: &mut Pcg64, max_tokens: usize) -> Result<SlowLorisOutcome> {
    let body = obj([
        ("prompt", s("adversarial slow loris")),
        ("max_tokens", num(max_tokens as f64)),
        ("stream", Json::Bool(false)),
    ])
    .to_string_compact();
    let head = request_head("POST", "/v1/completions", addr, Some(&body), true, "");
    let wire = format!("{head}{body}");
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    stream.set_nodelay(true)?;
    let bytes = wire.as_bytes();
    let mut sent = 0usize;
    let mut w = &stream;
    while sent < bytes.len() {
        let take = rng.usize_in(1, 4).min(bytes.len() - sent);
        match w.write_all(&bytes[sent..sent + take]).and_then(|()| w.flush()) {
            Ok(()) => sent += take,
            // reset/broken pipe mid-drip: the server's defense engaged
            Err(_) => return Ok(SlowLorisOutcome::Cut),
        }
        std::thread::sleep(Duration::from_micros(rng.usize_in(500, 8_000) as u64));
    }
    match read_response(&stream) {
        Ok(resp) => Ok(SlowLorisOutcome::Answered(resp.status)),
        Err(_) => Ok(SlowLorisOutcome::Cut),
    }
}

/// One mid-stream disconnect: start a streaming completion, consume a
/// seeded 1–3 SSE chunks, then sever the socket with no clean close.
/// Returns `(chunks_consumed, abandoned)` — not abandoned when the
/// server answered unary/shed (nothing to walk away from) or the stream
/// finished before the disconnect point.
fn sse_disconnect_once(
    addr: &str,
    rng: &mut Pcg64,
    max_tokens: usize,
) -> Result<(usize, bool)> {
    let body = obj([
        ("prompt", s("adversarial mid-stream disconnect")),
        ("max_tokens", num(max_tokens.max(2) as f64)),
        ("stream", Json::Bool(true)),
    ])
    .to_string_compact();
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(30)))?;
    stream.set_write_timeout(Some(Duration::from_secs(10)))?;
    stream.set_nodelay(true)?;
    let mut w = &stream;
    w.write_all(request_head("POST", "/v1/completions", addr, Some(&body), true, "").as_bytes())?;
    w.write_all(body.as_bytes())?;
    w.flush()?;
    let mut r = BufReader::new(&stream);
    let (status, headers) = read_response_head(&mut r)?;
    let chunked = headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false);
    if status != 200 || !chunked {
        // shed or error answer — drop the socket, nothing was streaming
        return Ok((0, false));
    }
    let target = rng.usize_in(1, 4);
    let mut consumed = 0usize;
    while consumed < target {
        match read_chunk(&mut r)? {
            Some(_) => consumed += 1,
            // the stream finished before we got to be rude
            None => return Ok((consumed, false)),
        }
    }
    let _ = stream.shutdown(std::net::Shutdown::Both);
    Ok((consumed, true))
}

/// Run the selected misbehaving personas against `addr` until the
/// configured duration elapses. Runs alongside a normal loadgen/scenario
/// (spawn it on its own thread) to answer: does hostile traffic degrade
/// the well-behaved tenants?
pub fn run_adversarial(addr: &str, cfg: &AdversarialConfig) -> AdversarialReport {
    let deadline = Instant::now() + cfg.duration;
    let (tx, rx) = mpsc::channel::<AdversarialReport>();
    let mut handles = Vec::new();
    let mut root = Pcg64::new(cfg.seed);
    for worker in 0..cfg.clients.max(1) {
        let tx = tx.clone();
        let addr = addr.to_string();
        let kinds = cfg.kinds.clone();
        let max_tokens = cfg.max_tokens;
        let mut rng = root.fork(worker as u64 + 1);
        handles.push(std::thread::spawn(move || {
            let mut local = AdversarialReport::default();
            while !kinds.is_empty() && Instant::now() < deadline {
                match *rng.choice(&kinds) {
                    AdversarialKind::SlowLoris => {
                        local.slow_loris_sent += 1;
                        match slow_loris_once(&addr, &mut rng, max_tokens) {
                            Ok(SlowLorisOutcome::Answered(_)) => local.slow_loris_answered += 1,
                            Ok(SlowLorisOutcome::Cut) => local.slow_loris_cut += 1,
                            Err(_) => local.errors += 1,
                        }
                    }
                    AdversarialKind::SseDisconnect => {
                        local.sse_attempts += 1;
                        match sse_disconnect_once(&addr, &mut rng, max_tokens) {
                            Ok((chunks, abandoned)) => {
                                local.sse_chunks_consumed += chunks;
                                if abandoned {
                                    local.sse_abandoned += 1;
                                }
                            }
                            Err(_) => local.errors += 1,
                        }
                    }
                }
            }
            let _ = tx.send(local);
        }));
    }
    drop(tx);
    let mut report = AdversarialReport::default();
    for part in rx {
        report.merge(&part);
    }
    for h in handles {
        let _ = h.join();
    }
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conn_pool_reuses_checked_in_sockets() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            let mut held = Vec::new();
            for s in listener.incoming().flatten() {
                held.push(s);
            }
        });
        let pool = ConnPool::new(&addr);
        let (a, reused) = pool.checkout().unwrap();
        assert!(!reused, "empty pool must dial");
        assert_eq!(pool.connections_opened(), 1);
        pool.checkin(a);
        assert_eq!(pool.idle_count(), 1);
        let (_b, reused) = pool.checkout().unwrap();
        assert!(reused, "parked socket must be reused before dialing");
        assert_eq!(pool.connections_opened(), 1);
        assert_eq!(pool.idle_count(), 0);
    }

    #[test]
    fn sse_data_extraction() {
        let resp = HttpResponse {
            status: 200,
            headers: BTreeMap::new(),
            body: b"data: {\"a\":1}\n\ndata: {\"b\":2}\n\ndata: [DONE]\n\n".to_vec(),
        };
        assert_eq!(resp.sse_data(), vec!["{\"a\":1}", "{\"b\":2}", "[DONE]"]);
    }

    #[test]
    fn chunked_body_decoding() {
        let wire = b"5\r\nhello\r\n6\r\n world\r\n0\r\n\r\n";
        let mut r = std::io::BufReader::new(&wire[..]);
        let mut times = Vec::new();
        assert_eq!(
            read_chunked_timed(&mut r, Some(&mut times)).unwrap(),
            b"hello world"
        );
        assert_eq!(times.len(), 2, "one arrival instant per chunk");
    }

    #[test]
    fn chunked_rejects_garbage_size() {
        let wire = b"zz\r\nhello\r\n";
        let mut r = std::io::BufReader::new(&wire[..]);
        assert!(read_chunked_timed(&mut r, None).is_err());
    }

    #[test]
    fn report_json_roundtrips() {
        let mut report = LoadgenReport {
            requests: 3,
            ok: 2,
            errors: 1,
            elapsed_secs: 2.0,
            p99_ms: 12.5,
            ..Default::default()
        };
        report.status_counts.insert(200, 2);
        let j = Json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.get("requests").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("errors").and_then(Json::as_usize), Some(1));
        assert_eq!(j.at(&["status_counts", "200"]).and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("p99_ms").and_then(Json::as_f64), Some(12.5));
        assert_eq!(j.get("requests_per_sec").and_then(Json::as_f64), Some(1.5));
    }

    #[test]
    fn stream_timing_percentiles_land_in_report() {
        let mut report = LoadgenReport::default();
        let samples = LatencySamples {
            latencies_ms: vec![1.0, 2.0, 3.0],
            ttft_ms: vec![5.0, 7.0, 9.0],
            inter_token_ms: vec![0.5, 1.5, 2.5],
        };
        fill_percentiles(&mut report, &samples);
        assert_eq!(report.p50_ms, 2.0);
        assert_eq!(report.ttft_p50_ms, 7.0);
        assert_eq!(report.ttft_p99_ms, 9.0);
        assert_eq!(report.itl_p50_ms, 1.5);
        assert_eq!(report.itl_p95_ms, 2.5);
        let j = Json::parse(&report.to_json().to_string_compact()).unwrap();
        assert_eq!(j.get("ttft_p50_ms").and_then(Json::as_f64), Some(7.0));
        assert_eq!(j.get("itl_p99_ms").and_then(Json::as_f64), Some(2.5));
        // empty sample lists stay at zero instead of panicking
        let mut empty = LoadgenReport::default();
        fill_percentiles(&mut empty, &LatencySamples::default());
        assert_eq!(empty.ttft_p99_ms, 0.0);
    }

    #[test]
    fn request_heads_differ_on_connection_policy() {
        let one_shot = request_head("POST", "/x", "h:1", Some("{}"), true, "");
        assert!(one_shot.contains("Connection: close\r\n"));
        assert!(one_shot.contains("Content-Length: 2\r\n"));
        let keep_alive = request_head("GET", "/x", "h:1", None, false, "");
        assert!(!keep_alive.contains("Connection:"));
        assert!(keep_alive.ends_with("\r\n\r\n"));
        // an extra header block lands verbatim in the head section
        let tenanted = request_head("POST", "/x", "h:1", None, false, "x-enova-tenant: chat\r\n");
        assert!(tenanted.contains("\r\nx-enova-tenant: chat\r\n"));
        assert!(tenanted.ends_with("\r\n\r\n"));
    }

    #[test]
    fn scenario_kind_names_roundtrip() {
        for kind in ScenarioKind::ALL {
            assert_eq!(ScenarioKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(ScenarioKind::parse("tsunami"), None);
    }

    fn scenario(kind: ScenarioKind) -> ScenarioConfig {
        ScenarioConfig {
            kind,
            duration: Duration::from_secs(60),
            base_rps: 2.0,
            peak_rps: 10.0,
            seed: 7,
            ..ScenarioConfig::default()
        }
    }

    #[test]
    fn rate_shapes_match_their_names() {
        let steady = scenario(ScenarioKind::Steady);
        assert_eq!(steady.rate_at(0.0), 2.0);
        assert_eq!(steady.rate_at(59.0), 2.0);

        let diurnal = scenario(ScenarioKind::Diurnal);
        assert!((diurnal.rate_at(0.0) - 2.0).abs() < 1e-9, "starts at base");
        assert!((diurnal.rate_at(30.0) - 10.0).abs() < 1e-9, "peaks mid-period");
        assert!((diurnal.peak_time_secs() - 30.0).abs() < 1e-9);
        // symmetric around the peak
        assert!((diurnal.rate_at(20.0) - diurnal.rate_at(40.0)).abs() < 1e-9);

        let spike = scenario(ScenarioKind::Spike);
        assert_eq!(spike.rate_at(10.0), 2.0, "before the burst");
        assert_eq!(spike.rate_at(31.0), 10.0, "inside the burst");
        assert_eq!(spike.rate_at(43.0), 2.0, "after the burst");
        assert!((spike.peak_time_secs() - 30.0).abs() < 1e-9);

        let ramp = scenario(ScenarioKind::Ramp);
        assert!((ramp.rate_at(0.0) - 2.0).abs() < 1e-9);
        assert!((ramp.rate_at(60.0) - 10.0).abs() < 1e-9);
        assert!((ramp.rate_at(30.0) - 6.0).abs() < 1e-9);

        let mixture = scenario(ScenarioKind::Mixture);
        assert_eq!(mixture.rate_at(17.0), 2.0, "aggregate stays steady");
    }

    #[test]
    fn schedules_are_seeded_sorted_and_in_range() {
        let cfg = scenario(ScenarioKind::Diurnal);
        let a = cfg.arrivals();
        let b = cfg.arrivals();
        assert_eq!(a.len(), b.len(), "same seed, same schedule");
        assert!(a
            .iter()
            .zip(&b)
            .all(|(x, y)| x.at == y.at && x.prompt == y.prompt));
        assert!(a.windows(2).all(|w| w[0].at <= w[1].at), "sorted arrivals");
        assert!(a.iter().all(|x| x.at >= 0.0 && x.at < 60.0));

        let other = ScenarioConfig {
            seed: 8,
            ..cfg.clone()
        };
        let c = other.arrivals();
        assert!(
            a.len() != c.len() || a.iter().zip(&c).any(|(x, y)| x.at != y.at),
            "different seed, different schedule"
        );

        // offered volume tracks the λ(t) integral: mean rate of the
        // raised cosine is (base+peak)/2 = 6 rps over 60 s ≈ 360
        let n = a.len() as f64;
        assert!((250.0..=470.0).contains(&n), "diurnal volume {n}");
    }

    #[test]
    fn mixture_assigns_heterogeneous_tenants() {
        let cfg = ScenarioConfig {
            kind: ScenarioKind::Mixture,
            duration: Duration::from_secs(120),
            base_rps: 4.0,
            seed: 3,
            ..ScenarioConfig::default()
        };
        let arrivals = cfg.arrivals();
        assert!(arrivals.len() > 100, "enough volume: {}", arrivals.len());
        // all three tenants show up, with their own budgets
        for tenant in default_tenants() {
            let of_tenant: Vec<_> = arrivals
                .iter()
                .filter(|a| a.prompt.contains(&format!("tenant {}", tenant.name)))
                .collect();
            assert!(!of_tenant.is_empty(), "tenant {} missing", tenant.name);
            assert!(of_tenant.iter().all(|a| a.max_tokens == tenant.max_tokens));
            assert!(of_tenant.iter().all(|a| a.stream == tenant.stream));
            assert!(
                of_tenant.iter().all(|a| a.tenant.as_deref() == Some(tenant.name.as_str())),
                "every arrival carries its tenant identity"
            );
        }
        // the dominant tenant dominates
        let chat = arrivals
            .iter()
            .filter(|a| a.prompt.contains("tenant chat"))
            .count();
        assert!(
            chat * 3 > arrivals.len(),
            "chat holds its ~50% share: {chat}/{}",
            arrivals.len()
        );
    }

    #[test]
    fn scenario_params_land_in_the_report_json() {
        let cfg = ScenarioConfig {
            kind: ScenarioKind::Diurnal,
            duration: Duration::from_secs(30),
            base_rps: 1.0,
            peak_rps: 5.0,
            seed: 9,
            ..ScenarioConfig::default()
        };
        let report = LoadgenReport {
            requests: 10,
            ok: 10,
            elapsed_secs: 30.0,
            p95_ms: 7.5,
            scenario: Some(cfg.to_json(42)),
            ..Default::default()
        };
        let j = Json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.at(&["scenario", "kind"]).and_then(Json::as_str), Some("diurnal"));
        assert_eq!(j.at(&["scenario", "base_rps"]).and_then(Json::as_f64), Some(1.0));
        assert_eq!(j.at(&["scenario", "peak_rps"]).and_then(Json::as_f64), Some(5.0));
        assert_eq!(j.at(&["scenario", "seed"]).and_then(Json::as_usize), Some(9));
        assert_eq!(j.at(&["scenario", "offered"]).and_then(Json::as_usize), Some(42));
        assert_eq!(
            j.at(&["scenario", "peak_time_secs"]).and_then(Json::as_f64),
            Some(15.0)
        );
        assert_eq!(j.get("p95_ms").and_then(Json::as_f64), Some(7.5));
        // mixture reports its tenant set
        let mix = ScenarioConfig {
            kind: ScenarioKind::Mixture,
            ..ScenarioConfig::default()
        };
        let mj = mix.to_json(0);
        assert_eq!(mj.get("tenants").and_then(Json::as_arr).map(<[Json]>::len), Some(3));
        let first = &mj.get("tenants").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(first.get("tier").and_then(Json::as_str), Some("latency"));
        assert_eq!(first.get("slo_p95_ms").and_then(Json::as_f64), Some(5_000.0));
    }

    #[test]
    fn adversarial_kind_names_and_lists_parse() {
        for kind in AdversarialKind::ALL {
            assert_eq!(AdversarialKind::parse(kind.name()), Some(kind));
        }
        assert_eq!(AdversarialKind::parse("teapot"), None);
        assert_eq!(
            parse_adversarial_list("slow-loris, sse-disconnect").unwrap(),
            AdversarialKind::ALL.to_vec()
        );
        assert_eq!(
            parse_adversarial_list("").unwrap(),
            AdversarialKind::ALL.to_vec(),
            "empty list selects every persona"
        );
        assert!(parse_adversarial_list("slow-loris,teapot").is_err());
    }

    #[test]
    fn adversarial_report_merges_and_serializes() {
        let mut a = AdversarialReport {
            slow_loris_sent: 2,
            slow_loris_answered: 1,
            slow_loris_cut: 1,
            ..Default::default()
        };
        let b = AdversarialReport {
            sse_attempts: 3,
            sse_abandoned: 2,
            sse_chunks_consumed: 5,
            errors: 1,
            ..Default::default()
        };
        a.merge(&b);
        let j = Json::parse(&a.to_json().to_string_compact()).unwrap();
        assert_eq!(j.get("slow_loris_sent").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("slow_loris_cut").and_then(Json::as_usize), Some(1));
        assert_eq!(j.get("sse_abandoned").and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("sse_chunks_consumed").and_then(Json::as_usize), Some(5));
        assert_eq!(j.get("errors").and_then(Json::as_usize), Some(1));
        assert!(a.summary().contains("2 loris"));
    }

    /// Minimal HTTP server: read one full request (head + Content-Length
    /// body), then answer a canned 200 and close.
    fn canned_unary_server() -> String {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                let mut buf = Vec::new();
                let mut tmp = [0u8; 256];
                loop {
                    match s.read(&mut tmp) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => buf.extend_from_slice(&tmp[..n]),
                    }
                    let text = String::from_utf8_lossy(&buf);
                    if let Some(head_end) = text.find("\r\n\r\n") {
                        let clen = text
                            .lines()
                            .find_map(|l| l.to_ascii_lowercase().strip_prefix("content-length:")
                                .and_then(|v| v.trim().parse::<usize>().ok()))
                            .unwrap_or(0);
                        if buf.len() >= head_end + 4 + clen {
                            let _ = s.write_all(
                                b"HTTP/1.1 200 OK\r\nContent-Length: 2\r\nConnection: close\r\n\r\nok",
                            );
                            break;
                        }
                    }
                }
            }
        });
        addr
    }

    #[test]
    fn slow_loris_drips_a_parseable_request() {
        let addr = canned_unary_server();
        let mut rng = Pcg64::new(11);
        match slow_loris_once(&addr, &mut rng, 4).unwrap() {
            SlowLorisOutcome::Answered(status) => assert_eq!(status, 200),
            SlowLorisOutcome::Cut => panic!("patient server must see the full request"),
        }
    }

    #[test]
    fn sse_disconnect_walks_away_mid_stream() {
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        std::thread::spawn(move || {
            if let Ok((mut s, _)) = listener.accept() {
                // read the request head far enough to unblock the client
                let mut tmp = [0u8; 2048];
                let _ = s.read(&mut tmp);
                let _ = s.write_all(
                    b"HTTP/1.1 200 OK\r\nContent-Type: text/event-stream\r\n\
                      Transfer-Encoding: chunked\r\n\r\n",
                );
                // five content chunks, never a terminal chunk: the client
                // must bail out on its own
                for i in 0..5 {
                    let event = format!("data: {{\"n\":{i}}}\n\n");
                    let frame = format!("{:x}\r\n{event}\r\n", event.len());
                    if s.write_all(frame.as_bytes()).is_err() {
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(5));
                }
            }
        });
        let mut rng = Pcg64::new(5);
        let (consumed, abandoned) = sse_disconnect_once(&addr, &mut rng, 8).unwrap();
        assert!(abandoned, "client must sever mid-stream");
        assert!((1..=3).contains(&consumed), "consumed {consumed}");
    }

    #[test]
    fn tenant_stats_grade_against_their_slo_budgets() {
        let specs = default_tenants();
        let mut samples = LatencySamples::default();
        samples.tenants.insert(
            "chat".into(),
            TenantSamples {
                requests: 4,
                ok: 3,
                shed: 1,
                latencies_ms: vec![10.0, 20.0, 9_999.0],
            },
        );
        samples.tenants.insert(
            "codegen".into(),
            TenantSamples {
                requests: 2,
                ok: 2,
                shed: 0,
                latencies_ms: vec![50_000.0, 60_000.0],
            },
        );
        let mut report = LoadgenReport::default();
        fill_tenant_stats(&mut report, &samples, &specs);
        assert_eq!(report.tenant_stats.len(), 2);
        let chat = report.tenant_stats.iter().find(|t| t.name == "chat").unwrap();
        assert_eq!(chat.tier, "latency");
        assert_eq!(chat.shed, 1);
        assert_eq!(chat.p95_ms, 9_999.0);
        // chat blew its 5000ms budget; codegen is batch-tier and ungraded
        // no matter how slow
        let violations = report.slo_violations();
        assert_eq!(violations.len(), 1, "{violations:?}");
        assert!(violations[0].contains("tenant chat"), "{violations:?}");
        // stats land in the JSON artifact
        let j = Json::parse(&report.to_json().to_string_compact()).unwrap();
        let stats = j.get("tenant_stats").and_then(Json::as_arr).unwrap();
        assert_eq!(stats.len(), 2);
        assert_eq!(stats[0].get("name").and_then(Json::as_str), Some("chat"));
        assert_eq!(stats[0].get("slo_p95_ms").and_then(Json::as_f64), Some(5_000.0));
    }
}
