//! Self-contained HTTP client + closed-loop load generator that drives the
//! gateway over real sockets — the integration-test harness and the
//! `examples/serve_http.rs` demo driver. The client understands exactly
//! what the gateway emits: Content-Length bodies and chunked SSE streams.
//!
//! The closed loop runs on persistent HTTP/1.1 keep-alive connections
//! ([`Client`]): one socket per worker for its whole request sequence, so
//! attainable attack rates are not capped by per-request TCP handshakes.
//! [`LoadgenReport::connections_opened`] lets tests assert the reuse.

use crate::util::json::{num, obj, s, Json};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    /// header names lowercased
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }

    pub fn json(&self) -> Result<Json> {
        Json::parse(&self.body_str()).map_err(|e| anyhow!("response is not JSON: {e}"))
    }

    /// The `data:` payloads of an SSE body, in order (including `[DONE]`).
    pub fn sse_data(&self) -> Vec<String> {
        self.body_str()
            .split("\n\n")
            .filter_map(|event| event.trim().strip_prefix("data: ").map(str::to_string))
            .collect()
    }
}

fn read_chunked<R: BufRead>(r: &mut R) -> Result<Vec<u8>> {
    let mut body = Vec::new();
    loop {
        let mut size_line = String::new();
        r.read_line(&mut size_line)?;
        let size_str = size_line.trim().split(';').next().unwrap_or("").trim();
        let size = usize::from_str_radix(size_str, 16)
            .with_context(|| format!("bad chunk size line {size_line:?}"))?;
        if size == 0 {
            // trailers (we send none) up to the blank line
            loop {
                let mut trailer = String::new();
                if r.read_line(&mut trailer)? == 0 || trailer.trim().is_empty() {
                    break;
                }
            }
            return Ok(body);
        }
        let mut chunk = vec![0u8; size];
        r.read_exact(&mut chunk)?;
        body.extend_from_slice(&chunk);
        let mut crlf = [0u8; 2];
        r.read_exact(&mut crlf)?;
    }
}

/// The request head for one exchange. `close` asks the server to close
/// the connection after responding; omitted, HTTP/1.1 defaults to
/// keep-alive.
fn request_head(method: &str, path: &str, addr: &str, body: Option<&str>, close: bool) -> String {
    let mut head = format!("{method} {path} HTTP/1.1\r\nHost: {addr}\r\nAccept: */*\r\n");
    if close {
        head.push_str("Connection: close\r\n");
    }
    if let Some(b) = body {
        head.push_str(&format!(
            "Content-Type: application/json\r\nContent-Length: {}\r\n",
            b.len()
        ));
    }
    head.push_str("\r\n");
    head
}

/// Read one response off the stream. The `BufReader` is scoped to this
/// exchange: the gateway never pushes unsolicited bytes, and both
/// Content-Length and chunked bodies are exactly delimited, so no buffered
/// bytes are lost when it drops — which is what makes keep-alive reuse of
/// the bare `TcpStream` safe.
fn read_response(stream: &TcpStream) -> Result<HttpResponse> {
    let mut r = BufReader::new(stream);
    let mut status_line = String::new();
    r.read_line(&mut status_line)?;
    let mut parts = status_line.split_whitespace();
    let proto = parts.next().unwrap_or("");
    let status: u16 = parts
        .next()
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad status line {status_line:?}"))?;
    if !proto.starts_with("HTTP/") {
        bail!("bad status line {status_line:?}");
    }

    let mut headers = BTreeMap::new();
    loop {
        let mut line = String::new();
        if r.read_line(&mut line)? == 0 {
            bail!("EOF inside response headers");
        }
        let line = line.trim_end();
        if line.is_empty() {
            break;
        }
        if let Some((name, value)) = line.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }

    let body = if headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false)
    {
        read_chunked(&mut r)?
    } else if let Some(len) = headers.get("content-length") {
        let len: usize = len.parse().context("bad Content-Length in response")?;
        let mut buf = vec![0u8; len];
        r.read_exact(&mut buf)?;
        buf
    } else {
        // no framing: the peer signals the end by closing
        let mut buf = Vec::new();
        r.read_to_end(&mut buf)?;
        buf
    };

    Ok(HttpResponse {
        status,
        headers,
        body,
    })
}

/// One blocking HTTP/1.1 exchange on a fresh connection
/// (`Connection: close`). For request sequences, prefer [`Client`].
pub fn request(
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> Result<HttpResponse> {
    let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.set_nodelay(true)?;

    let mut w = &stream;
    w.write_all(request_head(method, path, addr, body, true).as_bytes())?;
    if let Some(b) = body {
        w.write_all(b.as_bytes())?;
    }
    w.flush()?;
    read_response(&stream)
}

pub fn get(addr: &str, path: &str) -> Result<HttpResponse> {
    request(addr, "GET", path, None, Duration::from_secs(30))
}

pub fn post_json(addr: &str, path: &str, body: &str) -> Result<HttpResponse> {
    request(addr, "POST", path, Some(body), Duration::from_secs(60))
}

/// Persistent HTTP/1.1 client: one keep-alive connection reused across
/// exchanges, redialed transparently when the server closes it (or when a
/// previously-idle socket turns out stale on send). Counts dials so the
/// integration suite can assert that a closed loop reuses sockets.
pub struct Client {
    addr: String,
    timeout: Duration,
    stream: Option<TcpStream>,
    /// sockets dialed over this client's lifetime
    pub connections_opened: usize,
}

impl Client {
    pub fn new(addr: &str) -> Client {
        Client {
            addr: addr.to_string(),
            timeout: Duration::from_secs(60),
            stream: None,
            connections_opened: 0,
        }
    }

    fn connect(&mut self) -> Result<()> {
        if self.stream.is_none() {
            let stream = TcpStream::connect(&self.addr)
                .with_context(|| format!("connect {}", self.addr))?;
            stream.set_read_timeout(Some(self.timeout))?;
            stream.set_write_timeout(Some(self.timeout))?;
            stream.set_nodelay(true)?;
            self.connections_opened += 1;
            self.stream = Some(stream);
        }
        Ok(())
    }

    /// One exchange on the persistent connection. Only a *stale-socket*
    /// failure on a reused connection (the server closed an idle
    /// keep-alive socket: reset/EOF before any response byte) redials and
    /// retries once. Timeouts and mid-response failures are returned as
    /// errors — blindly retrying would re-execute a non-idempotent POST
    /// whose first copy may still be running on the server.
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        body: Option<&str>,
    ) -> Result<HttpResponse> {
        let reused = self.stream.is_some();
        match self.try_request(method, path, body) {
            Ok(resp) => Ok(resp),
            Err(e) => {
                self.stream = None;
                if reused && stale_socket_error(&e) {
                    self.try_request(method, path, body)
                } else {
                    Err(e)
                }
            }
        }
    }

    fn try_request(&mut self, method: &str, path: &str, body: Option<&str>) -> Result<HttpResponse> {
        self.connect()?;
        let resp = {
            let stream = self.stream.as_ref().expect("connected above");
            let mut w = stream;
            w.write_all(request_head(method, path, &self.addr, body, false).as_bytes())?;
            if let Some(b) = body {
                w.write_all(b.as_bytes())?;
            }
            w.flush()?;
            read_response(stream)?
        };
        // honor the server's wish to close; an unframed body also means
        // the connection is done
        let close = resp
            .headers
            .get("connection")
            .map(|v| v.eq_ignore_ascii_case("close"))
            .unwrap_or(false);
        let unframed = !resp.headers.contains_key("content-length")
            && !resp.headers.contains_key("transfer-encoding");
        if close || unframed {
            self.stream = None;
        }
        Ok(resp)
    }

    pub fn get(&mut self, path: &str) -> Result<HttpResponse> {
        self.request("GET", path, None)
    }

    pub fn post_json(&mut self, path: &str, body: &str) -> Result<HttpResponse> {
        self.request("POST", path, Some(body))
    }
}

/// True for failures that mean the server closed a previously-idle
/// keep-alive socket — reset/abort/broken pipe, or EOF before any status
/// byte (which parses as an empty status line). A timeout or an error
/// after response bytes arrived is NOT stale: the request may well be
/// executing server-side, so a retry would duplicate it.
fn stale_socket_error(e: &anyhow::Error) -> bool {
    for cause in e.chain() {
        if let Some(io) = cause.downcast_ref::<std::io::Error>() {
            return matches!(
                io.kind(),
                std::io::ErrorKind::BrokenPipe
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::ConnectionAborted
                    | std::io::ErrorKind::UnexpectedEof
            );
        }
    }
    e.to_string().contains("bad status line \"\"")
}

/// Closed-loop driver configuration: `concurrency` workers each issue
/// `requests_per_worker` sequential requests on one keep-alive connection.
#[derive(Debug, Clone)]
pub struct LoadgenConfig {
    pub concurrency: usize,
    pub requests_per_worker: usize,
    pub max_tokens: usize,
    /// every k-th request of a worker streams (0 = never)
    pub stream_every: usize,
    /// every k-th request goes to /v1/chat/completions (0 = never)
    pub chat_every: usize,
    pub prompt_prefix: String,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            concurrency: 8,
            requests_per_worker: 4,
            max_tokens: 8,
            stream_every: 2,
            chat_every: 3,
            prompt_prefix: "benchmark this serving gateway".into(),
        }
    }
}

#[derive(Debug, Clone, Default)]
pub struct LoadgenReport {
    pub requests: usize,
    pub ok: usize,
    /// transport-level failures (connect/read errors)
    pub errors: usize,
    pub status_counts: BTreeMap<u16, usize>,
    pub sse_events: usize,
    pub completion_tokens: usize,
    /// TCP connections dialed across all workers; == concurrency when
    /// keep-alive reuse held for every request
    pub connections_opened: usize,
    pub p50_ms: f64,
    pub p99_ms: f64,
    pub elapsed_secs: f64,
}

impl LoadgenReport {
    pub fn count(&self, status: u16) -> usize {
        self.status_counts.get(&status).copied().unwrap_or(0)
    }

    /// The full report as JSON — what `enova loadgen --report FILE`
    /// writes and the CI gateway-smoke job uploads as its artifact.
    pub fn to_json(&self) -> Json {
        let statuses = Json::Obj(
            self.status_counts
                .iter()
                .map(|(code, n)| (code.to_string(), num(*n as f64)))
                .collect(),
        );
        obj([
            ("requests", num(self.requests as f64)),
            ("ok", num(self.ok as f64)),
            ("errors", num(self.errors as f64)),
            ("status_counts", statuses),
            ("sse_events", num(self.sse_events as f64)),
            ("completion_tokens", num(self.completion_tokens as f64)),
            ("connections_opened", num(self.connections_opened as f64)),
            ("p50_ms", num(self.p50_ms)),
            ("p99_ms", num(self.p99_ms)),
            ("elapsed_secs", num(self.elapsed_secs)),
            (
                "requests_per_sec",
                num(self.requests as f64 / self.elapsed_secs.max(1e-9)),
            ),
        ])
    }

    pub fn summary(&self) -> String {
        format!(
            "{} requests in {:.2}s ({:.1} req/s) over {} connections: {} ok, {} errors, \
             statuses {:?}, {} completion tokens, {} SSE events, p50 {:.1}ms p99 {:.1}ms",
            self.requests,
            self.elapsed_secs,
            self.requests as f64 / self.elapsed_secs.max(1e-9),
            self.connections_opened,
            self.ok,
            self.errors,
            self.status_counts,
            self.completion_tokens,
            self.sse_events,
            self.p50_ms,
            self.p99_ms,
        )
    }
}

struct OneResult {
    status: Option<u16>,
    latency: Duration,
    sse_events: usize,
    completion_tokens: usize,
}

fn one_request(client: &mut Client, cfg: &LoadgenConfig, worker: usize, k: usize) -> OneResult {
    let stream = cfg.stream_every != 0 && (worker + k) % cfg.stream_every == 0;
    let chat = cfg.chat_every != 0 && (worker + k) % cfg.chat_every == 0;
    let prompt = format!("{} w{worker} r{k}", cfg.prompt_prefix);
    // build through util::json so arbitrary prompt_prefix content is escaped
    let body = if chat {
        obj([
            (
                "messages",
                Json::Arr(vec![obj([("role", s("user")), ("content", s(&prompt))])]),
            ),
            ("max_tokens", num(cfg.max_tokens as f64)),
            ("stream", Json::Bool(stream)),
        ])
    } else {
        obj([
            ("prompt", s(&prompt)),
            ("max_tokens", num(cfg.max_tokens as f64)),
            ("stream", Json::Bool(stream)),
        ])
    }
    .to_string_compact();
    let path = if chat {
        "/v1/chat/completions"
    } else {
        "/v1/completions"
    };
    let t0 = Instant::now();
    match client.post_json(path, &body) {
        Err(_) => OneResult {
            status: None,
            latency: t0.elapsed(),
            sse_events: 0,
            completion_tokens: 0,
        },
        Ok(resp) => {
            let mut sse_events = 0;
            let mut completion_tokens = 0;
            if resp.status == 200 {
                if stream {
                    let events = resp.sse_data();
                    sse_events = events.len();
                    completion_tokens = events
                        .iter()
                        .filter(|e| e.as_str() != "[DONE]")
                        .filter(|e| {
                            Json::parse(e)
                                .ok()
                                .and_then(|j| {
                                    j.get("choices")?.as_arr()?.first().map(|c| {
                                        c.get("text").is_some()
                                            || c.at(&["delta", "content"]).is_some()
                                    })
                                })
                                .unwrap_or(false)
                        })
                        .count();
                } else if let Ok(j) = resp.json() {
                    completion_tokens = j
                        .at(&["usage", "completion_tokens"])
                        .and_then(Json::as_usize)
                        .unwrap_or(0);
                }
            }
            OneResult {
                status: Some(resp.status),
                latency: t0.elapsed(),
                sse_events,
                completion_tokens,
            }
        }
    }
}

/// Run the closed loop against `addr` and aggregate a report.
pub fn run(addr: &str, cfg: &LoadgenConfig) -> LoadgenReport {
    let t0 = Instant::now();
    let (tx, rx) = std::sync::mpsc::channel::<OneResult>();
    let (conn_tx, conn_rx) = std::sync::mpsc::channel::<usize>();
    let mut handles = Vec::new();
    for worker in 0..cfg.concurrency {
        let tx = tx.clone();
        let conn_tx = conn_tx.clone();
        let cfg = cfg.clone();
        let addr = addr.to_string();
        handles.push(std::thread::spawn(move || {
            let mut client = Client::new(&addr);
            for k in 0..cfg.requests_per_worker {
                let _ = tx.send(one_request(&mut client, &cfg, worker, k));
            }
            let _ = conn_tx.send(client.connections_opened);
        }));
    }
    drop(tx);
    drop(conn_tx);

    let mut report = LoadgenReport::default();
    let mut latencies_ms: Vec<f64> = Vec::new();
    for r in rx {
        report.requests += 1;
        match r.status {
            None => report.errors += 1,
            Some(code) => {
                *report.status_counts.entry(code).or_insert(0) += 1;
                if code == 200 {
                    report.ok += 1;
                    latencies_ms.push(r.latency.as_secs_f64() * 1e3);
                }
            }
        }
        report.sse_events += r.sse_events;
        report.completion_tokens += r.completion_tokens;
    }
    report.connections_opened = conn_rx.iter().sum();
    for h in handles {
        let _ = h.join();
    }
    report.elapsed_secs = t0.elapsed().as_secs_f64();
    latencies_ms.sort_by(f64::total_cmp);
    let pct = |q: f64| -> f64 {
        if latencies_ms.is_empty() {
            return 0.0;
        }
        let idx = ((latencies_ms.len() - 1) as f64 * q).round() as usize;
        latencies_ms[idx]
    };
    report.p50_ms = pct(0.50);
    report.p99_ms = pct(0.99);
    report
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sse_data_extraction() {
        let resp = HttpResponse {
            status: 200,
            headers: BTreeMap::new(),
            body: b"data: {\"a\":1}\n\ndata: {\"b\":2}\n\ndata: [DONE]\n\n".to_vec(),
        };
        assert_eq!(resp.sse_data(), vec!["{\"a\":1}", "{\"b\":2}", "[DONE]"]);
    }

    #[test]
    fn chunked_body_decoding() {
        let wire = b"5\r\nhello\r\n6\r\n world\r\n0\r\n\r\n";
        let mut r = std::io::BufReader::new(&wire[..]);
        assert_eq!(read_chunked(&mut r).unwrap(), b"hello world");
    }

    #[test]
    fn chunked_rejects_garbage_size() {
        let wire = b"zz\r\nhello\r\n";
        let mut r = std::io::BufReader::new(&wire[..]);
        assert!(read_chunked(&mut r).is_err());
    }

    #[test]
    fn report_json_roundtrips() {
        let mut report = LoadgenReport {
            requests: 3,
            ok: 2,
            errors: 1,
            elapsed_secs: 2.0,
            p99_ms: 12.5,
            ..Default::default()
        };
        report.status_counts.insert(200, 2);
        let j = Json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(j.get("requests").and_then(Json::as_usize), Some(3));
        assert_eq!(j.get("errors").and_then(Json::as_usize), Some(1));
        assert_eq!(j.at(&["status_counts", "200"]).and_then(Json::as_usize), Some(2));
        assert_eq!(j.get("p99_ms").and_then(Json::as_f64), Some(12.5));
        assert_eq!(j.get("requests_per_sec").and_then(Json::as_f64), Some(1.5));
    }

    #[test]
    fn request_heads_differ_on_connection_policy() {
        let one_shot = request_head("POST", "/x", "h:1", Some("{}"), true);
        assert!(one_shot.contains("Connection: close\r\n"));
        assert!(one_shot.contains("Content-Length: 2\r\n"));
        let keep_alive = request_head("GET", "/x", "h:1", None, false);
        assert!(!keep_alive.contains("Connection:"));
        assert!(keep_alive.ends_with("\r\n\r\n"));
    }
}
