//! Sharded nonblocking reactor ingress: the epoll-based replacement for
//! the thread-per-connection accept loops.
//!
//! Architecture (one [`Reactor`] per listening socket):
//!
//! * N **shard threads**, each owning a private `epoll` instance (raw
//!   syscalls through `std::os::fd` — no new crates; a `poll(2)` fallback
//!   keeps non-Linux unix hosts working). Every shard registers the
//!   shared nonblocking listener, so accepts spread across shards without
//!   a dedicated accept thread.
//! * Each shard owns its connections' **parse state machines**: a
//!   [`http::RequestParser`] per connection is fed whatever bytes are
//!   readable and resumed on the next readiness event, so a slow client
//!   trickling a header never pins a thread (slow-loris costs one idle
//!   fd, not one parked worker).
//! * A bounded **handler pool** runs the application callback. Once a
//!   request head+body is fully parsed the connection is deregistered
//!   from the shard, flipped back to blocking with the legacy socket
//!   timeouts, and handed over; the handler writes the response exactly
//!   like the old per-connection worker did, so response semantics
//!   (SSE streaming, chunked framing, trace recording) are unchanged.
//! * **Keep-alive return path**: when the handler keeps the connection,
//!   it travels back to a shard over a return channel plus a socketpair
//!   waker, carrying any pipelined leftover bytes, which are replayed
//!   into a fresh parser before the fd is re-armed — pipelined requests
//!   dispatch immediately without waiting for new readability.
//!
//! The win over thread-per-connection: the old model served at most
//! `http_workers` connections *total* because a worker was pinned to its
//! keep-alive connection for the connection's lifetime. The reactor pins
//! workers only while a request is actually being served, so fan-in is
//! bounded by fds, not threads.
//!
//! Shutdown drains: shards stop accepting and drop idle connections;
//! dispatched requests finish on the handler pool (the pool exits only
//! after its queue is empty), so an in-flight client always receives a
//! complete HTTP response.

use super::http;
use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::AsRawFd;
use std::os::unix::net::UnixStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{self, Receiver, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Application callback, run on a handler-pool thread with a *blocking*
/// stream (legacy timeouts applied). Returns whether the connection may
/// be kept alive for another request; an `Err`-ish outcome is expressed
/// by returning `false` (the reactor then closes the connection).
pub type Handler = Arc<dyn Fn(&mut TcpStream, &http::Request) -> bool + Send + Sync>;

/// Maps a parse failure to the wire response the application wants (the
/// gateway answers OpenAI-style error JSON; the coordinator the same).
pub type ErrorResponder = Arc<dyn Fn(&http::HttpError) -> http::Response + Send + Sync>;

/// Polled every tick by shard threads; `true` starts the drain.
pub type StopCheck = Arc<dyn Fn() -> bool + Send + Sync>;

/// Ingress connection accounting, rendered as `/metrics` gauges. Shared
/// by reference between the reactor (or the legacy threaded path) and
/// the metrics exporter, so `render_prometheus` signatures stay put.
#[derive(Debug, Default)]
pub struct IngressStats {
    /// connections accepted since boot
    pub accepted_total: AtomicU64,
    /// currently-open ingress connections (accepted, not yet closed)
    pub open: AtomicU64,
    /// requests currently executing on the handler pool
    pub handler_inflight: AtomicU64,
    /// configured handler-pool size (threads that can serve concurrently)
    pub handler_threads: AtomicU64,
    /// 1 = reactor ingress, 0 = legacy thread-per-connection
    pub reactor_mode: AtomicU64,
}

#[derive(Debug, Clone)]
pub struct ReactorConfig {
    /// event-loop shards (each with its own epoll instance)
    pub shards: usize,
    /// handler-pool threads == max concurrently *served* requests
    pub handler_threads: usize,
    /// request body cap handed to the incremental parser
    pub max_body_bytes: usize,
    /// idle keep-alive / mid-parse silence deadline (legacy: the 5s read
    /// timeout on blocking sockets)
    pub idle_timeout: Duration,
}

impl Default for ReactorConfig {
    fn default() -> Self {
        ReactorConfig {
            shards: default_shards(),
            handler_threads: 64,
            max_body_bytes: 1024 * 1024,
            idle_timeout: Duration::from_secs(5),
        }
    }
}

/// Shards default to a small constant: each shard is purely event-loop
/// work (parse + dispatch), so a handful saturates well before the
/// handler pool does.
pub fn default_shards() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().min(4))
        .unwrap_or(2)
        .max(1)
}

/// How often a shard wakes up regardless of events, to check the stop
/// flag and reap idle connections.
const TICK: Duration = Duration::from_millis(100);

const TOKEN_LISTENER: u64 = 0;
const TOKEN_WAKER: u64 = 1;
const FIRST_CONN_TOKEN: u64 = 2;

/// Blocking-mode socket deadlines applied when a parsed request is
/// handed to the handler pool (mirrors the legacy accept loop).
const HANDLER_READ_TIMEOUT: Duration = Duration::from_secs(5);
const HANDLER_WRITE_TIMEOUT: Duration = Duration::from_secs(30);

// ---------------------------------------------------------------------------
// poller: hand-rolled epoll (Linux) with a poll(2) fallback (other unix)
// ---------------------------------------------------------------------------

#[cfg(target_os = "linux")]
mod sys {
    //! Raw epoll bindings. std already links libc, so plain `extern "C"`
    //! declarations resolve without adding a crate.
    use std::os::fd::{FromRawFd, OwnedFd};

    pub const EPOLL_CLOEXEC: i32 = 0o2000000;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLRDHUP: u32 = 0x2000;

    /// Mirrors the kernel's `struct epoll_event` ABI: packed on x86 so
    /// the 64-bit data field sits at offset 4, natural alignment
    /// elsewhere.
    #[cfg_attr(any(target_arch = "x86", target_arch = "x86_64"), repr(C, packed))]
    #[cfg_attr(not(any(target_arch = "x86", target_arch = "x86_64")), repr(C))]
    #[derive(Clone, Copy)]
    pub struct EpollEvent {
        pub events: u32,
        pub data: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut EpollEvent) -> i32;
        fn epoll_wait(epfd: i32, events: *mut EpollEvent, maxevents: i32, timeout: i32) -> i32;
    }

    pub fn create() -> std::io::Result<OwnedFd> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(unsafe { OwnedFd::from_raw_fd(fd) })
    }

    pub fn ctl(epfd: i32, op: i32, fd: i32, event: Option<&mut EpollEvent>) -> std::io::Result<()> {
        let ptr = match event {
            Some(e) => e as *mut EpollEvent,
            None => std::ptr::null_mut(),
        };
        if unsafe { epoll_ctl(epfd, op, fd, ptr) } < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn wait(epfd: i32, events: &mut [EpollEvent], timeout_ms: i32) -> std::io::Result<usize> {
        let n = unsafe { epoll_wait(epfd, events.as_mut_ptr(), events.len() as i32, timeout_ms) };
        if n < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(n as usize)
    }
}

/// Readiness poller: one instance per shard, single-threaded use.
#[cfg(target_os = "linux")]
pub struct Poller {
    epfd: std::os::fd::OwnedFd,
}

#[cfg(target_os = "linux")]
impl Poller {
    pub fn new() -> std::io::Result<Poller> {
        Ok(Poller { epfd: sys::create()? })
    }

    pub fn add(&mut self, fd: i32, token: u64) -> std::io::Result<()> {
        let mut ev = sys::EpollEvent {
            events: sys::EPOLLIN | sys::EPOLLRDHUP,
            data: token,
        };
        sys::ctl(self.epfd.as_raw_fd(), sys::EPOLL_CTL_ADD, fd, Some(&mut ev))
    }

    pub fn del(&mut self, fd: i32) -> std::io::Result<()> {
        sys::ctl(self.epfd.as_raw_fd(), sys::EPOLL_CTL_DEL, fd, None)
    }

    /// Collect ready tokens into `out`; returns after `timeout` with an
    /// empty set when nothing fired. EINTR is surfaced as an empty tick.
    pub fn wait(&mut self, out: &mut Vec<u64>, timeout: Duration) -> std::io::Result<()> {
        out.clear();
        let mut events = [sys::EpollEvent { events: 0, data: 0 }; 64];
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        match sys::wait(self.epfd.as_raw_fd(), &mut events, ms) {
            Ok(n) => {
                for ev in &events[..n] {
                    out.push(ev.data);
                }
                Ok(())
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => Ok(()),
            Err(e) => Err(e),
        }
    }
}

#[cfg(not(target_os = "linux"))]
mod sys {
    //! `poll(2)` fallback for non-Linux unix hosts.
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct PollFd {
        pub fd: i32,
        pub events: i16,
        pub revents: i16,
    }

    pub const POLLIN: i16 = 0x001;

    extern "C" {
        pub fn poll(fds: *mut PollFd, nfds: usize, timeout: i32) -> i32;
    }
}

#[cfg(not(target_os = "linux"))]
pub struct Poller {
    fds: Vec<(i32, u64)>,
}

#[cfg(not(target_os = "linux"))]
impl Poller {
    pub fn new() -> std::io::Result<Poller> {
        Ok(Poller { fds: Vec::new() })
    }

    pub fn add(&mut self, fd: i32, token: u64) -> std::io::Result<()> {
        self.fds.push((fd, token));
        Ok(())
    }

    pub fn del(&mut self, fd: i32) -> std::io::Result<()> {
        self.fds.retain(|&(f, _)| f != fd);
        Ok(())
    }

    pub fn wait(&mut self, out: &mut Vec<u64>, timeout: Duration) -> std::io::Result<()> {
        out.clear();
        let mut pfds: Vec<sys::PollFd> = self
            .fds
            .iter()
            .map(|&(fd, _)| sys::PollFd { fd, events: sys::POLLIN, revents: 0 })
            .collect();
        let ms = timeout.as_millis().min(i32::MAX as u128) as i32;
        let n = unsafe { sys::poll(pfds.as_mut_ptr(), pfds.len(), ms) };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(());
            }
            return Err(e);
        }
        for (pfd, &(_, token)) in pfds.iter().zip(self.fds.iter()) {
            if pfd.revents != 0 {
                out.push(token);
            }
        }
        Ok(())
    }
}

// ---------------------------------------------------------------------------
// connection plumbing
// ---------------------------------------------------------------------------

/// A connection's stream plus the accounting guard: wherever the
/// connection is finally dropped (shard close path, handler close path,
/// failed return), the open-connections gauge decrements exactly once.
struct TrackedConn {
    stream: TcpStream,
    stats: Arc<IngressStats>,
}

impl Drop for TrackedConn {
    fn drop(&mut self) {
        self.stats.open.fetch_sub(1, Ordering::AcqRel);
    }
}

/// Shard-resident connection state: the resumable parser plus the idle
/// clock.
struct Conn {
    tracked: TrackedConn,
    parser: http::RequestParser,
    last_active: Instant,
}

/// A fully-parsed request in flight to the handler pool.
struct DispatchJob {
    tracked: TrackedConn,
    req: http::Request,
    /// pipelined bytes read past the request end; replayed on return
    leftover: Vec<u8>,
    /// route back to the originating shard for keep-alive re-arming
    return_tx: Sender<ReturnedConn>,
    waker: Waker,
}

/// A kept-alive connection returning from the handler pool.
struct ReturnedConn {
    tracked: TrackedConn,
    leftover: Vec<u8>,
}

/// Write end of a shard's socketpair; one byte per wake, nonblocking so
/// a saturated pipe never stalls a handler thread.
#[derive(Clone)]
struct Waker {
    tx: Arc<UnixStream>,
}

impl Waker {
    fn wake(&self) {
        let _ = (&*self.tx).write(&[1u8]);
    }
}

// ---------------------------------------------------------------------------
// the reactor
// ---------------------------------------------------------------------------

/// Handle to a running reactor; its threads are surrendered to the
/// owner's join list via [`Reactor::into_threads`].
pub struct Reactor {
    threads: Vec<JoinHandle<()>>,
}

impl Reactor {
    /// Spawn shard + handler threads over an already-nonblocking
    /// listener. `stop` is polled every tick; once it reports true the
    /// shards drain (stop accepting, drop idle connections, exit) and
    /// the handler pool finishes every dispatched request before
    /// exiting.
    pub fn start(
        listener: TcpListener,
        cfg: ReactorConfig,
        handler: Handler,
        on_parse_error: ErrorResponder,
        stop: StopCheck,
        stats: Arc<IngressStats>,
    ) -> std::io::Result<Reactor> {
        stats.reactor_mode.store(1, Ordering::Release);
        stats
            .handler_threads
            .store(cfg.handler_threads.max(1) as u64, Ordering::Release);
        let listener = Arc::new(listener);
        let (job_tx, job_rx) = mpsc::channel::<DispatchJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let mut threads = Vec::new();

        for shard in 0..cfg.shards.max(1) {
            let (ret_tx, ret_rx) = mpsc::channel::<ReturnedConn>();
            let (wake_rx, wake_tx) = UnixStream::pair()?;
            wake_rx.set_nonblocking(true)?;
            wake_tx.set_nonblocking(true)?;
            let waker = Waker { tx: Arc::new(wake_tx) };
            let listener = Arc::clone(&listener);
            let job_tx = job_tx.clone();
            let on_parse_error = Arc::clone(&on_parse_error);
            let stop = Arc::clone(&stop);
            let stats = Arc::clone(&stats);
            let cfg = cfg.clone();
            threads.push(
                std::thread::Builder::new()
                    .name(format!("ingress-shard-{shard}"))
                    .spawn(move || {
                        shard_loop(
                            &listener,
                            &cfg,
                            job_tx,
                            ret_tx,
                            ret_rx,
                            wake_rx,
                            waker,
                            &on_parse_error,
                            &stop,
                            &stats,
                        );
                    })?,
            );
        }
        drop(job_tx); // handler pool exits when the last shard sender drops

        for i in 0..cfg.handler_threads.max(1) {
            let job_rx = Arc::clone(&job_rx);
            let handler = Arc::clone(&handler);
            let stop = Arc::clone(&stop);
            let stats = Arc::clone(&stats);
            threads.push(
                std::thread::Builder::new()
                    .name(format!("ingress-handler-{i}"))
                    .spawn(move || handler_loop(&job_rx, &handler, &stop, &stats))?,
            );
        }

        Ok(Reactor { threads })
    }

    /// Surrender the thread handles for the owner's shutdown join.
    pub fn into_threads(self) -> Vec<JoinHandle<()>> {
        self.threads
    }
}

#[allow(clippy::too_many_arguments)]
fn shard_loop(
    listener: &TcpListener,
    cfg: &ReactorConfig,
    job_tx: Sender<DispatchJob>,
    ret_tx: Sender<ReturnedConn>,
    ret_rx: Receiver<ReturnedConn>,
    wake_rx: UnixStream,
    waker: Waker,
    on_parse_error: &ErrorResponder,
    stop: &StopCheck,
    stats: &Arc<IngressStats>,
) {
    let mut poller = match Poller::new() {
        Ok(p) => p,
        Err(e) => {
            crate::warn!("ingress", "poller init failed, shard down: {e}");
            return;
        }
    };
    if poller.add(listener.as_raw_fd(), TOKEN_LISTENER).is_err()
        || poller.add(wake_rx.as_raw_fd(), TOKEN_WAKER).is_err()
    {
        crate::warn!("ingress", "poller registration failed, shard down");
        return;
    }

    let mut conns: BTreeMap<u64, Conn> = BTreeMap::new();
    let mut next_token = FIRST_CONN_TOKEN;
    let mut ready = Vec::with_capacity(64);

    loop {
        if stop() {
            // drain: accepting stops (loop exits), idle and mid-parse
            // connections drop here; dispatched requests finish on the
            // handler pool, which outlives the shards.
            break;
        }
        if poller.wait(&mut ready, TICK).is_err() {
            break;
        }
        for &token in &ready {
            match token {
                TOKEN_LISTENER => accept_burst(
                    listener,
                    &mut poller,
                    &mut conns,
                    &mut next_token,
                    cfg,
                    stats,
                ),
                TOKEN_WAKER => {
                    drain_waker(&wake_rx);
                    while let Ok(ret) = ret_rx.try_recv() {
                        adopt_returned(
                            ret,
                            &mut poller,
                            &mut conns,
                            &mut next_token,
                            cfg,
                            &job_tx,
                            &ret_tx,
                            &waker,
                            on_parse_error,
                        );
                    }
                }
                conn_token => drive_conn(
                    conn_token,
                    &mut poller,
                    &mut conns,
                    &job_tx,
                    &ret_tx,
                    &waker,
                    on_parse_error,
                ),
            }
        }
        reap_idle(&mut poller, &mut conns, cfg.idle_timeout);
    }
}

/// Accept everything currently pending on the shared listener.
fn accept_burst(
    listener: &TcpListener,
    poller: &mut Poller,
    conns: &mut BTreeMap<u64, Conn>,
    next_token: &mut u64,
    cfg: &ReactorConfig,
    stats: &Arc<IngressStats>,
) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nodelay(true);
                if stream.set_nonblocking(true).is_err() {
                    continue;
                }
                stats.accepted_total.fetch_add(1, Ordering::AcqRel);
                stats.open.fetch_add(1, Ordering::AcqRel);
                let token = *next_token;
                *next_token += 1;
                if poller.add(stream.as_raw_fd(), token).is_err() {
                    // TrackedConn drop rebalances the gauge
                    drop(TrackedConn { stream, stats: Arc::clone(stats) });
                    continue;
                }
                conns.insert(
                    token,
                    Conn {
                        tracked: TrackedConn { stream, stats: Arc::clone(stats) },
                        parser: http::RequestParser::new(cfg.max_body_bytes),
                        last_active: Instant::now(),
                    },
                );
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => break,
            // transient accept error (EMFILE, aborted handshake): back
            // off briefly so a level-triggered listener can't busy-spin
            Err(_) => {
                std::thread::sleep(Duration::from_millis(20));
                break;
            }
        }
    }
}

fn drain_waker(wake_rx: &UnixStream) {
    let mut buf = [0u8; 64];
    while matches!((&*wake_rx).read(&mut buf), Ok(n) if n > 0) {}
}

/// Re-arm a keep-alive connection coming back from the handler pool and
/// immediately replay its pipelined leftover (a queued second request
/// dispatches without waiting for new bytes).
#[allow(clippy::too_many_arguments)]
fn adopt_returned(
    ret: ReturnedConn,
    poller: &mut Poller,
    conns: &mut BTreeMap<u64, Conn>,
    next_token: &mut u64,
    cfg: &ReactorConfig,
    job_tx: &Sender<DispatchJob>,
    ret_tx: &Sender<ReturnedConn>,
    waker: &Waker,
    on_parse_error: &ErrorResponder,
) {
    if ret.tracked.stream.set_nonblocking(true).is_err() {
        return; // drop; gauge rebalanced by TrackedConn
    }
    let mut parser = http::RequestParser::new(cfg.max_body_bytes);
    parser.feed(&ret.leftover);
    let token = *next_token;
    *next_token += 1;
    if poller.add(ret.tracked.stream.as_raw_fd(), token).is_err() {
        return;
    }
    conns.insert(
        token,
        Conn { tracked: ret.tracked, parser, last_active: Instant::now() },
    );
    drive_conn(token, poller, conns, job_tx, ret_tx, waker, on_parse_error);
}

/// What a readiness event did to a connection.
enum Drive {
    /// still parsing; stays registered
    Keep,
    /// parsed a full request: deregister and hand to the handler pool
    Dispatch(http::Request),
    /// close silently (EOF, stray blank lines, transport error)
    Close,
    /// close after answering a parse error
    Reject(http::HttpError),
}

/// Pump one connection: replay already-buffered bytes through the
/// parser, then read until `WouldBlock`, dispatching at most one request
/// (pipelined successors ride along as leftover).
fn drive_conn(
    token: u64,
    poller: &mut Poller,
    conns: &mut BTreeMap<u64, Conn>,
    job_tx: &Sender<DispatchJob>,
    ret_tx: &Sender<ReturnedConn>,
    waker: &Waker,
    on_parse_error: &ErrorResponder,
) {
    let outcome = {
        let Some(conn) = conns.get_mut(&token) else {
            return; // already closed this tick
        };
        let mut buf = [0u8; 8192];
        loop {
            // parse-before-read: leftover replay and multi-request reads
            // make progress without waiting for another readiness event
            match conn.parser.poll() {
                Ok(http::Poll::Ready(req)) => break Drive::Dispatch(req),
                Ok(http::Poll::Close) => break Drive::Close,
                Err(e) => break Drive::Reject(e),
                Ok(http::Poll::NeedMore) => {}
            }
            match conn.tracked.stream.read(&mut buf) {
                Ok(0) => {
                    break match conn.parser.eof() {
                        Ok(()) => Drive::Close,
                        Err(e) => Drive::Reject(e),
                    };
                }
                Ok(n) => {
                    conn.parser.feed(&buf[..n]);
                    conn.last_active = Instant::now();
                }
                Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => break Drive::Keep,
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(_) => break Drive::Close,
            }
        }
    };

    match outcome {
        Drive::Keep => {}
        Drive::Close => {
            if let Some(conn) = conns.remove(&token) {
                let _ = poller.del(conn.tracked.stream.as_raw_fd());
            }
        }
        Drive::Reject(e) => {
            if let Some(conn) = conns.remove(&token) {
                let _ = poller.del(conn.tracked.stream.as_raw_fd());
                respond_and_close(conn.tracked, &on_parse_error(&e));
            }
        }
        Drive::Dispatch(req) => {
            let Some(mut conn) = conns.remove(&token) else {
                return;
            };
            let _ = poller.del(conn.tracked.stream.as_raw_fd());
            let leftover = conn.parser.take_leftover();
            // back to blocking with the legacy per-request deadlines:
            // the handler writes responses exactly like the old worker
            let s = &conn.tracked.stream;
            if s.set_nonblocking(false).is_err()
                || s.set_read_timeout(Some(HANDLER_READ_TIMEOUT)).is_err()
                || s.set_write_timeout(Some(HANDLER_WRITE_TIMEOUT)).is_err()
            {
                return; // drop
            }
            let job = DispatchJob {
                tracked: conn.tracked,
                req,
                leftover,
                return_tx: ret_tx.clone(),
                waker: waker.clone(),
            };
            // send fails only during teardown; the drop closes the conn
            let _ = job_tx.send(job);
        }
    }
}

/// Write a parse-error response on a best-effort blocking socket, then
/// close (mirrors the legacy error path).
fn respond_and_close(mut tracked: TrackedConn, resp: &http::Response) {
    let _ = tracked.stream.set_nonblocking(false);
    let _ = tracked.stream.set_write_timeout(Some(Duration::from_secs(5)));
    let _ = resp.write_to(&mut tracked.stream, false);
}

/// Close connections silent past the idle deadline — the reactor
/// equivalent of the legacy 5s blocking read timeout, covering idle
/// keep-alive *and* stalled mid-parse (slow-loris) connections alike.
fn reap_idle(poller: &mut Poller, conns: &mut BTreeMap<u64, Conn>, idle: Duration) {
    let expired: Vec<u64> = conns
        .iter()
        .filter(|(_, c)| c.last_active.elapsed() > idle)
        .map(|(&t, _)| t)
        .collect();
    for token in expired {
        if let Some(conn) = conns.remove(&token) {
            let _ = poller.del(conn.tracked.stream.as_raw_fd());
        }
    }
}

/// Handler-pool thread: serve dispatched requests until every shard
/// sender has dropped *and* the queue is empty — that ordering is the
/// drain guarantee (an accepted, parsed request is always answered).
fn handler_loop(
    job_rx: &Arc<Mutex<Receiver<DispatchJob>>>,
    handler: &Handler,
    stop: &StopCheck,
    stats: &Arc<IngressStats>,
) {
    loop {
        let job = {
            let rx = job_rx.lock().unwrap();
            match rx.recv() {
                Ok(j) => j,
                Err(_) => break,
            }
        };
        stats.handler_inflight.fetch_add(1, Ordering::AcqRel);
        let mut tracked = job.tracked;
        let keep = job.req.keep_alive() && handler(&mut tracked.stream, &job.req);
        stats.handler_inflight.fetch_sub(1, Ordering::AcqRel);
        if keep && !stop() {
            let waker = job.waker;
            if job
                .return_tx
                .send(ReturnedConn { tracked, leftover: job.leftover })
                .is_ok()
            {
                waker.wake();
            }
            // send failure = shard gone (drain); the conn drops here
        }
        // !keep: tracked drops, closing the conn + decrementing the gauge
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::{BufRead, BufReader};

    fn start_echo_reactor(handler_threads: usize) -> (Reactor, std::net::SocketAddr, Arc<IngressStats>, Arc<std::sync::atomic::AtomicBool>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        listener.set_nonblocking(true).unwrap();
        let stats = Arc::new(IngressStats::default());
        let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let stop_flag = Arc::clone(&stop);
        let handler: Handler = Arc::new(|stream, req| {
            let body = format!("{{\"path\":{:?}}}", req.path);
            http::Response::json(200, body).write_to(stream, req.keep_alive()).is_ok()
        });
        let on_err: ErrorResponder =
            Arc::new(|e| http::Response::json(e.status, format!("{{\"error\":{:?}}}", e.message)));
        let reactor = Reactor::start(
            listener,
            ReactorConfig {
                shards: 2,
                handler_threads,
                max_body_bytes: 64 * 1024,
                idle_timeout: Duration::from_secs(5),
            },
            handler,
            on_err,
            Arc::new(move || stop_flag.load(Ordering::Acquire)),
            Arc::clone(&stats),
        )
        .unwrap();
        (reactor, addr, stats, stop)
    }

    fn read_one_response(reader: &mut BufReader<TcpStream>) -> (u16, String) {
        let mut line = String::new();
        reader.read_line(&mut line).unwrap();
        let status: u16 = line.split_whitespace().nth(1).unwrap().parse().unwrap();
        let mut len = 0usize;
        loop {
            let mut h = String::new();
            reader.read_line(&mut h).unwrap();
            let h = h.trim_end();
            if h.is_empty() {
                break;
            }
            if let Some(v) = h.to_ascii_lowercase().strip_prefix("content-length:") {
                len = v.trim().parse().unwrap();
            }
        }
        let mut body = vec![0u8; len];
        reader.read_exact(&mut body).unwrap();
        (status, String::from_utf8(body).unwrap())
    }

    #[test]
    fn serves_requests_written_byte_by_byte() {
        let (reactor, addr, _stats, stop) = start_echo_reactor(2);
        let mut s = TcpStream::connect(addr).unwrap();
        for b in b"GET /slow HTTP/1.1\r\nhost: x\r\n\r\n" {
            s.write_all(&[*b]).unwrap();
            std::thread::sleep(Duration::from_millis(1));
        }
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let (status, body) = read_one_response(&mut reader);
        assert_eq!(status, 200);
        assert!(body.contains("/slow"), "{body}");
        stop.store(true, Ordering::Release);
        for t in reactor.into_threads() {
            let _ = t.join();
        }
    }

    #[test]
    fn pipelined_requests_on_one_connection_all_answered() {
        let (reactor, addr, stats, stop) = start_echo_reactor(2);
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"GET /a HTTP/1.1\r\n\r\nGET /b HTTP/1.1\r\n\r\nGET /c HTTP/1.1\r\nconnection: close\r\n\r\n")
            .unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        for path in ["/a", "/b", "/c"] {
            let (status, body) = read_one_response(&mut reader);
            assert_eq!(status, 200);
            assert!(body.contains(path), "expected {path} in {body}");
        }
        assert!(stats.accepted_total.load(Ordering::Acquire) >= 1);
        stop.store(true, Ordering::Release);
        for t in reactor.into_threads() {
            let _ = t.join();
        }
    }

    #[test]
    fn parse_errors_get_a_response_and_a_close() {
        let (reactor, addr, _stats, stop) = start_echo_reactor(1);
        let mut s = TcpStream::connect(addr).unwrap();
        s.write_all(b"NOT A VALID START LINE AT ALL\r\n\r\n").unwrap();
        let mut reader = BufReader::new(s.try_clone().unwrap());
        let (status, _body) = read_one_response(&mut reader);
        assert_eq!(status, 400);
        stop.store(true, Ordering::Release);
        for t in reactor.into_threads() {
            let _ = t.join();
        }
    }

    #[test]
    fn open_gauge_returns_to_zero_after_close() {
        let (reactor, addr, stats, stop) = start_echo_reactor(2);
        {
            let mut s = TcpStream::connect(addr).unwrap();
            s.write_all(b"GET /x HTTP/1.1\r\nconnection: close\r\n\r\n").unwrap();
            let mut reader = BufReader::new(s.try_clone().unwrap());
            let (status, _) = read_one_response(&mut reader);
            assert_eq!(status, 200);
        }
        // the close is observed on the next readiness tick
        let deadline = Instant::now() + Duration::from_secs(5);
        while stats.open.load(Ordering::Acquire) != 0 && Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(20));
        }
        assert_eq!(stats.open.load(Ordering::Acquire), 0);
        stop.store(true, Ordering::Release);
        for t in reactor.into_threads() {
            let _ = t.join();
        }
    }
}
