//! The closed-loop autoscaling supervisor (§IV-B + §V): the paper's
//! monitor → detect → act loop running *inside* the serving process,
//! against live replicas instead of the offline simulator that
//! [`crate::autoscaler`] drives.
//!
//! Every `sample_interval` the supervisor averages the newest Table II
//! frame of each live replica into one cluster row. The first
//! `calib_samples` rows (healthy traffic assumed) calibrate a
//! [`ZscoreDetector`] — the same energy + POT-threshold + mean-difference
//! decision logic the offline loop uses. After calibration each row is
//! scored: `patience` consecutive anomalous rows with MD > 0 hot-spawn a
//! replica ([`super::hot_add_replica`]); MD < 0 retires the newest one
//! with the drain-then-join protocol. A queue-pressure guard scales up
//! when the cluster-mean queue wait stays over its budget even while the
//! detector is within threshold — real queue pressure, not only
//! throughput, drives the decision.

//!
//! With a [`ForecastPolicy`] the supervisor also runs a *proactive*
//! planner ahead of the reactive loop: a [`crate::forecast::Forecaster`]
//! over the sampled cluster arrival rate predicts demand `horizon_steps`
//! ahead, [`crate::forecast::replicas_for_rate`] converts the prediction
//! into a replica target from per-replica service capacity, and the
//! planner pre-promotes warm standbys (and re-sizes the warm pool) before
//! the ramp arrives instead of after the detector notices it. When the
//! forecaster's trailing error overshoots the policy's budget the planner
//! stands down and the reactive loop alone drives scaling — a wrong
//! forecast can cost efficiency, never stability.

use super::GatewayState;
use crate::autoscaler::Action;
use crate::detect::{Detection, ScaleDirection, ZscoreDetector};
use crate::forecast::{ForecastConfig, Forecaster, MultiForecaster};
use crate::metrics::Frame;
use crate::simulator::gpu::{GpuSpec, RTX4090_24G};
use crate::simulator::modelcard::{ModelCard, MISTRAL_7B};
use std::collections::BTreeMap;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// cadence at which cluster-averaged frames are sampled and scored
    pub sample_interval: Duration,
    /// rows collected (healthy traffic assumed) before the detector is
    /// calibrated; raised to the detector's minimum internally
    pub calib_samples: usize,
    /// consecutive anomalous samples in one direction required to act
    pub patience: usize,
    /// minimum wall-clock between scaling actions
    pub cooldown: Duration,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// scale up when the cluster-mean queue wait stays above this for
    /// `patience` samples, even if the detector is within threshold;
    /// zero disables the guard
    pub queue_wait_budget: Duration,
    /// run the detector-driven replica-count loop; off, the supervisor
    /// only executes the reconfiguration policy (if any)
    pub detector_scaling: bool,
    /// live §IV-A reconfiguration of `max_num_seqs`/`gpu_memory`; `None`
    /// disables the loop
    pub reconfig: Option<ReconfigPolicy>,
    /// forecast-aware proactive planning (pre-promotion + warm-pool
    /// sizing); `None` leaves the supervisor purely reactive
    pub forecast: Option<ForecastPolicy>,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            sample_interval: Duration::from_secs(1),
            calib_samples: 30,
            patience: 3,
            cooldown: Duration::from_secs(30),
            min_replicas: 1,
            max_replicas: 4,
            queue_wait_budget: Duration::from_millis(500),
            detector_scaling: true,
            reconfig: None,
            forecast: None,
        }
    }
}

/// Policy for the proactive planner: how far ahead to predict, what error
/// makes the forecast untrustworthy, and how a predicted rate maps onto
/// replicas.
#[derive(Debug, Clone)]
pub struct ForecastPolicy {
    /// prediction horizon in `sample_interval` steps (≥ 1); pre-promotion
    /// leads demand by roughly this much wall-clock
    pub horizon_steps: usize,
    /// season length in samples for the seasonal models; 0 disables them
    pub season_steps: usize,
    /// trailing weighted-MAPE above which the planner stands down and the
    /// reactive loop alone drives scaling
    pub err_budget: f64,
    /// per-replica service capacity in requests/second; 0 learns it from
    /// the peak per-replica finish rate observed while the cluster was
    /// under pressure (queueing or ≥90% slot occupancy)
    pub replica_capacity_rps: f64,
    /// relative safety margin applied to the predicted rate
    pub headroom: f64,
    /// warm standbys kept even when no promotions are anticipated, so the
    /// first proactive scale-up is always O(route-update)
    pub min_warm: usize,
    /// cost-aware trough scale-down: retire replicas *before* they go
    /// idle when both the cluster forecaster and the per-tenant mixture
    /// forecast predict a demand trough at the horizon. Off, replicas are
    /// only retired reactively (detector underload)
    pub trough_scale_down: bool,
}

impl Default for ForecastPolicy {
    fn default() -> Self {
        ForecastPolicy {
            horizon_steps: 5,
            season_steps: 0,
            err_budget: 1.0,
            replica_capacity_rps: 0.0,
            headroom: 0.15,
            min_warm: 1,
            trough_scale_down: false,
        }
    }
}

/// Policy for the live configuration-recommendation loop: how often to
/// re-derive the Table I knobs from the monitoring window, and the
/// hysteresis that keeps it from thrashing or fighting the scale loop.
#[derive(Debug, Clone)]
pub struct ReconfigPolicy {
    /// cadence at which the §IV-A estimators run over the live window
    pub interval: Duration,
    /// minimum wall-clock between applied reconfigurations, *and* the
    /// keep-out period after any scale-up/down action
    pub cooldown: Duration,
    /// relative dead-band: |recommended − applied| / applied must exceed
    /// this before a verdict is applied
    pub deadband: f64,
    /// clamp bounds on the recommended `max_num_seqs`
    pub min_max_num_seqs: usize,
    pub max_max_num_seqs: usize,
    /// Table II frames per replica fed to the estimators
    pub window: usize,
    /// device/model card the gpu_memory projection maps onto (Fig. 6
    /// pairs Mistral-7B with an RTX 4090 by default)
    pub gpu: &'static GpuSpec,
    pub model: &'static ModelCard,
}

impl Default for ReconfigPolicy {
    fn default() -> Self {
        ReconfigPolicy {
            interval: Duration::from_secs(10),
            cooldown: Duration::from_secs(60),
            deadband: 0.25,
            min_max_num_seqs: 1,
            max_max_num_seqs: 256,
            window: 120,
            gpu: &RTX4090_24G,
            model: &MISTRAL_7B,
        }
    }
}

/// What tripped a scaling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// the anomaly detector (energy over POT threshold, MD direction)
    Detector,
    /// the queue-pressure guard (mean queue wait over budget)
    QueueWait,
    /// the §IV-A configuration recommender (live window re-derivation)
    Recommender,
    /// the proactive planner (predicted arrival rate over capacity)
    Forecast,
}

/// One executed scaling action.
#[derive(Debug, Clone)]
pub struct ScalingEvent {
    /// seconds since gateway start
    pub at: f64,
    pub direction: ScaleDirection,
    pub action: Action,
    pub trigger: Trigger,
    /// detector energy and threshold at decision time (0/0 for
    /// recommender- and forecast-triggered actions — no detector involved)
    pub energy: f64,
    pub threshold: f64,
    /// the replica the action spawned or retired; for a cluster-wide
    /// [`Action::Reconfigure`], the lowest live replica id
    pub replica_id: u64,
    pub replicas_after: usize,
}

/// Supervisor state shared with `/metrics` and the [`super::Gateway`]
/// accessors.
#[derive(Debug, Default)]
pub(super) struct SupervisorStatus {
    pub enabled: bool,
    pub calibrated: bool,
    pub events: Vec<ScalingEvent>,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub last_energy: f64,
    pub last_threshold: f64,
    /// reconfiguration verdicts applied to the live replica set
    pub reconfigures: u64,
    /// last max_num_seqs applied cluster-wide (0 = never)
    pub last_max_num_seqs: usize,
    /// true when a [`ForecastPolicy`] is active
    pub forecast_enabled: bool,
    /// latest predicted cluster arrival rate (requests/second)
    pub last_forecast: f64,
    /// trailing weighted-MAPE of the forecaster at the planning horizon
    pub forecast_error: f64,
    /// true while the error budget is blown and the planner stands down
    pub forecast_degraded: bool,
    /// scale actions by origin: proactive = forecast-triggered, reactive =
    /// detector- or queue-guard-triggered
    pub proactive_events: u64,
    pub reactive_events: u64,
    /// latest sum of the per-tenant mixture forecasts (0 until every
    /// tenant's component can answer)
    pub last_tenant_forecast: f64,
    /// forecast-triggered retires executed before the replicas went idle
    pub trough_events: u64,
}

impl SupervisorStatus {
    pub fn new(enabled: bool, forecast_enabled: bool) -> SupervisorStatus {
        SupervisorStatus {
            enabled,
            forecast_enabled,
            ..SupervisorStatus::default()
        }
    }

    pub fn snapshot(&self) -> SupervisorSnapshot {
        SupervisorSnapshot {
            enabled: self.enabled,
            calibrated: self.calibrated,
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            last_energy: self.last_energy,
            last_threshold: self.last_threshold,
            events: self.events.len(),
            reconfigures: self.reconfigures,
            last_max_num_seqs: self.last_max_num_seqs,
            forecast_enabled: self.forecast_enabled,
            last_forecast: self.last_forecast,
            forecast_error: self.forecast_error,
            forecast_degraded: self.forecast_degraded,
            proactive_events: self.proactive_events,
            reactive_events: self.reactive_events,
            last_tenant_forecast: self.last_tenant_forecast,
            trough_events: self.trough_events,
        }
    }
}

/// Cheap copy of the supervisor's state for rendering and tests.
#[derive(Debug, Clone, Default)]
pub struct SupervisorSnapshot {
    pub enabled: bool,
    pub calibrated: bool,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub last_energy: f64,
    pub last_threshold: f64,
    pub events: usize,
    pub reconfigures: u64,
    pub last_max_num_seqs: usize,
    pub forecast_enabled: bool,
    pub last_forecast: f64,
    pub forecast_error: f64,
    pub forecast_degraded: bool,
    pub proactive_events: u64,
    pub reactive_events: u64,
    pub last_tenant_forecast: f64,
    pub trough_events: u64,
}

/// Consecutive-sample counters feeding the patience rule. Pure logic so
/// the decision layer is testable without threads or sockets — and shared
/// with the cluster-wide supervisor ([`crate::cluster::coordinator`]),
/// which runs the same rule over cluster-mean rows.
///
/// Besides the raw streaks, the struct remembers the direction of the
/// last action it fired. Reversing that direction within a short window
/// (`2 × patience` observations) requires a doubled streak — hysteresis
/// that keeps a degraded-but-noisy signal (e.g. a node tripping its
/// circuit breaker and recovering) from flapping replica counts.
#[derive(Debug, Default)]
pub(crate) struct Streaks {
    up: usize,
    down: usize,
    wait: usize,
    /// direction of the most recent *successful* scaling action
    last_fired: Option<ScaleDirection>,
    /// observations since that action (saturating)
    since_fire: usize,
}

impl Streaks {
    pub(crate) fn observe(&mut self, d: &Detection, queue_wait: f64, wait_budget: f64) {
        self.since_fire = self.since_fire.saturating_add(1);
        if d.is_anomaly && d.direction == ScaleDirection::Up {
            self.up += 1;
            self.down = 0;
        } else if d.is_anomaly {
            self.down += 1;
            self.up = 0;
        } else {
            self.up = 0;
            self.down = 0;
        }
        if wait_budget > 0.0 && queue_wait > wait_budget {
            self.wait += 1;
        } else {
            self.wait = 0;
        }
    }

    /// Streak length demanded for `direction`: the configured patience,
    /// doubled while we are inside the hysteresis window after firing the
    /// opposite direction.
    fn required(&self, patience: usize, direction: ScaleDirection) -> usize {
        match self.last_fired {
            Some(last) if last != direction && self.since_fire <= patience * 2 => patience * 2,
            _ => patience,
        }
    }

    /// The action the patience rule asks for, if any. Scale-up wins ties:
    /// under genuine overload both the detector and the queue guard fire,
    /// and adding capacity is the safe direction.
    pub(crate) fn decide(&self, patience: usize) -> Option<(ScaleDirection, Trigger)> {
        let patience = patience.max(1);
        let need_up = self.required(patience, ScaleDirection::Up);
        let need_down = self.required(patience, ScaleDirection::Down);
        if self.up >= need_up {
            Some((ScaleDirection::Up, Trigger::Detector))
        } else if self.wait >= need_up {
            Some((ScaleDirection::Up, Trigger::QueueWait))
        } else if self.down >= need_down {
            Some((ScaleDirection::Down, Trigger::Detector))
        } else {
            None
        }
    }

    /// Record that a scaling action in `direction` actually happened.
    /// Clears the streaks and arms the reversal hysteresis.
    pub(crate) fn note_fired(&mut self, direction: ScaleDirection) {
        self.last_fired = Some(direction);
        self.since_fire = 0;
        self.up = 0;
        self.down = 0;
        self.wait = 0;
    }

    /// Clear the streak counters without touching the hysteresis memory:
    /// an external event (reconfigure, calibration restart) invalidates
    /// the streaks but not the fact that we recently scaled.
    pub(crate) fn reset(&mut self) {
        self.up = 0;
        self.down = 0;
        self.wait = 0;
    }
}

/// Mutable state of the reconfiguration loop between ticks.
struct ReconfigState {
    next_due: Instant,
    last_applied: Option<Instant>,
    /// last *requested* target. The engine may clamp below the request
    /// (compiled batch width), so the dead-band must also compare against
    /// what was asked — otherwise a clamped verdict re-fires forever.
    last_target: Option<usize>,
}

/// Consecutive frames of each replica's `n_arriving` series averaged into
/// one de-noised arrival sample for the forecaster.
const FORECAST_SAMPLE_FRAMES: usize = 3;

/// Minimum per-replica capacity evidence (requests/second) before the
/// planner converts predictions into replica counts.
const MIN_CAPACITY_EVIDENCE: f64 = 0.05;

/// Mutable state of the proactive planner between ticks.
struct ForecastState {
    forecaster: Forecaster,
    /// peak per-replica finish rate observed under pressure — the learned
    /// stand-in for service capacity when the policy does not configure one
    learned_capacity: f64,
    /// one forecaster per tenant over its admitted-request rate; every
    /// tenant is observed every tick (zeros included) so the mixture's
    /// components mature in lockstep and `forecast_sum` can answer
    tenants: MultiForecaster,
    /// previous tick's admitted-counter reading per tenant, for the
    /// per-interval delta that feeds the tenant forecasters
    last_admitted: BTreeMap<String, u64>,
}

/// Run the supervisor until the gateway stops. Spawned by
/// [`super::Gateway::start_scalable`] when a [`SupervisorConfig`] is
/// given.
pub(super) fn supervisor_loop(state: &Arc<GatewayState>, cfg: SupervisorConfig) {
    // detector minimums: ZscoreDetector wants ≥15 rows, POT wants ≥20
    let calib_target = cfg.calib_samples.max(20);
    let mut calib_frames: Vec<Frame> = Vec::new();
    let mut detector: Option<ZscoreDetector> = None;
    let mut streaks = Streaks::default();
    let mut last_action: Option<Instant> = None;
    let mut reconfig_state = cfg.reconfig.as_ref().map(|p| ReconfigState {
        next_due: Instant::now() + p.interval,
        last_applied: None,
        last_target: None,
    });
    let mut forecast_state = cfg.forecast.as_ref().map(|p| {
        let fc = ForecastConfig {
            horizon: p.horizon_steps.max(1),
            season: p.season_steps,
            ..ForecastConfig::default()
        };
        ForecastState {
            forecaster: Forecaster::new(fc.clone()),
            learned_capacity: 0.0,
            tenants: MultiForecaster::new(fc),
            last_admitted: BTreeMap::new(),
        }
    });

    crate::info!(
        "gateway",
        "autoscaling supervisor up: interval {:?}, calib {} samples, patience {}, \
         replicas {}..={}, detector scaling {}, reconfig {}, forecast {}",
        cfg.sample_interval,
        calib_target,
        cfg.patience,
        cfg.min_replicas,
        cfg.max_replicas,
        cfg.detector_scaling,
        cfg.reconfig.is_some(),
        cfg.forecast.is_some()
    );

    loop {
        if sleep_interruptible(state, cfg.sample_interval) {
            break;
        }

        // the §IV-A reconfiguration loop runs on its own cadence; an
        // applied verdict changes the service the detector was calibrated
        // on, so calibration and streaks restart from scratch
        if let (Some(policy), Some(rs)) = (cfg.reconfig.as_ref(), reconfig_state.as_mut()) {
            if maybe_reconfigure(state, policy, rs, last_action) {
                streaks.reset();
                detector = None;
                calib_frames.clear();
                state.supervisor.lock().unwrap().calibrated = false;
            }
        }

        // only the detector and the planner consume cluster samples; a
        // reconfig-only supervisor skips the per-tick store walk entirely
        let sample = if cfg.detector_scaling || cfg.forecast.is_some() {
            cluster_sample(state)
        } else {
            None
        };

        // the proactive planner runs ahead of the reactive loop: it acts
        // on where demand is *going*, the detector on where it already is
        if let (Some(policy), Some(fs), Some((frame, _))) =
            (cfg.forecast.as_ref(), forecast_state.as_mut(), sample.as_ref())
        {
            if maybe_forecast_scale(state, &cfg, policy, fs, frame, &mut last_action) {
                // the cluster the detector calibrated on just changed size
                streaks.reset();
            }
        }

        if !cfg.detector_scaling {
            continue;
        }

        let Some((frame, queue_wait)) = sample else {
            continue;
        };

        let Some(det) = &detector else {
            calib_frames.push(frame);
            if calib_frames.len() >= calib_target {
                match ZscoreDetector::calibrate_frames(&calib_frames) {
                    // a zero threshold means the calibration traffic was
                    // degenerate (constant rows); keep extending the window
                    Some(d) if d.threshold > 1e-9 => {
                        crate::info!(
                            "gateway",
                            "supervisor calibrated on {} samples (threshold {:.3})",
                            calib_frames.len(),
                            d.threshold
                        );
                        state.supervisor.lock().unwrap().calibrated = true;
                        detector = Some(d);
                    }
                    _ => {
                        // bound the window so a forever-idle gateway does
                        // not grow the buffer unboundedly
                        let cap = calib_target * 50;
                        if calib_frames.len() > cap {
                            calib_frames.drain(..calib_frames.len() - cap / 2);
                        }
                    }
                }
            }
            continue;
        };

        let d = det.detect_frame(&frame);
        {
            let mut status = state.supervisor.lock().unwrap();
            status.last_energy = d.kl;
            status.last_threshold = d.threshold;
        }
        streaks.observe(&d, queue_wait, cfg.queue_wait_budget.as_secs_f64());

        let cooled = last_action
            .map(|t| t.elapsed() >= cfg.cooldown)
            .unwrap_or(true);
        if !cooled {
            continue;
        }
        let Some((direction, trigger)) = streaks.decide(cfg.patience) else {
            continue;
        };

        let live = state.replicas.read().unwrap().len();
        match direction {
            ScaleDirection::Up if live < cfg.max_replicas => {
                match super::hot_add_replica(state) {
                    Ok(id) => {
                        record_event(
                            state,
                            d.kl,
                            d.threshold,
                            direction,
                            trigger,
                            Action::AddReplica,
                            id,
                        );
                        last_action = Some(Instant::now());
                        streaks.note_fired(direction);
                    }
                    Err(e) => crate::error!("gateway", "supervisor scale-up failed: {e}"),
                }
                streaks.reset();
            }
            ScaleDirection::Down if live > cfg.min_replicas => {
                // retire the newest replica: the oldest ids carry the
                // calibration-era traffic history
                let id = state.replicas.read().unwrap().keys().max().copied();
                if let Some(id) = id {
                    match super::retire_replica(state, id) {
                        Ok(()) => {
                            record_event(
                                state,
                                d.kl,
                                d.threshold,
                                direction,
                                trigger,
                                Action::ScaleDown,
                                id,
                            );
                            last_action = Some(Instant::now());
                            streaks.note_fired(direction);
                        }
                        Err(e) => crate::error!("gateway", "supervisor scale-down failed: {e}"),
                    }
                }
                streaks.reset();
            }
            // at the configured bound: hold the decision, keep observing
            _ => streaks.reset(),
        }
    }
}

/// One tick of the reconfiguration loop: re-derive the Table I knobs from
/// the live window and apply them when the verdict clears the dead-band
/// and every cooldown. Returns true when a verdict was applied.
fn maybe_reconfigure(
    state: &Arc<GatewayState>,
    policy: &ReconfigPolicy,
    rs: &mut ReconfigState,
    last_scale_action: Option<Instant>,
) -> bool {
    let now = Instant::now();
    if now < rs.next_due {
        return false;
    }
    rs.next_due = now + policy.interval;
    // hysteresis: never reconfigure while the scale loop just acted (the
    // new replica set needs fresh evidence), nor twice within cooldown
    if let Some(t) = last_scale_action {
        if t.elapsed() < policy.cooldown {
            return false;
        }
    }
    if let Some(t) = rs.last_applied {
        if t.elapsed() < policy.cooldown {
            return false;
        }
    }
    let Some(current) = super::applied_max_num_seqs(state) else {
        return false;
    };
    let frames = super::window_frames(state, policy.window);
    // §IV-A-1: refuses degenerate windows (idle traffic, too few busy
    // frames) — the service is only re-derived from real evidence
    let Some(decision) = crate::config::determine_max_num_seqs(&frames) else {
        return false;
    };
    let hi = policy.max_max_num_seqs.max(policy.min_max_num_seqs);
    let target = decision.max_num_seqs.clamp(policy.min_max_num_seqs, hi);
    let rel = (target as f64 - current as f64).abs() / current.max(1) as f64;
    if rel <= policy.deadband {
        return false;
    }
    // the engine may have clamped the previous request below what was
    // asked (compiled batch width); re-applying a near-identical verdict
    // would churn forever without changing anything
    if let Some(prev) = rs.last_target {
        let rel_prev = (target as f64 - prev as f64).abs() / prev.max(1) as f64;
        if rel_prev <= policy.deadband {
            return false;
        }
    }
    // §IV-A-2: project gpu_memory at the recommended concurrency
    let gm = crate::config::determine_gpu_memory(&frames, target, policy.gpu, policy.model);
    let asked = super::reconfigure_live(state, target, gm.gpu_memory);
    if asked == 0 {
        return false;
    }
    rs.last_applied = Some(Instant::now());
    rs.last_target = Some(target);
    let direction = if target > current {
        ScaleDirection::Up
    } else {
        ScaleDirection::Down
    };
    let subject = state
        .replicas
        .read()
        .unwrap()
        .keys()
        .min()
        .copied()
        .unwrap_or(0);
    crate::info!(
        "gateway",
        "supervisor reconfigure: max_num_seqs {current} -> {target} (n_limit {:.2}, \
         t_limit {:.2}s, {:?}), gpu_memory {:.2} -> {} replica(s)",
        decision.n_limit,
        decision.t_limit,
        decision.saturation,
        gm.gpu_memory,
        asked
    );
    record_event(
        state,
        0.0,
        0.0,
        direction,
        Trigger::Recommender,
        Action::Reconfigure {
            max_num_seqs: target,
            gpu_memory: gm.gpu_memory,
        },
        subject,
    );
    let mut status = state.supervisor.lock().unwrap();
    status.reconfigures += 1;
    status.last_max_num_seqs = target;
    true
}

/// One tick of the proactive planner: feed the cluster forecaster and the
/// per-tenant mixture, publish the forecast gauges, size the warm pool for
/// the anticipated promotions, pre-promote when predicted demand exceeds
/// live capacity — and, with [`ForecastPolicy::trough_scale_down`], retire
/// replicas *before* they go idle when both forecasts agree a trough is
/// ahead. Returns true when a proactive scale action was executed.
fn maybe_forecast_scale(
    state: &Arc<GatewayState>,
    cfg: &SupervisorConfig,
    policy: &ForecastPolicy,
    fs: &mut ForecastState,
    frame: &Frame,
    last_action: &mut Option<Instant>,
) -> bool {
    let live = state.replicas.read().unwrap().len();
    // de-noised sample: mean of the last few frames per replica, summed
    // across the live set — the total rate the cluster must absorb
    let total = forecast_sample(state, FORECAST_SAMPLE_FRAMES)
        .unwrap_or(frame.n_arriving * live as f64);
    // capacity is only learnable under pressure: a lightly loaded
    // replica's finish rate equals its *demand*, not its capacity, and
    // learning from it would make the planner over-provision any steady
    // load (ceil(demand/demand·live) > live, forever)
    let under_pressure = frame.n_pending > 0.5 || frame.gpu_util >= 0.9;
    if under_pressure && frame.n_finished > fs.learned_capacity {
        fs.learned_capacity = frame.n_finished;
    }
    fs.forecaster.observe(total);

    // per-tenant mixture feed: every tenant observed every tick, as the
    // per-interval delta of its admitted counter (a rate in req/s). The
    // first tick a tenant is seen contributes 0, not a counter-sized spike.
    let interval = cfg.sample_interval.as_secs_f64().max(1e-3);
    for t in state.tenants.all() {
        let admitted = t.admitted_total();
        let prev = fs
            .last_admitted
            .insert(t.id().to_string(), admitted)
            .unwrap_or(admitted);
        fs.tenants.observe(t.id(), admitted.saturating_sub(prev) as f64 / interval);
    }

    let horizon = policy.horizon_steps.max(1);
    let pred = fs.forecaster.forecast(horizon);
    let err = fs.forecaster.error();
    let degraded = fs.forecaster.degraded(policy.err_budget);
    let tenant_pred = fs.tenants.forecast_sum(horizon);
    let tenant_ok = tenant_pred.is_some() && !fs.tenants.degraded(policy.err_budget);
    {
        let mut status = state.supervisor.lock().unwrap();
        status.last_forecast = pred.unwrap_or(0.0);
        status.forecast_error = err.unwrap_or(0.0);
        status.forecast_degraded = degraded;
        status.last_tenant_forecast = tenant_pred.unwrap_or(0.0);
    }

    let capacity = if policy.replica_capacity_rps > 0.0 {
        policy.replica_capacity_rps
    } else {
        fs.learned_capacity
    };
    // stand down to reactive-only while there is nothing trustworthy to
    // plan from: no capacity evidence yet, not enough history, or the
    // trailing error blew its budget. Standing down includes releasing
    // any forecast-sized pre-provisioning back to the configured floor —
    // parked standby engines must not outlive the forecast that asked
    // for them.
    let trustworthy = capacity >= MIN_CAPACITY_EVIDENCE && !degraded;
    let pred = match pred {
        Some(p) if trustworthy => p,
        _ => {
            super::set_warm_target(state, policy.min_warm);
            return false;
        }
    };

    let replicas_for = |rate: f64| {
        crate::forecast::replicas_for_rate(
            rate,
            capacity,
            policy.headroom,
            cfg.min_replicas,
            cfg.max_replicas,
        )
    };
    // plan capacity on the more pessimistic of the two views: the cluster
    // aggregate or the sum of the per-tenant mixture components
    let planning_rate = match tenant_pred {
        Some(tp) if tenant_ok => pred.max(tp),
        _ => pred,
    };
    let needed = replicas_for(planning_rate);
    // keep enough standbys that reaching `needed` stays O(route-update)
    let warm_target = needed.saturating_sub(live).max(policy.min_warm);
    super::set_warm_target(state, warm_target);
    let cooled = last_action
        .map(|t| t.elapsed() >= cfg.cooldown)
        .unwrap_or(true);
    if needed > live {
        if !cooled || live >= cfg.max_replicas {
            return false;
        }
        match super::hot_add_replica(state) {
            Ok(id) => {
                crate::info!(
                    "gateway",
                    "proactive scale-up: predicted {planning_rate:.1} rps vs {capacity:.1} \
                     rps/replica x{live} live -> target {needed} (err {:.3})",
                    err.unwrap_or(0.0)
                );
                record_event(
                    state,
                    0.0,
                    0.0,
                    ScaleDirection::Up,
                    Trigger::Forecast,
                    Action::AddReplica,
                    id,
                );
                *last_action = Some(Instant::now());
                true
            }
            Err(e) => {
                crate::error!("gateway", "proactive scale-up failed: {e}");
                false
            }
        }
    } else if policy.trough_scale_down && needed < live {
        // cost-aware trough scale-down: retire *before* idle, but only
        // when both views agree — a single forecaster predicting a trough
        // the tenant mixture does not see is not enough evidence to give
        // up paid-for capacity
        if !cooled || live <= cfg.min_replicas {
            return false;
        }
        let tenant_trough = match tenant_pred {
            Some(tp) if tenant_ok => replicas_for(tp) < live,
            _ => false,
        };
        if !tenant_trough {
            return false;
        }
        let id = state.replicas.read().unwrap().keys().max().copied();
        let Some(id) = id else { return false };
        match super::retire_replica(state, id) {
            Ok(()) => {
                crate::info!(
                    "gateway",
                    "trough scale-down: predicted {planning_rate:.1} rps (tenant mixture \
                     {:.1}) vs {capacity:.1} rps/replica x{live} live -> target {needed}",
                    tenant_pred.unwrap_or(0.0)
                );
                record_event(
                    state,
                    0.0,
                    0.0,
                    ScaleDirection::Down,
                    Trigger::Forecast,
                    Action::ScaleDown,
                    id,
                );
                state.supervisor.lock().unwrap().trough_events += 1;
                *last_action = Some(Instant::now());
                true
            }
            Err(e) => {
                crate::error!("gateway", "trough scale-down failed: {e}");
                false
            }
        }
    } else {
        false
    }
}

/// Metric-store instance names of the live replica set — the one walk
/// both sampling paths (detector and forecaster) key their reads on.
fn live_instances(state: &GatewayState) -> Vec<String> {
    state
        .replicas
        .read()
        .unwrap()
        .keys()
        .map(|id| format!("replica-{id}"))
        .collect()
}

/// Mean of the newest `k` `n_arriving` frame values per live replica,
/// summed across the live set: the cluster arrival rate the forecaster
/// consumes (also the `arrival_rps` a node reports on `/cluster/status`).
/// `None` until at least one replica recorded a frame.
pub(crate) fn forecast_sample(state: &GatewayState, k: usize) -> Option<f64> {
    let instances = live_instances(state);
    if instances.is_empty() {
        return None;
    }
    let store = state.store.lock().unwrap();
    let mut total = 0.0;
    let mut seen = false;
    for instance in &instances {
        let vals = store.tail(crate::metrics::N_ARRIVING, instance, k.max(1));
        if vals.is_empty() {
            continue;
        }
        total += vals.iter().sum::<f64>() / vals.len() as f64;
        seen = true;
    }
    seen.then_some(total)
}

fn record_event(
    state: &GatewayState,
    energy: f64,
    threshold: f64,
    direction: ScaleDirection,
    trigger: Trigger,
    action: Action,
    replica_id: u64,
) {
    let replicas_after = state.replicas.read().unwrap().len();
    let event = ScalingEvent {
        at: state.started.elapsed().as_secs_f64(),
        direction,
        action,
        trigger,
        energy,
        threshold,
        replica_id,
        replicas_after,
    };
    crate::info!(
        "gateway",
        "supervisor action: {:?} via {:?} (energy {:.3} vs {:.3}) -> replica {} ({} live)",
        action,
        trigger,
        energy,
        threshold,
        replica_id,
        replicas_after
    );
    let mut status = state.supervisor.lock().unwrap();
    // reconfigurations have their own counter; only replica-count actions
    // feed the scale-up/down tallies and the origin split
    if !matches!(action, Action::Reconfigure { .. }) {
        match direction {
            ScaleDirection::Up => status.scale_ups += 1,
            ScaleDirection::Down => status.scale_downs += 1,
        }
        match trigger {
            Trigger::Forecast => status.proactive_events += 1,
            Trigger::Detector | Trigger::QueueWait => status.reactive_events += 1,
            Trigger::Recommender => {}
        }
    }
    status.events.push(event);
    let (last_forecast, forecast_error, forecast_degraded) = (
        status.last_forecast,
        status.forecast_error,
        status.forecast_degraded,
    );
    drop(status);

    // the decision flight recorder gets the full cause snapshot: what the
    // detector and forecaster saw, and the queue pressure at that instant
    let kind = match action {
        Action::Reconfigure { .. } => "reconfigure",
        _ => match direction {
            ScaleDirection::Up => "scale_up",
            ScaleDirection::Down => "scale_down",
        },
    };
    let reason = match trigger {
        Trigger::Detector => "detector",
        Trigger::QueueWait => "queue_wait",
        Trigger::Recommender => "recommender",
        Trigger::Forecast => "forecast",
    };
    let mut attrs = vec![
        ("detector_energy", format!("{energy:.4}")),
        ("detector_threshold", format!("{threshold:.4}")),
        ("replica_id", replica_id.to_string()),
        ("replicas_after", replicas_after.to_string()),
        (
            "queue_wait_p95_s",
            format!("{:.4}", state.metrics.queue_wait_quantile(0.95)),
        ),
        ("forecast_rps", format!("{last_forecast:.3}")),
        ("forecast_wmape", format!("{forecast_error:.4}")),
        ("forecast_degraded", forecast_degraded.to_string()),
    ];
    if let Action::Reconfigure {
        max_num_seqs,
        gpu_memory,
    } = action
    {
        attrs.push(("max_num_seqs", max_num_seqs.to_string()));
        attrs.push(("gpu_memory", format!("{gpu_memory:.2}")));
    }
    state.decisions.record(&state.service, kind, reason, attrs);
}

/// Average the newest Table II frame (and mean queue wait) of every live
/// replica into one detector row (also the aggregate a node reports on
/// `/cluster/status`). `None` until at least one replica has recorded a
/// frame.
pub(crate) fn cluster_sample(state: &GatewayState) -> Option<(Frame, f64)> {
    let instances = live_instances(state);
    if instances.is_empty() {
        return None;
    }
    let store = state.store.lock().unwrap();
    let mut acc = [0.0f64; 8];
    let mut wait = 0.0f64;
    let mut n = 0usize;
    for instance in &instances {
        let frames = crate::metrics::recent_frames(&store, instance, 1);
        let Some(f) = frames.last() else { continue };
        for (a, v) in acc.iter_mut().zip(f.to_array()) {
            *a += v;
        }
        wait += store
            .series(super::QUEUE_WAIT, instance)
            .and_then(|s| s.last())
            .unwrap_or(0.0);
        n += 1;
    }
    if n == 0 {
        return None;
    }
    for a in acc.iter_mut() {
        *a /= n as f64;
    }
    Some((Frame::from_array(acc), wait / n as f64))
}

/// Sleep `total` in short slices; true means the gateway is stopping.
fn sleep_interruptible(state: &GatewayState, total: Duration) -> bool {
    let deadline = Instant::now() + total;
    loop {
        if state.stop.load(Ordering::Acquire) {
            return true;
        }
        match deadline.checked_duration_since(Instant::now()) {
            None => return false,
            Some(rem) => std::thread::sleep(rem.min(Duration::from_millis(20))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(anomaly: bool, direction: ScaleDirection) -> Detection {
        Detection {
            kl: if anomaly { 10.0 } else { 0.1 },
            threshold: 1.0,
            is_anomaly: anomaly,
            direction,
        }
    }

    #[test]
    fn patience_gates_detector_decisions() {
        let mut s = Streaks::default();
        s.observe(&det(true, ScaleDirection::Up), 0.0, 1.0);
        assert_eq!(s.decide(2), None, "one anomalous sample is not enough");
        s.observe(&det(true, ScaleDirection::Up), 0.0, 1.0);
        assert_eq!(s.decide(2), Some((ScaleDirection::Up, Trigger::Detector)));
        // a healthy sample resets the streak
        s.observe(&det(false, ScaleDirection::Up), 0.0, 1.0);
        assert_eq!(s.decide(2), None);
    }

    #[test]
    fn down_streak_requires_consecutive_underload() {
        let mut s = Streaks::default();
        for _ in 0..3 {
            s.observe(&det(true, ScaleDirection::Down), 0.0, 1.0);
        }
        assert_eq!(s.decide(3), Some((ScaleDirection::Down, Trigger::Detector)));
        // flipping direction restarts from zero
        s.observe(&det(true, ScaleDirection::Up), 0.0, 1.0);
        assert_eq!(s.decide(3), None);
    }

    #[test]
    fn queue_wait_guard_fires_without_detector_anomaly() {
        let mut s = Streaks::default();
        for _ in 0..2 {
            s.observe(&det(false, ScaleDirection::Up), 2.0, 1.0);
        }
        assert_eq!(s.decide(2), Some((ScaleDirection::Up, Trigger::QueueWait)));
        // wait back under budget resets the guard
        s.observe(&det(false, ScaleDirection::Up), 0.5, 1.0);
        assert_eq!(s.decide(2), None);
        // zero budget disables the guard entirely
        let mut s = Streaks::default();
        for _ in 0..5 {
            s.observe(&det(false, ScaleDirection::Up), 100.0, 0.0);
        }
        assert_eq!(s.decide(2), None);
    }

    #[test]
    fn reversal_after_firing_needs_double_patience() {
        let mut s = Streaks::default();
        // fire a scale-up, then watch an immediate underload signal
        for _ in 0..2 {
            s.observe(&det(true, ScaleDirection::Up), 0.0, 1.0);
        }
        assert_eq!(s.decide(2), Some((ScaleDirection::Up, Trigger::Detector)));
        s.note_fired(ScaleDirection::Up);
        for _ in 0..2 {
            s.observe(&det(true, ScaleDirection::Down), 0.0, 1.0);
        }
        assert_eq!(
            s.decide(2),
            None,
            "reversing right after a scale-up must clear doubled patience"
        );
        for _ in 0..2 {
            s.observe(&det(true, ScaleDirection::Down), 0.0, 1.0);
        }
        assert_eq!(
            s.decide(2),
            Some((ScaleDirection::Down, Trigger::Detector)),
            "a doubled streak overrides the hysteresis"
        );
        // repeating the same direction is never penalised
        let mut s = Streaks::default();
        s.note_fired(ScaleDirection::Up);
        for _ in 0..2 {
            s.observe(&det(true, ScaleDirection::Up), 0.0, 1.0);
        }
        assert_eq!(s.decide(2), Some((ScaleDirection::Up, Trigger::Detector)));
    }

    #[test]
    fn hysteresis_window_expires() {
        let mut s = Streaks::default();
        s.note_fired(ScaleDirection::Up);
        // burn through the 2×patience window with healthy samples
        for _ in 0..5 {
            s.observe(&det(false, ScaleDirection::Up), 0.0, 1.0);
        }
        for _ in 0..2 {
            s.observe(&det(true, ScaleDirection::Down), 0.0, 1.0);
        }
        assert_eq!(
            s.decide(2),
            Some((ScaleDirection::Down, Trigger::Detector)),
            "outside the window single patience suffices"
        );
        // reset() keeps the hysteresis memory, only the streaks clear
        let mut s = Streaks::default();
        s.note_fired(ScaleDirection::Up);
        s.observe(&det(true, ScaleDirection::Down), 0.0, 1.0);
        s.reset();
        for _ in 0..2 {
            s.observe(&det(true, ScaleDirection::Down), 0.0, 1.0);
        }
        assert_eq!(s.decide(2), None, "reset() must not forget the recent fire");
    }

    #[test]
    fn detector_up_outranks_queue_guard_and_down() {
        let mut s = Streaks::default();
        for _ in 0..3 {
            s.observe(&det(true, ScaleDirection::Up), 2.0, 1.0);
        }
        // both up and wait streaks are ≥ patience; the detector wins
        assert_eq!(s.decide(2), Some((ScaleDirection::Up, Trigger::Detector)));
    }
}
