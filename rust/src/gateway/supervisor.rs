//! The closed-loop autoscaling supervisor (§IV-B + §V): the paper's
//! monitor → detect → act loop running *inside* the serving process,
//! against live replicas instead of the offline simulator that
//! [`crate::autoscaler`] drives.
//!
//! Every `sample_interval` the supervisor averages the newest Table II
//! frame of each live replica into one cluster row. The first
//! `calib_samples` rows (healthy traffic assumed) calibrate a
//! [`ZscoreDetector`] — the same energy + POT-threshold + mean-difference
//! decision logic the offline loop uses. After calibration each row is
//! scored: `patience` consecutive anomalous rows with MD > 0 hot-spawn a
//! replica ([`super::hot_add_replica`]); MD < 0 retires the newest one
//! with the drain-then-join protocol. A queue-pressure guard scales up
//! when the cluster-mean queue wait stays over its budget even while the
//! detector is within threshold — real queue pressure, not only
//! throughput, drives the decision.

use super::GatewayState;
use crate::autoscaler::Action;
use crate::detect::{Detection, ScaleDirection, ZscoreDetector};
use crate::metrics::Frame;
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct SupervisorConfig {
    /// cadence at which cluster-averaged frames are sampled and scored
    pub sample_interval: Duration,
    /// rows collected (healthy traffic assumed) before the detector is
    /// calibrated; raised to the detector's minimum internally
    pub calib_samples: usize,
    /// consecutive anomalous samples in one direction required to act
    pub patience: usize,
    /// minimum wall-clock between scaling actions
    pub cooldown: Duration,
    pub min_replicas: usize,
    pub max_replicas: usize,
    /// scale up when the cluster-mean queue wait stays above this for
    /// `patience` samples, even if the detector is within threshold;
    /// zero disables the guard
    pub queue_wait_budget: Duration,
}

impl Default for SupervisorConfig {
    fn default() -> Self {
        SupervisorConfig {
            sample_interval: Duration::from_secs(1),
            calib_samples: 30,
            patience: 3,
            cooldown: Duration::from_secs(30),
            min_replicas: 1,
            max_replicas: 4,
            queue_wait_budget: Duration::from_millis(500),
        }
    }
}

/// What tripped a scaling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Trigger {
    /// the anomaly detector (energy over POT threshold, MD direction)
    Detector,
    /// the queue-pressure guard (mean queue wait over budget)
    QueueWait,
}

/// One executed scaling action.
#[derive(Debug, Clone)]
pub struct ScalingEvent {
    /// seconds since gateway start
    pub at: f64,
    pub direction: ScaleDirection,
    pub action: Action,
    pub trigger: Trigger,
    /// detector energy and threshold at decision time
    pub energy: f64,
    pub threshold: f64,
    /// the replica the action spawned or retired
    pub replica_id: u64,
    pub replicas_after: usize,
}

/// Supervisor state shared with `/metrics` and the [`super::Gateway`]
/// accessors.
#[derive(Debug, Default)]
pub(super) struct SupervisorStatus {
    pub enabled: bool,
    pub calibrated: bool,
    pub events: Vec<ScalingEvent>,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub last_energy: f64,
    pub last_threshold: f64,
}

impl SupervisorStatus {
    pub fn new(enabled: bool) -> SupervisorStatus {
        SupervisorStatus {
            enabled,
            ..SupervisorStatus::default()
        }
    }

    pub fn snapshot(&self) -> SupervisorSnapshot {
        SupervisorSnapshot {
            enabled: self.enabled,
            calibrated: self.calibrated,
            scale_ups: self.scale_ups,
            scale_downs: self.scale_downs,
            last_energy: self.last_energy,
            last_threshold: self.last_threshold,
            events: self.events.len(),
        }
    }
}

/// Cheap copy of the supervisor's state for rendering and tests.
#[derive(Debug, Clone, Default)]
pub struct SupervisorSnapshot {
    pub enabled: bool,
    pub calibrated: bool,
    pub scale_ups: u64,
    pub scale_downs: u64,
    pub last_energy: f64,
    pub last_threshold: f64,
    pub events: usize,
}

/// Consecutive-sample counters feeding the patience rule. Pure logic so
/// the decision layer is testable without threads or sockets.
#[derive(Debug, Default)]
struct Streaks {
    up: usize,
    down: usize,
    wait: usize,
}

impl Streaks {
    fn observe(&mut self, d: &Detection, queue_wait: f64, wait_budget: f64) {
        if d.is_anomaly && d.direction == ScaleDirection::Up {
            self.up += 1;
            self.down = 0;
        } else if d.is_anomaly {
            self.down += 1;
            self.up = 0;
        } else {
            self.up = 0;
            self.down = 0;
        }
        if wait_budget > 0.0 && queue_wait > wait_budget {
            self.wait += 1;
        } else {
            self.wait = 0;
        }
    }

    /// The action the patience rule asks for, if any. Scale-up wins ties:
    /// under genuine overload both the detector and the queue guard fire,
    /// and adding capacity is the safe direction.
    fn decide(&self, patience: usize) -> Option<(ScaleDirection, Trigger)> {
        let patience = patience.max(1);
        if self.up >= patience {
            Some((ScaleDirection::Up, Trigger::Detector))
        } else if self.wait >= patience {
            Some((ScaleDirection::Up, Trigger::QueueWait))
        } else if self.down >= patience {
            Some((ScaleDirection::Down, Trigger::Detector))
        } else {
            None
        }
    }

    fn reset(&mut self) {
        *self = Streaks::default();
    }
}

/// Run the supervisor until the gateway stops. Spawned by
/// [`super::Gateway::start_scalable`] when a [`SupervisorConfig`] is
/// given.
pub(super) fn supervisor_loop(state: &Arc<GatewayState>, cfg: SupervisorConfig) {
    // detector minimums: ZscoreDetector wants ≥15 rows, POT wants ≥20
    let calib_target = cfg.calib_samples.max(20);
    let mut calib_frames: Vec<Frame> = Vec::new();
    let mut detector: Option<ZscoreDetector> = None;
    let mut streaks = Streaks::default();
    let mut last_action: Option<Instant> = None;

    crate::info!(
        "gateway",
        "autoscaling supervisor up: interval {:?}, calib {} samples, patience {}, \
         replicas {}..={}",
        cfg.sample_interval,
        calib_target,
        cfg.patience,
        cfg.min_replicas,
        cfg.max_replicas
    );

    loop {
        if sleep_interruptible(state, cfg.sample_interval) {
            break;
        }
        let Some((frame, queue_wait)) = cluster_sample(state) else {
            continue;
        };

        let Some(det) = &detector else {
            calib_frames.push(frame);
            if calib_frames.len() >= calib_target {
                match ZscoreDetector::calibrate_frames(&calib_frames) {
                    // a zero threshold means the calibration traffic was
                    // degenerate (constant rows); keep extending the window
                    Some(d) if d.threshold > 1e-9 => {
                        crate::info!(
                            "gateway",
                            "supervisor calibrated on {} samples (threshold {:.3})",
                            calib_frames.len(),
                            d.threshold
                        );
                        state.supervisor.lock().unwrap().calibrated = true;
                        detector = Some(d);
                    }
                    _ => {
                        // bound the window so a forever-idle gateway does
                        // not grow the buffer unboundedly
                        let cap = calib_target * 50;
                        if calib_frames.len() > cap {
                            calib_frames.drain(..calib_frames.len() - cap / 2);
                        }
                    }
                }
            }
            continue;
        };

        let d = det.detect_frame(&frame);
        {
            let mut status = state.supervisor.lock().unwrap();
            status.last_energy = d.kl;
            status.last_threshold = d.threshold;
        }
        streaks.observe(&d, queue_wait, cfg.queue_wait_budget.as_secs_f64());

        let cooled = last_action
            .map(|t| t.elapsed() >= cfg.cooldown)
            .unwrap_or(true);
        if !cooled {
            continue;
        }
        let Some((direction, trigger)) = streaks.decide(cfg.patience) else {
            continue;
        };

        let live = state.replicas.read().unwrap().len();
        match direction {
            ScaleDirection::Up if live < cfg.max_replicas => {
                match super::hot_add_replica(state) {
                    Ok(id) => {
                        record_event(state, &d, direction, trigger, Action::AddReplica, id);
                        last_action = Some(Instant::now());
                    }
                    Err(e) => crate::error!("gateway", "supervisor scale-up failed: {e}"),
                }
                streaks.reset();
            }
            ScaleDirection::Down if live > cfg.min_replicas => {
                // retire the newest replica: the oldest ids carry the
                // calibration-era traffic history
                let id = state.replicas.read().unwrap().keys().max().copied();
                if let Some(id) = id {
                    match super::retire_replica(state, id) {
                        Ok(()) => {
                            record_event(state, &d, direction, trigger, Action::ScaleDown, id);
                            last_action = Some(Instant::now());
                        }
                        Err(e) => crate::error!("gateway", "supervisor scale-down failed: {e}"),
                    }
                }
                streaks.reset();
            }
            // at the configured bound: hold the decision, keep observing
            _ => streaks.reset(),
        }
    }
}

fn record_event(
    state: &GatewayState,
    d: &Detection,
    direction: ScaleDirection,
    trigger: Trigger,
    action: Action,
    replica_id: u64,
) {
    let replicas_after = state.replicas.read().unwrap().len();
    let event = ScalingEvent {
        at: state.started.elapsed().as_secs_f64(),
        direction,
        action,
        trigger,
        energy: d.kl,
        threshold: d.threshold,
        replica_id,
        replicas_after,
    };
    crate::info!(
        "gateway",
        "supervisor action: {:?} via {:?} (energy {:.3} > {:.3}) -> replica {} ({} live)",
        action,
        trigger,
        d.kl,
        d.threshold,
        replica_id,
        replicas_after
    );
    let mut status = state.supervisor.lock().unwrap();
    match direction {
        ScaleDirection::Up => status.scale_ups += 1,
        ScaleDirection::Down => status.scale_downs += 1,
    }
    status.events.push(event);
}

/// Average the newest Table II frame (and mean queue wait) of every live
/// replica into one detector row. `None` until at least one replica has
/// recorded a frame.
fn cluster_sample(state: &GatewayState) -> Option<(Frame, f64)> {
    let ids: Vec<u64> = state.replicas.read().unwrap().keys().copied().collect();
    if ids.is_empty() {
        return None;
    }
    let store = state.store.lock().unwrap();
    let mut acc = [0.0f64; 8];
    let mut wait = 0.0f64;
    let mut n = 0usize;
    for id in &ids {
        let instance = format!("replica-{id}");
        let frames = crate::metrics::recent_frames(&store, &instance, 1);
        let Some(f) = frames.last() else { continue };
        for (a, v) in acc.iter_mut().zip(f.to_array()) {
            *a += v;
        }
        wait += store
            .series(super::QUEUE_WAIT, &instance)
            .and_then(|s| s.last())
            .unwrap_or(0.0);
        n += 1;
    }
    if n == 0 {
        return None;
    }
    for a in acc.iter_mut() {
        *a /= n as f64;
    }
    Some((Frame::from_array(acc), wait / n as f64))
}

/// Sleep `total` in short slices; true means the gateway is stopping.
fn sleep_interruptible(state: &GatewayState, total: Duration) -> bool {
    let deadline = Instant::now() + total;
    loop {
        if state.stop.load(Ordering::Acquire) {
            return true;
        }
        match deadline.checked_duration_since(Instant::now()) {
            None => return false,
            Some(rem) => std::thread::sleep(rem.min(Duration::from_millis(20))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn det(anomaly: bool, direction: ScaleDirection) -> Detection {
        Detection {
            kl: if anomaly { 10.0 } else { 0.1 },
            threshold: 1.0,
            is_anomaly: anomaly,
            direction,
        }
    }

    #[test]
    fn patience_gates_detector_decisions() {
        let mut s = Streaks::default();
        s.observe(&det(true, ScaleDirection::Up), 0.0, 1.0);
        assert_eq!(s.decide(2), None, "one anomalous sample is not enough");
        s.observe(&det(true, ScaleDirection::Up), 0.0, 1.0);
        assert_eq!(s.decide(2), Some((ScaleDirection::Up, Trigger::Detector)));
        // a healthy sample resets the streak
        s.observe(&det(false, ScaleDirection::Up), 0.0, 1.0);
        assert_eq!(s.decide(2), None);
    }

    #[test]
    fn down_streak_requires_consecutive_underload() {
        let mut s = Streaks::default();
        for _ in 0..3 {
            s.observe(&det(true, ScaleDirection::Down), 0.0, 1.0);
        }
        assert_eq!(s.decide(3), Some((ScaleDirection::Down, Trigger::Detector)));
        // flipping direction restarts from zero
        s.observe(&det(true, ScaleDirection::Up), 0.0, 1.0);
        assert_eq!(s.decide(3), None);
    }

    #[test]
    fn queue_wait_guard_fires_without_detector_anomaly() {
        let mut s = Streaks::default();
        for _ in 0..2 {
            s.observe(&det(false, ScaleDirection::Up), 2.0, 1.0);
        }
        assert_eq!(s.decide(2), Some((ScaleDirection::Up, Trigger::QueueWait)));
        // wait back under budget resets the guard
        s.observe(&det(false, ScaleDirection::Up), 0.5, 1.0);
        assert_eq!(s.decide(2), None);
        // zero budget disables the guard entirely
        let mut s = Streaks::default();
        for _ in 0..5 {
            s.observe(&det(false, ScaleDirection::Up), 100.0, 0.0);
        }
        assert_eq!(s.decide(2), None);
    }

    #[test]
    fn detector_up_outranks_queue_guard_and_down() {
        let mut s = Streaks::default();
        for _ in 0..3 {
            s.observe(&det(true, ScaleDirection::Up), 2.0, 1.0);
        }
        // both up and wait streaks are ≥ patience; the detector wins
        assert_eq!(s.decide(2), Some((ScaleDirection::Up, Trigger::Detector)));
    }
}
