//! Gateway observability: lock-light request counters and latency
//! histograms, rendered together with the per-replica Table II frames from
//! [`crate::tsdb::MetricStore`] as Prometheus text exposition (the format
//! the paper's monitoring system scrapes). Also ships a small exposition
//! parser so tests can verify the scrape body instead of substring-matching.

use super::admission::TenantSnapshot;
use super::supervisor::SupervisorSnapshot;
use crate::metrics::{COLUMNS, N_RUNNING};
use crate::tsdb::MetricStore;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

/// `(endpoint, status) -> count`, relaxed for the request hot path: the
/// common case — a pair that has been seen before — is a shared read lock
/// plus one relaxed atomic bump, so concurrent handler threads don't
/// serialize on a map mutex. Only a pair's *first* occurrence takes the
/// write lock to insert its counter. Used by both the gateway and the
/// cluster coordinator metrics.
#[derive(Debug, Default)]
pub struct StatusCounters {
    counters: RwLock<BTreeMap<String, BTreeMap<u16, Arc<AtomicU64>>>>,
}

impl StatusCounters {
    pub fn bump(&self, endpoint: &str, status: u16) {
        if let Some(c) = self
            .counters
            .read()
            .unwrap()
            .get(endpoint)
            .and_then(|m| m.get(&status))
        {
            c.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.counters
            .write()
            .unwrap()
            .entry(endpoint.to_string())
            .or_default()
            .entry(status)
            .or_default()
            .fetch_add(1, Ordering::Relaxed);
    }

    /// Ordered `((endpoint, status), count)` rows for rendering.
    pub fn snapshot(&self) -> Vec<((String, u16), u64)> {
        self.counters
            .read()
            .unwrap()
            .iter()
            .flat_map(|(endpoint, by_status)| {
                by_status.iter().map(move |(status, count)| {
                    ((endpoint.clone(), *status), count.load(Ordering::Relaxed))
                })
            })
            .collect()
    }

    pub fn total(&self) -> u64 {
        self.counters
            .read()
            .unwrap()
            .values()
            .flat_map(|m| m.values())
            .map(|c| c.load(Ordering::Relaxed))
            .sum()
    }
}

/// Upper bounds (seconds) of the request-latency histogram buckets.
pub const LATENCY_BUCKETS: [f64; 10] = [
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 1.0, 5.0,
];

/// Upper bounds (seconds) of the replica-promotion latency histogram: warm
/// promotions land in the sub-millisecond buckets, cold hot-spawns pay
/// engine init and land in the tail.
pub const PROMOTION_BUCKETS: [f64; 8] = [0.0005, 0.002, 0.01, 0.05, 0.25, 1.0, 5.0, 30.0];

/// Upper bounds (seconds) of the time-in-queue histogram: how long
/// admitted jobs waited in a replica's worker queue before reaching the
/// engine (or being shed). The proactive-vs-reactive e2e comparison reads
/// its quantiles.
pub const QUEUE_WAIT_BUCKETS: [f64; 11] = [
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 5.0,
];

/// Upper bounds (seconds) of the per-phase lifecycle histogram: phases
/// range from sub-millisecond admission checks to multi-second decodes.
pub const PHASE_BUCKETS: [f64; 12] = [
    0.0001, 0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 1.0, 5.0,
];

/// Upper bounds (seconds) of the time-to-first-token histogram.
pub const TTFT_BUCKETS: [f64; 11] = QUEUE_WAIT_BUCKETS;

/// Upper bounds (seconds) of the inter-token (decode step gap) histogram.
pub const INTER_TOKEN_BUCKETS: [f64; 10] = [
    0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 1.0,
];

/// One cumulative latency histogram (lock-free) over a fixed set of
/// upper bounds.
#[derive(Debug)]
struct Histo {
    bounds: &'static [f64],
    buckets: Vec<AtomicU64>,
    sum_micros: AtomicU64,
    count: AtomicU64,
}

impl Histo {
    fn new(bounds: &'static [f64]) -> Histo {
        Histo {
            bounds,
            buckets: bounds.iter().map(|_| AtomicU64::new(0)).collect(),
            sum_micros: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    fn observe(&self, secs: f64) {
        for (i, &le) in self.bounds.iter().enumerate() {
            if secs <= le {
                self.buckets[i].fetch_add(1, Ordering::Relaxed);
            }
        }
        self.sum_micros
            .fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Upper-bound `q`-quantile estimate: the smallest bucket bound whose
    /// cumulative count reaches the rank. 0 with no observations; +inf
    /// past the largest bound.
    fn quantile(&self, q: f64) -> f64 {
        let total = self.count.load(Ordering::Relaxed);
        if total == 0 {
            return 0.0;
        }
        let rank = ((total as f64) * q.clamp(0.0, 1.0)).ceil().max(1.0) as u64;
        for (i, &le) in self.bounds.iter().enumerate() {
            if self.buckets[i].load(Ordering::Relaxed) >= rank {
                return le;
            }
        }
        f64::INFINITY
    }
}

#[derive(Debug)]
pub struct GatewayMetrics {
    /// (endpoint, status) -> count
    requests: StatusCounters,
    bucket_counts: [AtomicU64; LATENCY_BUCKETS.len()],
    latency_sum_micros: AtomicU64,
    latency_count: AtomicU64,
    tokens_generated: AtomicU64,
    sse_events: AtomicU64,
    rejected_queue_full: AtomicU64,
    rejected_rate_limited: AtomicU64,
    queue_shed: AtomicU64,
    /// live capacity mutations applied by replica workers
    reconfigure_applied: AtomicU64,
    /// integral of live-replica count over wall time (micro-replica-seconds):
    /// the denominator of the cost story — what the fleet *spent*, against
    /// which the per-tenant GPU-seconds ledger is apportioned
    replica_micros: AtomicU64,
    /// AddReplica latency, split by how the replica came up: warm-pool
    /// promotion, cold hot-spawn, or snapshot restore
    promotion_warm: Histo,
    promotion_cold: Histo,
    promotion_snapshot: Histo,
    /// legacy (pre-/v1) alias hits by path — the deprecation-sunset meter
    deprecated: std::sync::Mutex<BTreeMap<String, u64>>,
    /// time admitted jobs spent in replica worker queues
    queue_wait: Histo,
    /// per-lifecycle-phase durations, indexed parallel to
    /// [`crate::trace::PHASES`]
    phases: [Histo; crate::trace::PHASES.len()],
    /// request arrival → first generated token (TTFT)
    ttft: Histo,
    /// gap between consecutive generated tokens of one request
    inter_token: Histo,
    /// ingress connection accounting, shared with the reactor (or the
    /// legacy threaded accept loop) that actually moves the counters
    pub ingress: std::sync::Arc<super::reactor::IngressStats>,
}

impl Default for GatewayMetrics {
    fn default() -> Self {
        GatewayMetrics {
            requests: StatusCounters::default(),
            bucket_counts: Default::default(),
            latency_sum_micros: AtomicU64::new(0),
            latency_count: AtomicU64::new(0),
            tokens_generated: AtomicU64::new(0),
            sse_events: AtomicU64::new(0),
            rejected_queue_full: AtomicU64::new(0),
            rejected_rate_limited: AtomicU64::new(0),
            queue_shed: AtomicU64::new(0),
            reconfigure_applied: AtomicU64::new(0),
            replica_micros: AtomicU64::new(0),
            promotion_warm: Histo::new(&PROMOTION_BUCKETS),
            promotion_cold: Histo::new(&PROMOTION_BUCKETS),
            promotion_snapshot: Histo::new(&PROMOTION_BUCKETS),
            deprecated: std::sync::Mutex::new(BTreeMap::new()),
            queue_wait: Histo::new(&QUEUE_WAIT_BUCKETS),
            phases: std::array::from_fn(|_| Histo::new(&PHASE_BUCKETS)),
            ttft: Histo::new(&TTFT_BUCKETS),
            inter_token: Histo::new(&INTER_TOKEN_BUCKETS),
            ingress: std::sync::Arc::new(super::reactor::IngressStats::default()),
        }
    }
}

impl GatewayMetrics {
    pub fn new() -> GatewayMetrics {
        GatewayMetrics::default()
    }

    /// Record one finished HTTP exchange.
    pub fn observe(&self, endpoint: &str, status: u16, latency_secs: f64) {
        self.requests.bump(endpoint, status);
        for (i, &le) in LATENCY_BUCKETS.iter().enumerate() {
            if latency_secs <= le {
                self.bucket_counts[i].fetch_add(1, Ordering::Relaxed);
            }
        }
        self.latency_sum_micros
            .fetch_add((latency_secs * 1e6) as u64, Ordering::Relaxed);
        self.latency_count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_tokens(&self, n: usize) {
        self.tokens_generated.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn add_sse_events(&self, n: usize) {
        self.sse_events.fetch_add(n as u64, Ordering::Relaxed);
    }

    pub fn note_queue_full(&self) {
        self.rejected_queue_full.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_rate_limited(&self) {
        self.rejected_rate_limited.fetch_add(1, Ordering::Relaxed);
    }

    /// An admitted job was failed with a 503 because it overshot its
    /// queue-time budget or deadline before reaching the engine.
    pub fn note_queue_shed(&self) {
        self.queue_shed.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how long a job waited in a replica's worker queue before it
    /// was promoted into the engine or shed.
    pub fn observe_queue_wait(&self, secs: f64) {
        self.queue_wait.observe(secs);
    }

    /// Upper-bound `q`-quantile of time-in-queue from the histogram
    /// buckets (see [`QUEUE_WAIT_BUCKETS`]).
    pub fn queue_wait_quantile(&self, q: f64) -> f64 {
        self.queue_wait.quantile(q)
    }

    /// Record the duration of one lifecycle phase (see
    /// [`crate::trace::PHASES`]); unknown names are ignored.
    pub fn observe_phase(&self, phase: &str, secs: f64) {
        if let Some(idx) = crate::trace::PHASES.iter().position(|p| *p == phase) {
            self.phases[idx].observe(secs);
        }
    }

    /// Observations recorded for one phase — test/report helper.
    pub fn phase_count(&self, phase: &str) -> u64 {
        crate::trace::PHASES
            .iter()
            .position(|p| *p == phase)
            .map(|idx| self.phases[idx].count.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Record request arrival → first generated token.
    pub fn observe_ttft(&self, secs: f64) {
        self.ttft.observe(secs);
    }

    /// Record the gap between two consecutive tokens of one request.
    pub fn observe_inter_token(&self, secs: f64) {
        self.inter_token.observe(secs);
    }

    /// Accumulate `secs` of one live replica's wall time into the
    /// replica-seconds integral (each worker contributes its own frame
    /// windows, so N live replicas advance the integral N× real time).
    pub fn add_replica_seconds(&self, secs: f64) {
        if secs.is_finite() && secs > 0.0 {
            self.replica_micros.fetch_add((secs * 1e6) as u64, Ordering::Relaxed);
        }
    }

    /// Total replica-seconds spent since boot (the fleet's GPU-time cost).
    pub fn replica_seconds(&self) -> f64 {
        self.replica_micros.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// A replica worker applied a live capacity mutation.
    pub fn note_reconfigure(&self) {
        self.reconfigure_applied.fetch_add(1, Ordering::Relaxed);
    }

    /// Record how long bringing one more replica live took; `warm` marks a
    /// warm-pool promotion, otherwise a cold hot-spawn.
    pub fn observe_promotion(&self, warm: bool, secs: f64) {
        if warm {
            self.promotion_warm.observe(secs);
        } else {
            self.promotion_cold.observe(secs);
        }
    }

    /// Record a replica brought live by restoring an engine snapshot —
    /// the third `kind` of `enova_gateway_promotion_seconds`, sitting
    /// between `warm` (no init at all) and `cold` (full init).
    pub fn observe_promotion_snapshot(&self, secs: f64) {
        self.promotion_snapshot.observe(secs);
    }

    fn promotion_histo(&self, kind: &str) -> Option<&Histo> {
        match kind {
            "warm" => Some(&self.promotion_warm),
            "cold" => Some(&self.promotion_cold),
            "snapshot" => Some(&self.promotion_snapshot),
            _ => None,
        }
    }

    /// `(count, mean seconds)` of promotions by kind — test/report helper
    /// mirroring the `enova_gateway_promotion_seconds` histogram.
    pub fn promotion_stats(&self, warm: bool) -> (u64, f64) {
        let h = if warm {
            &self.promotion_warm
        } else {
            &self.promotion_cold
        };
        let count = h.count.load(Ordering::Relaxed);
        let mean = if count > 0 {
            h.sum_micros.load(Ordering::Relaxed) as f64 / 1e6 / count as f64
        } else {
            0.0
        };
        (count, mean)
    }

    /// Upper-bound `q`-quantile of the promotion histogram for one `kind`
    /// (`"warm"`, `"cold"`, `"snapshot"`); 0 for unknown kinds or when no
    /// promotion of that kind has been observed.
    pub fn promotion_quantile(&self, kind: &str, q: f64) -> f64 {
        self.promotion_histo(kind).map(|h| h.quantile(q)).unwrap_or(0.0)
    }

    /// Observations recorded for one promotion kind.
    pub fn promotion_count(&self, kind: &str) -> u64 {
        self.promotion_histo(kind)
            .map(|h| h.count.load(Ordering::Relaxed))
            .unwrap_or(0)
    }

    /// Count one hit on a legacy (pre-/v1) alias path — the meter behind
    /// the `Deprecation`/`Sunset` headers.
    pub fn note_deprecated(&self, path: &str) {
        *self
            .deprecated
            .lock()
            .unwrap()
            .entry(path.to_string())
            .or_insert(0) += 1;
    }

    /// Hits recorded for one legacy alias path.
    pub fn deprecated_for(&self, path: &str) -> u64 {
        self.deprecated.lock().unwrap().get(path).copied().unwrap_or(0)
    }

    pub fn requests_total(&self) -> u64 {
        self.requests.total()
    }
}

pub(crate) fn escape_label(v: &str) -> String {
    v.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Render the full `/metrics` body: gateway request metrics, the replica
/// set + warm pool + supervisor state, the per-tenant admission/cost
/// ledger, and the last Table II frame of every replica instance in
/// `store`.
#[allow(clippy::too_many_arguments)]
pub fn render_prometheus(
    gw: &GatewayMetrics,
    store: &MetricStore,
    inflight: usize,
    live_instances: &[String],
    warm_pool: usize,
    warm_target: usize,
    uptime_secs: f64,
    sup: &SupervisorSnapshot,
    tenants: &[TenantSnapshot],
) -> String {
    let live_replicas = live_instances.len();
    let mut out = String::with_capacity(4096);

    out.push_str("# HELP enova_gateway_requests_total HTTP requests served, by endpoint and status code.\n");
    out.push_str("# TYPE enova_gateway_requests_total counter\n");
    for ((endpoint, status), count) in gw.requests.snapshot() {
        let _ = writeln!(
            out,
            "enova_gateway_requests_total{{endpoint=\"{}\",code=\"{}\"}} {}",
            escape_label(&endpoint),
            status,
            count
        );
    }

    out.push_str("# HELP enova_gateway_request_seconds End-to-end request latency.\n");
    out.push_str("# TYPE enova_gateway_request_seconds histogram\n");
    let total = gw.latency_count.load(Ordering::Relaxed);
    for (i, &le) in LATENCY_BUCKETS.iter().enumerate() {
        let _ = writeln!(
            out,
            "enova_gateway_request_seconds_bucket{{le=\"{}\"}} {}",
            le,
            gw.bucket_counts[i].load(Ordering::Relaxed)
        );
    }
    let _ = writeln!(
        out,
        "enova_gateway_request_seconds_bucket{{le=\"+Inf\"}} {total}"
    );
    let _ = writeln!(
        out,
        "enova_gateway_request_seconds_sum {}",
        gw.latency_sum_micros.load(Ordering::Relaxed) as f64 / 1e6
    );
    let _ = writeln!(out, "enova_gateway_request_seconds_count {total}");

    for (name, help, value) in [
        (
            "enova_gateway_tokens_generated_total",
            "Completion tokens produced by all replicas.",
            gw.tokens_generated.load(Ordering::Relaxed),
        ),
        (
            "enova_gateway_sse_events_total",
            "Server-sent events written to streaming clients.",
            gw.sse_events.load(Ordering::Relaxed),
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {value}");
    }

    out.push_str("# HELP enova_gateway_admission_rejected_total Requests rejected with 429 at admission.\n");
    out.push_str("# TYPE enova_gateway_admission_rejected_total counter\n");
    let _ = writeln!(
        out,
        "enova_gateway_admission_rejected_total{{reason=\"queue_full\"}} {}",
        gw.rejected_queue_full.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "enova_gateway_admission_rejected_total{{reason=\"rate_limited\"}} {}",
        gw.rejected_rate_limited.load(Ordering::Relaxed)
    );

    out.push_str(
        "# HELP enova_gateway_queue_shed_total Admitted jobs failed with 503 after \
         overshooting their queue-time budget or deadline.\n",
    );
    out.push_str("# TYPE enova_gateway_queue_shed_total counter\n");
    let _ = writeln!(
        out,
        "enova_gateway_queue_shed_total {}",
        gw.queue_shed.load(Ordering::Relaxed)
    );

    out.push_str("# HELP enova_gateway_replicas Live (routable) engine replicas.\n");
    out.push_str("# TYPE enova_gateway_replicas gauge\n");
    let _ = writeln!(out, "enova_gateway_replicas {live_replicas}");

    out.push_str(
        "# HELP enova_gateway_warm_pool_replicas Pre-initialized standby replicas awaiting \
         promotion.\n",
    );
    out.push_str("# TYPE enova_gateway_warm_pool_replicas gauge\n");
    let _ = writeln!(out, "enova_gateway_warm_pool_replicas {warm_pool}");

    out.push_str(
        "# HELP enova_gateway_warm_pool_target Live warm-pool size target (forecast-sized \
         when the proactive planner runs).\n",
    );
    out.push_str("# TYPE enova_gateway_warm_pool_target gauge\n");
    let _ = writeln!(out, "enova_gateway_warm_pool_target {warm_target}");

    out.push_str(
        "# HELP enova_gateway_queue_wait_seconds Time admitted jobs spent in replica worker \
         queues before reaching the engine (or being shed).\n",
    );
    out.push_str("# TYPE enova_gateway_queue_wait_seconds histogram\n");
    let qw_total = gw.queue_wait.count.load(Ordering::Relaxed);
    for (i, &le) in QUEUE_WAIT_BUCKETS.iter().enumerate() {
        let _ = writeln!(
            out,
            "enova_gateway_queue_wait_seconds_bucket{{le=\"{}\"}} {}",
            le,
            gw.queue_wait.buckets[i].load(Ordering::Relaxed)
        );
    }
    let _ = writeln!(
        out,
        "enova_gateway_queue_wait_seconds_bucket{{le=\"+Inf\"}} {qw_total}"
    );
    let _ = writeln!(
        out,
        "enova_gateway_queue_wait_seconds_sum {}",
        gw.queue_wait.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
    );
    let _ = writeln!(out, "enova_gateway_queue_wait_seconds_count {qw_total}");

    out.push_str(
        "# HELP enova_request_phase_seconds Request lifecycle phase durations (admission, \
         dispatch, queue_wait, prefill, decode, sse) from the tracing layer.\n",
    );
    out.push_str("# TYPE enova_request_phase_seconds histogram\n");
    for (idx, phase) in crate::trace::PHASES.iter().enumerate() {
        let histo = &gw.phases[idx];
        let total = histo.count.load(Ordering::Relaxed);
        for (i, &le) in PHASE_BUCKETS.iter().enumerate() {
            let _ = writeln!(
                out,
                "enova_request_phase_seconds_bucket{{phase=\"{phase}\",le=\"{le}\"}} {}",
                histo.buckets[i].load(Ordering::Relaxed)
            );
        }
        let _ = writeln!(
            out,
            "enova_request_phase_seconds_bucket{{phase=\"{phase}\",le=\"+Inf\"}} {total}"
        );
        let _ = writeln!(
            out,
            "enova_request_phase_seconds_sum{{phase=\"{phase}\"}} {}",
            histo.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
        );
        let _ = writeln!(
            out,
            "enova_request_phase_seconds_count{{phase=\"{phase}\"}} {total}"
        );
    }

    for (name, help, histo, bounds) in [
        (
            "enova_gateway_ttft_seconds",
            "Request arrival to first generated token (time-to-first-token).",
            &gw.ttft,
            &TTFT_BUCKETS[..],
        ),
        (
            "enova_gateway_inter_token_seconds",
            "Gap between consecutive generated tokens of one request.",
            &gw.inter_token,
            &INTER_TOKEN_BUCKETS[..],
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} histogram");
        let total = histo.count.load(Ordering::Relaxed);
        for (i, &le) in bounds.iter().enumerate() {
            let _ = writeln!(
                out,
                "{name}_bucket{{le=\"{le}\"}} {}",
                histo.buckets[i].load(Ordering::Relaxed)
            );
        }
        let _ = writeln!(out, "{name}_bucket{{le=\"+Inf\"}} {total}");
        let _ = writeln!(
            out,
            "{name}_sum {}",
            histo.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
        );
        let _ = writeln!(out, "{name}_count {total}");
    }

    out.push_str(
        "# HELP enova_gateway_reconfigure_events_total Live capacity mutations applied by \
         replica workers (max_num_seqs / gpu_memory).\n",
    );
    out.push_str("# TYPE enova_gateway_reconfigure_events_total counter\n");
    let _ = writeln!(
        out,
        "enova_gateway_reconfigure_events_total {}",
        gw.reconfigure_applied.load(Ordering::Relaxed)
    );

    out.push_str(
        "# HELP enova_gateway_promotion_seconds Latency of bringing one more replica live, \
         by promotion kind (warm pool, cold hot-spawn, or snapshot restore).\n",
    );
    out.push_str("# TYPE enova_gateway_promotion_seconds histogram\n");
    for (kind, histo) in [
        ("warm", &gw.promotion_warm),
        ("cold", &gw.promotion_cold),
        ("snapshot", &gw.promotion_snapshot),
    ] {
        let total = histo.count.load(Ordering::Relaxed);
        for (i, &le) in PROMOTION_BUCKETS.iter().enumerate() {
            let _ = writeln!(
                out,
                "enova_gateway_promotion_seconds_bucket{{kind=\"{kind}\",le=\"{le}\"}} {}",
                histo.buckets[i].load(Ordering::Relaxed)
            );
        }
        let _ = writeln!(
            out,
            "enova_gateway_promotion_seconds_bucket{{kind=\"{kind}\",le=\"+Inf\"}} {total}"
        );
        let _ = writeln!(
            out,
            "enova_gateway_promotion_seconds_sum{{kind=\"{kind}\"}} {}",
            histo.sum_micros.load(Ordering::Relaxed) as f64 / 1e6
        );
        let _ = writeln!(
            out,
            "enova_gateway_promotion_seconds_count{{kind=\"{kind}\"}} {total}"
        );
    }

    // legacy alias hits (only recorded paths render — zero hits, no series)
    out.push_str(
        "# HELP enova_api_deprecated_requests_total Requests served via deprecated pre-/v1 \
         alias paths (answered with Deprecation/Sunset headers).\n",
    );
    out.push_str("# TYPE enova_api_deprecated_requests_total counter\n");
    for (path, count) in gw.deprecated.lock().unwrap().iter() {
        let _ = writeln!(
            out,
            "enova_api_deprecated_requests_total{{path=\"{}\"}} {count}",
            escape_label(path)
        );
    }

    for (name, help, value) in [
        (
            "enova_supervisor_enabled",
            "1 when the closed-loop autoscaling supervisor is running.",
            sup.enabled as u64 as f64,
        ),
        (
            "enova_supervisor_calibrated",
            "1 once the supervisor's detector finished calibration.",
            sup.calibrated as u64 as f64,
        ),
        (
            "enova_supervisor_anomaly_energy",
            "Detector energy of the latest supervisor sample.",
            sup.last_energy,
        ),
        (
            "enova_supervisor_anomaly_threshold",
            "POT threshold the supervisor scores against.",
            sup.last_threshold,
        ),
        (
            "enova_supervisor_forecast_enabled",
            "1 when the forecast-aware proactive planner is running.",
            sup.forecast_enabled as u64 as f64,
        ),
        (
            "enova_supervisor_forecast_rps",
            "Predicted cluster arrival rate at the planning horizon (requests/second).",
            sup.last_forecast,
        ),
        (
            "enova_supervisor_forecast_error",
            "Trailing weighted-MAPE of the forecaster at the planning horizon.",
            sup.forecast_error,
        ),
        (
            "enova_supervisor_forecast_degraded",
            "1 while forecast error is over budget and the planner stands down to reactive.",
            sup.forecast_degraded as u64 as f64,
        ),
        (
            "enova_supervisor_tenant_forecast_rps",
            "Sum of the per-tenant mixture forecasts at the planning horizon (requests/second).",
            sup.last_tenant_forecast,
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {value}");
    }
    out.push_str(
        "# HELP enova_supervisor_scale_events_total Scaling actions executed by the supervisor.\n",
    );
    out.push_str("# TYPE enova_supervisor_scale_events_total counter\n");
    let _ = writeln!(
        out,
        "enova_supervisor_scale_events_total{{direction=\"up\"}} {}",
        sup.scale_ups
    );
    let _ = writeln!(
        out,
        "enova_supervisor_scale_events_total{{direction=\"down\"}} {}",
        sup.scale_downs
    );
    out.push_str(
        "# HELP enova_supervisor_scale_origin_total Scaling actions by origin: proactive = \
         forecast-triggered pre-promotion, reactive = detector or queue-guard.\n",
    );
    out.push_str("# TYPE enova_supervisor_scale_origin_total counter\n");
    let _ = writeln!(
        out,
        "enova_supervisor_scale_origin_total{{origin=\"proactive\"}} {}",
        sup.proactive_events
    );
    let _ = writeln!(
        out,
        "enova_supervisor_scale_origin_total{{origin=\"reactive\"}} {}",
        sup.reactive_events
    );
    out.push_str(
        "# HELP enova_supervisor_trough_scale_downs_total Forecast-triggered retires executed \
         before the replicas went idle (cost-aware trough scale-down).\n",
    );
    out.push_str("# TYPE enova_supervisor_trough_scale_downs_total counter\n");
    let _ = writeln!(
        out,
        "enova_supervisor_trough_scale_downs_total {}",
        sup.trough_events
    );
    out.push_str(
        "# HELP enova_supervisor_reconfigure_total Reconfiguration verdicts the supervisor \
         applied to the live replica set.\n",
    );
    out.push_str("# TYPE enova_supervisor_reconfigure_total counter\n");
    let _ = writeln!(out, "enova_supervisor_reconfigure_total {}", sup.reconfigures);
    out.push_str(
        "# HELP enova_supervisor_applied_max_num_seqs Last max_num_seqs the supervisor \
         applied cluster-wide (0 = never reconfigured).\n",
    );
    out.push_str("# TYPE enova_supervisor_applied_max_num_seqs gauge\n");
    let _ = writeln!(
        out,
        "enova_supervisor_applied_max_num_seqs {}",
        sup.last_max_num_seqs
    );

    out.push_str("# HELP enova_gateway_inflight_requests Requests admitted and not yet finished.\n");
    out.push_str("# TYPE enova_gateway_inflight_requests gauge\n");
    let _ = writeln!(out, "enova_gateway_inflight_requests {inflight}");

    // ingress connection accounting (reactor or threaded accept loop)
    out.push_str(
        "# HELP enova_ingress_connections_accepted_total Ingress connections accepted since boot.\n",
    );
    out.push_str("# TYPE enova_ingress_connections_accepted_total counter\n");
    let _ = writeln!(
        out,
        "enova_ingress_connections_accepted_total {}",
        gw.ingress.accepted_total.load(Ordering::Relaxed)
    );
    out.push_str("# HELP enova_ingress_connections_open Currently-open ingress connections.\n");
    out.push_str("# TYPE enova_ingress_connections_open gauge\n");
    let _ = writeln!(
        out,
        "enova_ingress_connections_open {}",
        gw.ingress.open.load(Ordering::Relaxed)
    );
    out.push_str(
        "# HELP enova_ingress_handler_inflight Requests currently executing on the handler pool.\n",
    );
    out.push_str("# TYPE enova_ingress_handler_inflight gauge\n");
    let _ = writeln!(
        out,
        "enova_ingress_handler_inflight {}",
        gw.ingress.handler_inflight.load(Ordering::Relaxed)
    );
    out.push_str(
        "# HELP enova_ingress_handler_threads Configured handler-pool size (bounds concurrent \
         request execution regardless of open connections).\n",
    );
    out.push_str("# TYPE enova_ingress_handler_threads gauge\n");
    let _ = writeln!(
        out,
        "enova_ingress_handler_threads {}",
        gw.ingress.handler_threads.load(Ordering::Relaxed)
    );
    out.push_str(
        "# HELP enova_ingress_reactor_mode 1 when the sharded epoll reactor serves ingress, \
         0 for the legacy thread-per-connection pool.\n",
    );
    out.push_str("# TYPE enova_ingress_reactor_mode gauge\n");
    let _ = writeln!(
        out,
        "enova_ingress_reactor_mode {}",
        gw.ingress.reactor_mode.load(Ordering::Relaxed)
    );

    out.push_str("# HELP enova_gateway_uptime_seconds Gateway uptime.\n");
    out.push_str("# TYPE enova_gateway_uptime_seconds gauge\n");
    let _ = writeln!(out, "enova_gateway_uptime_seconds {uptime_secs:.3}");

    // fleet cost denominator: integral of live replicas over wall time
    out.push_str(
        "# HELP enova_replica_seconds_total Replica-seconds spent since boot (integral of \
         live replicas over wall time; the fleet's GPU-time cost).\n",
    );
    out.push_str("# TYPE enova_replica_seconds_total counter\n");
    let _ = writeln!(out, "enova_replica_seconds_total {}", gw.replica_seconds());

    // per-tenant admission + cost ledger (the multi-tenant SLO surface)
    out.push_str(
        "# HELP enova_tenant_requests_total Requests admitted per tenant.\n",
    );
    out.push_str("# TYPE enova_tenant_requests_total counter\n");
    for t in tenants {
        let _ = writeln!(
            out,
            "enova_tenant_requests_total{{tenant=\"{}\",tier=\"{}\"}} {}",
            escape_label(&t.id),
            t.tier.as_str(),
            t.admitted
        );
    }
    out.push_str(
        "# HELP enova_tenant_rejected_total Requests rejected per tenant (rate limit, \
         admission gate, or global throttle).\n",
    );
    out.push_str("# TYPE enova_tenant_rejected_total counter\n");
    for t in tenants {
        let _ = writeln!(
            out,
            "enova_tenant_rejected_total{{tenant=\"{}\",tier=\"{}\"}} {}",
            escape_label(&t.id),
            t.tier.as_str(),
            t.rejected
        );
    }
    out.push_str(
        "# HELP enova_tenant_gpu_seconds_total GPU-seconds of engine time attributed to \
         each tenant's completed requests (the cost ledger).\n",
    );
    out.push_str("# TYPE enova_tenant_gpu_seconds_total counter\n");
    for t in tenants {
        let _ = writeln!(
            out,
            "enova_tenant_gpu_seconds_total{{tenant=\"{}\",tier=\"{}\"}} {}",
            escape_label(&t.id),
            t.tier.as_str(),
            t.gpu_seconds
        );
    }
    out.push_str(
        "# HELP enova_tenant_arrival_rps Trailing per-tenant arrival rate \
         (requests/second over the last few seconds).\n",
    );
    out.push_str("# TYPE enova_tenant_arrival_rps gauge\n");
    for t in tenants {
        let _ = writeln!(
            out,
            "enova_tenant_arrival_rps{{tenant=\"{}\",tier=\"{}\"}} {}",
            escape_label(&t.id),
            t.tier.as_str(),
            t.arrival_rps
        );
    }

    // Table II per replica: the last recorded frame value of each column
    for metric in COLUMNS {
        let _ = writeln!(
            out,
            "# HELP enova_replica_{metric} Table II monitoring metric `{metric}` per replica."
        );
        let _ = writeln!(out, "# TYPE enova_replica_{metric} gauge");
        for instance in store.instances(metric) {
            if let Some(v) = store.series(metric, &instance).and_then(|s| s.last()) {
                let _ = writeln!(
                    out,
                    "enova_replica_{metric}{{instance=\"{}\"}} {v}",
                    escape_label(&instance)
                );
            }
        }
    }

    // mean queue wait per replica (recorded alongside the Table II frame)
    out.push_str(
        "# HELP enova_replica_queue_wait_seconds Mean worker-queue wait per replica over \
         the last monitoring window.\n",
    );
    out.push_str("# TYPE enova_replica_queue_wait_seconds gauge\n");
    for instance in store.instances(super::QUEUE_WAIT) {
        if let Some(v) = store.series(super::QUEUE_WAIT, &instance).and_then(|s| s.last()) {
            let _ = writeln!(
                out,
                "enova_replica_queue_wait_seconds{{instance=\"{}\"}} {v}",
                escape_label(&instance)
            );
        }
    }

    // applied concurrency ceiling per replica (the live Fig. 6 knob)
    out.push_str(
        "# HELP enova_replica_max_num_seqs Applied max_num_seqs (live concurrency ceiling) \
         per replica.\n",
    );
    out.push_str("# TYPE enova_replica_max_num_seqs gauge\n");
    for instance in store.instances(super::MAX_SEQS) {
        if let Some(v) = store.series(super::MAX_SEQS, &instance).and_then(|s| s.last()) {
            let _ = writeln!(
                out,
                "enova_replica_max_num_seqs{{instance=\"{}\"}} {v}",
                escape_label(&instance)
            );
        }
    }

    // warm standbys keep reporting frames while derouted; this gauge lets
    // dashboards tell live replicas (1) from parked ones (0) so averages
    // do not silently include idle standbys
    out.push_str(
        "# HELP enova_replica_routable 1 when the replica instance is in the routable \
         (live) set, 0 for a warm standby.\n",
    );
    out.push_str("# TYPE enova_replica_routable gauge\n");
    for instance in store.instances(N_RUNNING) {
        let routable = live_instances.iter().any(|l| l == &instance);
        let _ = writeln!(
            out,
            "enova_replica_routable{{instance=\"{}\"}} {}",
            escape_label(&instance),
            routable as u8
        );
    }
    out
}

/// One parsed sample line of a text exposition.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub name: String,
    pub labels: BTreeMap<String, String>,
    pub value: f64,
}

fn valid_name(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().map(|c| c.is_ascii_alphabetic() || c == '_' || c == ':').unwrap_or(false)
        && s.chars().all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Strict-enough parser for the Prometheus text format (what our renderer
/// emits): used by tests to verify `/metrics` really is an exposition.
pub fn parse_exposition(text: &str) -> Result<Vec<Sample>, String> {
    let mut out = Vec::new();
    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let err = |what: &str| format!("line {}: {what}: {line:?}", lineno + 1);
        let (head, value_str) = line
            .rsplit_once(|c: char| c.is_ascii_whitespace())
            .ok_or_else(|| err("missing value"))?;
        let value: f64 = value_str.parse().map_err(|_| err("bad value"))?;
        let (name, labels) = match head.split_once('{') {
            None => (head.trim().to_string(), BTreeMap::new()),
            Some((n, rest)) => {
                let rest = rest.trim_end();
                let body = rest
                    .strip_suffix('}')
                    .ok_or_else(|| err("unterminated label set"))?;
                let mut labels = BTreeMap::new();
                for pair in body.split(',').filter(|p| !p.is_empty()) {
                    let (k, v) = pair.split_once('=').ok_or_else(|| err("bad label pair"))?;
                    let v = v
                        .strip_prefix('"')
                        .and_then(|v| v.strip_suffix('"'))
                        .ok_or_else(|| err("unquoted label value"))?;
                    labels.insert(k.trim().to_string(), v.replace("\\\"", "\"").replace("\\\\", "\\"));
                }
                (n.trim().to_string(), labels)
            }
        };
        if !valid_name(&name) {
            return Err(err("invalid metric name"));
        }
        out.push(Sample { name, labels, value });
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::Frame;

    #[test]
    fn render_includes_all_table2_columns_per_replica() {
        let gw = GatewayMetrics::new();
        gw.observe("/v1/completions", 200, 0.02);
        gw.observe("/v1/completions", 429, 0.0001);
        gw.add_tokens(12);
        gw.note_queue_full();

        let mut store = MetricStore::new();
        for i in 0..2 {
            Frame {
                n_finished: 1.0 + i as f64,
                ..Default::default()
            }
            .record(&mut store, &format!("replica-{i}"), 1.0);
        }
        // a warm standby also reports frames but is not in the live set
        Frame::default().record(&mut store, "replica-2", 1.0);

        gw.note_reconfigure();
        gw.observe_promotion(true, 0.001);
        gw.observe_promotion(false, 2.0);
        gw.observe_promotion_snapshot(0.03);
        gw.note_deprecated("/cluster/status");
        gw.note_deprecated("/cluster/status");
        gw.note_deprecated("/debug/traces");

        gw.observe_queue_wait(0.002);
        gw.observe_queue_wait(0.3);

        let sup = SupervisorSnapshot {
            enabled: true,
            calibrated: true,
            scale_ups: 2,
            scale_downs: 1,
            last_energy: 4.5,
            last_threshold: 3.0,
            events: 3,
            reconfigures: 1,
            last_max_num_seqs: 12,
            forecast_enabled: true,
            last_forecast: 42.5,
            forecast_error: 0.25,
            forecast_degraded: false,
            proactive_events: 2,
            reactive_events: 1,
            last_tenant_forecast: 12.0,
            trough_events: 1,
        };
        gw.add_replica_seconds(1.5);
        gw.add_replica_seconds(2.5);
        let tenants = vec![
            TenantSnapshot {
                id: "chat".to_string(),
                tier: crate::gateway::admission::SloTier::Latency,
                admitted: 7,
                rejected: 2,
                gpu_seconds: 1.25,
                arrival_rps: 3.5,
            },
            TenantSnapshot {
                id: "codegen".to_string(),
                tier: crate::gateway::admission::SloTier::Batch,
                admitted: 4,
                rejected: 0,
                gpu_seconds: 9.0,
                arrival_rps: 0.5,
            },
        ];
        let live = vec!["replica-0".to_string(), "replica-1".to_string()];
        let body = render_prometheus(&gw, &store, 3, &live, 1, 2, 12.5, &sup, &tenants);
        let samples = parse_exposition(&body).expect("valid exposition");
        for col in COLUMNS {
            for replica in ["replica-0", "replica-1"] {
                assert!(
                    samples.iter().any(|s| s.name == format!("enova_replica_{col}")
                        && s.labels.get("instance").map(String::as_str) == Some(replica)),
                    "missing {col} for {replica}"
                );
            }
        }
        let ok = samples
            .iter()
            .find(|s| {
                s.name == "enova_gateway_requests_total"
                    && s.labels.get("code").map(String::as_str) == Some("200")
            })
            .unwrap();
        assert_eq!(ok.value, 1.0);
        assert!(samples.iter().any(|s| s.name == "enova_gateway_request_seconds_count" && s.value == 2.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_gateway_request_seconds_bucket"
                && s.labels.get("le").map(String::as_str) == Some("+Inf")
                && s.value == 2.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_gateway_admission_rejected_total"
                && s.labels.get("reason").map(String::as_str) == Some("queue_full")
                && s.value == 1.0));
        assert!(samples.iter().any(|s| s.name == "enova_gateway_inflight_requests" && s.value == 3.0));
        // the ingress connection surface always renders, even before traffic
        for gauge in [
            "enova_ingress_connections_accepted_total",
            "enova_ingress_connections_open",
            "enova_ingress_handler_inflight",
            "enova_ingress_handler_threads",
            "enova_ingress_reactor_mode",
        ] {
            assert!(samples.iter().any(|s| s.name == gauge), "missing {gauge}");
        }
        assert!(samples.iter().any(|s| s.name == "enova_gateway_replicas" && s.value == 2.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_scale_events_total"
                && s.labels.get("direction").map(String::as_str) == Some("up")
                && s.value == 2.0));
        assert!(samples.iter().any(|s| s.name == "enova_supervisor_enabled" && s.value == 1.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_anomaly_energy" && s.value == 4.5));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_gateway_warm_pool_replicas" && s.value == 1.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_gateway_reconfigure_events_total" && s.value == 1.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_reconfigure_total" && s.value == 1.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_applied_max_num_seqs" && s.value == 12.0));
        // forecast gauges and the proactive/reactive origin split
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_forecast_enabled" && s.value == 1.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_forecast_rps" && s.value == 42.5));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_forecast_error" && s.value == 0.25));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_forecast_degraded" && s.value == 0.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_tenant_forecast_rps" && s.value == 12.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_trough_scale_downs_total" && s.value == 1.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_scale_origin_total"
                && s.labels.get("origin").map(String::as_str) == Some("proactive")
                && s.value == 2.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_supervisor_scale_origin_total"
                && s.labels.get("origin").map(String::as_str) == Some("reactive")
                && s.value == 1.0));
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_gateway_warm_pool_target" && s.value == 2.0));
        // the queue-wait histogram is cumulative and counts both samples
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_gateway_queue_wait_seconds_count" && s.value == 2.0));
        let qw_bucket = |le: &str| {
            samples
                .iter()
                .find(|s| s.name == "enova_gateway_queue_wait_seconds_bucket"
                    && s.labels.get("le").map(String::as_str) == Some(le))
                .unwrap()
                .value
        };
        assert_eq!(qw_bucket("0.001"), 0.0);
        assert_eq!(qw_bucket("0.0025"), 1.0);
        assert_eq!(qw_bucket("0.5"), 2.0);
        assert_eq!(qw_bucket("+Inf"), 2.0);
        // the promotion histogram carries all three kinds, and the warm
        // sample lands in a strictly lower bucket than the cold one
        for kind in ["warm", "cold", "snapshot"] {
            assert!(
                samples.iter().any(|s| {
                    s.name == "enova_gateway_promotion_seconds_count"
                        && s.labels.get("kind").map(String::as_str) == Some(kind)
                        && s.value == 1.0
                }),
                "missing promotion count for {kind}"
            );
        }
        let bucket = |kind: &str, le: &str| {
            samples
                .iter()
                .find(|s| {
                    s.name == "enova_gateway_promotion_seconds_bucket"
                        && s.labels.get("kind").map(String::as_str) == Some(kind)
                        && s.labels.get("le").map(String::as_str) == Some(le)
                })
                .unwrap()
                .value
        };
        assert_eq!(bucket("warm", "0.002"), 1.0);
        assert_eq!(bucket("cold", "0.002"), 0.0);
        assert_eq!(bucket("cold", "5"), 1.0);
        // snapshot restore sits between warm and cold, and the bucketed
        // quantile helper agrees with the rendered histogram
        assert_eq!(bucket("snapshot", "0.002"), 0.0);
        assert_eq!(bucket("snapshot", "0.05"), 1.0);
        assert_eq!(gw.promotion_quantile("snapshot", 0.95), 0.05);
        assert_eq!(gw.promotion_count("snapshot"), 1);
        assert_eq!(gw.promotion_quantile("nope", 0.95), 0.0);
        // deprecated alias hits render per path with their counts
        let dep = |path: &str| {
            samples
                .iter()
                .find(|s| s.name == "enova_api_deprecated_requests_total"
                    && s.labels.get("path").map(String::as_str) == Some(path))
                .unwrap_or_else(|| panic!("missing deprecated counter for {path}"))
                .value
        };
        assert_eq!(dep("/cluster/status"), 2.0);
        assert_eq!(dep("/debug/traces"), 1.0);
        assert_eq!(gw.deprecated_for("/cluster/status"), 2);
        assert_eq!(gw.deprecated_for("/never-hit"), 0);
        // per-tenant ledger series carry tenant+tier labels and the
        // fleet-wide replica-seconds integral sums the worker windows
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_replica_seconds_total" && (s.value - 4.0).abs() < 1e-9));
        let tenant_sample = |name: &str, tenant: &str| {
            samples
                .iter()
                .find(|s| s.name == name
                    && s.labels.get("tenant").map(String::as_str) == Some(tenant))
                .unwrap_or_else(|| panic!("missing {name} for {tenant}"))
                .clone()
        };
        let chat_req = tenant_sample("enova_tenant_requests_total", "chat");
        assert_eq!(chat_req.value, 7.0);
        assert_eq!(chat_req.labels.get("tier").map(String::as_str), Some("latency"));
        assert_eq!(tenant_sample("enova_tenant_rejected_total", "chat").value, 2.0);
        let code_cost = tenant_sample("enova_tenant_gpu_seconds_total", "codegen");
        assert_eq!(code_cost.value, 9.0);
        assert_eq!(code_cost.labels.get("tier").map(String::as_str), Some("batch"));
        assert_eq!(tenant_sample("enova_tenant_arrival_rps", "chat").value, 3.5);

        // live replicas are routable=1, the standby instance routable=0
        let routable = |instance: &str| {
            samples
                .iter()
                .find(|s| s.name == "enova_replica_routable"
                    && s.labels.get("instance").map(String::as_str) == Some(instance))
                .unwrap()
                .value
        };
        assert_eq!(routable("replica-0"), 1.0);
        assert_eq!(routable("replica-1"), 1.0);
        assert_eq!(routable("replica-2"), 0.0);
    }

    #[test]
    fn phase_histograms_render_per_phase_with_stream_timing() {
        use crate::trace::{PHASES, PHASE_ADMISSION, PHASE_DECODE, PHASE_PREFILL};
        let gw = GatewayMetrics::new();
        gw.observe_phase(PHASE_ADMISSION, 0.0002); // le=0.0005 bucket
        gw.observe_phase(PHASE_PREFILL, 0.02);
        gw.observe_phase(PHASE_DECODE, 0.2);
        gw.observe_phase("not_a_phase", 9.0); // silently ignored
        gw.observe_ttft(0.03);
        gw.observe_ttft(0.7);
        gw.observe_inter_token(0.004);

        assert_eq!(gw.phase_count(PHASE_ADMISSION), 1);
        assert_eq!(gw.phase_count("not_a_phase"), 0);

        let live: Vec<String> = Vec::new();
        let body = render_prometheus(
            &gw,
            &MetricStore::new(),
            0,
            &live,
            0,
            0,
            0.0,
            &SupervisorSnapshot::default(),
            &[],
        );
        let samples = parse_exposition(&body).expect("valid exposition");

        // every phase renders a full histogram even before any traffic
        for phase in PHASES {
            assert!(
                samples.iter().any(|s| s.name == "enova_request_phase_seconds_count"
                    && s.labels.get("phase").map(String::as_str) == Some(phase)),
                "missing phase histogram for {phase}"
            );
        }
        let bucket = |phase: &str, le: &str| {
            samples
                .iter()
                .find(|s| {
                    s.name == "enova_request_phase_seconds_bucket"
                        && s.labels.get("phase").map(String::as_str) == Some(phase)
                        && s.labels.get("le").map(String::as_str) == Some(le)
                })
                .unwrap()
                .value
        };
        assert_eq!(bucket(PHASE_ADMISSION, "0.0001"), 0.0);
        assert_eq!(bucket(PHASE_ADMISSION, "0.0005"), 1.0);
        assert_eq!(bucket(PHASE_ADMISSION, "+Inf"), 1.0);
        assert_eq!(bucket(PHASE_DECODE, "0.1"), 0.0);
        assert_eq!(bucket(PHASE_DECODE, "0.25"), 1.0);
        assert_eq!(bucket("sse", "+Inf"), 0.0);

        // TTFT and inter-token histograms
        let named = |name: &str, le: &str| {
            samples
                .iter()
                .find(|s| s.name == name && s.labels.get("le").map(String::as_str) == Some(le))
                .unwrap()
                .value
        };
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_gateway_ttft_seconds_count" && s.value == 2.0));
        assert_eq!(named("enova_gateway_ttft_seconds_bucket", "0.05"), 1.0);
        assert_eq!(named("enova_gateway_ttft_seconds_bucket", "+Inf"), 2.0);
        assert!(samples
            .iter()
            .any(|s| s.name == "enova_gateway_inter_token_seconds_count" && s.value == 1.0));
        assert_eq!(named("enova_gateway_inter_token_seconds_bucket", "0.005"), 1.0);
    }

    /// Regression for the instrumented request path: recording lifecycle
    /// phases, TTFT and inter-token gaps must never bump the request
    /// counters — one finished exchange is exactly one `observe`, no
    /// matter how many trace spans it left behind.
    #[test]
    fn phase_observations_do_not_double_count_requests() {
        use crate::trace::PHASES;
        let gw = GatewayMetrics::new();
        for phase in PHASES {
            gw.observe_phase(phase, 0.01);
        }
        gw.observe_ttft(0.02);
        gw.observe_inter_token(0.002);
        gw.observe_queue_wait(0.003);
        assert_eq!(gw.requests_total(), 0, "tracing alone moved no request counter");
        assert_eq!(gw.latency_count.load(Ordering::Relaxed), 0);

        // the one exchange lands exactly once, and re-observing a phase
        // moves only that phase's histogram
        gw.observe("/v1/completions", 200, 0.05);
        assert_eq!(gw.requests_total(), 1);
        let before = gw.phase_count("decode");
        gw.observe_phase("decode", 0.01);
        assert_eq!(gw.phase_count("decode"), before + 1);
        assert_eq!(gw.requests_total(), 1, "request counter stayed put");
    }

    #[test]
    fn histogram_buckets_are_cumulative() {
        let gw = GatewayMetrics::new();
        gw.observe("/x", 200, 0.002); // lands in le=0.0025 and wider
        gw.observe("/x", 200, 0.3); // lands in le=1.0 and wider
        let live = vec!["replica-0".to_string()];
        let body = render_prometheus(
            &gw,
            &MetricStore::new(),
            0,
            &live,
            0,
            0,
            0.0,
            &SupervisorSnapshot::default(),
            &[],
        );
        let samples = parse_exposition(&body).unwrap();
        let bucket = |le: &str| {
            samples
                .iter()
                .find(|s| s.name == "enova_gateway_request_seconds_bucket"
                    && s.labels.get("le").map(String::as_str) == Some(le))
                .unwrap()
                .value
        };
        assert_eq!(bucket("0.001"), 0.0);
        assert_eq!(bucket("0.0025"), 1.0);
        assert_eq!(bucket("0.25"), 1.0);
        assert_eq!(bucket("1"), 2.0);
        assert_eq!(bucket("+Inf"), 2.0);
    }

    #[test]
    fn queue_wait_quantile_estimates_from_buckets() {
        let gw = GatewayMetrics::new();
        assert_eq!(gw.queue_wait_quantile(0.95), 0.0, "no observations yet");
        for _ in 0..95 {
            gw.observe_queue_wait(0.003); // le=0.005 bucket
        }
        for _ in 0..5 {
            gw.observe_queue_wait(0.8); // le=1.0 bucket
        }
        assert_eq!(gw.queue_wait_quantile(0.5), 0.005);
        assert_eq!(gw.queue_wait_quantile(0.95), 0.005);
        assert_eq!(gw.queue_wait_quantile(1.0), 1.0);
        // past the largest bound the estimate is +inf, never a lie
        let gw = GatewayMetrics::new();
        gw.observe_queue_wait(30.0);
        assert!(gw.queue_wait_quantile(0.95).is_infinite());
    }

    #[test]
    fn parser_rejects_garbage() {
        assert!(parse_exposition("metric_no_value\n").is_err());
        assert!(parse_exposition("1metric 2\n").is_err());
        assert!(parse_exposition("m{a=b} 2\n").is_err());
        assert!(parse_exposition("m{a=\"b\" 2\n").is_err());
        assert!(parse_exposition("m abc\n").is_err());
        assert!(parse_exposition("# just a comment\n\n").unwrap().is_empty());
    }
}
