//! The network-facing serving surface (the "API Gateway + LLM Load
//! Balancer" layers of Table I): a dependency-free threaded HTTP/1.1
//! server exposing OpenAI-compatible endpoints over N in-process engine
//! replicas.
//!
//! * `POST /v1/completions`, `POST /v1/chat/completions` — JSON in, JSON
//!   out; `"stream": true` is served token-by-token as SSE from the
//!   engines' step-wise API ([`crate::engine::StreamEngine`]).
//! * `GET /metrics` — Prometheus text exposition: gateway counters and
//!   latency histograms plus the Table II frame of every replica.
//! * `GET /healthz`, `GET /ready` — liveness / replica readiness.
//! * `POST /admin/scale` — apply a new replica weight set through the
//!   [`WeightedRouter`] (the autoscaler's ingress-update path, §IV-A-4).
//!
//! Requests pass admission control first (token-bucket rate limiter +
//! bounded in-flight gate → fast 429s under overload), then dispatch via
//! weighted least-loaded routing to a replica worker thread that drives
//! its engine's continuous-batching loop and streams deltas back over a
//! channel.

pub mod admission;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod openai;
pub mod sse;

use crate::engine::{Completion, FinishReason, StreamEngine};
use crate::router::{ReplicaHandle, WeightedRouter};
use crate::tsdb::MetricStore;
use crate::util::json::Json;
use admission::{AdmissionGate, AdmissionPermit, TokenBucket};
use anyhow::{anyhow, Result};
use metrics::GatewayMetrics;
use std::collections::{BTreeMap, HashMap};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Constructs one replica's engine *inside* its worker thread, so engines
/// themselves never cross thread boundaries (PJRT handles are not
/// guaranteed `Send`).
pub type EngineFactory = Box<dyn FnOnce() -> Result<Box<dyn StreamEngine>> + Send + 'static>;

#[derive(Debug, Clone)]
pub struct GatewayConfig {
    pub host: String,
    /// 0 = ephemeral (tests)
    pub port: u16,
    /// default completion budget when the request omits `max_tokens`
    pub max_tokens_default: usize,
    /// admission bound on queued + running requests (429 beyond)
    pub max_pending: usize,
    /// token-bucket refill, requests/second; 0 disables rate limiting
    pub rate_limit: f64,
    pub rate_burst: usize,
    /// HTTP worker threads == max concurrently served connections
    pub http_workers: usize,
    pub max_body_bytes: usize,
    /// cadence of Table II frame recording per replica
    pub monitor_interval: Duration,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            host: "127.0.0.1".into(),
            port: 0,
            max_tokens_default: 64,
            max_pending: 256,
            rate_limit: 0.0,
            rate_burst: 64,
            http_workers: 64,
            max_body_bytes: 1024 * 1024,
            monitor_interval: Duration::from_millis(50),
        }
    }
}

/// What a replica worker sends back to the HTTP handler, per request.
enum StreamItem {
    Delta {
        text: String,
        finish: Option<FinishReason>,
    },
    Done(Completion),
    Error(String),
}

/// One admitted request, queued to a replica worker. The job owns its
/// admission permit and router handle: capacity and routing counts are
/// released when the *engine* finishes the request (see
/// [`Job::release`]), not when the HTTP handler responds — a request the
/// handler gave up on (timeout, client disconnect) still occupies engine
/// queue/slots until it completes.
struct Job {
    prompt: String,
    max_new: usize,
    stream: bool,
    tx: Sender<StreamItem>,
    permit: AdmissionPermit,
    handle: Arc<ReplicaHandle>,
}

impl Job {
    /// Release routing + admission accounting (the permit drops with self).
    fn release(self) -> Sender<StreamItem> {
        self.handle.complete();
        drop(self.permit);
        self.tx
    }
}

struct GatewayState {
    cfg: GatewayConfig,
    router: RwLock<WeightedRouter>,
    /// replica id -> job queue into that replica's worker thread
    replicas: BTreeMap<u64, Mutex<Sender<Job>>>,
    gate: Arc<AdmissionGate>,
    bucket: Option<Mutex<TokenBucket>>,
    metrics: GatewayMetrics,
    store: Mutex<MetricStore>,
    started: Instant,
    ready_replicas: AtomicUsize,
    next_req_id: AtomicU64,
    stop: AtomicBool,
}

/// Handle to a running gateway. [`Gateway::shutdown`] stops and joins all
/// threads; dropping without shutdown leaves daemon threads running (the
/// CLI path, where the process exit reaps them).
pub struct Gateway {
    pub addr: SocketAddr,
    state: Arc<GatewayState>,
    threads: Vec<JoinHandle<()>>,
}

impl Gateway {
    /// Bind, spawn one worker thread per engine factory plus the HTTP
    /// accept/worker pool, and wait until every replica engine is built.
    pub fn start(cfg: GatewayConfig, factories: Vec<EngineFactory>) -> Result<Gateway> {
        if factories.is_empty() {
            return Err(anyhow!("gateway needs at least one engine replica"));
        }
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let n = factories.len();
        let mut replicas = BTreeMap::new();
        let mut job_rxs = Vec::new();
        for id in 0..n as u64 {
            let (tx, rx) = mpsc::channel::<Job>();
            replicas.insert(id, Mutex::new(tx));
            job_rxs.push(rx);
        }
        let weights: Vec<(u64, f64)> = (0..n as u64).map(|id| (id, 1.0)).collect();

        let state = Arc::new(GatewayState {
            router: RwLock::new(WeightedRouter::new(&weights)),
            replicas,
            gate: AdmissionGate::new(cfg.max_pending),
            bucket: (cfg.rate_limit > 0.0)
                .then(|| Mutex::new(TokenBucket::new(cfg.rate_limit, cfg.rate_burst))),
            metrics: GatewayMetrics::new(),
            store: Mutex::new({
                // /metrics only reads the newest point per series; a small
                // history bound keeps a long-running gateway's RSS flat
                let mut store = MetricStore::new();
                store.retention = 4096;
                store
            }),
            started: Instant::now(),
            ready_replicas: AtomicUsize::new(0),
            next_req_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            cfg,
        });

        let mut threads = Vec::new();
        let (init_tx, init_rx) = mpsc::channel::<std::result::Result<u64, String>>();
        for (id, (factory, rx)) in factories.into_iter().zip(job_rxs).enumerate() {
            let state = Arc::clone(&state);
            let init_tx = init_tx.clone();
            threads.push(std::thread::spawn(move || {
                let engine = match factory() {
                    Ok(e) => e,
                    Err(e) => {
                        let _ = init_tx.send(Err(format!("replica {id}: {e}")));
                        return;
                    }
                };
                // initial frame before declaring ready, so /metrics exposes
                // every replica deterministically once start() returns
                record_frame(engine.as_ref(), &state, &format!("replica-{id}"), 0.0, 0.0, 0.0);
                state.ready_replicas.fetch_add(1, Ordering::Release);
                let _ = init_tx.send(Ok(id as u64));
                replica_loop(id as u64, engine, rx, &state);
            }));
        }
        drop(init_tx);
        for _ in 0..n {
            match init_rx.recv_timeout(Duration::from_secs(300)) {
                Ok(Ok(_)) => {}
                Ok(Err(e)) => {
                    state.stop.store(true, Ordering::Release);
                    return Err(anyhow!("engine init failed: {e}"));
                }
                Err(_) => {
                    state.stop.store(true, Ordering::Release);
                    return Err(anyhow!("engine init timed out"));
                }
            }
        }

        // connection fan-out: accept thread -> worker pool
        let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
        let conn_rx = Arc::new(Mutex::new(conn_rx));
        {
            let state = Arc::clone(&state);
            threads.push(std::thread::spawn(move || {
                accept_loop(listener, conn_tx, &state);
            }));
        }
        for _ in 0..state.cfg.http_workers.max(1) {
            let state = Arc::clone(&state);
            let conn_rx = Arc::clone(&conn_rx);
            threads.push(std::thread::spawn(move || loop {
                if state.stop.load(Ordering::Acquire) {
                    break;
                }
                let next = conn_rx
                    .lock()
                    .unwrap()
                    .recv_timeout(Duration::from_millis(100));
                match next {
                    Ok(stream) => handle_connection(stream, &state),
                    Err(RecvTimeoutError::Timeout) => continue,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }));
        }

        crate::info!(
            "gateway",
            "listening on http://{addr} with {n} replica(s), {} http workers",
            state.cfg.http_workers
        );
        Ok(Gateway {
            addr,
            state,
            threads,
        })
    }

    pub fn addr_string(&self) -> String {
        self.addr.to_string()
    }

    /// Replicas that finished constructing their engine.
    pub fn ready_replicas(&self) -> usize {
        self.state.ready_replicas.load(Ordering::Acquire)
    }

    /// Stop accepting, drain workers, join all threads.
    pub fn shutdown(self) {
        self.state.stop.store(true, Ordering::Release);
        for t in self.threads {
            let _ = t.join();
        }
    }

    /// Block forever serving (CLI path).
    pub fn serve_forever(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }
}

fn accept_loop(listener: TcpListener, conn_tx: Sender<TcpStream>, state: &GatewayState) {
    loop {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                // short read timeout doubles as the idle keep-alive
                // deadline: a worker parked in read_request re-checks the
                // stop flag within this bound, so shutdown stays prompt
                let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
                let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
                if conn_tx.send(stream).is_err() {
                    break;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Per-replica accounting for the current Table II monitoring window.
struct FrameWindow {
    finished: u64,
    arrived: u64,
    latency_sum: f64,
    latency_n: u64,
    last: Instant,
}

impl FrameWindow {
    fn new() -> FrameWindow {
        FrameWindow {
            finished: 0,
            arrived: 0,
            latency_sum: 0.0,
            latency_n: 0,
            last: Instant::now(),
        }
    }

    /// Record a frame and reset the window once the monitor interval has
    /// elapsed. Counts are normalized by the actual window length: Table II
    /// defines n^f/n^a as rates per unit time, and windows here vary with
    /// engine step duration.
    fn maybe_flush(&mut self, engine: &dyn StreamEngine, state: &GatewayState, instance: &str) {
        let elapsed = self.last.elapsed();
        if elapsed < state.cfg.monitor_interval {
            return;
        }
        let secs = elapsed.as_secs_f64().max(1e-9);
        let mean = if self.latency_n > 0 {
            self.latency_sum / self.latency_n as f64
        } else {
            0.0
        };
        record_frame(
            engine,
            state,
            instance,
            self.finished as f64 / secs,
            self.arrived as f64 / secs,
            mean,
        );
        *self = FrameWindow::new();
    }
}

/// Drive one replica's engine: admit queued jobs, step, fan deltas and
/// completions back out, and record Table II frames into the shared store.
fn replica_loop(
    id: u64,
    mut engine: Box<dyn StreamEngine>,
    rx: Receiver<Job>,
    state: &GatewayState,
) {
    let instance = format!("replica-{id}");
    let mut jobs: HashMap<u64, Job> = HashMap::new();
    let mut window = FrameWindow::new();

    loop {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        // block while idle; drain opportunistically while busy
        if engine.idle() && jobs.is_empty() {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(job) => {
                    admit(engine.as_mut(), &mut jobs, job);
                    window.arrived += 1;
                }
                Err(RecvTimeoutError::Timeout) => {
                    window.maybe_flush(engine.as_ref(), state, &instance);
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        while let Ok(job) = rx.try_recv() {
            admit(engine.as_mut(), &mut jobs, job);
            window.arrived += 1;
        }

        match engine.step_stream() {
            Ok(out) => {
                for d in out.deltas {
                    if let Some(job) = jobs.get(&d.id) {
                        if job.stream {
                            let _ = job.tx.send(StreamItem::Delta {
                                text: d.text,
                                finish: d.finish,
                            });
                        }
                    }
                }
                for c in out.finished {
                    window.finished += 1;
                    window.latency_sum += (c.finished_at - c.arrival).max(0.0);
                    window.latency_n += 1;
                    if let Some(job) = jobs.remove(&c.id) {
                        let tx = job.release();
                        let _ = tx.send(StreamItem::Done(c));
                    }
                }
            }
            Err(e) => {
                crate::error!("gateway", "replica {id} engine step failed: {e}");
                for (_, job) in jobs.drain() {
                    let tx = job.release();
                    let _ = tx.send(StreamItem::Error(format!("engine failure: {e}")));
                }
                // a persistently broken engine keeps its slots occupied
                // (never idle), so back off instead of hot-spinning
                std::thread::sleep(Duration::from_millis(50));
            }
        }

        window.maybe_flush(engine.as_ref(), state, &instance);
    }
}

fn admit(engine: &mut dyn StreamEngine, jobs: &mut HashMap<u64, Job>, job: Job) {
    let id = engine.submit(&job.prompt, job.max_new);
    jobs.insert(id, job);
}

fn record_frame(
    engine: &dyn StreamEngine,
    state: &GatewayState,
    instance: &str,
    finished: f64,
    arrived: f64,
    mean_latency: f64,
) {
    let frame = engine.frame(finished, arrived, mean_latency);
    let t = state.started.elapsed().as_secs_f64();
    frame.record(&mut state.store.lock().unwrap(), instance, t);
}

fn handle_connection(mut stream: TcpStream, state: &GatewayState) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    loop {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        let req = match http::read_request(&mut reader, state.cfg.max_body_bytes) {
            Ok(Some(r)) => r,
            Ok(None) => break,
            Err(e) => {
                let body = openai::to_wire(&openai::error_body("invalid_request_error", &e.message));
                let _ = http::Response::json(e.status, body).write_to(&mut stream, false);
                break;
            }
        };
        let keep_alive = req.keep_alive();
        if route(&req, &mut stream, state).is_err() {
            break; // client went away mid-response
        }
        if !keep_alive {
            break;
        }
    }
}

fn route(req: &http::Request, stream: &mut TcpStream, state: &GatewayState) -> std::io::Result<()> {
    let t0 = Instant::now();
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/completions") => serve_completion(req, stream, state, false, t0),
        ("POST", "/v1/chat/completions") => serve_completion(req, stream, state, true, t0),
        ("GET", "/metrics") => {
            let body = {
                let store = state.store.lock().unwrap();
                metrics::render_prometheus(
                    &state.metrics,
                    &store,
                    state.gate.inflight(),
                    state.started.elapsed().as_secs_f64(),
                )
            };
            finish(req, stream, state, "/metrics", t0, http::Response::prometheus(body))
        }
        ("GET", "/healthz") => {
            let body = format!(
                "{{\"status\":\"ok\",\"uptime_seconds\":{:.3},\"replicas\":{}}}",
                state.started.elapsed().as_secs_f64(),
                state.replicas.len()
            );
            finish(req, stream, state, "/healthz", t0, http::Response::json(200, body))
        }
        ("GET", "/ready") => {
            let ready = state.ready_replicas.load(Ordering::Acquire) == state.replicas.len();
            let status = if ready { 200 } else { 503 };
            let body = format!(
                "{{\"ready\":{ready},\"replicas_ready\":{},\"replicas\":{}}}",
                state.ready_replicas.load(Ordering::Acquire),
                state.replicas.len()
            );
            finish(req, stream, state, "/ready", t0, http::Response::json(status, body))
        }
        ("POST", "/admin/scale") => admin_scale(req, stream, state, t0),
        (_, "/v1/completions" | "/v1/chat/completions" | "/admin/scale" | "/metrics" | "/healthz"
        | "/ready") => {
            let body = openai::to_wire(&openai::error_body(
                "invalid_request_error",
                &format!("method {} not allowed on {}", req.method, req.path),
            ));
            finish(req, stream, state, "other", t0, http::Response::json(405, body))
        }
        _ => {
            let body = openai::to_wire(&openai::error_body(
                "invalid_request_error",
                &format!("unknown path {}", req.path),
            ));
            finish(req, stream, state, "other", t0, http::Response::json(404, body))
        }
    }
}

/// Write the response and record request metrics.
fn finish(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    endpoint: &str,
    t0: Instant,
    resp: http::Response,
) -> std::io::Result<()> {
    state
        .metrics
        .observe(endpoint, resp.status, t0.elapsed().as_secs_f64());
    resp.write_to(stream, req.keep_alive())
}

fn serve_completion(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    chat: bool,
    t0: Instant,
) -> std::io::Result<()> {
    let endpoint = if chat {
        "/v1/chat/completions"
    } else {
        "/v1/completions"
    };
    let bad = |msg: &str| {
        http::Response::json(
            400,
            openai::to_wire(&openai::error_body("invalid_request_error", msg)),
        )
    };

    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return finish(req, stream, state, endpoint, t0, bad(&e.message)),
    };
    let json = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return finish(req, stream, state, endpoint, t0, bad(&format!("invalid JSON: {e}")))
        }
    };
    let params = match if chat {
        openai::parse_chat(&json, state.cfg.max_tokens_default)
    } else {
        openai::parse_completion(&json, state.cfg.max_tokens_default)
    } {
        Ok(p) => p,
        Err(e) => return finish(req, stream, state, endpoint, t0, bad(&e)),
    };

    // admission control: rate limiter, then the bounded in-flight gate
    if let Some(bucket) = &state.bucket {
        if !bucket.lock().unwrap().try_take() {
            state.metrics.note_rate_limited();
            let resp = http::Response::json(
                429,
                openai::to_wire(&openai::error_body(
                    "rate_limit_exceeded",
                    "request rate over the configured limit; retry later",
                )),
            )
            .with_header("Retry-After", "1");
            return finish(req, stream, state, endpoint, t0, resp);
        }
    }
    let Some(permit) = AdmissionGate::try_acquire(&state.gate) else {
        state.metrics.note_queue_full();
        let resp = http::Response::json(
            429,
            openai::to_wire(&openai::error_body(
                "server_overloaded",
                &format!(
                    "admission queue full ({} in flight); retry later",
                    state.gate.capacity()
                ),
            )),
        )
        .with_header("Retry-After", "1");
        return finish(req, stream, state, endpoint, t0, resp);
    };

    let Some(handle) = state.router.read().unwrap().dispatch() else {
        drop(permit);
        let resp = http::Response::json(
            503,
            openai::to_wire(&openai::error_body("service_unavailable", "no replicas routable")),
        );
        return finish(req, stream, state, endpoint, t0, resp);
    };

    let (tx, rx) = mpsc::channel::<StreamItem>();
    let job = Job {
        prompt: params.prompt.clone(),
        max_new: params.max_tokens,
        stream: params.stream,
        tx,
        permit,
        handle: Arc::clone(&handle),
    };
    let sent = {
        let sender = state.replicas[&handle.id].lock().unwrap().clone();
        sender.send(job)
    };
    if let Err(mpsc::SendError(job)) = sent {
        drop(job.release()); // never reached the engine: undo accounting
        // deroute the dead replica: least-loaded dispatch would otherwise
        // keep preferring it (inflight pinned at 0) and black-hole traffic
        {
            let mut router = state.router.write().unwrap();
            let weights: Vec<(u64, f64)> = router
                .replicas()
                .iter()
                .filter(|r| r.id != handle.id)
                .map(|r| (r.id, r.weight()))
                .collect();
            router.set_weights(&weights);
        }
        crate::error!(
            "gateway",
            "replica {} worker is down; removed from routing",
            handle.id
        );
        let resp = http::Response::json(
            503,
            openai::to_wire(&openai::error_body("service_unavailable", "replica worker down")),
        );
        return finish(req, stream, state, endpoint, t0, resp);
    }

    let seq = state.next_req_id.fetch_add(1, Ordering::Relaxed);
    let req_id = if chat {
        format!("chatcmpl-{seq}")
    } else {
        format!("cmpl-{seq}")
    };

    // admission + routing accounting is released by the replica worker
    // when the engine finishes this job, not here: responding early (504,
    // client gone) must not free capacity the engine is still using
    if params.stream {
        stream_response(req, stream, state, &params, &req_id, &rx, chat, endpoint, t0)
    } else {
        unary_response(req, stream, state, &params, &req_id, &rx, chat, endpoint, t0)
    }
}

/// How long a handler waits for its engine to produce a completion.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(120);

/// Wait for the next engine item, polling in short slices so
/// [`Gateway::shutdown`] is never blocked for the full request timeout.
/// `None` means timed out, gateway stopping, or replica worker gone.
fn next_item(
    rx: &Receiver<StreamItem>,
    state: &GatewayState,
    deadline: Instant,
) -> Option<StreamItem> {
    loop {
        if state.stop.load(Ordering::Acquire) || Instant::now() >= deadline {
            return None;
        }
        match rx.recv_timeout(Duration::from_millis(250)) {
            Ok(item) => return Some(item),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return None,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn unary_response(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    params: &openai::CompletionParams,
    req_id: &str,
    rx: &Receiver<StreamItem>,
    chat: bool,
    endpoint: &str,
    t0: Instant,
) -> std::io::Result<()> {
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    loop {
        match next_item(rx, state, deadline) {
            Some(StreamItem::Delta { .. }) => continue,
            Some(StreamItem::Done(c)) => {
                state.metrics.add_tokens(c.tokens.len());
                let body = if chat {
                    openai::chat_body(
                        req_id,
                        &params.model,
                        &c.text,
                        c.finish_reason,
                        c.prompt_tokens,
                        c.tokens.len(),
                    )
                } else {
                    openai::completion_body(
                        req_id,
                        &params.model,
                        &c.text,
                        c.finish_reason,
                        c.prompt_tokens,
                        c.tokens.len(),
                    )
                };
                let resp = http::Response::json(200, openai::to_wire(&body));
                return finish(req, stream, state, endpoint, t0, resp);
            }
            Some(StreamItem::Error(msg)) => {
                let resp = http::Response::json(
                    500,
                    openai::to_wire(&openai::error_body("internal_error", &msg)),
                );
                return finish(req, stream, state, endpoint, t0, resp);
            }
            None => {
                let resp = http::Response::json(
                    504,
                    openai::to_wire(&openai::error_body(
                        "timeout",
                        "engine did not produce a completion in time",
                    )),
                );
                return finish(req, stream, state, endpoint, t0, resp);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn stream_response(
    _req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    params: &openai::CompletionParams,
    req_id: &str,
    rx: &Receiver<StreamItem>,
    chat: bool,
    endpoint: &str,
    t0: Instant,
) -> std::io::Result<()> {
    sse::write_sse_head(stream)?;
    let mut writer = sse::SseWriter::new(stream);
    let mut write_failed: Option<std::io::Error> = None;

    if chat {
        let chunk = openai::chat_role_chunk(req_id, &params.model);
        if let Err(e) = writer.event(&openai::to_wire(&chunk)) {
            write_failed = Some(e);
        }
    }

    // the wire status is already 200 (SSE head is out); this tracks the
    // *outcome* for metrics so incidents are visible on the scrape
    let mut outcome_status = 200u16;
    let deadline = Instant::now() + REQUEST_TIMEOUT;
    loop {
        match next_item(rx, state, deadline) {
            Some(StreamItem::Delta { text, finish }) => {
                if write_failed.is_none() {
                    let chunk = openai::stream_chunk(req_id, &params.model, &text, finish, chat);
                    if let Err(e) = writer.event(&openai::to_wire(&chunk)) {
                        write_failed = Some(e);
                    }
                }
            }
            Some(StreamItem::Done(c)) => {
                state.metrics.add_tokens(c.tokens.len());
                break;
            }
            Some(StreamItem::Error(msg)) => {
                outcome_status = 500;
                if write_failed.is_none() {
                    let chunk = openai::error_body("internal_error", &msg);
                    let _ = writer.event(&openai::to_wire(&chunk));
                }
                break;
            }
            None => {
                outcome_status = 504; // engine stalled or gateway stopping
                break;
            }
        }
    }

    // only a cleanly finished stream earns the `[DONE]` success marker; an
    // errored/stalled stream ends with the bare chunked terminator so
    // clients can tell truncation from completion
    let io_result = if write_failed.is_none() && outcome_status == 200 {
        writer.done()
    } else {
        writer.finish()
    };
    state.metrics.add_sse_events(writer.events_written);
    state
        .metrics
        .observe(endpoint, outcome_status, t0.elapsed().as_secs_f64());
    match write_failed {
        Some(e) => Err(e),
        None => io_result,
    }
}

fn admin_scale(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    t0: Instant,
) -> std::io::Result<()> {
    let bad = |msg: &str| {
        http::Response::json(
            400,
            openai::to_wire(&openai::error_body("invalid_request_error", msg)),
        )
    };
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return finish(req, stream, state, "/admin/scale", t0, bad(&e.message)),
    };
    let json = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return finish(req, stream, state, "/admin/scale", t0, bad(&format!("invalid JSON: {e}")))
        }
    };
    let Some(entries) = json.get("replicas").and_then(Json::as_arr) else {
        return finish(
            req,
            stream,
            state,
            "/admin/scale",
            t0,
            bad("body must be {\"replicas\": [{\"id\": N, \"weight\": W}, ...]}"),
        );
    };
    if entries.is_empty() {
        return finish(req, stream, state, "/admin/scale", t0, bad("replica set must not be empty"));
    }
    let mut weights: Vec<(u64, f64)> = Vec::with_capacity(entries.len());
    for e in entries {
        let id = match e.get("id").and_then(Json::as_f64) {
            Some(x) if x >= 0.0 && x.fract() == 0.0 => x as u64,
            _ => {
                return finish(
                    req,
                    stream,
                    state,
                    "/admin/scale",
                    t0,
                    bad("each replica needs a non-negative integer \"id\""),
                )
            }
        };
        let weight = match e.get("weight").and_then(Json::as_f64) {
            Some(w) if w > 0.0 => w,
            _ => return finish(req, stream, state, "/admin/scale", t0, bad("each replica needs a positive \"weight\"")),
        };
        if !state.replicas.contains_key(&id) {
            let known: Vec<u64> = state.replicas.keys().copied().collect();
            return finish(
                req,
                stream,
                state,
                "/admin/scale",
                t0,
                bad(&format!("unknown replica id {id}; live replicas are {known:?}")),
            );
        }
        if weights.iter().any(|&(seen, _)| seen == id) {
            return finish(req, stream, state, "/admin/scale", t0, bad(&format!("duplicate replica id {id}")));
        }
        weights.push((id, weight));
    }
    state.router.write().unwrap().set_weights(&weights);
    crate::info!("gateway", "ingress update applied: {weights:?}");
    let applied: Vec<String> = weights
        .iter()
        .map(|(id, w)| format!("{{\"id\":{id},\"weight\":{w}}}"))
        .collect();
    let body = format!(
        "{{\"applied\":[{}],\"routable_replicas\":{}}}",
        applied.join(","),
        weights.len()
    );
    finish(req, stream, state, "/admin/scale", t0, http::Response::json(200, body))
}
