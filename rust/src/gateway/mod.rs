//! The network-facing serving surface (the "API Gateway + LLM Load
//! Balancer" layers of Table I): a dependency-free threaded HTTP/1.1
//! server exposing OpenAI-compatible endpoints over N in-process engine
//! replicas.
//!
//! * `POST /v1/completions`, `POST /v1/chat/completions` — JSON in, JSON
//!   out; `"stream": true` is served token-by-token as SSE from the
//!   engines' step-wise API ([`crate::engine::StreamEngine`]).
//! * `GET /metrics` — Prometheus text exposition: gateway counters and
//!   latency histograms plus the Table II frame of every replica.
//! * `GET /healthz`, `GET /ready` — liveness / replica readiness.
//! * `POST /admin/scale` — apply a new replica weight set through the
//!   [`WeightedRouter`] (the autoscaler's ingress-update path, §IV-A-4).
//!
//! Replicas are a *lifecycle-managed* set, not a boxed-at-startup array:
//! workers are hot-spawned from an [`EngineSpawner`] and retired with a
//! drain-then-join protocol (in-flight requests finish; queued jobs are
//! handed to the engine before the worker exits). The closed-loop
//! autoscaling supervisor ([`supervisor`]) drives that lifecycle from the
//! detector (§IV-B): monitor → detect → act, inside the serving process.
//!
//! Requests pass admission control first (token-bucket rate limiter +
//! bounded in-flight gate → fast 429s under overload), then dispatch via
//! weighted least-loaded routing to a replica worker thread. Each worker
//! holds admitted jobs in a bounded-wait queue — jobs that overshoot the
//! queue-time budget or their deadline are shed with a 503 instead of
//! occupying engine slots — and promotes them into free engine capacity,
//! so Table II's n^p reflects real queue pressure the supervisor can act
//! on.

pub mod admission;
pub mod http;
pub mod loadgen;
pub mod metrics;
pub mod openai;
pub mod reactor;
pub mod sse;
pub mod supervisor;

use crate::engine::{Completion, FinishReason, StreamEngine};
use crate::router::{ReplicaHandle, WeightedRouter};
use crate::trace::{
    ActiveTrace, DecisionRecorder, TraceContext, TraceRecorder, TraceSettings, PHASE_ADMISSION,
    PHASE_DECODE, PHASE_DISPATCH, PHASE_PREFILL, PHASE_QUEUE_WAIT, PHASE_SSE,
};
use crate::tsdb::MetricStore;
use crate::util::json::Json;
use admission::{
    AdmissionGate, AdmissionPermit, SloTier, TenantRegistry, TenantSnapshot, TenantSpec,
    TenantState, TokenBucket,
};
use anyhow::{anyhow, Result};
use metrics::GatewayMetrics;
use std::collections::{BTreeMap, HashMap, VecDeque};
use std::io::BufReader;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

/// Constructs one replica's engine *inside* its worker thread, so engines
/// themselves never cross thread boundaries (PJRT handles are not
/// guaranteed `Send`).
pub type EngineFactory = Box<dyn FnOnce() -> Result<Box<dyn StreamEngine>> + Send + 'static>;

/// Reusable engine constructor for the replica lifecycle manager: unlike
/// the one-shot [`EngineFactory`], a spawner can build engines for
/// replicas that do not exist yet (hot-add by the supervisor or
/// [`Gateway::add_replica`]).
pub type EngineSpawner = Arc<dyn Fn(u64) -> Result<Box<dyn StreamEngine>> + Send + Sync + 'static>;

/// Series name for the per-replica mean queue wait recorded next to the
/// Table II frame columns.
pub(crate) const QUEUE_WAIT: &str = "queue_wait";

/// Series name for the per-replica applied `max_num_seqs` (the engine's
/// live concurrency ceiling), recorded alongside the Table II frame so
/// reconfigurations are visible on `/metrics`.
pub(crate) const MAX_SEQS: &str = "max_num_seqs";

/// How long a spawning replica may take to construct its engine.
const ENGINE_INIT_TIMEOUT: Duration = Duration::from_secs(300);

/// Consecutive spawn failures after which the warm-pool filler gives up
/// (until the next scale event re-triggers it).
const WARM_FILL_MAX_FAILURES: u32 = 5;

/// How long a snapshot capture may wait for the replica worker to answer
/// its mailbox (the worker services it between engine steps, so this only
/// trips when the worker is wedged).
const SNAPSHOT_REPLY_TIMEOUT: Duration = Duration::from_secs(5);

/// The deprecated pre-/v1 alias paths still served (behind
/// [`GatewayConfig::legacy_api`]) for one release. Every response on these
/// paths carries `Deprecation`/`Sunset` headers and bumps
/// `enova_api_deprecated_requests_total{path}`.
const LEGACY_PATHS: [&str; 6] = [
    "/admin/scale",
    "/cluster/status",
    "/cluster/scale-up",
    "/cluster/scale-down",
    "/debug/traces",
    "/debug/decisions",
];

/// `Sunset` header value announced on every deprecated alias response —
/// the date the pre-/v1 paths stop being served.
pub const LEGACY_SUNSET: &str = "Thu, 31 Dec 2026 00:00:00 GMT";

/// How many capture/restore [`crate::cluster::proto::SnapshotInfo`]
/// records the gateway keeps for `GET /v1/admin/snapshots`.
const SNAPSHOT_LEDGER_CAP: usize = 16;

/// Reply channel a snapshot capture parks in a replica's mailbox; the
/// worker answers with the checkpoint (or why it could not make one).
type SnapshotReply =
    Sender<std::result::Result<crate::cluster::snapshot::EngineSnapshot, String>>;

/// How the serving surface accepts and parses connections.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum IngressMode {
    /// sharded nonblocking reactor (default): a connection costs an fd
    /// and a parse state machine; handler threads are occupied only
    /// while a request is actually being served
    Reactor,
    /// legacy thread-per-connection worker pool, kept for same-run A/B
    /// benchmarking (`bench-gateway` emits both rows) and as a fallback
    Threaded,
}

impl IngressMode {
    /// CLI spelling (`--ingress reactor|threaded`).
    pub fn parse(s: &str) -> Option<IngressMode> {
        match s {
            "reactor" => Some(IngressMode::Reactor),
            "threaded" => Some(IngressMode::Threaded),
            _ => None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct GatewayConfig {
    pub host: String,
    /// 0 = ephemeral (tests)
    pub port: u16,
    /// default completion budget when the request omits `max_tokens`
    pub max_tokens_default: usize,
    /// admission bound on queued + running requests (429 beyond)
    pub max_pending: usize,
    /// token-bucket refill, requests/second; 0 disables rate limiting
    pub rate_limit: f64,
    pub rate_burst: usize,
    /// HTTP worker threads. Reactor ingress: the handler-pool size (max
    /// concurrently *served* requests; idle keep-alive connections are
    /// free). Threaded ingress: max concurrently *open* connections.
    pub http_workers: usize,
    /// connection acceptance model; [`IngressMode::Reactor`] by default
    pub ingress: IngressMode,
    pub max_body_bytes: usize,
    /// cadence of Table II frame recording per replica
    pub monitor_interval: Duration,
    /// longest a job may wait in a replica's queue before it is shed with
    /// a 503 instead of ever reaching the engine; zero disables shedding
    pub queue_budget: Duration,
    /// per-request deadline: how long a handler waits for its engine, and
    /// the point past which a still-queued job is shed rather than run
    pub request_timeout: Duration,
    /// standby replicas kept pre-initialized but derouted, so scale-up
    /// promotes in O(route-update) instead of paying engine init; 0
    /// disables the pool. Retirement demotes back to warm while the pool
    /// is below this target.
    pub warm_pool: usize,
    /// distributed-plane node identity: when set, the gateway answers the
    /// `/cluster/status` and `/cluster/scale-{up,down}` control endpoints
    /// so a [`crate::cluster::coordinator`] can place replicas on it
    pub node: Option<crate::cluster::NodeIdentity>,
    /// request-tracing knobs: sampling rate, slow-trace SLO, ring capacity
    pub trace: TraceSettings,
    /// tenant roster (id, SLO tier, budgets, API keys). Empty means the
    /// built-in mixture roster ([`TenantRegistry::with_defaults`]): the
    /// chat/summarize/codegen scenario tenants plus the `default`
    /// fallback every unmatched request resolves to.
    pub tenants: Vec<TenantSpec>,
    /// seeded fault-injection config for the serving path. Disarmed by
    /// default; armed configs fail or delay completions before dispatch
    /// and can sever SSE streams mid-flight. Mutable at runtime through
    /// `POST /v1/admin/chaos`.
    pub chaos: crate::chaos::ChaosConfig,
    /// serve the deprecated pre-/v1 alias paths (`/admin/scale`,
    /// `/cluster/*`, `/debug/*`). Default on for one release; every alias
    /// hit is counted in `enova_api_deprecated_requests_total` and
    /// answered with `Deprecation`/`Sunset` headers either way. Off, the
    /// aliases answer 410 Gone with a structured error.
    pub legacy_api: bool,
}

impl Default for GatewayConfig {
    fn default() -> Self {
        GatewayConfig {
            host: "127.0.0.1".into(),
            port: 0,
            max_tokens_default: 64,
            max_pending: 256,
            rate_limit: 0.0,
            rate_burst: 64,
            http_workers: 64,
            ingress: IngressMode::Reactor,
            max_body_bytes: 1024 * 1024,
            monitor_interval: Duration::from_millis(50),
            queue_budget: Duration::ZERO,
            request_timeout: Duration::from_secs(120),
            warm_pool: 0,
            node: None,
            trace: TraceSettings::default(),
            tenants: Vec::new(),
            chaos: crate::chaos::ChaosConfig::default(),
            legacy_api: true,
        }
    }
}

/// What a replica worker sends back to the HTTP handler, per request.
enum StreamItem {
    Delta {
        text: String,
        finish: Option<FinishReason>,
    },
    Done(Completion),
    Error(String),
    /// Shed before reaching the engine (queue budget, deadline, shutdown,
    /// drain) — the handler answers 503 / a terminal SSE event.
    Unavailable(String),
}

/// One admitted request, queued to a replica worker. The job owns its
/// admission permit and router handle: capacity and routing counts are
/// released when the *engine* finishes the request (see
/// [`Job::release`]), not when the HTTP handler responds — a request the
/// handler gave up on (timeout, client disconnect) still occupies engine
/// queue/slots until it completes.
struct Job {
    prompt: String,
    max_new: usize,
    stream: bool,
    tx: Sender<StreamItem>,
    permit: AdmissionPermit,
    handle: Arc<ReplicaHandle>,
    /// when the handler handed the job to the replica worker
    enqueued_at: Instant,
    /// past this instant the job is shed instead of submitted
    deadline: Instant,
    /// the request's trace, shared with the HTTP handler; the worker
    /// records queue_wait / prefill / decode phase spans into it
    trace: Arc<ActiveTrace>,
    /// when the worker promoted the job into the engine (prefill start)
    submitted_at: Option<Instant>,
    /// when the engine produced the first token (prefill end / TTFT)
    first_token_at: Option<Instant>,
    /// when the engine produced the latest token (inter-token gaps)
    last_token_at: Option<Instant>,
    /// SLO tier the job was admitted under: picks the worker lane
    tier: SloTier,
    /// resolved per-tenant queue-time budget (gateway default when unset)
    queue_budget: Duration,
    /// the tenant this job bills GPU time and counters to
    tenant: Arc<TenantState>,
}

impl Job {
    /// Release routing + admission accounting (the permit drops with self).
    fn release(self) -> Sender<StreamItem> {
        self.handle.complete();
        drop(self.permit);
        self.tx
    }

    /// Credit engine busy time (submit → now) to the tenant cost ledger.
    fn credit_tenant(&self, now: Instant) {
        if let Some(submitted) = self.submitted_at {
            self.tenant
                .credit_gpu(now.saturating_duration_since(submitted).as_secs_f64());
        }
    }
}

/// One live replica as the lifecycle manager sees it: the job channel into
/// its worker thread, the drain request flag, and the thread handle joined
/// on retirement or shutdown.
struct ReplicaSlot {
    tx: Mutex<Sender<Job>>,
    /// asks the worker to finish queued + in-flight work and exit
    draining: Arc<AtomicBool>,
    /// mailbox for a pending live capacity mutation `(max_num_seqs,
    /// gpu_memory)`; the worker applies it between engine steps
    reconfig: Arc<Mutex<Option<(usize, f64)>>>,
    /// mailbox for a pending snapshot capture: the worker checkpoints its
    /// engine between steps (a consistent point — no step in flight) and
    /// answers on the parked channel
    snapshot_req: Arc<Mutex<Option<SnapshotReply>>>,
    /// engine concurrency as last applied by the worker (gauge + tests)
    applied_max_num_seqs: Arc<AtomicUsize>,
    join: Mutex<Option<JoinHandle<()>>>,
}

/// A pre-initialized standby replica: engine built, worker thread parked
/// on an empty queue, not routable. Promotion to live is O(route-update).
struct WarmReplica {
    id: u64,
    slot: Arc<ReplicaSlot>,
}

struct GatewayState {
    cfg: GatewayConfig,
    router: RwLock<WeightedRouter>,
    /// the live replica set; mutated by hot-add / retire. Lock order:
    /// never acquire `router` while holding `replicas` write (and vice
    /// versa) — every path takes them sequentially, not nested.
    replicas: RwLock<BTreeMap<u64, Arc<ReplicaSlot>>>,
    /// present when the gateway was started scalable: lets the supervisor
    /// and [`Gateway::add_replica`] hot-spawn workers at runtime
    spawner: Option<EngineSpawner>,
    /// pre-initialized standby replicas awaiting promotion (LIFO)
    warm: Mutex<Vec<WarmReplica>>,
    /// live warm-pool size target. Seeded from `cfg.warm_pool`; the
    /// forecast-aware supervisor re-sizes it from predicted demand, so
    /// the pool tracks anticipated promotions instead of a fixed number
    warm_target: AtomicUsize,
    /// true while a background warm-pool filler thread is running
    warm_filling: AtomicBool,
    /// last cluster-wide capacity verdict; replayed onto replicas that
    /// join later (warm promotions, cold spawns, refilled standbys) so a
    /// late joiner never serves with a pre-reconfiguration config
    last_reconfig: Mutex<Option<(usize, f64)>>,
    next_replica_id: AtomicU64,
    gate: Arc<AdmissionGate>,
    bucket: Option<Mutex<TokenBucket>>,
    metrics: GatewayMetrics,
    store: Mutex<MetricStore>,
    supervisor: Mutex<supervisor::SupervisorStatus>,
    started: Instant,
    ready_replicas: AtomicUsize,
    next_req_id: AtomicU64,
    stop: AtomicBool,
    /// service name stamped on spans: "gateway", or "node:<id>" when the
    /// gateway runs as a cluster node
    service: String,
    /// finished request traces (`/debug/traces`)
    tracer: TraceRecorder,
    /// autoscaling decision flight recorder (`/debug/decisions`)
    decisions: DecisionRecorder,
    /// tenant roster resolved once per request at ingress
    tenants: Arc<TenantRegistry>,
    /// seeded fault injector; always present (disarmed when no chaos
    /// config was given) so `POST /v1/admin/chaos` can arm at runtime
    chaos: Arc<crate::chaos::ChaosInjector>,
    /// capture/restore ledger served by `GET /v1/admin/snapshots`
    /// (bounded; newest last)
    snapshots: Mutex<Vec<crate::cluster::proto::SnapshotInfo>>,
}

/// A replica worker mid-launch: the engine is constructed inside the
/// spawned thread; `init_rx` reports success or failure.
struct PendingReplica {
    id: u64,
    slot: Arc<ReplicaSlot>,
    init_rx: Receiver<std::result::Result<(), String>>,
}

/// Handle to a running gateway. [`Gateway::shutdown`] stops and joins all
/// threads; dropping without shutdown leaves daemon threads running (the
/// CLI path, where the process exit reaps them).
pub struct Gateway {
    pub addr: SocketAddr,
    state: Arc<GatewayState>,
    threads: Vec<JoinHandle<()>>,
}

impl Gateway {
    /// Bind, spawn one worker thread per engine factory plus the HTTP
    /// accept/worker pool, and wait until every replica engine is built.
    /// The replica set is fixed (no spawner): hot-add is unavailable.
    pub fn start(cfg: GatewayConfig, factories: Vec<EngineFactory>) -> Result<Gateway> {
        Gateway::start_inner(cfg, factories, None, None)
    }

    /// Start with a reusable [`EngineSpawner`] so replicas can be
    /// hot-added and retired at runtime; with `supervisor_cfg`, the
    /// closed-loop autoscaling supervisor drives that lifecycle from the
    /// performance detector.
    pub fn start_scalable(
        cfg: GatewayConfig,
        spawner: EngineSpawner,
        initial_replicas: usize,
        supervisor_cfg: Option<supervisor::SupervisorConfig>,
    ) -> Result<Gateway> {
        let factories: Vec<EngineFactory> = (0..initial_replicas.max(1) as u64)
            .map(|id| -> EngineFactory {
                let spawner = Arc::clone(&spawner);
                Box::new(move || spawner(id))
            })
            .collect();
        Gateway::start_inner(cfg, factories, Some(spawner), supervisor_cfg)
    }

    fn start_inner(
        cfg: GatewayConfig,
        factories: Vec<EngineFactory>,
        spawner: Option<EngineSpawner>,
        supervisor_cfg: Option<supervisor::SupervisorConfig>,
    ) -> Result<Gateway> {
        if factories.is_empty() {
            return Err(anyhow!("gateway needs at least one engine replica"));
        }
        let listener = TcpListener::bind((cfg.host.as_str(), cfg.port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;

        let n = factories.len();
        let state = Arc::new(GatewayState {
            router: RwLock::new(WeightedRouter::new(&[])),
            replicas: RwLock::new(BTreeMap::new()),
            spawner,
            warm: Mutex::new(Vec::new()),
            warm_target: AtomicUsize::new(cfg.warm_pool),
            warm_filling: AtomicBool::new(false),
            last_reconfig: Mutex::new(None),
            next_replica_id: AtomicU64::new(n as u64),
            gate: AdmissionGate::new(cfg.max_pending),
            bucket: (cfg.rate_limit > 0.0)
                .then(|| Mutex::new(TokenBucket::new(cfg.rate_limit, cfg.rate_burst))),
            metrics: GatewayMetrics::new(),
            store: Mutex::new({
                // /metrics only reads the newest point per series; a small
                // history bound keeps a long-running gateway's RSS flat
                let mut store = MetricStore::new();
                store.retention = 4096;
                store
            }),
            supervisor: Mutex::new(supervisor::SupervisorStatus::new(
                supervisor_cfg.is_some(),
                supervisor_cfg
                    .as_ref()
                    .map(|c| c.forecast.is_some())
                    .unwrap_or(false),
            )),
            started: Instant::now(),
            ready_replicas: AtomicUsize::new(0),
            next_req_id: AtomicU64::new(1),
            stop: AtomicBool::new(false),
            service: cfg
                .node
                .as_ref()
                .map(|n| format!("node:{}", n.node_id))
                .unwrap_or_else(|| "gateway".to_string()),
            tracer: TraceRecorder::new(cfg.trace.clone()),
            decisions: DecisionRecorder::new(256),
            tenants: if cfg.tenants.is_empty() {
                TenantRegistry::with_defaults()
            } else {
                TenantRegistry::new(cfg.tenants.clone())
            },
            chaos: Arc::new(crate::chaos::ChaosInjector::new(cfg.chaos.clone())),
            snapshots: Mutex::new(Vec::new()),
            cfg,
        });

        // launch every initial replica in parallel, then wait for each and
        // register it, so start() returns with the full set routable
        let pending: Vec<PendingReplica> = factories
            .into_iter()
            .enumerate()
            .map(|(id, factory)| launch_replica(&state, id as u64, factory))
            .collect();
        for p in pending {
            if let Err(e) = await_replica(&p) {
                state.stop.store(true, Ordering::Release);
                return Err(e);
            }
            register_replica(&state, p.id, p.slot, 1.0);
        }

        // connection fan-out, per the configured ingress mode
        let mut threads = Vec::new();
        match state.cfg.ingress {
            IngressMode::Reactor => {
                // the handler intentionally skips a stop-flag fast-exit:
                // during a drain, already-dispatched requests run route()
                // and get well-formed responses (replica workers shed
                // with 503s once stopping)
                let handler: reactor::Handler = {
                    let state = Arc::clone(&state);
                    Arc::new(move |stream: &mut TcpStream, req: &http::Request| {
                        let keep = req.keep_alive();
                        route(req, stream, &state).is_ok() && keep
                    })
                };
                let on_parse_error: reactor::ErrorResponder = Arc::new(|e| {
                    let body =
                        openai::to_wire(&openai::error_body("invalid_request_error", &e.message));
                    http::Response::json(e.status, body)
                });
                let stop: reactor::StopCheck = {
                    let state = Arc::clone(&state);
                    Arc::new(move || state.stop.load(Ordering::Acquire))
                };
                let rcfg = reactor::ReactorConfig {
                    shards: reactor::default_shards(),
                    handler_threads: state.cfg.http_workers.max(1),
                    max_body_bytes: state.cfg.max_body_bytes,
                    idle_timeout: Duration::from_secs(5),
                };
                let r = reactor::Reactor::start(
                    listener,
                    rcfg,
                    handler,
                    on_parse_error,
                    stop,
                    Arc::clone(&state.metrics.ingress),
                )?;
                threads.extend(r.into_threads());
            }
            IngressMode::Threaded => {
                // legacy: accept thread -> worker pool
                state
                    .metrics
                    .ingress
                    .handler_threads
                    .store(state.cfg.http_workers.max(1) as u64, Ordering::Release);
                let (conn_tx, conn_rx) = mpsc::channel::<TcpStream>();
                let conn_rx = Arc::new(Mutex::new(conn_rx));
                {
                    let state = Arc::clone(&state);
                    threads.push(std::thread::spawn(move || {
                        accept_loop(listener, conn_tx, &state);
                    }));
                }
                for _ in 0..state.cfg.http_workers.max(1) {
                    let state = Arc::clone(&state);
                    let conn_rx = Arc::clone(&conn_rx);
                    threads.push(std::thread::spawn(move || loop {
                        if state.stop.load(Ordering::Acquire) {
                            break;
                        }
                        let next = conn_rx
                            .lock()
                            .unwrap()
                            .recv_timeout(Duration::from_millis(100));
                        match next {
                            Ok(stream) => {
                                handle_connection(stream, &state);
                                state.metrics.ingress.open.fetch_sub(1, Ordering::AcqRel);
                            }
                            Err(RecvTimeoutError::Timeout) => continue,
                            Err(RecvTimeoutError::Disconnected) => break,
                        }
                    }));
                }
            }
        }

        if let Some(sup) = supervisor_cfg {
            let state = Arc::clone(&state);
            threads.push(std::thread::spawn(move || {
                supervisor::supervisor_loop(&state, sup);
            }));
        }

        // pre-warm standby replicas in the background so the first
        // scale-up already finds a built engine in the pool
        ensure_warm_fill(&state);

        crate::info!(
            "gateway",
            "listening on http://{addr} with {n} replica(s), {} http workers",
            state.cfg.http_workers
        );
        Ok(Gateway {
            addr,
            state,
            threads,
        })
    }

    pub fn addr_string(&self) -> String {
        self.addr.to_string()
    }

    /// Replicas that finished constructing their engine.
    pub fn ready_replicas(&self) -> usize {
        self.state.ready_replicas.load(Ordering::Acquire)
    }

    /// Ids of the live (routable) replica set, ascending.
    pub fn live_replicas(&self) -> Vec<u64> {
        self.state.replicas.read().unwrap().keys().copied().collect()
    }

    /// Per-replica routing counters: `(id, inflight, dispatched)`.
    pub fn replica_stats(&self) -> Vec<(u64, u64, u64)> {
        self.state
            .router
            .read()
            .unwrap()
            .replicas()
            .iter()
            .map(|r| (r.id, r.inflight(), r.dispatched()))
            .collect()
    }

    /// Bring one more replica live: promote a warm standby when the pool
    /// has one (O(route-update)), else hot-spawn cold from the engine
    /// spawner. Errors when the gateway was started without a spawner.
    pub fn add_replica(&self) -> Result<u64> {
        hot_add_replica(&self.state)
    }

    /// Retire a replica: deroute it, then either demote it to a warm
    /// standby (pool below target; its worker keeps draining in-flight
    /// work) or drain-then-join the worker thread. The drain path blocks
    /// until every queued and in-flight job finished.
    pub fn retire_replica(&self, id: u64) -> Result<()> {
        retire_replica(&self.state, id)
    }

    /// Standby replicas currently parked in the warm pool.
    pub fn warm_pool_size(&self) -> usize {
        self.state.warm.lock().unwrap().len()
    }

    /// The live warm-pool size target (seeded from the config; re-sized
    /// by the forecast-aware supervisor).
    pub fn warm_pool_target(&self) -> usize {
        self.state.warm_target.load(Ordering::Acquire)
    }

    /// Re-size the warm pool target: grows refill in the background,
    /// shrinks drain the excess standbys.
    pub fn set_warm_pool_target(&self, target: usize) {
        set_warm_target(&self.state, target);
    }

    /// Upper-bound estimate of the `q`-quantile of time-in-queue
    /// (seconds), read from the `enova_gateway_queue_wait_seconds`
    /// histogram buckets. 0 with no observations; +inf when the quantile
    /// lies beyond the largest bucket bound.
    pub fn queue_wait_quantile(&self, q: f64) -> f64 {
        self.state.metrics.queue_wait_quantile(q)
    }

    /// `(count, mean seconds)` of AddReplica promotions by kind — the
    /// programmatic view of the `enova_gateway_promotion_seconds`
    /// histogram (`warm` = pool promotion, else cold hot-spawn).
    pub fn promotion_stats(&self, warm: bool) -> (u64, f64) {
        self.state.metrics.promotion_stats(warm)
    }

    /// Upper-bound `q`-quantile (seconds) of the promotion histogram for
    /// one kind (`"warm"`, `"cold"`, `"snapshot"`); 0 for an unknown kind
    /// or no observations.
    pub fn promotion_quantile(&self, kind: &str, q: f64) -> f64 {
        self.state.metrics.promotion_quantile(kind, q)
    }

    /// Observation count of the promotion histogram for one kind.
    pub fn promotion_count(&self, kind: &str) -> u64 {
        self.state.metrics.promotion_count(kind)
    }

    /// Hits recorded against one legacy (pre-`/v1`) alias — the
    /// programmatic view of `enova_api_deprecated_requests_total{path}`.
    pub fn deprecated_hits(&self, path: &str) -> u64 {
        self.state.metrics.deprecated_for(path)
    }

    /// The bounded capture/restore ledger behind `GET /v1/admin/snapshots`.
    pub fn snapshot_ledger(&self) -> Vec<crate::cluster::proto::SnapshotInfo> {
        self.state.snapshots.lock().unwrap().clone()
    }

    /// Post a live capacity mutation to one replica's worker; it is
    /// applied between engine steps without dropping queued or in-flight
    /// work.
    pub fn reconfigure_replica(&self, id: u64, max_num_seqs: usize, gpu_memory: f64) -> Result<()> {
        let replicas = self.state.replicas.read().unwrap();
        let slot = replicas
            .get(&id)
            .ok_or_else(|| anyhow!("unknown replica id {id}"))?;
        *slot.reconfig.lock().unwrap() = Some((max_num_seqs, gpu_memory));
        Ok(())
    }

    /// Post a live capacity mutation to every live replica; returns how
    /// many workers were asked.
    pub fn reconfigure_all(&self, max_num_seqs: usize, gpu_memory: f64) -> usize {
        reconfigure_live(&self.state, max_num_seqs, gpu_memory)
    }

    /// Per-replica applied `max_num_seqs`: `(id, capacity)`, ascending id.
    pub fn replica_capacities(&self) -> Vec<(u64, usize)> {
        self.state
            .replicas
            .read()
            .unwrap()
            .iter()
            .map(|(id, slot)| (*id, slot.applied_max_num_seqs.load(Ordering::Acquire)))
            .collect()
    }

    /// Scaling actions the supervisor has executed so far.
    pub fn scaling_events(&self) -> Vec<supervisor::ScalingEvent> {
        self.state.supervisor.lock().unwrap().events.clone()
    }

    /// Snapshot of the supervisor's state (enabled/calibrated/counters).
    pub fn supervisor_snapshot(&self) -> supervisor::SupervisorSnapshot {
        self.state.supervisor.lock().unwrap().snapshot()
    }

    /// Retained request traces, oldest first — the programmatic view of
    /// `/debug/traces`.
    pub fn traces(&self) -> Vec<crate::trace::TraceRecord> {
        self.state.tracer.traces()
    }

    /// Recorded control-plane decisions, oldest first — the programmatic
    /// view of `/debug/decisions`.
    pub fn decisions(&self) -> Vec<crate::trace::Decision> {
        self.state.decisions.decisions()
    }

    /// Per-tenant counters, cost ledger, and arrival rates — the
    /// programmatic view of the `enova_tenant_*` series on `/metrics`.
    pub fn tenant_snapshots(&self) -> Vec<TenantSnapshot> {
        self.state.tenants.snapshots()
    }

    /// Total replica-seconds this gateway has run live replicas for —
    /// the denominator of the cost-aware scale-down comparison (the sum
    /// of every live worker's wall-clock, integrated at each monitoring
    /// flush).
    pub fn replica_seconds(&self) -> f64 {
        self.state.metrics.replica_seconds()
    }

    /// Stop accepting, fail outstanding jobs with 503s, join all threads.
    pub fn shutdown(self) {
        self.state.stop.store(true, Ordering::Release);
        // replica workers shed queued + in-flight jobs (clients get 503s)
        // and exit; join them via the slots — hot-added workers were never
        // in `threads`. Warm standbys exit on the stop flag too.
        let mut slots: Vec<Arc<ReplicaSlot>> =
            self.state.replicas.read().unwrap().values().cloned().collect();
        slots.extend(
            self.state
                .warm
                .lock()
                .unwrap()
                .iter()
                .map(|w| Arc::clone(&w.slot)),
        );
        for slot in slots {
            let join = slot.join.lock().unwrap().take();
            if let Some(h) = join {
                let _ = h.join();
            }
        }
        for t in self.threads {
            let _ = t.join();
        }
    }

    /// Block forever serving (CLI path).
    pub fn serve_forever(self) {
        for t in self.threads {
            let _ = t.join();
        }
    }
}

/// Spawn a replica worker thread; the engine is built inside it.
fn launch_replica(state: &Arc<GatewayState>, id: u64, factory: EngineFactory) -> PendingReplica {
    let (tx, rx) = mpsc::channel::<Job>();
    let draining = Arc::new(AtomicBool::new(false));
    let reconfig: Arc<Mutex<Option<(usize, f64)>>> = Arc::new(Mutex::new(None));
    let snapshot_req: Arc<Mutex<Option<SnapshotReply>>> = Arc::new(Mutex::new(None));
    let applied = Arc::new(AtomicUsize::new(0));
    let (init_tx, init_rx) = mpsc::channel::<std::result::Result<(), String>>();
    let thread_state = Arc::clone(state);
    let thread_draining = Arc::clone(&draining);
    let thread_reconfig = Arc::clone(&reconfig);
    let thread_snapshot = Arc::clone(&snapshot_req);
    let thread_applied = Arc::clone(&applied);
    let join = std::thread::spawn(move || {
        let engine = match factory() {
            Ok(e) => e,
            Err(e) => {
                let _ = init_tx.send(Err(format!("replica {id}: {e}")));
                return;
            }
        };
        thread_applied.store(engine.capacity(), Ordering::Release);
        // initial frame before declaring ready, so /metrics exposes the
        // replica deterministically once registration returns
        record_frame(
            engine.as_ref(),
            &thread_state,
            &format!("replica-{id}"),
            &WindowStats::default(),
        );
        thread_state.ready_replicas.fetch_add(1, Ordering::Release);
        let _ = init_tx.send(Ok(()));
        replica_loop(
            id,
            engine,
            rx,
            &thread_draining,
            &thread_reconfig,
            &thread_snapshot,
            &thread_applied,
            &thread_state,
        );
        thread_state.ready_replicas.fetch_sub(1, Ordering::Release);
    });
    PendingReplica {
        id,
        slot: Arc::new(ReplicaSlot {
            tx: Mutex::new(tx),
            draining,
            reconfig,
            snapshot_req,
            applied_max_num_seqs: applied,
            join: Mutex::new(Some(join)),
        }),
        init_rx,
    }
}

fn await_replica(p: &PendingReplica) -> Result<()> {
    match p.init_rx.recv_timeout(ENGINE_INIT_TIMEOUT) {
        Ok(Ok(())) => Ok(()),
        Ok(Err(e)) => Err(anyhow!("engine init failed: {e}")),
        Err(_) => Err(anyhow!("replica {} engine init timed out", p.id)),
    }
}

/// Insert a ready replica into the live set, then make it routable.
fn register_replica(state: &Arc<GatewayState>, id: u64, slot: Arc<ReplicaSlot>, weight: f64) {
    state.replicas.write().unwrap().insert(id, slot);
    let mut router = state.router.write().unwrap();
    let mut weights = router.weights();
    weights.push((id, weight));
    router.set_weights(&weights);
}

/// Build one standby replica (blocking on its engine init) and park it in
/// the warm pool, derouted. The last cluster-wide reconfiguration verdict
/// is replayed into its mailbox so a freshly built standby matches the
/// live configuration it will be promoted into.
fn spawn_warm(state: &Arc<GatewayState>) -> Result<u64> {
    let spawner = state
        .spawner
        .as_ref()
        .ok_or_else(|| anyhow!("gateway was started without an engine spawner; cannot pre-warm"))?
        .clone();
    let id = state.next_replica_id.fetch_add(1, Ordering::Relaxed);
    let factory: EngineFactory = Box::new(move || spawner(id));
    let p = launch_replica(state, id, factory);
    await_replica(&p)?;
    replay_last_reconfig(state, &p.slot);
    state.warm.lock().unwrap().push(WarmReplica { id, slot: p.slot });
    Ok(id)
}

/// Post the last cluster-wide capacity verdict (if any) to one replica's
/// mailbox — used for replicas that join after a reconfiguration.
fn replay_last_reconfig(state: &GatewayState, slot: &ReplicaSlot) {
    if let Some(v) = *state.last_reconfig.lock().unwrap() {
        *slot.reconfig.lock().unwrap() = Some(v);
    }
}

/// Re-size the warm-pool target at runtime (the forecast-aware
/// supervisor's pre-provisioning knob). Growing triggers a background
/// refill; excess standbys are drained by a background reaper so the
/// caller (the supervisor tick) never blocks on thread joins.
///
/// The drain check runs on every call, not only when the target
/// decreases: a filler that completes a build just after the target moved
/// under it leaves the pool over target with `prev == target` on all
/// later calls, so a `target < prev` guard would leak that standby (a
/// live engine) forever. The planner calls this every tick, which makes
/// the next tick the cleanup bound.
pub(crate) fn set_warm_target(state: &Arc<GatewayState>, target: usize) {
    let prev = state.warm_target.swap(target, Ordering::AcqRel);
    let excess: Vec<WarmReplica> = {
        let mut warm = state.warm.lock().unwrap();
        let mut out = Vec::new();
        while warm.len() > target {
            // LIFO: drop the most recently parked standby
            match warm.pop() {
                Some(w) => out.push(w),
                None => break,
            }
        }
        out
    };
    if !excess.is_empty() {
        let st = Arc::clone(state);
        std::thread::spawn(move || {
            for w in excess {
                w.slot.draining.store(true, Ordering::Release);
                let join = w.slot.join.lock().unwrap().take();
                if let Some(h) = join {
                    let _ = h.join();
                }
                st.store.lock().unwrap().remove_instance(&format!("replica-{}", w.id));
                crate::info!("gateway", "warm standby {} drained (target {target})", w.id);
            }
        });
    }
    if target > prev {
        ensure_warm_fill(state);
    }
}

/// Keep the warm pool at its target size by building standbys in a
/// background thread, so neither startup nor promotions ever wait on
/// engine init. At most one filler runs at a time.
fn ensure_warm_fill(state: &Arc<GatewayState>) {
    if state.warm_target.load(Ordering::Acquire) == 0 || state.spawner.is_none() {
        return;
    }
    if state.warm_filling.swap(true, Ordering::AcqRel) {
        return; // a filler is already running
    }
    let st = Arc::clone(state);
    std::thread::spawn(move || {
        let mut failures = 0u32;
        'fill: loop {
            while !st.stop.load(Ordering::Acquire) {
                if st.warm.lock().unwrap().len() >= st.warm_target.load(Ordering::Acquire) {
                    break;
                }
                match spawn_warm(&st) {
                    Ok(id) => {
                        failures = 0;
                        let pooled = st.warm.lock().unwrap().len();
                        crate::info!("gateway", "warm replica {id} standing by ({pooled} pooled)");
                    }
                    Err(e) => {
                        // transient init flakes get a bounded backoff; a
                        // persistently failing spawner stops the filler
                        // until the next scale event retriggers it
                        failures += 1;
                        if failures >= WARM_FILL_MAX_FAILURES {
                            crate::error!(
                                "gateway",
                                "warm pool fill stopped after {failures} consecutive failures: {e}"
                            );
                            st.warm_filling.store(false, Ordering::Release);
                            break 'fill;
                        }
                        let delay = Duration::from_millis(250u64 << failures.min(6));
                        crate::error!(
                            "gateway",
                            "warm pool fill failed (attempt {failures}, retrying in {delay:?}): {e}"
                        );
                        std::thread::sleep(delay);
                    }
                }
            }
            st.warm_filling.store(false, Ordering::Release);
            // close the lost-refill race: a promotion may have drained the
            // pool after our last check but before the flag cleared — its
            // ensure_warm_fill call saw the stale flag and bailed. Re-check,
            // and only exit while the pool is genuinely full (or stopping).
            if st.stop.load(Ordering::Acquire)
                || st.warm.lock().unwrap().len() >= st.warm_target.load(Ordering::Acquire)
                || st.warm_filling.swap(true, Ordering::AcqRel)
            {
                break;
            }
        }
    });
}

/// Bring one more replica live (supervisor scale-up /
/// `Gateway::add_replica`): promote from the warm pool when a standby is
/// ready — O(route-update), the latency-hiding path — else hot-spawn cold
/// and pay engine init inline. Either way the promotion latency lands in
/// the `enova_gateway_promotion_seconds` histogram under its `kind`.
fn hot_add_replica(state: &Arc<GatewayState>) -> Result<u64> {
    let t0 = Instant::now();
    let promoted = state.warm.lock().unwrap().pop();
    if let Some(w) = promoted {
        // replay the cluster verdict in case it changed while parked
        replay_last_reconfig(state, &w.slot);
        register_replica(state, w.id, Arc::clone(&w.slot), 1.0);
        state.metrics.observe_promotion(true, t0.elapsed().as_secs_f64());
        ensure_warm_fill(state); // refill behind the promotion
        let live = state.replicas.read().unwrap().len();
        crate::info!("gateway", "replica {} promoted from warm pool ({live} live)", w.id);
        return Ok(w.id);
    }
    let spawner = state
        .spawner
        .as_ref()
        .ok_or_else(|| anyhow!("gateway was started without an engine spawner; cannot hot-add"))?
        .clone();
    let id = state.next_replica_id.fetch_add(1, Ordering::Relaxed);
    let factory: EngineFactory = Box::new(move || spawner(id));
    let p = launch_replica(state, id, factory);
    await_replica(&p)?;
    replay_last_reconfig(state, &p.slot);
    register_replica(state, id, p.slot, 1.0);
    state.metrics.observe_promotion(false, t0.elapsed().as_secs_f64());
    ensure_warm_fill(state);
    let live = state.replicas.read().unwrap().len();
    crate::info!("gateway", "replica {id} hot-added cold ({live} live)");
    Ok(id)
}

/// Checkpoint one live replica's engine: park a reply channel in its
/// snapshot mailbox and wait for the worker to answer between steps.
/// In-flight work is NOT serialized — the migration contract drains it on
/// the source before retirement — so the snapshot is config + counters,
/// restorable in milliseconds.
fn snapshot_replica(
    state: &Arc<GatewayState>,
    id: u64,
) -> std::result::Result<crate::cluster::snapshot::EngineSnapshot, String> {
    let slot = state
        .replicas
        .read()
        .unwrap()
        .get(&id)
        .cloned()
        .ok_or_else(|| format!("unknown replica id {id}"))?;
    let (tx, rx) = mpsc::channel();
    *slot.snapshot_req.lock().unwrap() = Some(tx);
    match rx.recv_timeout(SNAPSHOT_REPLY_TIMEOUT) {
        Ok(Ok(snap)) => Ok(snap),
        Ok(Err(e)) => Err(e),
        Err(_) => Err(format!(
            "replica {id} did not answer the snapshot request within {SNAPSHOT_REPLY_TIMEOUT:?}"
        )),
    }
}

/// Spawn a replica *from a snapshot* instead of a cold init — the
/// restore-beats-cold-spawn path measured under
/// `enova_gateway_promotion_seconds{kind="snapshot"}`. A `sim` snapshot
/// rebuilds directly ([`crate::engine::sim::SimEngine::from_snapshot`],
/// bypassing the spawner and whatever init cost it models); any other
/// kind builds through the spawner and then fail-closed-restores into the
/// fresh engine. Returns `(replica_id, promote_seconds)`.
fn restore_replica_from_snapshot(
    state: &Arc<GatewayState>,
    snap: crate::cluster::snapshot::EngineSnapshot,
) -> Result<(u64, f64)> {
    let t0 = Instant::now();
    let id = state.next_replica_id.fetch_add(1, Ordering::Relaxed);
    let factory: EngineFactory = if snap.engine_kind == "sim" {
        Box::new(move || {
            let engine =
                crate::engine::sim::SimEngine::from_snapshot(&snap).map_err(|e| anyhow!("{e}"))?;
            Ok(Box::new(engine) as Box<dyn StreamEngine>)
        })
    } else {
        let spawner = state
            .spawner
            .as_ref()
            .ok_or_else(|| {
                anyhow!(
                    "cannot restore a {:?} snapshot without an engine spawner",
                    snap.engine_kind
                )
            })?
            .clone();
        Box::new(move || {
            let mut engine = spawner(id)?;
            engine.restore(&snap)?;
            Ok(engine)
        })
    };
    let p = launch_replica(state, id, factory);
    await_replica(&p)?;
    replay_last_reconfig(state, &p.slot);
    register_replica(state, id, p.slot, 1.0);
    let secs = t0.elapsed().as_secs_f64();
    state.metrics.observe_promotion_snapshot(secs);
    let live = state.replicas.read().unwrap().len();
    crate::info!("gateway", "replica {id} restored from snapshot in {secs:.4}s ({live} live)");
    Ok((id, secs))
}

/// Describe a snapshot for the typed control API (`info` in the
/// `/v1/admin/snapshots` exchanges and the gateway's capture ledger).
fn snapshot_info(
    snap: &crate::cluster::snapshot::EngineSnapshot,
    source: &str,
) -> crate::cluster::proto::SnapshotInfo {
    crate::cluster::proto::SnapshotInfo {
        engine_kind: snap.engine_kind.clone(),
        version: snap.version as usize,
        max_num_seqs: snap.max_num_seqs,
        gpu_memory: snap.gpu_memory,
        fingerprint: format!("{:016x}", snap.fingerprint),
        payload_bytes: snap.payload.len(),
        source: source.to_string(),
        taken_unix: std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs_f64())
            .unwrap_or(0.0),
    }
}

/// Append to the bounded capture/restore ledger behind
/// `GET /v1/admin/snapshots`.
fn remember_snapshot(state: &GatewayState, info: crate::cluster::proto::SnapshotInfo) {
    let mut ledger = state.snapshots.lock().unwrap();
    ledger.push(info);
    let excess = ledger.len().saturating_sub(SNAPSHOT_LEDGER_CAP);
    if excess > 0 {
        ledger.drain(..excess);
    }
}

/// Post a live capacity mutation to every live replica's worker mailbox
/// (and every parked standby, so promotions come up configured); returns
/// how many live workers were asked. The verdict is remembered and
/// replayed onto replicas that join later.
fn reconfigure_live(state: &GatewayState, max_num_seqs: usize, gpu_memory: f64) -> usize {
    *state.last_reconfig.lock().unwrap() = Some((max_num_seqs, gpu_memory));
    let asked = {
        let replicas = state.replicas.read().unwrap();
        for slot in replicas.values() {
            *slot.reconfig.lock().unwrap() = Some((max_num_seqs, gpu_memory));
        }
        replicas.len()
    };
    for w in state.warm.lock().unwrap().iter() {
        *w.slot.reconfig.lock().unwrap() = Some((max_num_seqs, gpu_memory));
    }
    asked
}

/// Highest applied `max_num_seqs` across the live set — the value the
/// supervisor's reconfiguration loop compares recommendations against.
fn applied_max_num_seqs(state: &GatewayState) -> Option<usize> {
    state
        .replicas
        .read()
        .unwrap()
        .values()
        .map(|s| s.applied_max_num_seqs.load(Ordering::Acquire))
        .max()
}

/// Concatenate the last `window` Table II frames of every live replica —
/// the monitoring window the supervisor feeds to the §IV-A estimators.
fn window_frames(state: &GatewayState, window: usize) -> Vec<crate::metrics::Frame> {
    let ids: Vec<u64> = state.replicas.read().unwrap().keys().copied().collect();
    let store = state.store.lock().unwrap();
    let mut frames = Vec::new();
    for id in ids {
        frames.extend(crate::metrics::recent_frames(
            &store,
            &format!("replica-{id}"),
            window,
        ));
    }
    frames
}

/// Retire a replica with the drain-then-join protocol:
///
/// 1. deroute — new dispatches stop picking it;
/// 2. drop it from the live set under the write lock — any handler
///    mid-send holds the read lock, so once the write is granted every
///    sent job is in the worker's queue;
/// 3. set the drain flag — the worker finishes queued + in-flight jobs
///    and exits;
/// 4. join the worker thread.
///
/// No in-flight request is dropped: the worker only exits once its queue,
/// job table and engine are all empty.
fn retire_replica(state: &Arc<GatewayState>, id: u64) -> Result<()> {
    {
        let mut router = state.router.write().unwrap();
        let weights: Vec<(u64, f64)> = router
            .weights()
            .into_iter()
            .filter(|&(rid, _)| rid != id)
            .collect();
        if weights.len() != router.len() {
            if weights.is_empty() {
                return Err(anyhow!("refusing to retire the last routable replica"));
            }
            router.set_weights(&weights);
        }
    }
    let slot = state
        .replicas
        .write()
        .unwrap()
        .remove(&id)
        .ok_or_else(|| anyhow!("unknown replica id {id}"))?;
    // demote instead of drain-kill while the warm pool is under target:
    // the worker stays alive (finishing any in-flight work on its own
    // schedule) and the built engine is reused by the next promotion
    {
        let target = state.warm_target.load(Ordering::Acquire);
        let mut warm = state.warm.lock().unwrap();
        if target > 0 && warm.len() < target {
            warm.push(WarmReplica { id, slot });
            drop(warm);
            let live = state.replicas.read().unwrap().len();
            crate::info!("gateway", "replica {id} demoted to warm standby ({live} live)");
            return Ok(());
        }
    }
    slot.draining.store(true, Ordering::Release);
    let join = slot.join.lock().unwrap().take();
    if let Some(h) = join {
        let _ = h.join();
    }
    // stop exporting the dead worker's frozen gauges
    state.store.lock().unwrap().remove_instance(&format!("replica-{id}"));
    let live = state.replicas.read().unwrap().len();
    crate::info!("gateway", "replica {id} retired and drained ({live} live)");
    Ok(())
}

/// Drop a replica whose worker died without draining (send failed): pull
/// it out of the live set, the routing table, and the metric export.
fn deregister_replica(state: &GatewayState, id: u64) {
    state.replicas.write().unwrap().remove(&id);
    {
        let mut router = state.router.write().unwrap();
        let weights: Vec<(u64, f64)> = router
            .weights()
            .into_iter()
            .filter(|&(rid, _)| rid != id)
            .collect();
        router.set_weights(&weights);
    }
    state.store.lock().unwrap().remove_instance(&format!("replica-{id}"));
}

fn accept_loop(listener: TcpListener, conn_tx: Sender<TcpStream>, state: &GatewayState) {
    loop {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        match listener.accept() {
            Ok((stream, _peer)) => {
                let _ = stream.set_nonblocking(false);
                let _ = stream.set_nodelay(true);
                state.metrics.ingress.accepted_total.fetch_add(1, Ordering::AcqRel);
                state.metrics.ingress.open.fetch_add(1, Ordering::AcqRel);
                // short read timeout doubles as the idle keep-alive
                // deadline: a worker parked in read_request re-checks the
                // stop flag within this bound, so shutdown stays prompt
                let _ = stream.set_read_timeout(Some(Duration::from_secs(5)));
                let _ = stream.set_write_timeout(Some(Duration::from_secs(30)));
                if conn_tx.send(stream).is_err() {
                    break;
                }
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => std::thread::sleep(Duration::from_millis(20)),
        }
    }
}

/// Per-replica accounting for the current Table II monitoring window.
struct FrameWindow {
    finished: u64,
    arrived: u64,
    latency_sum: f64,
    latency_n: u64,
    queue_wait_sum: f64,
    queue_wait_n: u64,
    last: Instant,
}

/// One flushed window, normalized for [`record_frame`].
#[derive(Debug, Default)]
struct WindowStats {
    finished: f64,
    arrived: f64,
    mean_latency: f64,
    mean_queue_wait: f64,
    /// jobs still waiting in the worker queue at flush time
    queued: usize,
}

impl FrameWindow {
    fn new() -> FrameWindow {
        FrameWindow {
            finished: 0,
            arrived: 0,
            latency_sum: 0.0,
            latency_n: 0,
            queue_wait_sum: 0.0,
            queue_wait_n: 0,
            last: Instant::now(),
        }
    }

    /// Record a frame and reset the window once the monitor interval has
    /// elapsed. Counts are normalized by the actual window length: Table II
    /// defines n^f/n^a as rates per unit time, and windows here vary with
    /// engine step duration.
    fn maybe_flush(
        &mut self,
        engine: &dyn StreamEngine,
        state: &GatewayState,
        instance: &str,
        queued: usize,
    ) {
        let elapsed = self.last.elapsed();
        if elapsed < state.cfg.monitor_interval {
            return;
        }
        let secs = elapsed.as_secs_f64().max(1e-9);
        // integrate this replica's live wall-clock into the fleet-wide
        // replica-seconds counter — the cost the trough scale-down is
        // judged against
        state.metrics.add_replica_seconds(secs);
        let stats = WindowStats {
            finished: self.finished as f64 / secs,
            arrived: self.arrived as f64 / secs,
            mean_latency: if self.latency_n > 0 {
                self.latency_sum / self.latency_n as f64
            } else {
                0.0
            },
            mean_queue_wait: if self.queue_wait_n > 0 {
                self.queue_wait_sum / self.queue_wait_n as f64
            } else {
                0.0
            },
            queued,
        };
        record_frame(engine, state, instance, &stats);
        *self = FrameWindow::new();
    }
}

/// Drive one replica's engine: queue admitted jobs, promote them into free
/// engine capacity (shedding budget-overshooters), step, fan deltas and
/// completions back out, and record Table II frames into the shared store.
fn replica_loop(
    id: u64,
    mut engine: Box<dyn StreamEngine>,
    rx: Receiver<Job>,
    draining: &AtomicBool,
    reconfig: &Mutex<Option<(usize, f64)>>,
    snapshot_req: &Mutex<Option<SnapshotReply>>,
    applied: &AtomicUsize,
    state: &GatewayState,
) {
    let instance = format!("replica-{id}");
    // two priority lanes: latency/standard-tier jobs never queue behind
    // batch-tier jobs — promote() drains `fast` to exhaustion first
    let mut fast: VecDeque<Job> = VecDeque::new();
    let mut slow: VecDeque<Job> = VecDeque::new();
    let mut jobs: HashMap<u64, Job> = HashMap::new();
    let mut window = FrameWindow::new();

    loop {
        // apply any pending live reconfiguration (the supervisor's §IV-A
        // verdict) between steps: queued and in-flight work is untouched —
        // a shrink only lowers the ceiling new admissions see
        if let Some((seqs, mem)) = reconfig.lock().unwrap().take() {
            match engine.reconfigure(seqs, mem) {
                Ok(out) => {
                    applied.store(out.max_num_seqs, Ordering::Release);
                    state.metrics.note_reconfigure();
                    crate::info!(
                        "gateway",
                        "replica {id} reconfigured live: max_num_seqs {} gpu_memory {:.2}",
                        out.max_num_seqs,
                        out.gpu_memory
                    );
                }
                Err(e) => crate::error!("gateway", "replica {id} reconfigure failed: {e}"),
            }
        }

        // answer a pending snapshot capture between steps: the engine is
        // at a consistent point (no step in flight), so the checkpoint is
        // exactly what a restored twin will resume from
        if let Some(reply) = snapshot_req.lock().unwrap().take() {
            let _ = reply.send(engine.snapshot().map_err(|e| e.to_string()));
        }

        if state.stop.load(Ordering::Acquire) {
            // shutdown: answer every queued and in-flight job with a 503
            // (terminal SSE event for streams) instead of silently
            // dropping them and leaving clients to hit their timeouts
            while let Ok(job) = rx.try_recv() {
                enqueue_lane(&mut fast, &mut slow, job);
            }
            for job in fast.drain(..).chain(slow.drain(..)) {
                shed(job, "gateway is shutting down");
            }
            for (_, job) in jobs.drain() {
                shed(job, "gateway is shutting down");
            }
            break;
        }

        // block while idle; drain opportunistically while busy
        if engine.idle()
            && jobs.is_empty()
            && fast.is_empty()
            && slow.is_empty()
            && !draining.load(Ordering::Acquire)
        {
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(job) => {
                    window.arrived += 1;
                    enqueue_lane(&mut fast, &mut slow, job);
                }
                Err(RecvTimeoutError::Timeout) => {
                    window.maybe_flush(engine.as_ref(), state, &instance, fast.len() + slow.len());
                    continue;
                }
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        while let Ok(job) = rx.try_recv() {
            window.arrived += 1;
            enqueue_lane(&mut fast, &mut slow, job);
        }
        promote(engine.as_mut(), &mut fast, &mut slow, &mut jobs, state, &mut window);

        // retire exit check. Observing `draining` here means retirement
        // already removed this replica from the live set, and every send
        // (made under the `replicas` read lock) has fully landed in rx —
        // but possibly *after* the opportunistic drain above. Re-drain
        // once more under that guarantee; only an empty channel may break.
        if draining.load(Ordering::Acquire)
            && fast.is_empty()
            && slow.is_empty()
            && jobs.is_empty()
            && engine.idle()
        {
            let mut late_arrival = false;
            while let Ok(job) = rx.try_recv() {
                window.arrived += 1;
                enqueue_lane(&mut fast, &mut slow, job);
                late_arrival = true;
            }
            if !late_arrival {
                break;
            }
            promote(engine.as_mut(), &mut fast, &mut slow, &mut jobs, state, &mut window);
        }

        match engine.step_stream() {
            Ok(out) => {
                for d in out.deltas {
                    if let Some(job) = jobs.get_mut(&d.id) {
                        let now = Instant::now();
                        if job.first_token_at.is_none() {
                            // first token: prefill ends, TTFT is measured
                            // from request ingress (the trace start)
                            job.first_token_at = Some(now);
                            let from = job.submitted_at.unwrap_or(job.enqueued_at);
                            trace_phase(state, &job.trace, PHASE_PREFILL, from, now);
                            state.metrics.observe_ttft(
                                now.saturating_duration_since(job.trace.started())
                                    .as_secs_f64(),
                            );
                        } else if let Some(prev) = job.last_token_at {
                            state.metrics.observe_inter_token(
                                now.saturating_duration_since(prev).as_secs_f64(),
                            );
                        }
                        job.last_token_at = Some(now);
                        if job.stream {
                            let _ = job.tx.send(StreamItem::Delta {
                                text: d.text,
                                finish: d.finish,
                            });
                        }
                    }
                }
                for c in out.finished {
                    window.finished += 1;
                    window.latency_sum += (c.finished_at - c.arrival).max(0.0);
                    window.latency_n += 1;
                    if let Some(job) = jobs.remove(&c.id) {
                        // decode span closes before the Done item is sent,
                        // so the handler always sees the complete phase set
                        let now = Instant::now();
                        let from = job
                            .first_token_at
                            .or(job.submitted_at)
                            .unwrap_or(job.enqueued_at);
                        trace_phase(state, &job.trace, PHASE_DECODE, from, now);
                        // bill the engine time (submit → completion) to the
                        // tenant's GPU-seconds ledger before releasing
                        job.credit_tenant(now);
                        let tx = job.release();
                        let _ = tx.send(StreamItem::Done(c));
                    }
                }
            }
            Err(e) => {
                crate::error!("gateway", "replica {id} engine step failed: {e}");
                for (_, job) in jobs.drain() {
                    let tx = job.release();
                    let _ = tx.send(StreamItem::Error(format!("engine failure: {e}")));
                }
                // a persistently broken engine keeps its slots occupied
                // (never idle), so back off instead of hot-spinning
                std::thread::sleep(Duration::from_millis(50));
            }
        }

        window.maybe_flush(engine.as_ref(), state, &instance, fast.len() + slow.len());
    }
}

/// Route an admitted job into its priority lane: latency/standard tiers
/// ride `fast`, batch rides `slow`.
fn enqueue_lane(fast: &mut VecDeque<Job>, slow: &mut VecDeque<Job>, job: Job) {
    if job.tier.is_fast() {
        fast.push_back(job);
    } else {
        slow.push_back(job);
    }
}

/// Promote queued jobs into free engine capacity, draining the fast lane
/// to exhaustion before the slow lane — a latency-tier request never
/// queues behind batch work that arrived earlier. A job that overshot its
/// (per-tenant) queue-time budget or its deadline while waiting is shed
/// with a 503 — the engine never spends compute on a request whose client
/// has already been failed.
fn promote(
    engine: &mut dyn StreamEngine,
    fast: &mut VecDeque<Job>,
    slow: &mut VecDeque<Job>,
    jobs: &mut HashMap<u64, Job>,
    state: &GatewayState,
    window: &mut FrameWindow,
) {
    while engine.pending_len() + engine.running_len() < engine.capacity() {
        let Some(mut job) = fast.pop_front().or_else(|| slow.pop_front()) else { break };
        let waited = job.enqueued_at.elapsed();
        window.queue_wait_sum += waited.as_secs_f64();
        window.queue_wait_n += 1;
        state.metrics.observe_queue_wait(waited.as_secs_f64());
        let promoted_at = Instant::now();
        trace_phase(state, &job.trace, PHASE_QUEUE_WAIT, job.enqueued_at, promoted_at);
        let budget = job.queue_budget;
        let over_budget = budget > Duration::ZERO && waited > budget;
        if over_budget || promoted_at >= job.deadline {
            state.metrics.note_queue_shed();
            shed(job, "request queued past its queue-time budget; retry later");
            continue;
        }
        let id = engine.submit(&job.prompt, job.max_new);
        job.submitted_at = Some(promoted_at);
        jobs.insert(id, job);
    }
}

/// Record one lifecycle phase on both the request's trace and the phase
/// histogram, so `/debug/traces` and `/metrics` never disagree.
fn trace_phase(
    state: &GatewayState,
    trace: &ActiveTrace,
    name: &'static str,
    from: Instant,
    to: Instant,
) {
    trace.phase(name, from, to);
    state
        .metrics
        .observe_phase(name, to.saturating_duration_since(from).as_secs_f64());
}

/// Snapshot a finished request's trace into the ring buffer.
fn record_trace(state: &GatewayState, trace: &ActiveTrace, status: u16) {
    state.tracer.record(trace.finish(status, state.cfg.trace.slo));
}

/// [`finish`] plus trace finalization — every completion-path response
/// goes through here so no request leaves without a trace record.
#[allow(clippy::too_many_arguments)]
fn finish_traced(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    endpoint: &str,
    t0: Instant,
    trace: &ActiveTrace,
    resp: http::Response,
) -> std::io::Result<()> {
    record_trace(state, trace, resp.status);
    finish(req, stream, state, endpoint, t0, resp)
}

/// Fail a job the engine will never serve: release its accounting and
/// send the terminal 503 item.
fn shed(job: Job, msg: &str) {
    let tx = job.release();
    let _ = tx.send(StreamItem::Unavailable(msg.to_string()));
}

fn record_frame(
    engine: &dyn StreamEngine,
    state: &GatewayState,
    instance: &str,
    stats: &WindowStats,
) {
    let mut frame = engine.frame(stats.finished, stats.arrived, stats.mean_latency);
    // queue pressure lives in the worker-side queue now that engine
    // admission is backpressured; fold it into Table II's n^p so the
    // detector sees it
    frame.n_pending += stats.queued as f64;
    let t = state.started.elapsed().as_secs_f64();
    let mut store = state.store.lock().unwrap();
    frame.record(&mut store, instance, t);
    store.push(QUEUE_WAIT, instance, t, stats.mean_queue_wait);
    store.push(MAX_SEQS, instance, t, engine.capacity() as f64);
}

fn handle_connection(mut stream: TcpStream, state: &Arc<GatewayState>) {
    let Ok(read_half) = stream.try_clone() else {
        return;
    };
    let mut reader = BufReader::new(read_half);
    loop {
        if state.stop.load(Ordering::Acquire) {
            break;
        }
        let req = match http::read_request(&mut reader, state.cfg.max_body_bytes) {
            Ok(Some(r)) => r,
            Ok(None) => break,
            Err(e) => {
                let body = openai::to_wire(&openai::error_body("invalid_request_error", &e.message));
                let _ = http::Response::json(e.status, body).write_to(&mut stream, false);
                break;
            }
        };
        let keep_alive = req.keep_alive();
        if route(&req, &mut stream, state).is_err() {
            break; // client went away mid-response
        }
        if !keep_alive {
            break;
        }
    }
}

fn route(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
) -> std::io::Result<()> {
    let t0 = Instant::now();
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/v1/completions") => serve_completion(req, stream, state, false, t0),
        ("POST", "/v1/chat/completions") => serve_completion(req, stream, state, true, t0),
        ("GET", "/metrics") => {
            let live: Vec<String> = state
                .replicas
                .read()
                .unwrap()
                .keys()
                .map(|id| format!("replica-{id}"))
                .collect();
            let warm = state.warm.lock().unwrap().len();
            let warm_target = state.warm_target.load(Ordering::Acquire);
            let sup = state.supervisor.lock().unwrap().snapshot();
            let tenants = state.tenants.snapshots();
            let body = {
                let store = state.store.lock().unwrap();
                metrics::render_prometheus(
                    &state.metrics,
                    &store,
                    state.gate.inflight(),
                    &live,
                    warm,
                    warm_target,
                    state.started.elapsed().as_secs_f64(),
                    &sup,
                    &tenants,
                )
            };
            finish(req, stream, state, "/metrics", t0, http::Response::prometheus(body))
        }
        ("GET", "/healthz") => {
            let body = format!(
                "{{\"status\":\"ok\",\"uptime_seconds\":{:.3},\"replicas\":{}}}",
                state.started.elapsed().as_secs_f64(),
                state.replicas.read().unwrap().len()
            );
            finish(req, stream, state, "/healthz", t0, http::Response::json(200, body))
        }
        ("GET", "/ready") => {
            let live = state.replicas.read().unwrap().len();
            let ready_n = state.ready_replicas.load(Ordering::Acquire);
            let ready = live > 0 && ready_n >= live;
            let status = if ready { 200 } else { 503 };
            let body =
                format!("{{\"ready\":{ready},\"replicas_ready\":{ready_n},\"replicas\":{live}}}");
            finish(req, stream, state, "/ready", t0, http::Response::json(status, body))
        }
        // versioned control API; the pre-v1 paths below stay as thin
        // deprecated aliases for one release
        ("POST", "/v1/admin/scale") => admin_scale(req, stream, state, t0, true),
        ("GET", "/v1/admin/status") => admin_status(req, stream, state, t0),
        ("POST", "/v1/admin/scale-up") => cluster_scale_up(req, stream, state, t0, true),
        ("POST", "/v1/admin/scale-down") => cluster_scale_down(req, stream, state, t0, true),
        ("GET" | "POST", "/v1/admin/chaos") => admin_chaos(req, stream, state, t0),
        ("GET" | "POST", "/v1/admin/snapshots") => admin_snapshots(req, stream, state, t0),
        // migration is coordinated by the cluster control plane; a node
        // (or standalone gateway) answers the typed refusal instead of a
        // bare 404 so clients learn where to ask
        ("POST", "/v1/admin/migrate") => migrate_unsupported(req, stream, state, t0, "/v1/admin/migrate"),
        ("GET", "/v1/admin/migrations") => {
            migrate_unsupported(req, stream, state, t0, "/v1/admin/migrations")
        }
        // versioned observability API: the typed envelope wraps the same
        // recorder export the legacy aliases below still serve bare
        ("GET", "/v1/debug/traces") => {
            let resp = crate::cluster::proto::DebugExportResponse::new(
                "traces",
                &state.service,
                state.tracer.export_json(),
            );
            let body = resp.to_json().to_string_compact();
            finish(req, stream, state, "/v1/debug/traces", t0, http::Response::json(200, body))
        }
        ("GET", "/v1/debug/decisions") => {
            let resp = crate::cluster::proto::DebugExportResponse::new(
                "decisions",
                &state.service,
                state.decisions.export_json(),
            );
            let body = resp.to_json().to_string_compact();
            finish(req, stream, state, "/v1/debug/decisions", t0, http::Response::json(200, body))
        }
        ("POST", "/admin/scale") => match legacy_gate(req, stream, state, t0, "/admin/scale") {
            Some(done) => done,
            None => admin_scale(req, stream, state, t0, false),
        },
        ("GET", "/debug/traces") => match legacy_gate(req, stream, state, t0, "/debug/traces") {
            Some(done) => done,
            None => {
                let body = state.tracer.export_json().to_string_compact();
                finish(req, stream, state, "/debug/traces", t0, http::Response::json(200, body))
            }
        },
        ("GET", "/debug/decisions") => {
            match legacy_gate(req, stream, state, t0, "/debug/decisions") {
                Some(done) => done,
                None => {
                    let body = state.decisions.export_json().to_string_compact();
                    finish(req, stream, state, "/debug/decisions", t0, http::Response::json(200, body))
                }
            }
        }
        ("GET", "/cluster/status") => match legacy_gate(req, stream, state, t0, "/cluster/status") {
            Some(done) => done,
            None => cluster_status(req, stream, state, t0, false),
        },
        ("POST", "/cluster/scale-up") => {
            match legacy_gate(req, stream, state, t0, "/cluster/scale-up") {
                Some(done) => done,
                None => cluster_scale_up(req, stream, state, t0, false),
            }
        }
        ("POST", "/cluster/scale-down") => {
            match legacy_gate(req, stream, state, t0, "/cluster/scale-down") {
                Some(done) => done,
                None => cluster_scale_down(req, stream, state, t0, false),
            }
        }
        (_, "/v1/completions" | "/v1/chat/completions" | "/admin/scale" | "/metrics" | "/healthz"
        | "/ready" | "/debug/traces" | "/debug/decisions" | "/cluster/status"
        | "/cluster/scale-up" | "/cluster/scale-down" | "/v1/admin/scale" | "/v1/admin/status"
        | "/v1/admin/scale-up" | "/v1/admin/scale-down" | "/v1/admin/chaos"
        | "/v1/admin/snapshots" | "/v1/admin/migrate" | "/v1/admin/migrations"
        | "/v1/debug/traces" | "/v1/debug/decisions") => {
            let body = openai::to_wire(&openai::error_body(
                "invalid_request_error",
                &format!("method {} not allowed on {}", req.method, req.path),
            ));
            finish(req, stream, state, "other", t0, http::Response::json(405, body))
        }
        _ => {
            let body = openai::to_wire(&openai::error_body(
                "invalid_request_error",
                &format!("unknown path {}", req.path),
            ));
            finish(req, stream, state, "other", t0, http::Response::json(404, body))
        }
    }
}

/// Write the response and record request metrics. Responses on a
/// deprecated alias path pick up the `Deprecation`/`Sunset` headers here,
/// so every legacy answer carries them no matter which handler built it.
fn finish(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    endpoint: &str,
    t0: Instant,
    resp: http::Response,
) -> std::io::Result<()> {
    let resp = if LEGACY_PATHS.contains(&endpoint) {
        resp.with_header("Deprecation", "true").with_header("Sunset", LEGACY_SUNSET)
    } else {
        resp
    };
    state
        .metrics
        .observe(endpoint, resp.status, t0.elapsed().as_secs_f64());
    resp.write_to(stream, req.keep_alive())
}

/// Deprecation machinery for the pre-/v1 alias paths: every hit bumps
/// `enova_api_deprecated_requests_total{path}`; with the legacy surface
/// disabled (`--legacy-api off`) the alias is answered `410 Gone` with a
/// structured error pointing at the `/v1` replacement. `None` means the
/// caller should serve the alias as before (headers are attached in
/// [`finish`]).
fn legacy_gate(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
    t0: Instant,
    path: &'static str,
) -> Option<std::io::Result<()>> {
    state.metrics.note_deprecated(path);
    if state.cfg.legacy_api {
        return None;
    }
    let err = crate::cluster::proto::AdminError::new(
        "deprecated",
        &format!("{path} was sunset; use the /v1 control API"),
    )
    .with_detail("path", path)
    .with_detail("sunset", LEGACY_SUNSET);
    Some(finish(
        req,
        stream,
        state,
        path,
        t0,
        http::Response::json(410, err.to_json().to_string_compact()),
    ))
}

fn serve_completion(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    chat: bool,
    t0: Instant,
) -> std::io::Result<()> {
    let endpoint = if chat {
        "/v1/chat/completions"
    } else {
        "/v1/completions"
    };
    let bad = |msg: &str| {
        http::Response::json(
            400,
            openai::to_wire(&openai::error_body("invalid_request_error", msg)),
        )
    };

    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return finish(req, stream, state, endpoint, t0, bad(&e.message)),
    };
    let json = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return finish(req, stream, state, endpoint, t0, bad(&format!("invalid JSON: {e}")))
        }
    };
    let params = match if chat {
        openai::parse_chat(&json, state.cfg.max_tokens_default)
    } else {
        openai::parse_completion(&json, state.cfg.max_tokens_default)
    } {
        Ok(p) => p,
        Err(e) => return finish(req, stream, state, endpoint, t0, bad(&e)),
    };

    // trace ingress: adopt an upstream traceparent (the coordinator's
    // proxy hop) with a fresh span ID, or mint a context here — the head
    // sampling decision travels in the flags byte either way
    let ctx = req
        .header("traceparent")
        .and_then(TraceContext::parse)
        .map(|c| c.child())
        .unwrap_or_else(|| TraceContext::mint(state.cfg.trace.sample_rate));
    let trace = ActiveTrace::begin(ctx, &state.service, endpoint);

    // tenant identity: explicit header, API key, then the OpenAI `user`
    // field as a hint; unknown tenants fall back to the default roster
    // entry, so anonymous traffic is served exactly as before
    let api_key = req.header("authorization").and_then(|h| {
        h.strip_prefix("Bearer ")
            .or_else(|| h.strip_prefix("bearer "))
    });
    let tenant = state
        .tenants
        .resolve(req.header("x-enova-tenant"), api_key, params.user.as_deref());

    // admission control: global rate limiter, the tenant's private
    // bucket, then the bounded in-flight gate
    if let Some(bucket) = &state.bucket {
        if !bucket.lock().unwrap().try_take() {
            tenant.note_rejected();
            state.metrics.note_rate_limited();
            trace_phase(state, &trace, PHASE_ADMISSION, trace.started(), Instant::now());
            let resp = http::Response::json(
                429,
                openai::to_wire(&openai::error_body(
                    "rate_limit_exceeded",
                    "request rate over the configured limit; retry later",
                )),
            )
            .with_header("Retry-After", "1");
            return finish_traced(req, stream, state, endpoint, t0, &trace, resp);
        }
    }
    if !tenant.try_admit() {
        tenant.note_rejected();
        state.metrics.note_rate_limited();
        trace_phase(state, &trace, PHASE_ADMISSION, trace.started(), Instant::now());
        let resp = http::Response::json(
            429,
            openai::to_wire(&openai::error_body(
                "rate_limit_exceeded",
                &format!(
                    "tenant {} is over its configured rate limit; retry later",
                    tenant.id()
                ),
            )),
        )
        .with_header("Retry-After", "1");
        return finish_traced(req, stream, state, endpoint, t0, &trace, resp);
    }
    let Some(permit) = AdmissionGate::try_acquire(&state.gate) else {
        tenant.note_rejected();
        state.metrics.note_queue_full();
        trace_phase(state, &trace, PHASE_ADMISSION, trace.started(), Instant::now());
        let resp = http::Response::json(
            429,
            openai::to_wire(&openai::error_body(
                "server_overloaded",
                &format!(
                    "admission queue full ({} in flight); retry later",
                    state.gate.capacity()
                ),
            )),
        )
        .with_header("Retry-After", "1");
        return finish_traced(req, stream, state, endpoint, t0, &trace, resp);
    };
    tenant.note_admitted();
    let admitted_at = Instant::now();
    trace_phase(state, &trace, PHASE_ADMISSION, trace.started(), admitted_at);

    // seeded fault injection, decided after admission but before dispatch
    // so an injected failure never occupies an engine slot. The delay
    // models a node-local latency spike (log-normal body, GPD tail); the
    // failure answers 500, which a cluster coordinator's proxy treats as
    // retryable on another node — chaos proves the retry path, it does
    // not have to surface to end clients.
    let chaos = if state.chaos.armed() {
        state.chaos.decide()
    } else {
        crate::chaos::ChaosDecision::NONE
    };
    if !chaos.delay.is_zero() {
        std::thread::sleep(chaos.delay);
    }
    if chaos.fail {
        drop(permit);
        let resp = http::Response::json(
            500,
            openai::to_wire(&openai::error_body(
                "chaos_injected",
                "seeded fault injection failed this request",
            )),
        );
        return finish_traced(req, stream, state, endpoint, t0, &trace, resp);
    }

    // weighted least-loaded dispatch with a stale-pick retry: a replica
    // can be retired between the router's choice and the live-set lookup
    let (tx, rx) = mpsc::channel::<StreamItem>();
    let mut permit = Some(permit);
    let mut failure = "no replicas routable";
    let mut sent = false;
    for _ in 0..4 {
        // lock-free dispatch: the read lock is held only for the O(1)
        // snapshot clone, never for the least-loaded scan — reactor
        // handler threads don't serialize on routing state
        let routable = state.router.read().unwrap().snapshot();
        let Some(handle) = routable.dispatch() else {
            break;
        };
        let replicas = state.replicas.read().unwrap();
        let Some(slot) = replicas.get(&handle.id) else {
            handle.complete(); // stale pick: retired mid-dispatch; retry
            continue;
        };
        let now = Instant::now();
        let job = Job {
            prompt: params.prompt.clone(),
            max_new: params.max_tokens,
            stream: params.stream,
            tx: tx.clone(),
            permit: permit.take().expect("permit consumed once"),
            handle: Arc::clone(&handle),
            enqueued_at: now,
            deadline: now + state.cfg.request_timeout,
            trace: Arc::clone(&trace),
            submitted_at: None,
            first_token_at: None,
            last_token_at: None,
            tier: tenant.tier(),
            queue_budget: tenant.queue_budget(state.cfg.queue_budget),
            tenant: Arc::clone(&tenant),
        };
        // sending under the read lock is the drain invariant: retirement
        // removes the slot under the write lock *before* asking the worker
        // to drain, so a job that lands here is always picked up
        let send_result = slot.tx.lock().unwrap().send(job);
        drop(replicas);
        match send_result {
            Ok(()) => {
                sent = true;
            }
            Err(mpsc::SendError(job)) => {
                drop(job.release());
                // the worker died without draining: deroute it so
                // least-loaded dispatch stops black-holing traffic into it
                deregister_replica(state, handle.id);
                crate::error!(
                    "gateway",
                    "replica {} worker is down; removed from routing",
                    handle.id
                );
                failure = "replica worker down";
            }
        }
        break;
    }
    trace_phase(state, &trace, PHASE_DISPATCH, admitted_at, Instant::now());
    if !sent {
        drop(permit);
        let resp = http::Response::json(
            503,
            openai::to_wire(&openai::error_body("service_unavailable", failure)),
        );
        return finish_traced(req, stream, state, endpoint, t0, &trace, resp);
    }

    let seq = state.next_req_id.fetch_add(1, Ordering::Relaxed);
    let req_id = if chat {
        format!("chatcmpl-{seq}")
    } else {
        format!("cmpl-{seq}")
    };

    // admission + routing accounting is released by the replica worker
    // when the engine finishes this job, not here: responding early (504,
    // client gone) must not free capacity the engine is still using
    if params.stream {
        stream_response(
            req,
            stream,
            state,
            &params,
            &req_id,
            &rx,
            chat,
            endpoint,
            t0,
            &trace,
            chaos.abort_sse,
        )
    } else {
        unary_response(req, stream, state, &params, &req_id, &rx, chat, endpoint, t0, &trace)
    }
}

/// Wait for the next engine item, polling in short slices so
/// [`Gateway::shutdown`] is never blocked for the full request timeout.
/// `None` means timed out, gateway stopped without a terminal item, or
/// replica worker gone.
fn next_item(
    rx: &Receiver<StreamItem>,
    state: &GatewayState,
    deadline: Instant,
) -> Option<StreamItem> {
    loop {
        if Instant::now() >= deadline {
            return None;
        }
        if state.stop.load(Ordering::Acquire) {
            // shutdown: the replica workers shed every outstanding job
            // with a terminal item; wait briefly for it so the client gets
            // its 503 instead of a timeout on a dying connection
            return rx.recv_timeout(Duration::from_millis(500)).ok();
        }
        match rx.recv_timeout(Duration::from_millis(250)) {
            Ok(item) => return Some(item),
            Err(RecvTimeoutError::Timeout) => continue,
            Err(RecvTimeoutError::Disconnected) => return None,
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn unary_response(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    params: &openai::CompletionParams,
    req_id: &str,
    rx: &Receiver<StreamItem>,
    chat: bool,
    endpoint: &str,
    t0: Instant,
    trace: &ActiveTrace,
) -> std::io::Result<()> {
    let deadline = Instant::now() + state.cfg.request_timeout;
    loop {
        match next_item(rx, state, deadline) {
            Some(StreamItem::Delta { .. }) => continue,
            Some(StreamItem::Done(c)) => {
                state.metrics.add_tokens(c.tokens.len());
                let body = if chat {
                    openai::chat_body(
                        req_id,
                        &params.model,
                        &c.text,
                        c.finish_reason,
                        c.prompt_tokens,
                        c.tokens.len(),
                    )
                } else {
                    openai::completion_body(
                        req_id,
                        &params.model,
                        &c.text,
                        c.finish_reason,
                        c.prompt_tokens,
                        c.tokens.len(),
                    )
                };
                let resp = http::Response::json(200, openai::to_wire(&body));
                return finish_traced(req, stream, state, endpoint, t0, trace, resp);
            }
            Some(StreamItem::Error(msg)) => {
                let resp = http::Response::json(
                    500,
                    openai::to_wire(&openai::error_body("internal_error", &msg)),
                );
                return finish_traced(req, stream, state, endpoint, t0, trace, resp);
            }
            Some(StreamItem::Unavailable(msg)) => {
                let resp = http::Response::json(
                    503,
                    openai::to_wire(&openai::error_body("service_unavailable", &msg)),
                )
                .with_header("Retry-After", "1");
                return finish_traced(req, stream, state, endpoint, t0, trace, resp);
            }
            None => {
                let resp = http::Response::json(
                    504,
                    openai::to_wire(&openai::error_body(
                        "timeout",
                        "engine did not produce a completion in time",
                    )),
                );
                return finish_traced(req, stream, state, endpoint, t0, trace, resp);
            }
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn stream_response(
    _req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    params: &openai::CompletionParams,
    req_id: &str,
    rx: &Receiver<StreamItem>,
    chat: bool,
    endpoint: &str,
    t0: Instant,
    trace: &ActiveTrace,
    chaos_abort: bool,
) -> std::io::Result<()> {
    sse::write_sse_head(stream)?;
    // reborrow: the severed path below needs the raw socket back after
    // the writer's last use to shut it down mid-body
    let mut writer = sse::SseWriter::new(&mut *stream);
    let mut write_failed: Option<std::io::Error> = None;
    // chaos: sever the socket after the first content event, with no
    // terminal error event and no chunked terminator — the messiest
    // mid-stream death a relay can observe. The coordinator's SSE relay
    // must convert this into exactly one terminal error event for its
    // own client (proven by chaos_resilience.rs).
    let mut severed = false;

    if chat {
        let chunk = openai::chat_role_chunk(req_id, &params.model);
        if let Err(e) = writer.event(&openai::to_wire(&chunk)) {
            write_failed = Some(e);
        }
    }

    // the wire status is already 200 (SSE head is out); this tracks the
    // *outcome* for metrics so incidents are visible on the scrape
    let mut outcome_status = 200u16;
    let deadline = Instant::now() + state.cfg.request_timeout;
    loop {
        match next_item(rx, state, deadline) {
            Some(StreamItem::Delta { text, finish }) => {
                if write_failed.is_none() {
                    let chunk = openai::stream_chunk(req_id, &params.model, &text, finish, chat);
                    if let Err(e) = writer.event(&openai::to_wire(&chunk)) {
                        write_failed = Some(e);
                    }
                }
                if chaos_abort && write_failed.is_none() {
                    severed = true;
                    break;
                }
            }
            Some(StreamItem::Done(c)) => {
                state.metrics.add_tokens(c.tokens.len());
                break;
            }
            Some(StreamItem::Error(msg)) => {
                outcome_status = 500;
                if write_failed.is_none() {
                    let chunk = openai::error_body("internal_error", &msg);
                    let _ = writer.event(&openai::to_wire(&chunk));
                }
                break;
            }
            Some(StreamItem::Unavailable(msg)) => {
                outcome_status = 503;
                if write_failed.is_none() {
                    let chunk = openai::error_body("service_unavailable", &msg);
                    let _ = writer.event(&openai::to_wire(&chunk));
                }
                break;
            }
            None => {
                outcome_status = 504; // engine stalled or handler deadline
                break;
            }
        }
    }

    if severed {
        state.metrics.add_sse_events(writer.events_written);
        record_trace(state, trace, 500);
        state
            .metrics
            .observe(endpoint, 500, t0.elapsed().as_secs_f64());
        // no chunked terminator, no terminal event: hard-close both
        // directions so the peer sees a truncated chunked body
        let _ = stream.shutdown(std::net::Shutdown::Both);
        return Err(std::io::Error::new(
            std::io::ErrorKind::ConnectionAborted,
            "chaos: SSE stream severed mid-flight",
        ));
    }

    // only a cleanly finished stream earns the `[DONE]` success marker; an
    // errored/shed/stalled stream ends with the bare chunked terminator so
    // clients can tell truncation from completion
    let tail_start = Instant::now();
    let io_result = if write_failed.is_none() && outcome_status == 200 {
        writer.done()
    } else {
        writer.finish()
    };
    state.metrics.add_sse_events(writer.events_written);
    // the sse phase covers the post-completion flush; per-delta writes
    // overlap the decode phase and are already accounted there
    trace_phase(state, trace, PHASE_SSE, tail_start, Instant::now());
    record_trace(state, trace, outcome_status);
    state
        .metrics
        .observe(endpoint, outcome_status, t0.elapsed().as_secs_f64());
    match write_failed {
        Some(e) => Err(e),
        None => io_result,
    }
}

/// `404` for the `/cluster/*` control surface when the gateway was not
/// started in node mode — a plain gateway must look exactly like one.
fn not_a_node(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    endpoint: &str,
    t0: Instant,
) -> std::io::Result<()> {
    let body = openai::to_wire(&openai::error_body(
        "invalid_request_error",
        "this gateway is not running in cluster node mode",
    ));
    finish(req, stream, state, endpoint, t0, http::Response::json(404, body))
}

/// Sum of batch-tier tenants' trailing arrival rates — the numerator of
/// the batch share the coordinator's tier-aware placement consumes.
fn batch_arrival_rps(state: &GatewayState) -> f64 {
    state
        .tenants
        .all()
        .iter()
        .filter(|t| t.tier() == SloTier::Batch)
        .map(|t| t.arrival_rps(5))
        .sum()
}

/// The status row served on `/v1/admin/status` and `/cluster/status`:
/// replica counts, free GPU memory against the node's advertisement, the
/// node-aggregated Table II frame + arrival rate the cluster-wide
/// supervisor scores, and the batch-tier share for tier-aware placement.
/// A plain (non-node) gateway reports a synthetic identity with no GPU
/// advertisement.
fn build_status(state: &Arc<GatewayState>) -> crate::cluster::proto::NodeStatus {
    let live = state.replicas.read().unwrap().len();
    let warm = state.warm.lock().unwrap().len();
    let ready_n = state.ready_replicas.load(Ordering::Acquire);
    let (frame, queue_wait) = match supervisor::cluster_sample(state) {
        Some((f, w)) => (Some(f), w),
        None => (None, 0.0),
    };
    let (node_id, total, free) = match &state.cfg.node {
        // warm standbys hold fully initialized engines: their memory is
        // just as claimed as a live replica's, so the advertisement the
        // coordinator bin-packs on must count them
        Some(id) => (
            id.node_id.clone(),
            id.gpu_memory_total,
            (id.gpu_memory_total - (live + warm) as f64 * id.replica_gpu_memory).max(0.0),
        ),
        None => (state.service.clone(), 0.0, 0.0),
    };
    crate::cluster::proto::NodeStatus {
        node_id,
        live_replicas: live,
        warm_replicas: warm,
        ready: live > 0 && ready_n >= live,
        gpu_memory_total: total,
        gpu_memory_free: free,
        frame,
        arrival_rps: supervisor::forecast_sample(state, 3).unwrap_or(0.0),
        queue_wait,
        batch_rps: batch_arrival_rps(state),
    }
}

/// `GET /v1/admin/status` — the versioned status row; unlike the
/// node-only `/cluster/status` alias this answers on every role.
fn admin_status(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
    t0: Instant,
) -> std::io::Result<()> {
    let body = build_status(state).to_json().to_string_compact();
    finish(req, stream, state, "/v1/admin/status", t0, http::Response::json(200, body))
}

/// `GET /cluster/status` (deprecated alias of `/v1/admin/status`) — the
/// heartbeat row a cluster coordinator polls; 404 off node mode, as the
/// pre-v1 contract promised.
fn cluster_status(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
    t0: Instant,
    _v1: bool,
) -> std::io::Result<()> {
    if state.cfg.node.is_none() {
        return not_a_node(req, stream, state, "/cluster/status", t0);
    }
    let body = build_status(state).to_json().to_string_compact();
    finish(req, stream, state, "/cluster/status", t0, http::Response::json(200, body))
}

/// A control-plane error rendered for the surface it was asked on: the
/// versioned `/v1/admin/*` endpoints answer with the structured
/// `{code, message, details}` body from [`crate::cluster::proto`], the
/// deprecated aliases keep the OpenAI-style `{"error": {...}}` wrapper
/// their existing callers parse.
fn admin_error_response(v1: bool, status: u16, err: crate::cluster::proto::AdminError) -> http::Response {
    if v1 {
        http::Response::json(status, err.to_json().to_string_compact())
    } else {
        http::Response::json(status, openai::to_wire(&openai::error_body(&err.code, &err.message)))
    }
}

/// `GET`/`POST /v1/admin/chaos` — read or replace the seeded
/// fault-injection config at runtime. Versioned surface only (this
/// endpoint never had a pre-v1 spelling). A POST reseeds the injector's
/// RNG from the new config's seed, so a scenario toggled on mid-run
/// replays exactly like one armed at startup; both verbs answer with the
/// resulting [`crate::cluster::proto::AdminChaosResponse`].
fn admin_chaos(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
    t0: Instant,
) -> std::io::Result<()> {
    use crate::cluster::proto::{AdminChaosRequest, AdminChaosResponse, AdminError};
    let endpoint = "/v1/admin/chaos";
    if req.method == "POST" {
        let body = match req.body_str() {
            Ok(b) => b,
            Err(e) => {
                let err = AdminError::new("invalid_request", &e.message);
                return finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, err));
            }
        };
        let json = match Json::parse(body) {
            Ok(j) => j,
            Err(e) => {
                let err = AdminError::new("invalid_request", &format!("invalid JSON: {e}"));
                return finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, err));
            }
        };
        let parsed = match AdminChaosRequest::from_json(&json) {
            Ok(r) => r,
            Err(e) => {
                return finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, e))
            }
        };
        state.chaos.set_config(parsed.config.clone());
        state.decisions.record(
            &state.service,
            "chaos_config",
            "admin",
            vec![
                ("armed", state.chaos.armed().to_string()),
                ("seed", parsed.config.seed.to_string()),
                ("generation", state.chaos.generation().to_string()),
            ],
        );
        crate::info!(
            "gateway",
            "chaos config replaced: armed={} generation={}",
            state.chaos.armed(),
            state.chaos.generation()
        );
    }
    let resp = AdminChaosResponse {
        service: state.service.clone(),
        config: state.chaos.config(),
        stats: state.chaos.stats_json(),
    };
    let body = resp.to_json().to_string_compact();
    finish(req, stream, state, endpoint, t0, http::Response::json(200, body))
}

/// `GET`/`POST /v1/admin/snapshots` — the node-side snapshot surface.
/// `GET` lists the bounded capture/restore ledger. `POST {"action":
/// "capture"}` checkpoints a live replica (between engine steps) and
/// returns the hex-encoded frame; `POST {"action": "restore",
/// "snapshot_hex": ...}` spawns a replica from a frame and reports the
/// promotion latency that beats a cold spawn. Restore failures are
/// fail-closed structured errors (`bad_snapshot`) — the caller falls back
/// to a cold spawn, never serves a half-restored engine.
fn admin_snapshots(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
    t0: Instant,
) -> std::io::Result<()> {
    use crate::cluster::proto::{
        AdminError, SnapshotAction, SnapshotListResponse, SnapshotRequest, SnapshotResponse,
    };
    use crate::cluster::snapshot::{from_hex, to_hex, EngineSnapshot};
    let endpoint = "/v1/admin/snapshots";
    if req.method == "GET" {
        let resp = SnapshotListResponse {
            service: state.service.clone(),
            snapshots: state.snapshots.lock().unwrap().clone(),
        };
        let body = resp.to_json().to_string_compact();
        return finish(req, stream, state, endpoint, t0, http::Response::json(200, body));
    }
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => {
            let err = AdminError::new("invalid_request", &e.message);
            return finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, err));
        }
    };
    let json = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            let err = AdminError::new("invalid_request", &format!("invalid JSON: {e}"));
            return finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, err));
        }
    };
    let sreq = match SnapshotRequest::from_json(&json) {
        Ok(r) => r,
        Err(e) => return finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, e)),
    };
    match sreq.action {
        SnapshotAction::Capture => {
            // default to the lowest live replica: deterministic, and on a
            // draining source it is the replica that has been up longest
            let id = match sreq.replica_id {
                Some(id) => id,
                None => match state.replicas.read().unwrap().keys().next().copied() {
                    Some(id) => id,
                    None => {
                        let err = AdminError::new("no_replicas", "no live replica to checkpoint");
                        return finish(
                            req, stream, state, endpoint, t0,
                            admin_error_response(true, 409, err),
                        );
                    }
                },
            };
            match snapshot_replica(state, id) {
                Ok(snap) => {
                    let info = snapshot_info(&snap, &format!("replica-{id}"));
                    remember_snapshot(state, info.clone());
                    state.decisions.record(
                        &state.service,
                        "snapshot",
                        "capture",
                        vec![
                            ("replica_id", id.to_string()),
                            ("engine_kind", snap.engine_kind.clone()),
                            ("payload_bytes", snap.payload.len().to_string()),
                        ],
                    );
                    let resp = SnapshotResponse {
                        service: state.service.clone(),
                        action: SnapshotAction::Capture,
                        info,
                        replica_id: id,
                        snapshot_hex: Some(to_hex(&snap.encode())),
                        promote_seconds: None,
                    };
                    let body = resp.to_json().to_string_compact();
                    finish(req, stream, state, endpoint, t0, http::Response::json(200, body))
                }
                Err(e) => {
                    let err = AdminError::new("snapshot_failed", &e)
                        .with_detail("replica_id", &id.to_string());
                    let status = if e.starts_with("unknown replica") { 404 } else { 500 };
                    finish(req, stream, state, endpoint, t0, admin_error_response(true, status, err))
                }
            }
        }
        SnapshotAction::Restore => {
            // presence validated by SnapshotRequest::from_json
            let hex = sreq.snapshot_hex.as_deref().unwrap_or_default();
            let snap = match from_hex(hex).and_then(|bytes| EngineSnapshot::decode(&bytes)) {
                Ok(s) => s,
                Err(e) => {
                    return finish(
                        req, stream, state, endpoint, t0,
                        admin_error_response(true, 400, e.to_admin_error()),
                    )
                }
            };
            // a node honors its advertised capacity on the restore path
            // exactly like on scale-up, so coordinator inventory and node
            // truth cannot drift through migrations
            if let Some(identity) = state.cfg.node.clone() {
                let live = state.replicas.read().unwrap().len();
                let warm = state.warm.lock().unwrap().len();
                let free =
                    identity.gpu_memory_total - (live + warm) as f64 * identity.replica_gpu_memory;
                if live >= identity.max_replicas || free < identity.replica_gpu_memory || free <= 0.0
                {
                    let err = AdminError::new(
                        "node_full",
                        &format!(
                            "node {} has no room to restore: {live} live + {warm} warm replicas, \
                             {free:.2} gpu_memory free",
                            identity.node_id
                        ),
                    )
                    .with_detail("node_id", &identity.node_id);
                    return finish(
                        req, stream, state, endpoint, t0,
                        admin_error_response(true, 409, err),
                    );
                }
            }
            let info = snapshot_info(&snap, &format!("restore:{}", snap.engine_kind));
            match restore_replica_from_snapshot(state, snap) {
                Ok((id, secs)) => {
                    remember_snapshot(state, info.clone());
                    state.decisions.record(
                        &state.service,
                        "snapshot",
                        "restore",
                        vec![
                            ("replica_id", id.to_string()),
                            ("engine_kind", info.engine_kind.clone()),
                            ("promote_seconds", format!("{secs:.6}")),
                        ],
                    );
                    let resp = SnapshotResponse {
                        service: state.service.clone(),
                        action: SnapshotAction::Restore,
                        info,
                        replica_id: id,
                        snapshot_hex: None,
                        promote_seconds: Some(secs),
                    };
                    let body = resp.to_json().to_string_compact();
                    finish(req, stream, state, endpoint, t0, http::Response::json(200, body))
                }
                Err(e) => {
                    let err = AdminError::new("bad_snapshot", &format!("restore failed: {e}"));
                    finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, err))
                }
            }
        }
    }
}

/// `POST /v1/admin/migrate` / `GET /v1/admin/migrations` on a node or
/// standalone gateway: migration is the coordinator's lifecycle, so this
/// surface answers the typed `unsupported` refusal (with the role in the
/// details) instead of a bare 404 — clients learn where to ask.
fn migrate_unsupported(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
    t0: Instant,
    endpoint: &'static str,
) -> std::io::Result<()> {
    let role = if state.cfg.node.is_some() { "node" } else { "gateway" };
    let err = crate::cluster::proto::AdminError::new(
        "unsupported",
        "live migration is driven by the cluster coordinator; call its /v1/admin/migrate",
    )
    .with_detail("role", role);
    finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, err))
}

/// `POST /v1/admin/scale-up` (alias `POST /cluster/scale-up`) — a
/// coordinator placement landing on this node: bring one more replica
/// live (warm promotion when the pool has a standby). `409` when the node
/// is at its advertised ceiling, so the coordinator's inventory and the
/// node's truth cannot drift silently.
fn cluster_scale_up(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
    t0: Instant,
    v1: bool,
) -> std::io::Result<()> {
    use crate::cluster::proto::{AdminError, AdminNodeScaleResponse, ScaleDirection};
    let endpoint = if v1 { "/v1/admin/scale-up" } else { "/cluster/scale-up" };
    let Some(identity) = state.cfg.node.clone() else {
        if v1 {
            let err = AdminError::new("not_a_node", "this gateway is not running in cluster node mode");
            return finish(req, stream, state, endpoint, t0, admin_error_response(true, 404, err));
        }
        return not_a_node(req, stream, state, endpoint, t0);
    };
    let live = state.replicas.read().unwrap().len();
    let warm = state.warm.lock().unwrap().len();
    // promotion consumes a warm engine rather than building a new one, but
    // the background refill rebuilds the standby — so admission counts
    // warm engines too: a node never holds more initialized engines than
    // its advertisement fits
    let free = identity.gpu_memory_total - (live + warm) as f64 * identity.replica_gpu_memory;
    if live >= identity.max_replicas || free < identity.replica_gpu_memory || free <= 0.0 {
        let err = AdminError::new(
            "node_full",
            &format!(
                "node {} has no room: {live} live + {warm} warm replicas, {free:.2} \
                 gpu_memory free",
                identity.node_id
            ),
        )
        .with_detail("node_id", &identity.node_id)
        .with_detail("live_replicas", &live.to_string())
        .with_detail("warm_replicas", &warm.to_string());
        return finish(req, stream, state, endpoint, t0, admin_error_response(v1, 409, err));
    }
    match hot_add_replica(state) {
        Ok(id) => {
            let live = state.replicas.read().unwrap().len();
            state.decisions.record(
                &state.service,
                "node_scale_up",
                "coordinator",
                vec![
                    ("replica_id", id.to_string()),
                    ("live_replicas", live.to_string()),
                ],
            );
            let resp = AdminNodeScaleResponse {
                node_id: identity.node_id.clone(),
                direction: ScaleDirection::Up,
                replica_id: id,
                live_replicas: live,
            };
            let body = resp.to_json().to_string_compact();
            finish(req, stream, state, endpoint, t0, http::Response::json(200, body))
        }
        Err(e) => {
            let err = AdminError::new("internal_error", &format!("{e}"));
            finish(req, stream, state, endpoint, t0, admin_error_response(v1, 500, err))
        }
    }
}

/// `POST /v1/admin/scale-down` (alias `POST /cluster/scale-down`) —
/// drain-then-retire this node's newest replica. `409` when only one
/// replica is live: a node never retires its last routable replica
/// (removing the whole node is the coordinator's call, not a drain's
/// side effect).
fn cluster_scale_down(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &Arc<GatewayState>,
    t0: Instant,
    v1: bool,
) -> std::io::Result<()> {
    use crate::cluster::proto::{AdminError, AdminNodeScaleResponse, ScaleDirection};
    let endpoint = if v1 { "/v1/admin/scale-down" } else { "/cluster/scale-down" };
    let Some(identity) = state.cfg.node.clone() else {
        if v1 {
            let err = AdminError::new("not_a_node", "this gateway is not running in cluster node mode");
            return finish(req, stream, state, endpoint, t0, admin_error_response(true, 404, err));
        }
        return not_a_node(req, stream, state, endpoint, t0);
    };
    let newest = {
        let replicas = state.replicas.read().unwrap();
        if replicas.len() <= 1 {
            None
        } else {
            replicas.keys().max().copied()
        }
    };
    let Some(id) = newest else {
        let err = AdminError::new(
            "node_at_floor",
            &format!("node {} will not retire its last replica", identity.node_id),
        )
        .with_detail("node_id", &identity.node_id);
        return finish(req, stream, state, endpoint, t0, admin_error_response(v1, 409, err));
    };
    match retire_replica(state, id) {
        Ok(()) => {
            let live = state.replicas.read().unwrap().len();
            state.decisions.record(
                &state.service,
                "node_scale_down",
                "coordinator",
                vec![
                    ("replica_id", id.to_string()),
                    ("live_replicas", live.to_string()),
                ],
            );
            let resp = AdminNodeScaleResponse {
                node_id: identity.node_id.clone(),
                direction: ScaleDirection::Down,
                replica_id: id,
                live_replicas: live,
            };
            let body = resp.to_json().to_string_compact();
            finish(req, stream, state, endpoint, t0, http::Response::json(200, body))
        }
        Err(e) => {
            let err = AdminError::new("internal_error", &format!("{e}"));
            finish(req, stream, state, endpoint, t0, admin_error_response(v1, 500, err))
        }
    }
}

/// `POST /v1/admin/scale` (alias `POST /admin/scale`) — replace the
/// router's replica weight table. The versioned surface validates through
/// [`crate::cluster::proto::AdminScaleRequest`] and reports failures as
/// structured `{code, message, details}` bodies; the deprecated alias
/// keeps its original OpenAI-style error strings for one release.
fn admin_scale(
    req: &http::Request,
    stream: &mut TcpStream,
    state: &GatewayState,
    t0: Instant,
    v1: bool,
) -> std::io::Result<()> {
    use crate::cluster::proto::{AdminError, AdminScaleRequest, AdminScaleResponse, ReplicaWeight};
    let endpoint = if v1 { "/v1/admin/scale" } else { "/admin/scale" };
    let bad = |msg: &str| {
        if v1 {
            admin_error_response(true, 400, AdminError::new("invalid_request", msg))
        } else {
            http::Response::json(
                400,
                openai::to_wire(&openai::error_body("invalid_request_error", msg)),
            )
        }
    };
    let body = match req.body_str() {
        Ok(b) => b,
        Err(e) => return finish(req, stream, state, endpoint, t0, bad(&e.message)),
    };
    let json = match Json::parse(body) {
        Ok(j) => j,
        Err(e) => {
            return finish(req, stream, state, endpoint, t0, bad(&format!("invalid JSON: {e}")))
        }
    };
    let weights: Vec<(u64, f64)> = if v1 {
        // versioned surface: one typed parser, shared with every client
        match AdminScaleRequest::from_json(&json) {
            Ok(r) => r.replicas.iter().map(|w| (w.id, w.weight)).collect(),
            Err(e) => {
                return finish(req, stream, state, endpoint, t0, admin_error_response(true, 400, e))
            }
        }
    } else {
        let Some(entries) = json.get("replicas").and_then(Json::as_arr) else {
            return finish(
                req,
                stream,
                state,
                endpoint,
                t0,
                bad("body must be {\"replicas\": [{\"id\": N, \"weight\": W}, ...]}"),
            );
        };
        if entries.is_empty() {
            return finish(req, stream, state, endpoint, t0, bad("replica set must not be empty"));
        }
        let mut weights: Vec<(u64, f64)> = Vec::with_capacity(entries.len());
        for e in entries {
            let id = match e.get("id").and_then(Json::as_f64) {
                Some(x) if x >= 0.0 && x.fract() == 0.0 => x as u64,
                _ => {
                    return finish(
                        req,
                        stream,
                        state,
                        endpoint,
                        t0,
                        bad("each replica needs a non-negative integer \"id\""),
                    )
                }
            };
            let weight = match e.get("weight").and_then(Json::as_f64) {
                Some(w) if w > 0.0 => w,
                _ => return finish(req, stream, state, endpoint, t0, bad("each replica needs a positive \"weight\"")),
            };
            if weights.iter().any(|&(seen, _)| seen == id) {
                return finish(req, stream, state, endpoint, t0, bad(&format!("duplicate replica id {id}")));
            }
            weights.push((id, weight));
        }
        weights
    };
    // validate the whole id set against *live workers*: weighting a
    // retired or never-spawned replica would route traffic into the void
    // (requests would hang until timeout with no worker to serve them)
    let (unknown, known): (Vec<u64>, Vec<u64>) = {
        let live = state.replicas.read().unwrap();
        (
            weights
                .iter()
                .map(|&(id, _)| id)
                .filter(|id| !live.contains_key(id))
                .collect(),
            live.keys().copied().collect(),
        )
    };
    if !unknown.is_empty() {
        let msg = format!("unknown replica ids {unknown:?}; live replicas are {known:?}");
        let resp = if v1 {
            let err = AdminError::new("unknown_replica", &msg)
                .with_detail("unknown", &format!("{unknown:?}"))
                .with_detail("live", &format!("{known:?}"));
            admin_error_response(true, 400, err)
        } else {
            bad(&msg)
        };
        return finish(req, stream, state, endpoint, t0, resp);
    }
    state.router.write().unwrap().set_weights(&weights);
    crate::info!("gateway", "ingress update applied: {weights:?}");
    let resp = AdminScaleResponse {
        applied: weights.iter().map(|&(id, weight)| ReplicaWeight { id, weight }).collect(),
        routable_replicas: weights.len(),
    };
    let body = resp.to_json().to_string_compact();
    finish(req, stream, state, endpoint, t0, http::Response::json(200, body))
}
