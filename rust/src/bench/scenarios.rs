//! Shared experiment scenarios for the paper-reproduction benches:
//! calibration-probe runs, the full per-method configuration
//! recommendation (ENOVA / Default / COSE / DDPG), and cluster assembly —
//! so each bench file is just sweep + reporting.

use crate::config;
use crate::metrics::Frame;
use crate::simulator::cluster::ClusterSim;
use crate::simulator::gpu::GpuSpec;
use crate::simulator::modelcard::ModelCard;
use crate::simulator::replica::{Replica, Request, ServiceConfig};
use crate::util::rng::Pcg64;
use crate::workload::arrivals::{poisson_stream, RateProfile};
use crate::workload::corpus::{CorpusMix, TaskFamily};
use crate::{baselines, baselines::cose, baselines::ddpg};

/// The gsm8k+mbpp mixed workload of §VI-A.
pub fn eval_mix() -> CorpusMix {
    CorpusMix::uniform(&[TaskFamily::Gsm8k, TaskFamily::Mbpp])
}

/// Run the calibration probe: a generously-configured replica under load,
/// returning its monitoring frames and finished-output lengths.
pub fn calibration_run(
    gpu: &'static GpuSpec,
    model: &'static ModelCard,
    seed: u64,
) -> (Vec<Frame>, Vec<f64>, f64) {
    let space = baselines::ConfigSpace::for_model(gpu, model);
    let probe_cfg = ServiceConfig {
        max_num_seqs: 256,
        gpu_memory: 0.9,
        max_tokens: model.max_model_tokens,
        parallel_size: space.parallel_size,
    };
    let rep = Replica::new(gpu, model, probe_cfg);
    let mut rng = Pcg64::new(seed);
    // saturating probe so the capacity limit is observable
    let arrivals = poisson_stream(&RateProfile::constant(30.0), &eval_mix(), 240.0, &mut rng);
    let res = rep.simulate(arrivals, 300.0);
    let frames: Vec<Frame> = res.frames.iter().map(|&(_, f)| f).collect();
    let lens: Vec<f64> = res.finished.iter().map(|f| f.out_len as f64).collect();
    (frames, lens, res.finished_rps())
}

/// ENOVA's full recommendation for one (gpu, model), plus the estimated
/// per-replica n_limit used for routing weights.
pub fn enova_recommend(
    gpu: &'static GpuSpec,
    model: &'static ModelCard,
    seed: u64,
) -> (ServiceConfig, f64) {
    let (frames, lens, n_limit) = calibration_run(gpu, model, seed);
    let cfg = config::recommend_for(gpu, model, &frames, &lens);
    (cfg, n_limit)
}

/// Per-community ENOVA max_tokens (gsm8k vs mbpp), as Table III reports.
pub fn enova_max_tokens_per_task(seed: u64) -> (usize, usize) {
    let mut rng = Pcg64::new(seed);
    let g: Vec<f64> = (0..4000)
        .map(|_| TaskFamily::Gsm8k.sample_output_len(&mut rng) as f64)
        .collect();
    let m: Vec<f64> = (0..4000)
        .map(|_| TaskFamily::Mbpp.sample_output_len(&mut rng) as f64)
        .collect();
    (
        config::determine_max_tokens(&g).unwrap_or(4096),
        config::determine_max_tokens(&m).unwrap_or(4096),
    )
}

/// The throughput-maximization environment the baselines search against.
pub fn throughput_env(
    gpu: &'static GpuSpec,
    model: &'static ModelCard,
    seed: u64,
) -> baselines::ThroughputEnv {
    let mut rng = Pcg64::new(seed ^ 0xe11);
    let arrivals = poisson_stream(&RateProfile::constant(25.0), &eval_mix(), 120.0, &mut rng);
    baselines::ThroughputEnv {
        gpu,
        model,
        arrivals,
        horizon: 180.0,
    }
}

#[derive(Debug, Clone)]
pub struct MethodConfig {
    pub method: &'static str,
    pub config: ServiceConfig,
    /// routing-weight basis (per-replica capacity estimate)
    pub weight_basis: f64,
}

/// Recommend configurations for one (gpu, model) with every method of
/// §VI-A. Weight basis: ENOVA uses n_limit (§IV-A-4); the baselines use
/// their own best-found throughput; Default has none (weight 1).
pub fn all_method_configs(
    gpu: &'static GpuSpec,
    model: &'static ModelCard,
    seed: u64,
) -> Vec<MethodConfig> {
    let space = baselines::ConfigSpace::for_model(gpu, model);
    let env = throughput_env(gpu, model, seed);
    let (enova_cfg, n_limit) = enova_recommend(gpu, model, seed);
    let cose_res = cose::optimize(&env, &space, &cose::CoseOpts { seed, ..Default::default() });
    let ddpg_res = ddpg::optimize(&env, &space, &ddpg::DdpgOpts { seed, ..Default::default() });
    vec![
        MethodConfig {
            method: "Default",
            config: baselines::default_config(&space),
            weight_basis: 1.0,
        },
        MethodConfig {
            method: "COSE",
            config: cose_res.config,
            weight_basis: cose_res.best_throughput.max(1e-9),
        },
        MethodConfig {
            method: "DDPG",
            config: ddpg_res.config,
            weight_basis: ddpg_res.best_throughput.max(1e-9),
        },
        MethodConfig {
            method: "ENOVA",
            config: enova_cfg,
            weight_basis: n_limit.max(1e-9),
        },
    ]
}

/// Build the paper's two-device cluster (1 replica on A100 + 1 on 4090,
/// §VI-A experiment setup) for a method's configs, with weights from the
/// method's weight basis.
pub fn two_device_cluster(
    model: &'static ModelCard,
    a100_cfg: ServiceConfig,
    a100_basis: f64,
    r4090_cfg: ServiceConfig,
    r4090_basis: f64,
) -> ClusterSim {
    use crate::simulator::gpu::{A100_80G, RTX4090_24G};
    let wmax = a100_basis.max(r4090_basis).max(1e-9);
    ClusterSim::new(
        vec![
            Replica::new(&A100_80G, model, a100_cfg),
            Replica::new(&RTX4090_24G, model, r4090_cfg),
        ],
        vec![a100_basis / wmax, r4090_basis / wmax],
    )
}

/// A 15-minute evaluation trace at a given tps.
pub fn eval_trace(tps: f64, seed: u64) -> Vec<Request> {
    let mut rng = Pcg64::new(seed);
    poisson_stream(&RateProfile::constant(tps), &eval_mix(), 900.0, &mut rng)
}
