//! In-tree benchmark harness (criterion is not in the offline crate set):
//! wall-clock measurement with warmup, percentile summaries, ASCII table /
//! series rendering, and CSV dumps under `target/bench_out/` so every
//! paper table and figure regenerates into both a terminal report and a
//! plottable file.

pub mod scenarios;

use std::fmt::Write as _;
use std::time::Instant;

/// Measure a closure: `warmup` unrecorded runs then `iters` timed runs.
pub fn time_it<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> Timing {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    Timing::from_samples(samples)
}

#[derive(Debug, Clone)]
pub struct Timing {
    pub samples: Vec<f64>,
}

impl Timing {
    pub fn from_samples(mut samples: Vec<f64>) -> Timing {
        samples.sort_by(f64::total_cmp);
        Timing { samples }
    }

    pub fn mean(&self) -> f64 {
        crate::stats::descriptive::mean(&self.samples)
    }

    pub fn p50(&self) -> f64 {
        crate::stats::descriptive::quantile_sorted(&self.samples, 0.5)
    }

    pub fn p99(&self) -> f64 {
        crate::stats::descriptive::quantile_sorted(&self.samples, 0.99)
    }

    pub fn min(&self) -> f64 {
        self.samples.first().copied().unwrap_or(0.0)
    }
}

pub fn fmt_duration(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}µs", secs * 1e6)
    }
}

/// A rendered table: header + rows, printed aligned and dumped as CSV.
pub struct Table {
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len());
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        let mut out = String::new();
        let _ = writeln!(out, "\n== {} ==", self.title);
        let line = |out: &mut String, cells: &[String]| {
            let mut s = String::from("| ");
            for (c, w) in cells.iter().zip(&widths) {
                let _ = write!(s, "{c:>w$} | ", w = w);
            }
            let _ = writeln!(out, "{}", s.trim_end());
        };
        line(&mut out, &self.header);
        let total: usize = widths.iter().sum::<usize>() + 3 * widths.len() + 1;
        let _ = writeln!(out, "{}", "-".repeat(total));
        for row in &self.rows {
            line(&mut out, row);
        }
        out
    }

    pub fn print(&self) {
        println!("{}", self.render());
    }

    /// Write `target/bench_out/<name>.csv`.
    pub fn dump_csv(&self, name: &str) {
        let dir = std::path::Path::new("target/bench_out");
        let _ = std::fs::create_dir_all(dir);
        let mut out = self.header.join(",");
        out.push('\n');
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        let _ = std::fs::write(dir.join(format!("{name}.csv")), out);
    }
}

/// Render an (x, y) series as a compact ASCII sparkline block — the
/// "figure" half of each bench's output.
pub fn render_series(title: &str, xs: &[f64], ys: &[f64], y_label: &str) -> String {
    const GLYPHS: [char; 8] = ['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let lo = ys.iter().copied().fold(f64::INFINITY, f64::min);
    let hi = ys.iter().copied().fold(f64::NEG_INFINITY, f64::max);
    let span = (hi - lo).max(1e-12);
    let mut bar = String::new();
    for &y in ys {
        let idx = (((y - lo) / span) * 7.0).round() as usize;
        bar.push(GLYPHS[idx.min(7)]);
    }
    format!(
        "{title}\n  x: {:.1}..{:.1}  {y_label}: {:.3}..{:.3}\n  {bar}",
        xs.first().copied().unwrap_or(0.0),
        xs.last().copied().unwrap_or(0.0),
        lo,
        hi
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timing_percentiles() {
        let t = Timing::from_samples(vec![3.0, 1.0, 2.0]);
        assert_eq!(t.p50(), 2.0);
        assert_eq!(t.min(), 1.0);
        assert!((t.mean() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn table_renders_and_dumps() {
        let mut t = Table::new("demo", &["a", "bb"]);
        t.row(&["1".into(), "2".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("| 1 |"));
    }

    #[test]
    fn series_sparkline() {
        let s = render_series("t", &[0.0, 1.0, 2.0], &[0.0, 0.5, 1.0], "y");
        assert!(s.contains('▁') && s.contains('█'));
    }

    #[test]
    fn time_it_measures() {
        let t = time_it(1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(t.samples.len(), 5);
        assert!(t.mean() >= 0.0);
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(2.5), "2.50s");
        assert_eq!(fmt_duration(0.0025), "2.50ms");
        assert_eq!(fmt_duration(2.5e-6), "2.5µs");
    }
}
