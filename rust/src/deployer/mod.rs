//! Multi-cluster deployment execution engine (§V): node/GPU inventory,
//! multi-cluster + local-cluster job scheduling, service lifecycle, and
//! ingress registration. In the paper this is Kubernetes + vLLM; here it
//! is one process orchestrating simulator replicas and/or real engines.

use crate::simulator::gpu::GpuSpec;
use crate::simulator::modelcard::ModelCard;
use crate::simulator::replica::ServiceConfig;
use std::collections::BTreeMap;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServiceState {
    Launching,
    Ready,
    Draining,
    Stopped,
}

#[derive(Debug, Clone)]
pub struct Node {
    pub name: String,
    pub gpu: &'static GpuSpec,
    pub total_gpus: usize,
    pub free_gpus: usize,
}

#[derive(Debug, Clone)]
pub struct LocalCluster {
    pub name: String,
    pub nodes: Vec<Node>,
}

impl LocalCluster {
    pub fn free_gpus_of(&self, gpu: &GpuSpec) -> usize {
        self.nodes
            .iter()
            .filter(|n| n.gpu.name == gpu.name)
            .map(|n| n.free_gpus)
            .sum()
    }
}

#[derive(Debug, Clone)]
pub struct Deployment {
    pub id: u64,
    pub model: &'static ModelCard,
    pub cluster: String,
    pub node: String,
    pub gpu: &'static GpuSpec,
    pub config: ServiceConfig,
    pub state: ServiceState,
    /// routing weight registered with the ingress
    pub weight: f64,
}

/// The multi-cluster job scheduler + ingress table.
#[derive(Debug, Default)]
pub struct Deployer {
    pub clusters: Vec<LocalCluster>,
    pub deployments: BTreeMap<u64, Deployment>,
    next_id: u64,
}

#[derive(Debug, PartialEq, Eq)]
pub enum DeployError {
    NoCapacity,
    UnknownDeployment,
}

impl Deployer {
    pub fn new(clusters: Vec<LocalCluster>) -> Deployer {
        Deployer {
            clusters,
            ..Default::default()
        }
    }

    /// Place one replica: first-fit over clusters/nodes with enough free
    /// GPUs of the requested type (the local-cluster scheduler decision).
    pub fn deploy(
        &mut self,
        model: &'static ModelCard,
        gpu: &'static GpuSpec,
        config: ServiceConfig,
        weight: f64,
    ) -> Result<u64, DeployError> {
        let need = config.parallel_size.max(1);
        for cluster in self.clusters.iter_mut() {
            for node in cluster.nodes.iter_mut() {
                if node.gpu.name == gpu.name && node.free_gpus >= need {
                    node.free_gpus -= need;
                    let id = self.next_id;
                    self.next_id += 1;
                    self.deployments.insert(
                        id,
                        Deployment {
                            id,
                            model,
                            cluster: cluster.name.clone(),
                            node: node.name.clone(),
                            gpu,
                            config,
                            state: ServiceState::Launching,
                            weight,
                        },
                    );
                    return Ok(id);
                }
            }
        }
        Err(DeployError::NoCapacity)
    }

    pub fn mark_ready(&mut self, id: u64) -> Result<(), DeployError> {
        let d = self
            .deployments
            .get_mut(&id)
            .ok_or(DeployError::UnknownDeployment)?;
        d.state = ServiceState::Ready;
        Ok(())
    }

    /// Drain + stop a deployment, releasing its GPUs.
    pub fn stop(&mut self, id: u64) -> Result<(), DeployError> {
        let d = self
            .deployments
            .get_mut(&id)
            .ok_or(DeployError::UnknownDeployment)?;
        d.state = ServiceState::Stopped;
        let (cluster, node, need) = (d.cluster.clone(), d.node.clone(), d.config.parallel_size.max(1));
        for c in self.clusters.iter_mut() {
            if c.name == cluster {
                for n in c.nodes.iter_mut() {
                    if n.name == node {
                        n.free_gpus = (n.free_gpus + need).min(n.total_gpus);
                    }
                }
            }
        }
        Ok(())
    }

    /// Relaunch with a new config (the autoscaler's reconfiguration path):
    /// same placement, Launching state, new knobs.
    pub fn reconfigure(&mut self, id: u64, config: ServiceConfig) -> Result<(), DeployError> {
        let d = self
            .deployments
            .get_mut(&id)
            .ok_or(DeployError::UnknownDeployment)?;
        d.config = config;
        d.state = ServiceState::Launching;
        Ok(())
    }

    /// The ingress view: (deployment id, weight) of all Ready services for
    /// a model.
    pub fn ingress_table(&self, model: &ModelCard) -> Vec<(u64, f64)> {
        self.deployments
            .values()
            .filter(|d| d.state == ServiceState::Ready && d.model.name == model.name)
            .map(|d| (d.id, d.weight))
            .collect()
    }

    pub fn ready_count(&self, model: &ModelCard) -> usize {
        self.ingress_table(model).len()
    }
}

/// One serving node as the *distributed* control plane sees it: the
/// capacity advertisement a [`crate::cluster`] node registers with the
/// coordinator, refreshed from its heartbeat status. Unlike [`Node`]
/// (whole GPUs of a named device type), inventory is tracked in abstract
/// GPU-memory units so heterogeneous nodes compare on one axis — the
/// quantity the paper's `gpu_memory` knob is denominated in.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeInventory {
    pub node_id: String,
    /// GPU memory the node advertises in total
    pub gpu_memory_total: f64,
    /// GPU memory not yet claimed by a live replica
    pub gpu_memory_free: f64,
    /// memory one more replica on this node would claim
    pub replica_gpu_memory: f64,
    pub live_replicas: usize,
    /// the node's own replica ceiling
    pub max_replicas: usize,
}

impl NodeInventory {
    /// Whether one more replica fits: under the node's replica ceiling and
    /// with enough free GPU memory for the node's per-replica footprint.
    /// A node with no free memory never has room, whatever its footprint
    /// claims.
    pub fn has_room(&self) -> bool {
        self.live_replicas < self.max_replicas
            && self.gpu_memory_free > 0.0
            && self.gpu_memory_free >= self.replica_gpu_memory
    }

    /// Free-to-total ratio — the fragmentation axis the retire path drains
    /// by (most-fragmented first). 0 for a degenerate zero-memory node.
    pub fn fragmentation(&self) -> f64 {
        if self.gpu_memory_total > 0.0 {
            (self.gpu_memory_free / self.gpu_memory_total).clamp(0.0, 1.0)
        } else {
            0.0
        }
    }
}

/// A standard two-cluster testbed mirroring the paper's: 8×A100 + 8×4090.
pub fn paper_testbed() -> Vec<LocalCluster> {
    use crate::simulator::gpu::{A100_80G, RTX4090_24G};
    vec![
        LocalCluster {
            name: "cluster-a100".into(),
            nodes: vec![Node {
                name: "a100-node-0".into(),
                gpu: &A100_80G,
                total_gpus: 8,
                free_gpus: 8,
            }],
        },
        LocalCluster {
            name: "cluster-4090".into(),
            nodes: vec![Node {
                name: "4090-node-0".into(),
                gpu: &RTX4090_24G,
                total_gpus: 8,
                free_gpus: 8,
            }],
        },
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::simulator::gpu::{A100_80G, RTX4090_24G};
    use crate::simulator::modelcard::{LLAMA2_70B, LLAMA2_7B};

    fn cfg(p: usize) -> ServiceConfig {
        ServiceConfig {
            max_num_seqs: 32,
            gpu_memory: 0.9,
            max_tokens: 512,
            parallel_size: p,
        }
    }

    #[test]
    fn placement_and_lifecycle() {
        let mut dep = Deployer::new(paper_testbed());
        let id = dep.deploy(&LLAMA2_7B, &A100_80G, cfg(1), 1.0).unwrap();
        assert_eq!(dep.deployments[&id].state, ServiceState::Launching);
        assert_eq!(dep.ready_count(&LLAMA2_7B), 0);
        dep.mark_ready(id).unwrap();
        assert_eq!(dep.ready_count(&LLAMA2_7B), 1);
        assert_eq!(dep.clusters[0].free_gpus_of(&A100_80G), 7);
        dep.stop(id).unwrap();
        assert_eq!(dep.clusters[0].free_gpus_of(&A100_80G), 8);
        assert_eq!(dep.ready_count(&LLAMA2_7B), 0);
    }

    #[test]
    fn tp_groups_consume_gpus() {
        let mut dep = Deployer::new(paper_testbed());
        // 70B on A100 takes TP2 → 4 fit on the 8-GPU node
        for _ in 0..4 {
            dep.deploy(&LLAMA2_70B, &A100_80G, cfg(2), 1.0).unwrap();
        }
        assert_eq!(
            dep.deploy(&LLAMA2_70B, &A100_80G, cfg(2), 1.0),
            Err(DeployError::NoCapacity)
        );
        // but the 4090 cluster is untouched
        assert_eq!(dep.clusters[1].free_gpus_of(&RTX4090_24G), 8);
    }

    #[test]
    fn ingress_filters_by_model_and_state() {
        let mut dep = Deployer::new(paper_testbed());
        let a = dep.deploy(&LLAMA2_7B, &A100_80G, cfg(1), 1.0).unwrap();
        let b = dep.deploy(&LLAMA2_7B, &RTX4090_24G, cfg(1), 0.89).unwrap();
        let c = dep.deploy(&LLAMA2_70B, &A100_80G, cfg(2), 1.0).unwrap();
        for id in [a, b, c] {
            dep.mark_ready(id).unwrap();
        }
        let table = dep.ingress_table(&LLAMA2_7B);
        assert_eq!(table.len(), 2);
        assert!(table.iter().any(|&(_, w)| (w - 0.89).abs() < 1e-9));
        // reconfiguration takes a service out of rotation until ready
        dep.reconfigure(b, cfg(1)).unwrap();
        assert_eq!(dep.ingress_table(&LLAMA2_7B).len(), 1);
    }
}
