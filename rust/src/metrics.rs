//! The Table II monitoring-metric registry shared by the real engine, the
//! simulator and the detection module. Column order must match the python
//! trace generator (`python/compile/traces.py::METRIC_NAMES`) because the
//! VAE artifact was trained on that layout.

use crate::tsdb::MetricStore;

pub const N_FINISHED: &str = "n_finished"; // n^f — finished requests / unit time
pub const N_RUNNING: &str = "n_running"; // n^r — running requests
pub const N_ARRIVING: &str = "n_arriving"; // n^a — arriving requests / unit time
pub const N_PENDING: &str = "n_pending"; // n^p — queued requests
pub const T_REQUEST: &str = "t_request"; // t^r — execution time per request (s)
pub const MEM_UTIL: &str = "mem_util"; // m^u — GPU memory utilization
pub const GPU_UTIL: &str = "gpu_util"; // g^u — GPU compute utilization
pub const KV_UTIL: &str = "kv_util"; // KV-cache block utilization

/// Column order of the VAE feature vector (== traces.METRIC_NAMES).
pub const COLUMNS: [&str; 8] = [
    N_FINISHED, N_RUNNING, N_ARRIVING, N_PENDING, T_REQUEST, MEM_UTIL, GPU_UTIL, KV_UTIL,
];

/// One observation row in COLUMNS order.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct Frame {
    pub n_finished: f64,
    pub n_running: f64,
    pub n_arriving: f64,
    pub n_pending: f64,
    pub t_request: f64,
    pub mem_util: f64,
    pub gpu_util: f64,
    pub kv_util: f64,
}

impl Frame {
    pub fn to_array(self) -> [f64; 8] {
        [
            self.n_finished,
            self.n_running,
            self.n_arriving,
            self.n_pending,
            self.t_request,
            self.mem_util,
            self.gpu_util,
            self.kv_util,
        ]
    }

    pub fn from_array(a: [f64; 8]) -> Frame {
        Frame {
            n_finished: a[0],
            n_running: a[1],
            n_arriving: a[2],
            n_pending: a[3],
            t_request: a[4],
            mem_util: a[5],
            gpu_util: a[6],
            kv_util: a[7],
        }
    }

    /// Record the frame into the store under `instance` at time `t`.
    pub fn record(&self, store: &mut MetricStore, instance: &str, t: f64) {
        for (name, value) in COLUMNS.iter().zip(self.to_array()) {
            store.push(name, instance, t, value);
        }
    }
}

/// Read the latest `n` frames for an instance back out of the store.
/// Rows are aligned by position (all series are appended together by
/// [`Frame::record`]).
pub fn recent_frames(store: &MetricStore, instance: &str, n: usize) -> Vec<Frame> {
    let per_metric: Vec<Vec<f64>> = COLUMNS
        .iter()
        .map(|m| {
            store
                .series(m, instance)
                .map(|s| s.last_n(n))
                .unwrap_or_default()
        })
        .collect();
    let rows = per_metric.iter().map(|v| v.len()).min().unwrap_or(0);
    (0..rows)
        .map(|i| {
            let mut a = [0.0; 8];
            for (j, col) in per_metric.iter().enumerate() {
                a[j] = col[col.len() - rows + i];
            }
            Frame::from_array(a)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_through_store() {
        let mut store = MetricStore::new();
        for i in 0..5 {
            let f = Frame {
                n_finished: i as f64,
                n_running: 2.0 * i as f64,
                ..Default::default()
            };
            f.record(&mut store, "replica-0", i as f64);
        }
        let frames = recent_frames(&store, "replica-0", 3);
        assert_eq!(frames.len(), 3);
        assert_eq!(frames[2].n_finished, 4.0);
        assert_eq!(frames[2].n_running, 8.0);
    }

    #[test]
    fn recent_frames_tolerates_misaligned_series() {
        let mut store = MetricStore::new();
        for i in 0..3 {
            Frame {
                n_finished: i as f64,
                n_running: 2.0 * i as f64,
                ..Default::default()
            }
            .record(&mut store, "r", i as f64);
        }
        // one series runs ahead by two points (partial frame write): row
        // count must clamp to the shortest series, aligned from the tail
        store.push(N_FINISHED, "r", 3.0, 100.0);
        store.push(N_FINISHED, "r", 4.0, 101.0);
        let frames = recent_frames(&store, "r", 5);
        assert_eq!(frames.len(), 3, "bounded by the shortest series");
        assert_eq!(frames[2].n_finished, 101.0, "tail-aligned");
        assert_eq!(frames[2].n_running, 4.0);
        assert_eq!(frames[0].n_finished, 2.0);

        // an instance missing one column entirely yields no rows rather
        // than panicking or fabricating values
        let mut partial = MetricStore::new();
        for m in COLUMNS.iter().take(7) {
            partial.push(m, "q", 0.0, 1.0);
        }
        assert!(recent_frames(&partial, "q", 4).is_empty());
        assert!(recent_frames(&partial, "absent", 4).is_empty());
    }

    #[test]
    fn array_roundtrip() {
        let f = Frame {
            n_finished: 1.0,
            n_running: 2.0,
            n_arriving: 3.0,
            n_pending: 4.0,
            t_request: 5.0,
            mem_util: 0.5,
            gpu_util: 0.7,
            kv_util: 0.9,
        };
        assert_eq!(Frame::from_array(f.to_array()), f);
    }

    #[test]
    fn column_order_matches_python() {
        // pinned: the VAE artifact depends on this exact order
        assert_eq!(
            COLUMNS,
            [
                "n_finished", "n_running", "n_arriving", "n_pending",
                "t_request", "mem_util", "gpu_util", "kv_util"
            ]
        );
    }
}
