//! Pure placement math for the cluster supervisor: which node receives
//! the next replica, and which node is drained first on scale-down. Kept
//! free of I/O and locks so every decision rule is unit-testable the same
//! way the `config` module's estimators are.
//!
//! Scale-up is **bin-packing by free `gpu_memory` with spread-by-default
//! anti-affinity**: among nodes with room (under their replica ceiling,
//! enough free memory for their per-replica footprint), pick the one with
//! the fewest live replicas — spreading load and blast radius — breaking
//! ties toward the most free memory (the best-packed bin for a later,
//! bigger tenant), then lexicographically by node id so equal clusters
//! place deterministically.
//!
//! Scale-down drains the **most-fragmented node first**: the highest
//! free/total memory ratio among drainable nodes, so retires consolidate
//! the fleet instead of nibbling evenly at every node. Nodes with a
//! single live replica are not drainable — a node's gateway refuses to
//! retire its last routable replica, and an empty-but-running node is the
//! coordinator's decision to make by *removing* the node, not this
//! function's.

use std::collections::BTreeMap;

use crate::deployer::NodeInventory;
use crate::gateway::admission::SloTier;

/// The node that should receive the next replica, or `None` when no node
/// has room (cluster full — the caller should hold the scale-up and keep
/// observing, exactly like the single-node supervisor at `max_replicas`).
pub fn place_replica(nodes: &[NodeInventory]) -> Option<&NodeInventory> {
    place_replica_tiered(nodes, &BTreeMap::new(), SloTier::Standard)
}

/// A node whose arrival traffic is more than half batch-tier is
/// "batch-heavy" for anti-affinity purposes (placement here, and the
/// coordinator's latency-tier proxy steering).
pub const BATCH_HEAVY_SHARE: f64 = 0.5;

/// Tier-aware scale-up (the SLO-tier placement constraint): a placement
/// driven by **latency**-tier demand avoids batch-heavy nodes (latency
/// tenants get anti-affinity from batch tenants' replicas), a placement
/// driven by **batch** demand prefers them (the two classes consolidate
/// apart instead of interleaving), and **standard** keeps the plain
/// spread-by-default rule. `batch_share` maps node id → the fraction
/// [0, 1] of that node's arrival rate coming from batch-tier tenants
/// (from [`crate::cluster::proto::NodeStatus`]'s `batch_rps /
/// arrival_rps`); missing nodes count as batch-free. The tier preference
/// is a coarse bucket, never a hard filter — when only a "wrong" node has
/// room, it is still used: capacity beats affinity.
pub fn place_replica_tiered<'a>(
    nodes: &'a [NodeInventory],
    batch_share: &BTreeMap<String, f64>,
    tier: SloTier,
) -> Option<&'a NodeInventory> {
    let heavy = |n: &NodeInventory| {
        batch_share.get(&n.node_id).copied().unwrap_or(0.0) > BATCH_HEAVY_SHARE
    };
    nodes.iter().filter(|n| n.has_room()).min_by(|a, b| {
        let affinity = match tier {
            SloTier::Latency => heavy(a).cmp(&heavy(b)), // false < true: avoid heavy
            SloTier::Batch => heavy(b).cmp(&heavy(a)),   // prefer heavy
            SloTier::Standard => std::cmp::Ordering::Equal,
        };
        affinity
            .then(a.live_replicas.cmp(&b.live_replicas))
            .then(b.gpu_memory_free.total_cmp(&a.gpu_memory_free))
            .then(a.node_id.cmp(&b.node_id))
    })
}

/// The node to drain on scale-down: most-fragmented first (highest
/// free/total ratio), ties toward fewer live replicas (cheapest to empty),
/// then node id. `None` when no node can give up a replica.
pub fn drain_node(nodes: &[NodeInventory]) -> Option<&NodeInventory> {
    nodes
        .iter()
        .filter(|n| n.live_replicas >= 2)
        .max_by(|a, b| {
            a.fragmentation()
                .total_cmp(&b.fragmentation())
                .then(b.live_replicas.cmp(&a.live_replicas))
                .then(b.node_id.cmp(&a.node_id))
        })
}

/// One defragmentation move for the idle supervisor: `(source, target)`
/// node ids such that live-migrating a replica off `source` onto `target`
/// genuinely improves the spread. The source is the drain pick (most
/// fragmented, ≥2 replicas so its gateway can retire one); the target is
/// the placement pick among the *other* nodes; and the move only counts
/// when the target ends up strictly below where the source started
/// (`target.live + 1 < source.live`) — anything weaker just swaps two
/// equally-loaded nodes forever. `None` means the fleet is already as
/// balanced as one move can make it.
pub fn defrag_plan(nodes: &[NodeInventory]) -> Option<(String, String)> {
    let source = drain_node(nodes)?;
    let others: Vec<NodeInventory> = nodes
        .iter()
        .filter(|n| n.node_id != source.node_id)
        .cloned()
        .collect();
    let target = place_replica(&others)?;
    if target.live_replicas + 1 < source.live_replicas {
        Some((source.node_id.clone(), target.node_id.clone()))
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn node(id: &str, live: usize, max: usize, total: f64, footprint: f64) -> NodeInventory {
        NodeInventory {
            node_id: id.to_string(),
            gpu_memory_total: total,
            gpu_memory_free: (total - live as f64 * footprint).max(0.0),
            replica_gpu_memory: footprint,
            live_replicas: live,
            max_replicas: max,
        }
    }

    #[test]
    fn empty_cluster_places_nowhere() {
        assert_eq!(place_replica(&[]), None);
        assert_eq!(drain_node(&[]), None);
    }

    #[test]
    fn full_node_is_skipped() {
        // node-a is at its replica ceiling; node-b has room
        let nodes = vec![node("node-a", 3, 3, 24.0, 8.0), node("node-b", 2, 3, 24.0, 8.0)];
        assert_eq!(place_replica(&nodes).unwrap().node_id, "node-b");
        // every node full -> no placement at all
        let full = vec![node("node-a", 3, 3, 24.0, 8.0), node("node-b", 3, 3, 24.0, 8.0)];
        assert_eq!(place_replica(&full), None);
    }

    #[test]
    fn spread_prefers_the_emptier_node() {
        let nodes = vec![node("node-a", 2, 4, 32.0, 8.0), node("node-b", 1, 4, 32.0, 8.0)];
        assert_eq!(place_replica(&nodes).unwrap().node_id, "node-b");
    }

    #[test]
    fn equal_fill_tie_break_is_deterministic() {
        // identical fill and free memory: lexicographic node id decides,
        // and the answer never depends on slice order
        let ab = vec![node("node-a", 1, 3, 24.0, 8.0), node("node-b", 1, 3, 24.0, 8.0)];
        let ba = vec![node("node-b", 1, 3, 24.0, 8.0), node("node-a", 1, 3, 24.0, 8.0)];
        assert_eq!(place_replica(&ab).unwrap().node_id, "node-a");
        assert_eq!(place_replica(&ba).unwrap().node_id, "node-a");
        // same replica count but more free memory wins over the id
        let nodes = vec![node("node-a", 1, 3, 24.0, 8.0), node("node-b", 1, 3, 48.0, 8.0)];
        assert_eq!(place_replica(&nodes).unwrap().node_id, "node-b");
    }

    #[test]
    fn zero_free_memory_is_never_selected() {
        // under the replica ceiling, but memory exhausted
        let mut broke = node("node-a", 1, 4, 8.0, 8.0);
        assert_eq!(broke.gpu_memory_free, 0.0);
        assert_eq!(place_replica(&[broke.clone()]), None);
        // even a zero-footprint advertisement cannot make an empty node fit
        broke.replica_gpu_memory = 0.0;
        assert_eq!(place_replica(&[broke]), None);
        // and a node with free memory below its footprint is skipped too
        let tight = node("node-b", 2, 4, 20.0, 8.0); // free = 4 < 8
        let roomy = node("node-c", 2, 4, 24.0, 8.0); // free = 8
        assert_eq!(place_replica(&[tight, roomy]).unwrap().node_id, "node-c");
    }

    #[test]
    fn latency_placement_avoids_batch_heavy_nodes() {
        // node-a is emptier but 80% batch traffic; a latency-driven
        // placement pays the spread penalty to stay away from it
        let nodes = vec![node("node-a", 1, 4, 32.0, 8.0), node("node-b", 2, 4, 32.0, 8.0)];
        let share = BTreeMap::from([("node-a".to_string(), 0.8)]);
        assert_eq!(
            place_replica_tiered(&nodes, &share, SloTier::Latency).unwrap().node_id,
            "node-b"
        );
        // standard ignores the shares entirely
        assert_eq!(
            place_replica_tiered(&nodes, &share, SloTier::Standard).unwrap().node_id,
            "node-a"
        );
        // batch consolidates onto the batch-heavy node
        assert_eq!(
            place_replica_tiered(&nodes, &share, SloTier::Batch).unwrap().node_id,
            "node-a"
        );
    }

    #[test]
    fn affinity_is_a_preference_not_a_filter() {
        // the only node with room is batch-heavy: a latency placement
        // still lands there — capacity beats affinity
        let nodes = vec![node("node-a", 2, 4, 32.0, 8.0), node("node-b", 3, 3, 24.0, 8.0)];
        let share = BTreeMap::from([("node-a".to_string(), 1.0)]);
        assert_eq!(
            place_replica_tiered(&nodes, &share, SloTier::Latency).unwrap().node_id,
            "node-a"
        );
        // nodes absent from the share map count as batch-free
        let nodes = vec![node("node-a", 1, 4, 32.0, 8.0), node("node-b", 1, 4, 32.0, 8.0)];
        let share = BTreeMap::from([("node-b".to_string(), 0.9)]);
        assert_eq!(
            place_replica_tiered(&nodes, &share, SloTier::Latency).unwrap().node_id,
            "node-a"
        );
    }

    #[test]
    fn drain_picks_the_most_fragmented_node() {
        // node-a: 2/24 used ratio free 16/24; node-b: 3 replicas, free 0/24
        let nodes = vec![node("node-a", 2, 3, 24.0, 4.0), node("node-b", 3, 3, 24.0, 8.0)];
        assert_eq!(drain_node(&nodes).unwrap().node_id, "node-a");
    }

    #[test]
    fn defrag_moves_toward_the_empty_node() {
        // 3 replicas on node-a, an empty node-b: one move improves the
        // spread, so the plan fires a->b
        let nodes = vec![node("node-a", 3, 4, 32.0, 8.0), node("node-b", 0, 4, 32.0, 8.0)];
        assert_eq!(
            defrag_plan(&nodes),
            Some(("node-a".to_string(), "node-b".to_string()))
        );
    }

    #[test]
    fn defrag_is_quiescent_on_a_balanced_fleet() {
        // 2/2: any move just swaps the skew — no plan
        let even = vec![node("node-a", 2, 4, 32.0, 8.0), node("node-b", 2, 4, 32.0, 8.0)];
        assert_eq!(defrag_plan(&even), None);
        // 2/1: moving lands 1/2 — mirror image, still no plan
        let near = vec![node("node-a", 2, 4, 32.0, 8.0), node("node-b", 1, 4, 32.0, 8.0)];
        assert_eq!(defrag_plan(&near), None);
        // a single node can never defrag onto itself
        let lone = vec![node("node-a", 3, 4, 32.0, 8.0)];
        assert_eq!(defrag_plan(&lone), None);
    }

    #[test]
    fn drain_never_empties_a_node() {
        // single-replica nodes are not drainable, however fragmented
        let nodes = vec![node("node-a", 1, 3, 24.0, 4.0), node("node-b", 1, 3, 24.0, 8.0)];
        assert_eq!(drain_node(&nodes), None);
        // ties on fragmentation break deterministically by node id
        let tied = vec![node("node-a", 2, 3, 24.0, 6.0), node("node-b", 2, 3, 24.0, 6.0)];
        assert_eq!(drain_node(&tied).unwrap().node_id, "node-a");
    }
}
