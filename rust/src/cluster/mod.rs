//! The distributed multi-node serving plane (§V's "deployment execution
//! engine" made live): one **coordinator** owns ingress and places work
//! across N **nodes**, each of which is a full single-node gateway (engine
//! replicas, warm pool, Table II monitoring) wearing a small HTTP control
//! surface.
//!
//! ```text
//!                clients
//!                   │  POST /v1/completions (SSE or unary)
//!            ┌──────▼───────┐   per-node weighted least-loaded routing,
//!            │ coordinator  │   health masks, retry-on-node-death
//!            │ serve-http   │──────────────┐
//!            │  --cluster   │  proxy       │ proxy
//!            └──┬───▲───────┘              │
//!   join/status │   │ heartbeat            │
//!         ┌─────▼───┴────┐          ┌──────▼───────┐
//!         │ enova node A │          │ enova node B │
//!         │ gateway +    │          │ gateway +    │
//!         │ replicas     │          │ replicas     │
//!         └──────────────┘          └──────────────┘
//! ```
//!
//! Control protocol (JSON over the same hand-rolled HTTP/1.1 stack):
//!
//! * node → coordinator `POST /cluster/join` — a [`proto::NodeAnnounce`]:
//!   the node's gateway address plus its capacity advertisement (GPU
//!   memory total, per-replica footprint, replica ceiling, per-replica
//!   service rate). Re-announced periodically, so a restarted coordinator
//!   re-learns its fleet without operator help.
//! * coordinator → node `GET /v1/admin/status` — a [`proto::NodeStatus`]
//!   heartbeat: live/warm replica counts, free GPU memory and the node's
//!   aggregated Table II frame + arrival rate, the rows the cluster-wide
//!   supervisor scores (deprecated alias: `GET /cluster/status`).
//! * coordinator → node `POST /v1/admin/scale-up` / `POST
//!   /v1/admin/scale-down` — the placement decision's actuation: promote
//!   a warm standby (or cold-spawn) on *that* node, or drain-then-retire
//!   its newest replica (deprecated aliases under `/cluster/`).
//!
//! All control exchanges speak the typed request/response structs and
//! structured `{code, message, details}` errors of [`proto`], under the
//! versioned [`proto::ADMIN_API_PREFIX`].
//!
//! Observability exports follow the same versioning: `GET
//! /v1/debug/traces` and `GET /v1/debug/decisions` serve the request
//! tracer and the autoscaling flight recorder wrapped in a typed
//! [`proto::DebugExportResponse`] envelope (the unversioned `/debug/*`
//! paths remain as deprecated aliases serving the legacy bare shapes).
//! Nodes additionally expose `GET|POST /v1/admin/chaos` to inspect or
//! re-seed the node-local fault injector ([`crate::chaos`]); the
//! coordinator's per-node circuit breakers ([`pool::CircuitBreaker`])
//! are the defense that keeps injected faults invisible to clients.
//!
//! Placement policy lives in [`placement`] (pure math over
//! [`crate::deployer::NodeInventory`]): scale-ups bin-pack by free
//! `gpu_memory` with spread-by-default anti-affinity, retires drain the
//! most-fragmented node first. The coordinator's supervisor
//! ([`coordinator`]) runs the same monitor → detect → act loop as the
//! single-node [`crate::gateway::supervisor`], but over cluster-mean
//! frames, and its forecast planner sizes the fleet with
//! [`crate::forecast::replicas_for_cluster_rate`] over per-node replica
//! capacities.
//!
//! Ingress makes the node set invisible to clients: unary requests are
//! retried on another node if the chosen node dies or sheds (a response
//! was never committed, so re-dispatch is safe — completions have no
//! server-side state to duplicate); SSE streams are passed through
//! chunk-by-chunk, and an upstream death before the first relayed chunk
//! re-dispatches too, so killing a node mid-run drops nothing.

pub mod coordinator;
pub mod metrics;
pub mod migrate;
pub mod node;
pub mod placement;
pub mod pool;
pub mod proto;
pub mod snapshot;

/// What a gateway in node mode knows about itself — set via
/// [`crate::gateway::GatewayConfig::node`], it turns on the node-only
/// `/v1/admin/{status,scale-up,scale-down}` control endpoints (and their
/// deprecated `/cluster/*` aliases) and is the capacity advertisement
/// sent to the coordinator on join.
#[derive(Debug, Clone)]
pub struct NodeIdentity {
    /// operator-chosen stable name (`node-a`); label value on the
    /// coordinator's per-node gauges
    pub node_id: String,
    /// GPU memory the node offers, in abstract units (the axis the
    /// paper's `gpu_memory` knob is denominated in)
    pub gpu_memory_total: f64,
    /// memory one replica claims; `free = total - live·footprint`
    pub replica_gpu_memory: f64,
    /// replica ceiling for this node
    pub max_replicas: usize,
    /// advertised per-replica service rate in requests/second; 0 lets the
    /// coordinator fall back to its configured or learned capacity
    pub replica_capacity_rps: f64,
}

impl Default for NodeIdentity {
    fn default() -> Self {
        NodeIdentity {
            node_id: "node-0".into(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 3,
            replica_capacity_rps: 0.0,
        }
    }
}
