//! Versioned binary engine snapshots — the primitive that kills the cold
//! start. A post-init engine checkpoints its weights handle + allocator
//! layout (for the sim engine: its deterministic config + counters) into
//! an [`EngineSnapshot`], serialized as a fixed little-endian frame with a
//! magic, a format version, a config fingerprint and a trailing checksum.
//! Restoring is **fail-closed**: any truncation, magic/version/checksum
//! mismatch or fingerprint disagreement is a structured [`SnapshotError`]
//! and the caller falls back to a cold spawn — a snapshot can make spawn
//! fast, never wrong.
//!
//! Wire frame (all integers little-endian):
//!
//! ```text
//! magic "ENSN" | version u16 | kind_len u16 | kind bytes
//! | max_num_seqs u64 | gpu_memory f64-bits | fingerprint u64
//! | payload_len u64 | payload bytes | fnv1a64 checksum of everything above
//! ```
//!
//! Snapshots are small (config + counters, not model weights — those are
//! re-mapped from the artifact files on restore), so they travel as hex
//! inside the typed `/v1/admin/snapshots` JSON exchanges and are pinned
//! locally in a memfd ([`persist`]) the way `memfd_create`-based model
//! loading keeps a restored image warm.

use super::proto::AdminError;
use std::fmt;
use std::io::{Read, Seek, SeekFrom, Write};

pub const SNAPSHOT_MAGIC: [u8; 4] = *b"ENSN";
pub const SNAPSHOT_VERSION: u16 = 1;

/// A checkpointed post-init engine: enough to rebuild a serving replica
/// without re-running init. `payload` is engine-kind-specific (the sim
/// engine's deterministic counters; the PJRT engine's config — its weights
/// re-map from the artifact directory on restore).
#[derive(Debug, Clone, PartialEq)]
pub struct EngineSnapshot {
    pub version: u16,
    /// `"sim"` or `"lm"` — restore refuses a kind it cannot rebuild
    pub engine_kind: String,
    pub max_num_seqs: usize,
    pub gpu_memory: f64,
    /// fnv1a64 over the engine's config invariants (token budget, step
    /// timing, compiled batch width); restoring onto an engine whose own
    /// fingerprint disagrees fails closed
    pub fingerprint: u64,
    pub payload: Vec<u8>,
}

/// Why a snapshot could not be decoded or restored. Every variant maps to
/// a structured admin error (code `bad_snapshot`) so the control API
/// reports the cause instead of restoring garbage.
#[derive(Debug, Clone, PartialEq)]
pub enum SnapshotError {
    Truncated,
    BadMagic,
    VersionMismatch { found: u16, expected: u16 },
    ChecksumMismatch,
    KindMismatch { found: String, expected: String },
    FingerprintMismatch { found: u64, expected: u64 },
    Malformed(String),
}

impl fmt::Display for SnapshotError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            SnapshotError::Truncated => write!(f, "snapshot truncated"),
            SnapshotError::BadMagic => write!(f, "not a snapshot (bad magic)"),
            SnapshotError::VersionMismatch { found, expected } => {
                write!(f, "snapshot version {found} != supported {expected}")
            }
            SnapshotError::ChecksumMismatch => write!(f, "snapshot checksum mismatch"),
            SnapshotError::KindMismatch { found, expected } => {
                write!(f, "snapshot is for engine {found:?}, not {expected:?}")
            }
            SnapshotError::FingerprintMismatch { found, expected } => write!(
                f,
                "snapshot config fingerprint {found:#018x} != engine {expected:#018x}"
            ),
            SnapshotError::Malformed(msg) => write!(f, "malformed snapshot: {msg}"),
        }
    }
}

impl SnapshotError {
    /// Ready-to-serve structured error for the `/v1/admin/snapshots` API.
    pub fn to_admin_error(&self) -> AdminError {
        AdminError::new("bad_snapshot", &self.to_string())
    }
}

pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// Little-endian byte writer for snapshot payloads — shared by the frame
/// encoder here and the engine-specific payload encoders.
#[derive(Default)]
pub struct SnapWriter {
    buf: Vec<u8>,
}

impl SnapWriter {
    pub fn new() -> SnapWriter {
        SnapWriter::default()
    }

    pub fn put_u16(&mut self, v: u16) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_u64(&mut self, v: u64) {
        self.buf.extend_from_slice(&v.to_le_bytes());
    }

    pub fn put_f64(&mut self, v: f64) {
        self.buf.extend_from_slice(&v.to_bits().to_le_bytes());
    }

    /// Length-prefixed (u64) UTF-8 string.
    pub fn put_str(&mut self, v: &str) {
        self.put_u64(v.len() as u64);
        self.buf.extend_from_slice(v.as_bytes());
    }

    pub fn put_bytes(&mut self, v: &[u8]) {
        self.buf.extend_from_slice(v);
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

/// Fail-closed little-endian reader: every take checks bounds and returns
/// [`SnapshotError::Truncated`] instead of panicking on a short buffer.
pub struct SnapReader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> SnapReader<'a> {
    pub fn new(buf: &'a [u8]) -> SnapReader<'a> {
        SnapReader { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], SnapshotError> {
        if self.pos + n > self.buf.len() {
            return Err(SnapshotError::Truncated);
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn take_u16(&mut self) -> Result<u16, SnapshotError> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    pub fn take_u64(&mut self) -> Result<u64, SnapshotError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    pub fn take_f64(&mut self) -> Result<f64, SnapshotError> {
        Ok(f64::from_bits(self.take_u64()?))
    }

    pub fn take_str(&mut self) -> Result<String, SnapshotError> {
        let len = self.take_u64()? as usize;
        if len > self.buf.len() {
            return Err(SnapshotError::Truncated);
        }
        String::from_utf8(self.take(len)?.to_vec())
            .map_err(|_| SnapshotError::Malformed("string is not UTF-8".into()))
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }
}

impl EngineSnapshot {
    pub fn new(engine_kind: &str, max_num_seqs: usize, gpu_memory: f64, fingerprint: u64, payload: Vec<u8>) -> EngineSnapshot {
        EngineSnapshot {
            version: SNAPSHOT_VERSION,
            engine_kind: engine_kind.to_string(),
            max_num_seqs,
            gpu_memory,
            fingerprint,
            payload,
        }
    }

    /// Serialize to the versioned binary frame (with trailing checksum).
    pub fn encode(&self) -> Vec<u8> {
        let mut w = SnapWriter::new();
        w.put_bytes(&SNAPSHOT_MAGIC);
        w.put_u16(self.version);
        w.put_u16(self.engine_kind.len() as u16);
        w.put_bytes(self.engine_kind.as_bytes());
        w.put_u64(self.max_num_seqs as u64);
        w.put_f64(self.gpu_memory);
        w.put_u64(self.fingerprint);
        w.put_u64(self.payload.len() as u64);
        w.put_bytes(&self.payload);
        let mut bytes = w.into_bytes();
        let sum = fnv1a64(&bytes);
        bytes.extend_from_slice(&sum.to_le_bytes());
        bytes
    }

    /// Decode, failing closed on truncation, bad magic, an unsupported
    /// version, or a checksum mismatch.
    pub fn decode(bytes: &[u8]) -> Result<EngineSnapshot, SnapshotError> {
        if bytes.len() < SNAPSHOT_MAGIC.len() + 8 {
            return Err(SnapshotError::Truncated);
        }
        let (body, sum_bytes) = bytes.split_at(bytes.len() - 8);
        if body[..4] != SNAPSHOT_MAGIC {
            return Err(SnapshotError::BadMagic);
        }
        let sum = u64::from_le_bytes(sum_bytes.try_into().unwrap());
        if fnv1a64(body) != sum {
            return Err(SnapshotError::ChecksumMismatch);
        }
        let mut r = SnapReader::new(&body[4..]);
        let version = r.take_u16()?;
        if version != SNAPSHOT_VERSION {
            return Err(SnapshotError::VersionMismatch {
                found: version,
                expected: SNAPSHOT_VERSION,
            });
        }
        let kind_len = r.take_u16()? as usize;
        let engine_kind = String::from_utf8(
            r.take(kind_len)?.to_vec(),
        )
        .map_err(|_| SnapshotError::Malformed("engine kind is not UTF-8".into()))?;
        let max_num_seqs = r.take_u64()? as usize;
        let gpu_memory = r.take_f64()?;
        if !gpu_memory.is_finite() {
            return Err(SnapshotError::Malformed("non-finite gpu_memory".into()));
        }
        let fingerprint = r.take_u64()?;
        let payload_len = r.take_u64()? as usize;
        if payload_len != r.remaining() {
            return Err(SnapshotError::Truncated);
        }
        let payload = r.take(payload_len)?.to_vec();
        Ok(EngineSnapshot {
            version,
            engine_kind,
            max_num_seqs,
            gpu_memory,
            fingerprint,
            payload,
        })
    }
}

/// Lowercase hex encoding — how a snapshot travels inside the typed JSON
/// control exchanges (std-only; snapshots are config-sized, not weights).
pub fn to_hex(bytes: &[u8]) -> String {
    let mut out = String::with_capacity(bytes.len() * 2);
    for b in bytes {
        out.push_str(&format!("{b:02x}"));
    }
    out
}

pub fn from_hex(hex: &str) -> Result<Vec<u8>, SnapshotError> {
    let hex = hex.trim();
    if hex.len() % 2 != 0 {
        return Err(SnapshotError::Malformed("odd-length hex".into()));
    }
    let mut out = Vec::with_capacity(hex.len() / 2);
    let bytes = hex.as_bytes();
    for pair in bytes.chunks(2) {
        let hi = (pair[0] as char).to_digit(16);
        let lo = (pair[1] as char).to_digit(16);
        match (hi, lo) {
            (Some(h), Some(l)) => out.push(((h << 4) | l) as u8),
            _ => return Err(SnapshotError::Malformed("non-hex byte".into())),
        }
    }
    Ok(out)
}

#[cfg(target_os = "linux")]
extern "C" {
    fn memfd_create(name: *const std::os::raw::c_char, flags: std::os::raw::c_uint) -> std::os::raw::c_int;
}

/// Pin snapshot bytes in an anonymous in-memory file (`memfd_create` on
/// Linux, a tempdir file elsewhere) and return it positioned at the start
/// — the restored-image-stays-warm trick serverless snapshot loaders use.
pub fn persist(data: &[u8]) -> std::io::Result<std::fs::File> {
    let mut file = create_backing_file()?;
    file.write_all(data)?;
    file.seek(SeekFrom::Start(0))?;
    Ok(file)
}

#[cfg(target_os = "linux")]
fn create_backing_file() -> std::io::Result<std::fs::File> {
    use std::os::fd::FromRawFd;
    const MFD_CLOEXEC: std::os::raw::c_uint = 1;
    let name = b"enova-snapshot\0";
    let fd = unsafe { memfd_create(name.as_ptr() as *const _, MFD_CLOEXEC) };
    if fd >= 0 {
        return Ok(unsafe { std::fs::File::from_raw_fd(fd) });
    }
    // older kernels/libcs: fall back to an unlinked temp file
    tempdir_backing_file()
}

#[cfg(not(target_os = "linux"))]
fn create_backing_file() -> std::io::Result<std::fs::File> {
    tempdir_backing_file()
}

fn tempdir_backing_file() -> std::io::Result<std::fs::File> {
    let path = std::env::temp_dir().join(format!(
        "enova-snapshot-{}-{:x}",
        std::process::id(),
        std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0)
    ));
    let file = std::fs::OpenOptions::new()
        .read(true)
        .write(true)
        .create_new(true)
        .open(&path)?;
    // unlink immediately: the fd is the only handle, like a memfd
    let _ = std::fs::remove_file(&path);
    Ok(file)
}

/// Read a persisted snapshot back from its backing file.
pub fn read_back(file: &mut std::fs::File) -> std::io::Result<Vec<u8>> {
    file.seek(SeekFrom::Start(0))?;
    let mut out = Vec::new();
    file.read_to_end(&mut out)?;
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::sim::{SimEngine, SimEngineConfig};
    use crate::engine::StreamEngine;
    use std::time::Duration;

    fn sample() -> EngineSnapshot {
        EngineSnapshot::new("sim", 8, 0.9, 0xdead_beef_cafe_f00d, vec![1, 2, 3, 4, 5])
    }

    #[test]
    fn encode_decode_round_trip() {
        let snap = sample();
        let decoded = EngineSnapshot::decode(&snap.encode()).unwrap();
        assert_eq!(decoded, snap);
    }

    #[test]
    fn truncation_fails_closed() {
        let bytes = sample().encode();
        for cut in [0, 3, 7, bytes.len() / 2, bytes.len() - 1] {
            let err = EngineSnapshot::decode(&bytes[..cut]).unwrap_err();
            assert!(
                matches!(err, SnapshotError::Truncated | SnapshotError::ChecksumMismatch),
                "cut at {cut}: {err:?}"
            );
        }
    }

    #[test]
    fn bad_magic_fails_closed() {
        let mut bytes = sample().encode();
        bytes[0] = b'X';
        assert_eq!(EngineSnapshot::decode(&bytes).unwrap_err(), SnapshotError::BadMagic);
    }

    #[test]
    fn version_mismatch_fails_closed() {
        let mut snap = sample();
        snap.version = SNAPSHOT_VERSION + 1;
        let err = EngineSnapshot::decode(&snap.encode()).unwrap_err();
        assert_eq!(
            err,
            SnapshotError::VersionMismatch {
                found: SNAPSHOT_VERSION + 1,
                expected: SNAPSHOT_VERSION
            }
        );
        // and the structured error names the cause
        let admin = err.to_admin_error();
        assert_eq!(admin.code, "bad_snapshot");
        assert!(admin.message.contains("version"), "{}", admin.message);
    }

    #[test]
    fn corruption_fails_the_checksum() {
        let mut bytes = sample().encode();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        let err = EngineSnapshot::decode(&bytes).unwrap_err();
        assert!(
            matches!(err, SnapshotError::ChecksumMismatch | SnapshotError::BadMagic),
            "{err:?}"
        );
    }

    #[test]
    fn hex_round_trip_and_rejection() {
        let bytes = sample().encode();
        assert_eq!(from_hex(&to_hex(&bytes)).unwrap(), bytes);
        assert!(from_hex("abc").is_err(), "odd length rejected");
        assert!(from_hex("zz").is_err(), "non-hex rejected");
        assert_eq!(from_hex("").unwrap(), Vec::<u8>::new());
    }

    #[test]
    fn memfd_persist_reads_back_verbatim() {
        let bytes = sample().encode();
        let mut file = persist(&bytes).expect("snapshot backing file");
        assert_eq!(read_back(&mut file).unwrap(), bytes);
    }

    /// The tentpole fail-closed contract: a snapshot from a differently-
    /// configured engine (different token budget → different fingerprint)
    /// must refuse to restore, with a structured error — the caller falls
    /// back to a cold spawn instead of restoring garbage.
    #[test]
    fn config_fingerprint_mismatch_refuses_restore() {
        let src = SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 64,
            step_delay: Duration::ZERO,
        });
        let snap = src.snapshot().unwrap();
        let mut other = SimEngine::new(SimEngineConfig {
            max_num_seqs: 4,
            max_tokens: 16, // different budget → different fingerprint
            step_delay: Duration::ZERO,
        });
        let err = other.restore(&snap).unwrap_err();
        assert!(
            err.to_string().contains("fingerprint"),
            "restore must name the fingerprint mismatch: {err}"
        );

        // matching config restores fine
        let mut twin = SimEngine::new(SimEngineConfig {
            max_num_seqs: 2, // ceiling comes from the snapshot
            max_tokens: 64,
            step_delay: Duration::ZERO,
        });
        twin.restore(&snap).unwrap();
        assert_eq!(twin.capacity(), 4, "restored ceiling");
    }

    /// A garbage payload inside a structurally-valid frame is rejected by
    /// the engine-side payload parser, not restored.
    #[test]
    fn garbage_payload_refuses_restore() {
        let src = SimEngine::new(SimEngineConfig::default());
        let mut snap = src.snapshot().unwrap();
        snap.payload = vec![0xff; 3];
        let mut dst = SimEngine::new(SimEngineConfig::default());
        assert!(dst.restore(&snap).is_err(), "truncated payload rejected");
    }
}
