//! Coordinator-driven live migration: move one replica's capacity from a
//! source node to a target node without dropping a request.
//!
//! The state machine (each phase timed and visible in `GET
//! /v1/admin/migrations` while it runs):
//!
//! ```text
//! pending → snapshotting → restoring → retiring → done
//!                │             │           │
//!                └─────────────┴───────────┴──→ failed {code, message}
//! ```
//!
//! * **snapshotting** — `POST /v1/admin/snapshots {"action":"capture"}`
//!   on the source node checkpoints one post-init engine
//!   ([`super::snapshot::EngineSnapshot`]) without touching its in-flight
//!   work.
//! * **restoring** — the frame travels to the target inside a
//!   `{"action":"restore"}` call over the coordinator's keep-alive
//!   [`super::pool::NodePool`] connections, and the target spawns a
//!   replica from it in milliseconds instead of re-running engine init.
//!   The router is rebuilt the moment the restore lands — the route flip
//!   is atomic because capacity is *added before* anything is removed.
//! * **retiring** — the source drains its newest replica through the same
//!   `POST /v1/admin/scale-down` the autoscaler uses (PR 2's
//!   drain-then-retire: the replica leaves the router first, finishes
//!   what it holds, then dies). Nothing is dropped because at every
//!   instant at least the pre-migration capacity is routable.
//!
//! Ordering is the whole design: capture → restore → retire means the
//! cluster briefly runs `n + 1` replicas, never `n - 1`. A failure after
//! the restore leaves the extra replica in place (over-capacity heals via
//! the supervisor's drain policy; under-capacity would drop requests).

use super::coordinator::{self, CoordinatorState};
use super::placement;
use super::pool::NodePool;
use super::proto::{
    AdminError, MigrationPhase, MigrationRequest, MigrationStatus, SnapshotInfo,
    SnapshotRequest, SnapshotResponse,
};
use crate::util::json::Json;
use anyhow::{bail, Context, Result};
use std::io::{BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant, SystemTime};

/// Capture is config + counters, never weights re-load: it answers fast.
const CAPTURE_RPC_TIMEOUT: Duration = Duration::from_secs(30);
/// Restore spawns a replica from the frame — milliseconds for the sim
/// engine, but bounded generously for a runtime-backed engine.
const RESTORE_RPC_TIMEOUT: Duration = Duration::from_secs(120);
/// Retire waits for the source replica's drain, like any scale-down.
const RETIRE_RPC_TIMEOUT: Duration = Duration::from_secs(310);
/// Largest control-RPC body `pool_rpc` will buffer (a snapshot frame in
/// hex dominates; the sim engine's is tiny, a runtime engine's is capped
/// here rather than trusted).
const MAX_CONTROL_BODY: usize = 64 * 1024 * 1024;
/// Migration records kept for `GET /v1/admin/migrations`.
const MIGRATION_HISTORY_CAP: usize = 64;

/// Bounded, id-allocating migration history — the backing store of
/// `GET /v1/admin/migrations`. Phase transitions overwrite the record in
/// place, so a poll mid-migration sees the live phase.
#[derive(Debug, Default)]
pub struct MigrationRegistry {
    history: Mutex<Vec<MigrationStatus>>,
    next_id: AtomicU64,
}

impl MigrationRegistry {
    pub fn new() -> MigrationRegistry {
        MigrationRegistry {
            history: Mutex::new(Vec::new()),
            next_id: AtomicU64::new(1),
        }
    }

    /// Claim the next migration id (monotonic, never reused).
    pub fn allocate(&self) -> u64 {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    /// Insert a record, or replace the record with the same id (phase
    /// transitions). Oldest records fall off past the cap.
    pub fn put(&self, status: MigrationStatus) {
        let mut h = self.history.lock().unwrap();
        if let Some(slot) = h.iter_mut().find(|m| m.id == status.id) {
            *slot = status;
            return;
        }
        h.push(status);
        if h.len() > MIGRATION_HISTORY_CAP {
            let overflow = h.len() - MIGRATION_HISTORY_CAP;
            h.drain(..overflow);
        }
    }

    /// All retained records, oldest first.
    pub fn list(&self) -> Vec<MigrationStatus> {
        self.history.lock().unwrap().clone()
    }
}

/// A periodic engine checkpoint the coordinator holds per node, ready to
/// back a near-instant dead-node backfill.
#[derive(Debug, Clone)]
pub struct StoredSnapshot {
    pub info: SnapshotInfo,
    /// the encoded frame, hex — exactly what a restore call carries
    pub hex: String,
}

pub(super) fn unix_now() -> f64 {
    SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_secs_f64())
        .unwrap_or(0.0)
}

/// Capture an engine snapshot from `node_id` and cache it as that node's
/// latest stored frame (what backfill restores from). Returns the node's
/// raw response body, relayed verbatim by the coordinator's
/// `POST /v1/admin/snapshots`.
pub(super) fn capture_from_node(
    state: &Arc<CoordinatorState>,
    node_id: &str,
) -> Result<String, AdminError> {
    let addr = {
        let nodes = state.nodes.read().unwrap();
        match nodes.get(node_id) {
            None => {
                return Err(AdminError::new("unknown_node", "node is not registered")
                    .with_detail("node", node_id))
            }
            Some(e) if !e.healthy => {
                return Err(AdminError::new(
                    "node_unhealthy",
                    "node is not answering heartbeats",
                )
                .with_detail("node", node_id))
            }
            Some(e) => e.announce.addr.clone(),
        }
    };
    let body = SnapshotRequest::capture().to_json().to_string_compact();
    match pool_rpc(
        &state.pool,
        &addr,
        "POST",
        "/v1/admin/snapshots",
        Some(&body),
        CAPTURE_RPC_TIMEOUT,
    ) {
        Ok((st, raw)) if (200..300).contains(&st) => {
            let parsed = Json::parse(&raw)
                .map_err(|e| e.to_string())
                .and_then(|j| SnapshotResponse::from_json(&j));
            match parsed {
                Ok(resp) => {
                    if let Some(hex) = &resp.snapshot_hex {
                        state.snapshots.lock().unwrap().insert(
                            node_id.to_string(),
                            StoredSnapshot {
                                info: resp.info.clone(),
                                hex: hex.clone(),
                            },
                        );
                    }
                    Ok(raw)
                }
                Err(e) => Err(AdminError::new(
                    "snapshot_failed",
                    &format!("node answered a malformed capture response: {e}"),
                )
                .with_detail("node", node_id)),
            }
        }
        Ok((st, raw)) => {
            Err(rpc_error("snapshot_failed", "node refused the capture", st, &raw)
                .with_detail("node", node_id))
        }
        Err(e) => Err(
            AdminError::new("snapshot_failed", &format!("capture RPC failed: {e:#}"))
                .with_detail("node", node_id),
        ),
    }
}

/// One periodic capture sweep across the serving nodes: refresh each
/// node's stored frame, shrugging off individual failures (the next sweep
/// retries).
pub(super) fn capture_sweep(state: &Arc<CoordinatorState>, node_ids: &[&str]) {
    for id in node_ids {
        if let Err(e) = capture_from_node(state, id) {
            crate::warn!(
                "cluster",
                "periodic snapshot of node {id} failed: {} ({})",
                e.message,
                e.code
            );
        }
    }
}

/// Run one migration to completion (synchronously — the control API
/// answers with the final record). `reason` labels the metrics and the
/// flight-recorder entry: `migration` (operator API), `defrag`
/// (idle-supervisor rebalancing).
pub(crate) fn execute(
    state: &Arc<CoordinatorState>,
    req: &MigrationRequest,
    reason: &'static str,
) -> MigrationStatus {
    let mut status = MigrationStatus {
        id: state.migrations.allocate(),
        source_node: req.source_node.clone(),
        target_node: req.target_node.clone().unwrap_or_default(),
        reason: reason.to_string(),
        phase: MigrationPhase::Pending,
        new_replica_id: None,
        error: None,
        started_unix: unix_now(),
        snapshot_seconds: 0.0,
        restore_seconds: 0.0,
        retire_seconds: 0.0,
        total_seconds: 0.0,
    };
    state.migrations.put(status.clone());
    let t_total = Instant::now();

    // -- resolve the source: registered, healthy, and able to give up a
    // replica (a node's gateway refuses to retire its last routable one)
    let source = {
        let nodes = state.nodes.read().unwrap();
        nodes.get(&req.source_node).map(|e| {
            (
                e.announce.addr.clone(),
                e.healthy,
                e.status.as_ref().map(|s| s.live_replicas).unwrap_or(0),
            )
        })
    };
    let Some((source_addr, source_healthy, source_live)) = source else {
        let err = AdminError::new("unknown_node", "source node is not registered")
            .with_detail("node", &req.source_node);
        return fail(state, status, t_total, err);
    };
    if !source_healthy {
        let err = AdminError::new("node_unhealthy", "source node is not answering heartbeats")
            .with_detail("node", &req.source_node);
        return fail(state, status, t_total, err);
    }
    if source_live < 2 {
        let err = AdminError::new(
            "source_at_floor",
            "live migration drains the source replica after the restore; the source needs \
             at least 2 live replicas so its gateway can retire one",
        )
        .with_detail("node", &req.source_node)
        .with_detail("live_replicas", &source_live.to_string());
        return fail(state, status, t_total, err);
    }

    // -- resolve the target: the named node (must have room), or the
    // placement policy's pick among everyone else
    let invs = coordinator::inventories(state);
    let target_id = match &req.target_node {
        Some(t) => {
            let Some(inv) = invs.iter().find(|i| &i.node_id == t) else {
                let err =
                    AdminError::new("unknown_node", "target node is not registered and healthy")
                        .with_detail("node", t);
                return fail(state, status, t_total, err);
            };
            if !inv.has_room() {
                let err = AdminError::new("no_target", "target node has no room for a replica")
                    .with_detail("node", t)
                    .with_detail("live_replicas", &inv.live_replicas.to_string())
                    .with_detail("max_replicas", &inv.max_replicas.to_string());
                return fail(state, status, t_total, err);
            }
            t.clone()
        }
        None => {
            let candidates: Vec<_> = invs
                .iter()
                .filter(|i| i.node_id != req.source_node)
                .cloned()
                .collect();
            match placement::place_replica(&candidates) {
                Some(n) => n.node_id.clone(),
                None => {
                    let err = AdminError::new(
                        "no_target",
                        "no other node has room for the migrated replica",
                    );
                    return fail(state, status, t_total, err);
                }
            }
        }
    };
    status.target_node = target_id.clone();
    let target_addr = {
        let nodes = state.nodes.read().unwrap();
        nodes.get(&target_id).map(|e| e.announce.addr.clone())
    };
    let Some(target_addr) = target_addr else {
        let err = AdminError::new("unknown_node", "target node vanished mid-migration")
            .with_detail("node", &target_id);
        return fail(state, status, t_total, err);
    };

    // -- phase: snapshotting (capture on the source, in-flight work untouched)
    status.phase = MigrationPhase::Snapshotting;
    state.migrations.put(status.clone());
    let t0 = Instant::now();
    let capture_body = SnapshotRequest::capture().to_json().to_string_compact();
    let capture = pool_rpc(
        &state.pool,
        &source_addr,
        "POST",
        "/v1/admin/snapshots",
        Some(&capture_body),
        CAPTURE_RPC_TIMEOUT,
    );
    let (snap_hex, snap_info) = match capture {
        Ok((st, body)) if (200..300).contains(&st) => {
            match Json::parse(&body)
                .map_err(|e| e.to_string())
                .and_then(|j| SnapshotResponse::from_json(&j))
            {
                Ok(resp) => match resp.snapshot_hex {
                    Some(hex) => (hex, resp.info),
                    None => {
                        let err = AdminError::new(
                            "snapshot_failed",
                            "source answered a capture without a snapshot frame",
                        )
                        .with_detail("node", &req.source_node);
                        return fail(state, status, t_total, err);
                    }
                },
                Err(e) => {
                    let err = AdminError::new(
                        "snapshot_failed",
                        &format!("source answered a malformed capture response: {e}"),
                    )
                    .with_detail("node", &req.source_node);
                    return fail(state, status, t_total, err);
                }
            }
        }
        Ok((st, body)) => {
            let err = rpc_error("snapshot_failed", "source refused the capture", st, &body)
                .with_detail("node", &req.source_node);
            return fail(state, status, t_total, err);
        }
        Err(e) => {
            let err = AdminError::new("snapshot_failed", &format!("capture RPC failed: {e:#}"))
                .with_detail("node", &req.source_node);
            return fail(state, status, t_total, err);
        }
    };
    status.snapshot_seconds = t0.elapsed().as_secs_f64();

    // -- phase: restoring (transfer + spawn on the target, then the route
    // flip — capacity is added before anything is removed)
    status.phase = MigrationPhase::Restoring;
    state.migrations.put(status.clone());
    let t1 = Instant::now();
    let restore_body = SnapshotRequest::restore(&snap_hex).to_json().to_string_compact();
    let restore = pool_rpc(
        &state.pool,
        &target_addr,
        "POST",
        "/v1/admin/snapshots",
        Some(&restore_body),
        RESTORE_RPC_TIMEOUT,
    );
    let new_replica_id = match restore {
        Ok((st, body)) if (200..300).contains(&st) => Json::parse(&body)
            .ok()
            .and_then(|j| j.get("replica_id").and_then(Json::as_usize))
            .unwrap_or(0) as u64,
        Ok((st, body)) => {
            let err = rpc_error("restore_failed", "target refused the restore", st, &body)
                .with_detail("node", &target_id)
                .with_detail("engine_kind", &snap_info.engine_kind);
            return fail(state, status, t_total, err);
        }
        Err(e) => {
            let err = AdminError::new("restore_failed", &format!("restore RPC failed: {e:#}"))
                .with_detail("node", &target_id);
            return fail(state, status, t_total, err);
        }
    };
    status.new_replica_id = Some(new_replica_id);
    status.restore_seconds = t1.elapsed().as_secs_f64();
    {
        let mut nodes = state.nodes.write().unwrap();
        if let Some(e) = nodes.get_mut(&target_id) {
            if let Some(s) = e.status.as_mut() {
                s.live_replicas += 1;
                s.gpu_memory_free =
                    (s.gpu_memory_free - e.announce.replica_gpu_memory).max(0.0);
            }
        }
    }
    coordinator::rebuild_router(state);
    state.metrics.note_placement(reason);

    // -- phase: retiring (drain-then-retire on the source; the replica
    // leaves the router first and finishes what it holds)
    status.phase = MigrationPhase::Retiring;
    state.migrations.put(status.clone());
    let t2 = Instant::now();
    let retire = pool_rpc(
        &state.pool,
        &source_addr,
        "POST",
        "/v1/admin/scale-down",
        Some("{}"),
        RETIRE_RPC_TIMEOUT,
    );
    let retired_id = match retire {
        Ok((st, body)) if (200..300).contains(&st) => Json::parse(&body)
            .ok()
            .and_then(|j| j.get("retired").and_then(Json::as_usize))
            .unwrap_or(0) as u64,
        Ok((st, body)) => {
            let err = rpc_error("retire_failed", "source refused the drain", st, &body)
                .with_detail("node", &req.source_node)
                .with_detail("surviving_replica", &new_replica_id.to_string());
            return fail(state, status, t_total, err);
        }
        Err(e) => {
            let err = AdminError::new("retire_failed", &format!("drain RPC failed: {e:#}"))
                .with_detail("node", &req.source_node)
                .with_detail("surviving_replica", &new_replica_id.to_string());
            return fail(state, status, t_total, err);
        }
    };
    {
        let mut nodes = state.nodes.write().unwrap();
        if let Some(e) = nodes.get_mut(&req.source_node) {
            if let Some(s) = e.status.as_mut() {
                s.live_replicas = s.live_replicas.saturating_sub(1);
                s.gpu_memory_free = (s.gpu_memory_free + e.announce.replica_gpu_memory)
                    .min(e.announce.gpu_memory_total);
            }
        }
    }
    coordinator::rebuild_router(state);
    state.metrics.note_retire(reason);
    status.retire_seconds = t2.elapsed().as_secs_f64();

    status.phase = MigrationPhase::Done;
    status.total_seconds = t_total.elapsed().as_secs_f64();
    state.migrations.put(status.clone());
    state.decisions.record(
        "coordinator",
        "migration",
        reason,
        vec![
            ("source", req.source_node.clone()),
            ("target", target_id.clone()),
            ("new_replica_id", new_replica_id.to_string()),
            ("retired_replica_id", retired_id.to_string()),
            ("engine_kind", snap_info.engine_kind.clone()),
            ("snapshot_seconds", format!("{:.4}", status.snapshot_seconds)),
            ("restore_seconds", format!("{:.4}", status.restore_seconds)),
            ("retire_seconds", format!("{:.4}", status.retire_seconds)),
            ("total_seconds", format!("{:.4}", status.total_seconds)),
        ],
    );
    crate::info!(
        "cluster",
        "migrated a replica {} -> {} (new {new_replica_id}, retired {retired_id}, \
         snapshot {:.1}ms, restore {:.1}ms, total {:.2}s, reason {reason})",
        req.source_node,
        target_id,
        status.snapshot_seconds * 1e3,
        status.restore_seconds * 1e3,
        status.total_seconds,
    );
    status
}

/// Mark a migration failed: final record, flight-recorder entry, log line.
fn fail(
    state: &Arc<CoordinatorState>,
    mut status: MigrationStatus,
    t_total: Instant,
    err: AdminError,
) -> MigrationStatus {
    let failed_phase = status.phase.as_str();
    status.phase = MigrationPhase::Failed;
    status.total_seconds = t_total.elapsed().as_secs_f64();
    status.error = Some(err.clone());
    state.migrations.put(status.clone());
    state.decisions.record(
        "coordinator",
        "migration",
        &status.reason,
        vec![
            ("source", status.source_node.clone()),
            ("target", status.target_node.clone()),
            ("outcome", "failed".to_string()),
            ("failed_phase", failed_phase.to_string()),
            ("code", err.code.clone()),
            ("message", err.message.clone()),
        ],
    );
    crate::warn!(
        "cluster",
        "migration {} ({} -> {}) failed in {failed_phase}: {} ({})",
        status.id,
        status.source_node,
        if status.target_node.is_empty() { "?" } else { &status.target_node },
        err.message,
        err.code
    );
    status
}

/// Fold a non-2xx control response into a structured error, preserving
/// the node's own `{code, message}` when the body carries one.
fn rpc_error(code: &str, context: &str, http_status: u16, body: &str) -> AdminError {
    match Json::parse(body).ok().and_then(|j| AdminError::from_json(&j).ok()) {
        Some(inner) => AdminError::new(code, &format!("{context}: {}", inner.message))
            .with_detail("node_code", &inner.code)
            .with_detail("http_status", &http_status.to_string()),
        None => AdminError::new(code, &format!("{context}: HTTP {http_status}"))
            .with_detail("http_status", &http_status.to_string()),
    }
}

/// One control RPC over the coordinator's keep-alive node pool: checkout
/// (or dial), exchange, park the connection back when the response ended
/// at a clean framing boundary. A transport failure on a *reused* socket
/// redials once on a fresh connection — the node may simply have reaped
/// the idle socket, which is not the node's fault.
pub(crate) fn pool_rpc(
    pool: &NodePool,
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> Result<(u16, String)> {
    let mut force_fresh = false;
    loop {
        let pooled = if force_fresh { None } else { pool.checkout(addr) };
        let reused = pooled.is_some();
        let stream = match pooled {
            Some(s) => s,
            None => dial(addr, timeout)?,
        };
        match rpc_once(stream, addr, method, path, body, timeout) {
            Ok((status, body, parked)) => {
                if let Some(reader) = parked {
                    if reader.buffer().is_empty() {
                        pool.checkin(addr, reader.into_inner());
                    }
                }
                return Ok((status, body));
            }
            Err(_) if reused && !force_fresh => force_fresh = true,
            Err(e) => return Err(e),
        }
    }
}

fn dial(addr: &str, timeout: Duration) -> Result<TcpStream> {
    let stream = match addr.parse::<SocketAddr>() {
        Ok(sa) => TcpStream::connect_timeout(&sa, Duration::from_secs(2))
            .with_context(|| format!("connect {addr}"))?,
        Err(_) => TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?,
    };
    stream.set_nodelay(true)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    Ok(stream)
}

/// One request/response exchange on an already-open connection. Returns
/// the reader when the response ended at a reusable framing boundary.
fn rpc_once(
    stream: TcpStream,
    addr: &str,
    method: &str,
    path: &str,
    body: Option<&str>,
    timeout: Duration,
) -> Result<(u16, String, Option<BufReader<TcpStream>>)> {
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    {
        let mut w = &stream;
        let body = body.unwrap_or("");
        // keep-alive head (no `Connection: close`): the node parks the
        // connection after answering and the pool reuses it
        let head = format!(
            "{method} {path} HTTP/1.1\r\nHost: {addr}\r\nAccept: */*\r\n\
             Content-Type: application/json\r\nContent-Length: {}\r\n\r\n",
            body.len()
        );
        w.write_all(head.as_bytes())?;
        w.write_all(body.as_bytes())?;
        w.flush()?;
    }
    let mut reader = BufReader::new(stream);
    let (status, headers) = crate::gateway::loadgen::read_response_head(&mut reader)?;
    let keep_alive = !headers
        .get("connection")
        .map(|v| v.eq_ignore_ascii_case("close"))
        .unwrap_or(false);
    let mut out = Vec::new();
    if headers
        .get("transfer-encoding")
        .map(|v| v.eq_ignore_ascii_case("chunked"))
        .unwrap_or(false)
    {
        while let Some(chunk) = crate::gateway::loadgen::read_chunk(&mut reader)? {
            out.extend_from_slice(&chunk);
            if out.len() > MAX_CONTROL_BODY {
                bail!("control response over the {MAX_CONTROL_BODY}-byte limit");
            }
        }
    } else if let Some(len) = headers.get("content-length") {
        let len: usize = len.parse().context("bad Content-Length")?;
        if len > MAX_CONTROL_BODY {
            bail!("control response of {len} bytes over the limit");
        }
        out = vec![0u8; len];
        reader.read_exact(&mut out)?;
    } else {
        // unframed: the body runs to EOF, so the socket is not reusable
        reader.read_to_end(&mut out)?;
        if out.len() > MAX_CONTROL_BODY {
            bail!("control response over the {MAX_CONTROL_BODY}-byte limit");
        }
        return Ok((status, String::from_utf8_lossy(&out).into_owned(), None));
    }
    let parked = keep_alive.then_some(reader);
    Ok((status, String::from_utf8_lossy(&out).into_owned(), parked))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn record(id: u64, phase: MigrationPhase) -> MigrationStatus {
        MigrationStatus {
            id,
            source_node: "node-a".into(),
            target_node: "node-b".into(),
            reason: "migration".into(),
            phase,
            new_replica_id: None,
            error: None,
            started_unix: 0.0,
            snapshot_seconds: 0.0,
            restore_seconds: 0.0,
            retire_seconds: 0.0,
            total_seconds: 0.0,
        }
    }

    #[test]
    fn registry_allocates_monotonic_ids() {
        let r = MigrationRegistry::new();
        let a = r.allocate();
        let b = r.allocate();
        assert!(b > a);
    }

    #[test]
    fn registry_replaces_records_in_place_on_phase_transitions() {
        let r = MigrationRegistry::new();
        let id = r.allocate();
        r.put(record(id, MigrationPhase::Pending));
        r.put(record(id, MigrationPhase::Restoring));
        r.put(record(id, MigrationPhase::Done));
        let list = r.list();
        assert_eq!(list.len(), 1);
        assert_eq!(list[0].phase, MigrationPhase::Done);
    }

    #[test]
    fn registry_history_is_bounded() {
        let r = MigrationRegistry::new();
        for _ in 0..(MIGRATION_HISTORY_CAP + 10) {
            let id = r.allocate();
            r.put(record(id, MigrationPhase::Done));
        }
        let list = r.list();
        assert_eq!(list.len(), MIGRATION_HISTORY_CAP);
        // oldest fell off, newest retained, order preserved
        assert_eq!(list.first().unwrap().id, 11);
        assert!(list.windows(2).all(|w| w[0].id < w[1].id));
    }
}
