//! Coordinator observability: cluster-level counters plus per-node
//! labeled gauges, rendered as the same Prometheus text exposition the
//! single-node gateway serves (and parseable by
//! [`crate::gateway::metrics::parse_exposition`], which the tests use).

use super::coordinator::ClusterSupervisorSnapshot;
use super::pool::BreakerState;
use crate::gateway::metrics::{escape_label, StatusCounters};
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Placement reasons that always appear on the scrape (at zero before the
/// first event), so dashboards and CI greps never miss a series that
/// simply has not fired yet.
pub const PLACEMENT_REASONS: [&str; 7] =
    ["forecast", "detector", "queue_wait", "backfill", "admin", "migration", "defrag"];

/// Circuit-breaker transitions that always appear on the scrape (at zero
/// before the first state change) — CI greps for these by name.
pub const BREAKER_TRANSITIONS: [&str; 3] = ["open", "half_open", "close"];

#[derive(Debug, Default)]
pub struct ClusterMetrics {
    /// coordinator ingress: (endpoint, status) -> count, relaxed so
    /// reactor handler threads don't serialize on a map mutex per request
    requests: StatusCounters,
    /// scale-up placements by reason
    placement: Mutex<BTreeMap<String, u64>>,
    /// scale-down drains by reason
    retire: Mutex<BTreeMap<String, u64>>,
    /// circuit-breaker state changes by transition kind
    breaker_transitions: Mutex<BTreeMap<String, u64>>,
    /// hits on deprecated pre-v1 alias paths, by path — the sunset gauge:
    /// when every series here flatlines, `--legacy-api off` is safe
    deprecated: Mutex<BTreeMap<String, u64>>,
    proxy_retries: AtomicU64,
    node_deaths: AtomicU64,
    rejected_queue_full: AtomicU64,
    rejected_rate_limited: AtomicU64,
    sse_chunks_relayed: AtomicU64,
    /// coordinator→node keep-alive pool accounting
    upstream_reused: AtomicU64,
    upstream_dialed: AtomicU64,
    upstream_pool_idle: AtomicU64,
    /// connection-level ingress accounting, shared with the reactor (or the
    /// legacy accept loop) serving this coordinator's listener
    pub ingress: std::sync::Arc<crate::gateway::reactor::IngressStats>,
}

impl ClusterMetrics {
    pub fn new() -> ClusterMetrics {
        ClusterMetrics::default()
    }

    pub fn observe(&self, endpoint: &str, status: u16) {
        self.requests.bump(endpoint, status);
    }

    pub fn note_placement(&self, reason: &str) {
        *self
            .placement
            .lock()
            .unwrap()
            .entry(reason.to_string())
            .or_insert(0) += 1;
    }

    pub fn note_retire(&self, reason: &str) {
        *self
            .retire
            .lock()
            .unwrap()
            .entry(reason.to_string())
            .or_insert(0) += 1;
    }

    /// One request on a deprecated pre-v1 alias path.
    pub fn note_deprecated(&self, path: &str) {
        *self
            .deprecated
            .lock()
            .unwrap()
            .entry(path.to_string())
            .or_insert(0) += 1;
    }

    /// Deprecated-alias hits recorded for one path (test/report helper).
    pub fn deprecated_for(&self, path: &str) -> u64 {
        self.deprecated.lock().unwrap().get(path).copied().unwrap_or(0)
    }

    /// One circuit-breaker state change (`open`, `half_open`, `close`).
    pub fn note_breaker_transition(&self, transition: &str) {
        *self
            .breaker_transitions
            .lock()
            .unwrap()
            .entry(transition.to_string())
            .or_insert(0) += 1;
    }

    /// Transitions recorded for one kind (test/report helper).
    pub fn breaker_transitions_for(&self, transition: &str) -> u64 {
        self.breaker_transitions
            .lock()
            .unwrap()
            .get(transition)
            .copied()
            .unwrap_or(0)
    }

    pub fn note_proxy_retry(&self) {
        self.proxy_retries.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_node_death(&self) {
        self.node_deaths.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_queue_full(&self) {
        self.rejected_queue_full.fetch_add(1, Ordering::Relaxed);
    }

    pub fn note_rate_limited(&self) {
        self.rejected_rate_limited.fetch_add(1, Ordering::Relaxed);
    }

    pub fn add_sse_chunks(&self, n: usize) {
        self.sse_chunks_relayed.fetch_add(n as u64, Ordering::Relaxed);
    }

    /// A proxy attempt ran on a pooled keep-alive node connection.
    pub fn note_upstream_reuse(&self) {
        self.upstream_reused.fetch_add(1, Ordering::Relaxed);
    }

    /// A proxy attempt had to dial a fresh node connection.
    pub fn note_upstream_dial(&self) {
        self.upstream_dialed.fetch_add(1, Ordering::Relaxed);
    }

    /// Refresh the idle-pool gauge (called after checkout/checkin).
    pub fn set_upstream_pool_idle(&self, n: usize) {
        self.upstream_pool_idle.store(n as u64, Ordering::Relaxed);
    }

    /// Total scale-up placements across all reasons (test/report helper
    /// mirroring `enova_cluster_placement_total`).
    pub fn placements_total(&self) -> u64 {
        self.placement.lock().unwrap().values().sum()
    }

    /// Placements recorded for one reason.
    pub fn placements_for(&self, reason: &str) -> u64 {
        self.placement.lock().unwrap().get(reason).copied().unwrap_or(0)
    }
}

/// One node row of the `/metrics` exposition — a snapshot the coordinator
/// builds from its registry under lock, so rendering itself is lock-free
/// over node state.
#[derive(Debug, Clone)]
pub struct NodeSample {
    pub node_id: String,
    pub healthy: bool,
    pub ready: bool,
    pub live_replicas: usize,
    pub warm_replicas: usize,
    pub gpu_memory_total: f64,
    pub gpu_memory_free: f64,
    pub arrival_rps: f64,
    pub queue_wait: f64,
    /// share of `arrival_rps` from batch-tier tenants (absolute req/s)
    pub batch_rps: f64,
    /// coordinator-side in-flight proxied requests on this node
    pub inflight: u64,
    /// the node's circuit-breaker position (closed 0, half-open 1, open 2)
    pub breaker_state: BreakerState,
}

/// Render the coordinator's `/metrics` body.
pub fn render_prometheus(
    m: &ClusterMetrics,
    nodes: &[NodeSample],
    sup: &ClusterSupervisorSnapshot,
    inflight: usize,
    uptime_secs: f64,
) -> String {
    let mut out = String::with_capacity(4096);
    let healthy = nodes.iter().filter(|n| n.healthy).count();
    let replicas: usize = nodes
        .iter()
        .filter(|n| n.healthy)
        .map(|n| n.live_replicas)
        .sum();

    out.push_str("# HELP enova_cluster_nodes Healthy serving nodes registered with the coordinator.\n");
    out.push_str("# TYPE enova_cluster_nodes gauge\n");
    let _ = writeln!(out, "enova_cluster_nodes {healthy}");

    out.push_str("# HELP enova_cluster_nodes_registered Nodes ever registered (healthy or not).\n");
    out.push_str("# TYPE enova_cluster_nodes_registered gauge\n");
    let _ = writeln!(out, "enova_cluster_nodes_registered {}", nodes.len());

    out.push_str("# HELP enova_cluster_replicas Live engine replicas across healthy nodes.\n");
    out.push_str("# TYPE enova_cluster_replicas gauge\n");
    let _ = writeln!(out, "enova_cluster_replicas {replicas}");

    out.push_str("# HELP enova_cluster_replicas_per_node Live replicas per node.\n");
    out.push_str("# TYPE enova_cluster_replicas_per_node gauge\n");
    for n in nodes {
        let _ = writeln!(
            out,
            "enova_cluster_replicas_per_node{{node=\"{}\"}} {}",
            escape_label(&n.node_id),
            n.live_replicas
        );
    }

    for (name, help, value) in [
        (
            "enova_cluster_node_healthy",
            "1 while the node answers heartbeats.",
            (|n: &NodeSample| n.healthy as u64 as f64) as fn(&NodeSample) -> f64,
        ),
        (
            "enova_cluster_node_ready",
            "1 while every live replica on the node is ready.",
            |n: &NodeSample| n.ready as u64 as f64,
        ),
        (
            "enova_cluster_node_warm_replicas",
            "Warm standby replicas parked on the node.",
            |n: &NodeSample| n.warm_replicas as f64,
        ),
        (
            "enova_cluster_node_gpu_memory_total",
            "GPU memory the node advertises in total.",
            |n: &NodeSample| n.gpu_memory_total,
        ),
        (
            "enova_cluster_node_gpu_memory_free",
            "GPU memory not yet claimed by a live replica.",
            |n: &NodeSample| n.gpu_memory_free,
        ),
        (
            "enova_cluster_node_arrival_rps",
            "De-noised request arrival rate the node reports (requests/second).",
            |n: &NodeSample| n.arrival_rps,
        ),
        (
            "enova_cluster_node_queue_wait_seconds",
            "Mean worker-queue wait the node reports.",
            |n: &NodeSample| n.queue_wait,
        ),
        (
            "enova_cluster_node_batch_rps",
            "Arrival rate from batch-tier tenants on the node (requests/second).",
            |n: &NodeSample| n.batch_rps,
        ),
        (
            "enova_cluster_node_inflight_requests",
            "Coordinator-side in-flight proxied requests per node.",
            |n: &NodeSample| n.inflight as f64,
        ),
        (
            "enova_cluster_breaker_state",
            "Per-node circuit-breaker position: 0 closed, 1 half-open, 2 open.",
            |n: &NodeSample| n.breaker_state.gauge(),
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        for n in nodes {
            let _ = writeln!(out, "{name}{{node=\"{}\"}} {}", escape_label(&n.node_id), value(n));
        }
    }

    out.push_str(
        "# HELP enova_cluster_placement_total Replica placements executed by the cluster \
         supervisor, by reason.\n",
    );
    out.push_str("# TYPE enova_cluster_placement_total counter\n");
    {
        let placement = m.placement.lock().unwrap();
        let mut reasons: Vec<&str> = PLACEMENT_REASONS.to_vec();
        for r in placement.keys() {
            if !reasons.contains(&r.as_str()) {
                reasons.push(r);
            }
        }
        for reason in reasons {
            let _ = writeln!(
                out,
                "enova_cluster_placement_total{{reason=\"{}\"}} {}",
                escape_label(reason),
                placement.get(reason).copied().unwrap_or(0)
            );
        }
    }

    out.push_str(
        "# HELP enova_cluster_breaker_transitions_total Circuit-breaker state changes, by \
         transition (open, half_open, close).\n",
    );
    out.push_str("# TYPE enova_cluster_breaker_transitions_total counter\n");
    {
        let transitions = m.breaker_transitions.lock().unwrap();
        let mut kinds: Vec<&str> = BREAKER_TRANSITIONS.to_vec();
        for k in transitions.keys() {
            if !kinds.contains(&k.as_str()) {
                kinds.push(k);
            }
        }
        for kind in kinds {
            let _ = writeln!(
                out,
                "enova_cluster_breaker_transitions_total{{transition=\"{}\"}} {}",
                escape_label(kind),
                transitions.get(kind).copied().unwrap_or(0)
            );
        }
    }

    out.push_str(
        "# HELP enova_cluster_retire_total Replica drains executed by the cluster supervisor, \
         by reason.\n",
    );
    out.push_str("# TYPE enova_cluster_retire_total counter\n");
    for (reason, count) in m.retire.lock().unwrap().iter() {
        let _ = writeln!(
            out,
            "enova_cluster_retire_total{{reason=\"{}\"}} {count}",
            escape_label(reason)
        );
    }

    out.push_str(
        "# HELP enova_api_deprecated_requests_total Requests served on deprecated pre-v1 \
         alias paths, by path.\n",
    );
    out.push_str("# TYPE enova_api_deprecated_requests_total counter\n");
    for (path, count) in m.deprecated.lock().unwrap().iter() {
        let _ = writeln!(
            out,
            "enova_api_deprecated_requests_total{{path=\"{}\"}} {count}",
            escape_label(path)
        );
    }

    out.push_str("# HELP enova_cluster_requests_total Coordinator ingress requests, by endpoint and status code.\n");
    out.push_str("# TYPE enova_cluster_requests_total counter\n");
    for ((endpoint, status), count) in m.requests.snapshot() {
        let _ = writeln!(
            out,
            "enova_cluster_requests_total{{endpoint=\"{}\",code=\"{}\"}} {}",
            escape_label(&endpoint),
            status,
            count
        );
    }

    out.push_str("# HELP enova_cluster_admission_rejected_total Requests rejected with 429 at the coordinator.\n");
    out.push_str("# TYPE enova_cluster_admission_rejected_total counter\n");
    let _ = writeln!(
        out,
        "enova_cluster_admission_rejected_total{{reason=\"queue_full\"}} {}",
        m.rejected_queue_full.load(Ordering::Relaxed)
    );
    let _ = writeln!(
        out,
        "enova_cluster_admission_rejected_total{{reason=\"rate_limited\"}} {}",
        m.rejected_rate_limited.load(Ordering::Relaxed)
    );

    for (name, help, value) in [
        (
            "enova_cluster_proxy_retries_total",
            "Proxied requests re-dispatched to another node after a node failed an attempt.",
            m.proxy_retries.load(Ordering::Relaxed),
        ),
        (
            "enova_cluster_node_deaths_total",
            "Nodes declared dead after consecutive missed heartbeats.",
            m.node_deaths.load(Ordering::Relaxed),
        ),
        (
            "enova_cluster_sse_chunks_relayed_total",
            "SSE chunks passed through from nodes to streaming clients.",
            m.sse_chunks_relayed.load(Ordering::Relaxed),
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} counter");
        let _ = writeln!(out, "{name} {value}");
    }

    for (name, help, value) in [
        (
            "enova_cluster_supervisor_enabled",
            "1 when the cluster-wide scaling supervisor is running.",
            sup.enabled as u64 as f64,
        ),
        (
            "enova_cluster_supervisor_calibrated",
            "1 once the cluster detector finished calibration.",
            sup.calibrated as u64 as f64,
        ),
        (
            "enova_cluster_target_replicas",
            "Cluster-wide replica count the supervisor currently wants (backfilled on node death).",
            sup.target_replicas as f64,
        ),
        (
            "enova_cluster_forecast_enabled",
            "1 when the cluster forecast planner is running.",
            sup.forecast_enabled as u64 as f64,
        ),
        (
            "enova_cluster_forecast_rps",
            "Predicted cluster arrival rate at the planning horizon (requests/second).",
            sup.last_forecast,
        ),
        (
            "enova_cluster_forecast_error",
            "Trailing weighted-MAPE of the cluster forecaster.",
            sup.forecast_error,
        ),
        (
            "enova_cluster_forecast_degraded",
            "1 while forecast error is over budget and the planner stands down.",
            sup.forecast_degraded as u64 as f64,
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} gauge");
        let _ = writeln!(out, "{name} {value}");
    }

    out.push_str("# HELP enova_cluster_scale_events_total Scaling actions executed cluster-wide.\n");
    out.push_str("# TYPE enova_cluster_scale_events_total counter\n");
    let _ = writeln!(
        out,
        "enova_cluster_scale_events_total{{direction=\"up\"}} {}",
        sup.scale_ups
    );
    let _ = writeln!(
        out,
        "enova_cluster_scale_events_total{{direction=\"down\"}} {}",
        sup.scale_downs
    );

    out.push_str("# HELP enova_cluster_inflight_requests Requests admitted at the coordinator and not yet finished.\n");
    out.push_str("# TYPE enova_cluster_inflight_requests gauge\n");
    let _ = writeln!(out, "enova_cluster_inflight_requests {inflight}");

    for (name, kind, help, value) in [
        (
            "enova_ingress_connections_accepted_total",
            "counter",
            "Client connections accepted by the coordinator listener.",
            m.ingress.accepted_total.load(Ordering::Relaxed),
        ),
        (
            "enova_ingress_connections_open",
            "gauge",
            "Client connections currently open at the coordinator.",
            m.ingress.open.load(Ordering::Relaxed),
        ),
        (
            "enova_ingress_handler_inflight",
            "gauge",
            "Requests currently executing in the coordinator handler pool.",
            m.ingress.handler_inflight.load(Ordering::Relaxed),
        ),
        (
            "enova_ingress_handler_threads",
            "gauge",
            "Handler threads serving parsed requests at the coordinator.",
            m.ingress.handler_threads.load(Ordering::Relaxed),
        ),
        (
            "enova_ingress_reactor_mode",
            "gauge",
            "1 when the sharded reactor serves ingress, 0 on the legacy thread-per-connection path.",
            m.ingress.reactor_mode.load(Ordering::Relaxed),
        ),
        (
            "enova_cluster_upstream_reused_total",
            "counter",
            "Proxy attempts served over a pooled keep-alive node connection.",
            m.upstream_reused.load(Ordering::Relaxed),
        ),
        (
            "enova_cluster_upstream_dialed_total",
            "counter",
            "Proxy attempts that dialed a fresh node connection.",
            m.upstream_dialed.load(Ordering::Relaxed),
        ),
        (
            "enova_cluster_upstream_pool_idle",
            "gauge",
            "Idle keep-alive node connections parked in the coordinator pool.",
            m.upstream_pool_idle.load(Ordering::Relaxed),
        ),
    ] {
        let _ = writeln!(out, "# HELP {name} {help}");
        let _ = writeln!(out, "# TYPE {name} {kind}");
        let _ = writeln!(out, "{name} {value}");
    }

    out.push_str("# HELP enova_cluster_uptime_seconds Coordinator uptime.\n");
    out.push_str("# TYPE enova_cluster_uptime_seconds gauge\n");
    let _ = writeln!(out, "enova_cluster_uptime_seconds {uptime_secs:.3}");

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gateway::metrics::parse_exposition;

    fn sample(id: &str, healthy: bool, live: usize) -> NodeSample {
        NodeSample {
            node_id: id.to_string(),
            healthy,
            ready: healthy,
            live_replicas: live,
            warm_replicas: 1,
            gpu_memory_total: 24.0,
            gpu_memory_free: 24.0 - live as f64 * 8.0,
            arrival_rps: 3.5,
            queue_wait: 0.01,
            batch_rps: 1.5,
            inflight: 2,
            breaker_state: if healthy {
                BreakerState::Closed
            } else {
                BreakerState::Open
            },
        }
    }

    #[test]
    fn render_is_a_parseable_exposition_with_per_node_labels() {
        let m = ClusterMetrics::new();
        m.observe("/v1/completions", 200);
        m.observe("/v1/completions", 503);
        m.note_placement("forecast");
        m.note_placement("backfill");
        m.note_placement("backfill");
        m.note_retire("detector");
        m.note_proxy_retry();
        m.note_node_death();
        m.note_queue_full();
        m.add_sse_chunks(7);
        m.note_breaker_transition("open");
        m.note_breaker_transition("open");
        m.note_breaker_transition("half_open");
        m.note_deprecated("/cluster/status");
        m.note_deprecated("/cluster/status");
        m.note_deprecated("/debug/traces");

        let nodes = vec![sample("node-a", true, 2), sample("node-b", false, 1)];
        let sup = ClusterSupervisorSnapshot {
            enabled: true,
            calibrated: false,
            scale_ups: 3,
            scale_downs: 1,
            target_replicas: 3,
            forecast_enabled: true,
            last_forecast: 12.5,
            forecast_error: 0.2,
            forecast_degraded: false,
            events: 4,
        };
        let body = render_prometheus(&m, &nodes, &sup, 5, 9.5);
        let samples = parse_exposition(&body).expect("valid exposition");

        let find = |name: &str, label: Option<(&str, &str)>| {
            samples
                .iter()
                .find(|s| {
                    s.name == name
                        && label
                            .map(|(k, v)| s.labels.get(k).map(String::as_str) == Some(v))
                            .unwrap_or(true)
                })
                .unwrap_or_else(|| panic!("missing {name} {label:?}"))
                .value
        };
        // only node-a is healthy: one healthy node, its 2 replicas counted
        assert_eq!(find("enova_cluster_nodes", None), 1.0);
        assert_eq!(find("enova_cluster_nodes_registered", None), 2.0);
        assert_eq!(find("enova_cluster_replicas", None), 2.0);
        assert_eq!(
            find("enova_cluster_replicas_per_node", Some(("node", "node-b"))),
            1.0
        );
        assert_eq!(find("enova_cluster_node_healthy", Some(("node", "node-a"))), 1.0);
        assert_eq!(find("enova_cluster_node_healthy", Some(("node", "node-b"))), 0.0);
        assert_eq!(
            find("enova_cluster_node_gpu_memory_free", Some(("node", "node-a"))),
            8.0
        );
        assert_eq!(
            find("enova_cluster_node_batch_rps", Some(("node", "node-a"))),
            1.5
        );
        // placement counter: recorded reasons count, unfired reasons are 0
        assert_eq!(
            find("enova_cluster_placement_total", Some(("reason", "backfill"))),
            2.0
        );
        assert_eq!(
            find("enova_cluster_placement_total", Some(("reason", "forecast"))),
            1.0
        );
        assert_eq!(
            find("enova_cluster_placement_total", Some(("reason", "detector"))),
            0.0
        );
        assert_eq!(
            find("enova_cluster_retire_total", Some(("reason", "detector"))),
            1.0
        );
        assert_eq!(
            find("enova_cluster_requests_total", Some(("code", "503"))),
            1.0
        );
        // breaker: per-node state gauge plus zero-filled transition counters
        assert_eq!(
            find("enova_cluster_breaker_state", Some(("node", "node-a"))),
            0.0
        );
        assert_eq!(
            find("enova_cluster_breaker_state", Some(("node", "node-b"))),
            2.0
        );
        assert_eq!(
            find("enova_cluster_breaker_transitions_total", Some(("transition", "open"))),
            2.0
        );
        assert_eq!(
            find("enova_cluster_breaker_transitions_total", Some(("transition", "half_open"))),
            1.0
        );
        assert_eq!(
            find("enova_cluster_breaker_transitions_total", Some(("transition", "close"))),
            0.0
        );
        assert_eq!(m.breaker_transitions_for("open"), 2);
        assert_eq!(m.breaker_transitions_for("close"), 0);
        // deprecated-alias hits render per path and zero out once unused
        assert_eq!(
            find(
                "enova_api_deprecated_requests_total",
                Some(("path", "/cluster/status"))
            ),
            2.0
        );
        assert_eq!(
            find(
                "enova_api_deprecated_requests_total",
                Some(("path", "/debug/traces"))
            ),
            1.0
        );
        assert_eq!(m.deprecated_for("/cluster/status"), 2);
        assert_eq!(m.deprecated_for("/admin/scale"), 0);
        // new placement reasons are pre-registered on the scrape
        assert_eq!(
            find("enova_cluster_placement_total", Some(("reason", "migration"))),
            0.0
        );
        assert_eq!(
            find("enova_cluster_placement_total", Some(("reason", "defrag"))),
            0.0
        );
        assert_eq!(find("enova_cluster_proxy_retries_total", None), 1.0);
        assert_eq!(find("enova_cluster_node_deaths_total", None), 1.0);
        assert_eq!(find("enova_cluster_sse_chunks_relayed_total", None), 7.0);
        assert_eq!(find("enova_cluster_target_replicas", None), 3.0);
        assert_eq!(
            find("enova_cluster_scale_events_total", Some(("direction", "up"))),
            3.0
        );
        assert_eq!(find("enova_cluster_inflight_requests", None), 5.0);
        for ingress_metric in [
            "enova_ingress_connections_accepted_total",
            "enova_ingress_connections_open",
            "enova_ingress_handler_inflight",
            "enova_ingress_handler_threads",
            "enova_ingress_reactor_mode",
            "enova_cluster_upstream_reused_total",
            "enova_cluster_upstream_dialed_total",
            "enova_cluster_upstream_pool_idle",
        ] {
            find(ingress_metric, None);
        }
        assert_eq!(m.placements_total(), 3);
        assert_eq!(m.placements_for("backfill"), 2);
        assert_eq!(m.placements_for("never"), 0);
    }
}
