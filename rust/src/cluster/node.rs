//! One serving node of the distributed plane: today's full single-node
//! gateway (engine replicas, warm pool, admission, `/metrics`) started in
//! node mode — so it answers the `/cluster/*` control surface — plus a
//! background announce loop that registers the node with its coordinator
//! and keeps the registration fresh. The node is deliberately dumb about
//! the fleet: it advertises capacity and executes placement decisions;
//! *where* replicas go is the coordinator's problem.
//!
//! Chaos drills ride in on the wrapped gateway: `gateway.chaos` arms the
//! node's seeded fault injector at boot ([`crate::chaos`]), and the
//! node's `/v1/admin/chaos` endpoint re-arms or disarms it at runtime —
//! the coordinator's circuit breakers are exercised against exactly this.

use super::proto::NodeAnnounce;
use super::NodeIdentity;
use crate::gateway::{loadgen, EngineSpawner, Gateway, GatewayConfig};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct NodeConfig {
    /// the wrapped gateway's configuration; [`NodeServer::start`] fills in
    /// `gateway.node` from `identity`
    pub gateway: GatewayConfig,
    pub identity: NodeIdentity,
    /// engine replicas to boot with
    pub initial_replicas: usize,
    /// coordinator `host:port` to register with; `None` runs the node
    /// standalone (control surface up, nobody driving it)
    pub coordinator: Option<String>,
    /// cadence of the registration refresh — also how fast a restarted
    /// coordinator re-learns this node
    pub announce_interval: Duration,
    /// address advertised to the coordinator; defaults to the bound
    /// listener address (override when the node sits behind NAT)
    pub advertise_addr: Option<String>,
}

impl Default for NodeConfig {
    fn default() -> Self {
        NodeConfig {
            gateway: GatewayConfig::default(),
            identity: NodeIdentity::default(),
            initial_replicas: 1,
            coordinator: None,
            announce_interval: Duration::from_millis(1000),
            advertise_addr: None,
        }
    }
}

/// A running node: the wrapped [`Gateway`] plus the announce thread.
pub struct NodeServer {
    gateway: Gateway,
    announce: NodeAnnounce,
    coordinator: Option<String>,
    stop: Arc<AtomicBool>,
    announcer: Option<JoinHandle<()>>,
}

impl NodeServer {
    /// Boot the gateway in node mode and start announcing to the
    /// coordinator (when one is configured). Returns once the initial
    /// replica set is routable; registration happens in the background so
    /// a node can come up before its coordinator does.
    pub fn start(cfg: NodeConfig, spawner: EngineSpawner) -> Result<NodeServer> {
        if cfg.identity.initial_fit(cfg.initial_replicas).is_err() {
            return Err(anyhow!(
                "node {} cannot fit {} initial replicas: {} gpu_memory total, {} per replica, \
                 max {} replicas",
                cfg.identity.node_id,
                cfg.initial_replicas,
                cfg.identity.gpu_memory_total,
                cfg.identity.replica_gpu_memory,
                cfg.identity.max_replicas
            ));
        }
        let mut gw_cfg = cfg.gateway.clone();
        gw_cfg.node = Some(cfg.identity.clone());
        let gateway = Gateway::start_scalable(gw_cfg, spawner, cfg.initial_replicas, None)?;
        let advertised = cfg
            .advertise_addr
            .clone()
            .unwrap_or_else(|| gateway.addr_string());
        let announce = NodeAnnounce::new(&cfg.identity, &advertised);
        let stop = Arc::new(AtomicBool::new(false));
        let announcer = cfg.coordinator.clone().map(|coordinator| {
            let announce = announce.clone();
            let stop = Arc::clone(&stop);
            let interval = cfg.announce_interval.max(Duration::from_millis(50));
            std::thread::spawn(move || announce_loop(&coordinator, &announce, &stop, interval))
        });
        crate::info!(
            "cluster",
            "node {} serving on {} ({} replica(s), {} gpu_memory, coordinator: {})",
            announce.node_id,
            advertised,
            cfg.initial_replicas,
            cfg.identity.gpu_memory_total,
            cfg.coordinator.as_deref().unwrap_or("none")
        );
        if cfg.gateway.chaos.armed() {
            crate::warn!(
                "cluster",
                "node {} boots with chaos ARMED (seed {}): seeded fault injection is live \
                 on this node's serving path",
                announce.node_id,
                cfg.gateway.chaos.seed
            );
        }
        Ok(NodeServer {
            gateway,
            announce,
            coordinator: cfg.coordinator,
            stop,
            announcer,
        })
    }

    pub fn addr_string(&self) -> String {
        self.gateway.addr_string()
    }

    pub fn node_id(&self) -> &str {
        &self.announce.node_id
    }

    /// The wrapped gateway, for tests and programmatic drivers.
    pub fn gateway(&self) -> &Gateway {
        &self.gateway
    }

    /// Block until the coordinator acknowledged a registration, or the
    /// timeout elapsed. Purely a convenience for tests and scripts — the
    /// announce loop keeps retrying either way.
    pub fn wait_registered(&self, timeout: Duration) -> bool {
        let Some(coordinator) = &self.coordinator else {
            return false;
        };
        let deadline = Instant::now() + timeout;
        while Instant::now() < deadline {
            if announce_once(coordinator, &self.announce) {
                return true;
            }
            std::thread::sleep(Duration::from_millis(25));
        }
        false
    }

    /// Stop announcing and shut the gateway down (drains as
    /// [`Gateway::shutdown`] does). This is the in-process stand-in for
    /// killing a node: from the coordinator's view the node simply stops
    /// answering.
    pub fn shutdown(self) {
        self.stop.store(true, Ordering::Release);
        if let Some(h) = self.announcer {
            let _ = h.join();
        }
        self.gateway.shutdown();
    }

    /// Block forever serving (CLI path).
    pub fn serve_forever(self) {
        if let Some(h) = self.announcer {
            let _ = h.join();
        }
        self.gateway.serve_forever();
    }
}

impl NodeIdentity {
    /// Checks that `n` replicas fit the advertisement — the same bound the
    /// coordinator's placement math will enforce later, applied up front
    /// so a node never advertises a state it could not have reached.
    pub fn initial_fit(&self, n: usize) -> Result<(), String> {
        if n > self.max_replicas {
            return Err(format!("{n} replicas over the ceiling of {}", self.max_replicas));
        }
        if n as f64 * self.replica_gpu_memory > self.gpu_memory_total {
            return Err(format!(
                "{n} replicas x {} gpu_memory exceed the {} advertised",
                self.replica_gpu_memory, self.gpu_memory_total
            ));
        }
        Ok(())
    }
}

/// POST one announce; true on a 2xx acknowledgment.
fn announce_once(coordinator: &str, announce: &NodeAnnounce) -> bool {
    let body = announce.to_json().to_string_compact();
    match loadgen::request(
        coordinator,
        "POST",
        "/cluster/join",
        Some(&body),
        Duration::from_secs(2),
    ) {
        Ok(resp) => (200..300).contains(&resp.status),
        Err(_) => false,
    }
}

/// Register with the coordinator, then keep the registration fresh until
/// the node stops. Failures only log at a low duty cycle: a node starting
/// before its coordinator is normal, not an incident.
fn announce_loop(
    coordinator: &str,
    announce: &NodeAnnounce,
    stop: &AtomicBool,
    interval: Duration,
) {
    let mut registered = false;
    let mut failures = 0u32;
    while !stop.load(Ordering::Acquire) {
        if announce_once(coordinator, announce) {
            if !registered {
                crate::info!(
                    "cluster",
                    "node {} registered with coordinator {coordinator}",
                    announce.node_id
                );
            }
            registered = true;
            failures = 0;
        } else {
            failures += 1;
            if failures == 1 || failures % 20 == 0 {
                crate::warn!(
                    "cluster",
                    "node {} cannot reach coordinator {coordinator} (attempt {failures})",
                    announce.node_id
                );
            }
        }
        // short slices so shutdown is prompt even with long intervals
        let deadline = Instant::now() + interval;
        while Instant::now() < deadline {
            if stop.load(Ordering::Acquire) {
                return;
            }
            std::thread::sleep(Duration::from_millis(20));
        }
    }
}
