//! Wire types of the coordinator ↔ node control protocol: plain JSON
//! bodies over the crate's hand-rolled HTTP stack. Every type serializes
//! with [`crate::util::json`] and parses defensively — a malformed peer
//! yields an error string, never a panic — so a version-skewed node and
//! coordinator fail loudly at the protocol boundary.

use super::NodeIdentity;
use crate::chaos::ChaosConfig;
use crate::metrics::Frame;
use crate::util::json::{arr_f64, num, obj, s, Json};

/// What a node POSTs to the coordinator's `/cluster/join`: where its
/// gateway listens plus its capacity advertisement.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeAnnounce {
    pub node_id: String,
    /// `host:port` of the node's gateway (ingress proxy + control target)
    pub addr: String,
    pub gpu_memory_total: f64,
    pub replica_gpu_memory: f64,
    pub max_replicas: usize,
    /// advertised per-replica service rate (requests/second); 0 = unknown
    pub replica_capacity_rps: f64,
}

impl NodeAnnounce {
    pub fn new(identity: &NodeIdentity, addr: &str) -> NodeAnnounce {
        NodeAnnounce {
            node_id: identity.node_id.clone(),
            addr: addr.to_string(),
            gpu_memory_total: identity.gpu_memory_total,
            replica_gpu_memory: identity.replica_gpu_memory,
            max_replicas: identity.max_replicas,
            replica_capacity_rps: identity.replica_capacity_rps,
        }
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("node_id", s(&self.node_id)),
            ("addr", s(&self.addr)),
            ("gpu_memory_total", num(self.gpu_memory_total)),
            ("replica_gpu_memory", num(self.replica_gpu_memory)),
            ("max_replicas", num(self.max_replicas as f64)),
            ("replica_capacity_rps", num(self.replica_capacity_rps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<NodeAnnounce, String> {
        let node_id = j
            .get("node_id")
            .and_then(Json::as_str)
            .ok_or("announce needs a string \"node_id\"")?
            .to_string();
        if node_id.is_empty() {
            return Err("announce \"node_id\" must not be empty".into());
        }
        let addr = j
            .get("addr")
            .and_then(Json::as_str)
            .ok_or("announce needs a string \"addr\"")?
            .to_string();
        if addr.is_empty() {
            return Err("announce \"addr\" must not be empty".into());
        }
        let f = |key: &str| j.get(key).and_then(Json::as_f64).filter(|v| v.is_finite());
        Ok(NodeAnnounce {
            node_id,
            addr,
            gpu_memory_total: f("gpu_memory_total").unwrap_or(0.0).max(0.0),
            replica_gpu_memory: f("replica_gpu_memory").unwrap_or(0.0).max(0.0),
            max_replicas: j
                .get("max_replicas")
                .and_then(Json::as_usize)
                .ok_or("announce needs an integer \"max_replicas\"")?,
            replica_capacity_rps: f("replica_capacity_rps").unwrap_or(0.0).max(0.0),
        })
    }
}

/// What a node answers on `GET /cluster/status`: the heartbeat row the
/// cluster supervisor monitors. `frame` is the mean of the newest Table II
/// frame across the node's live replicas (the same aggregation the
/// single-node supervisor scores); `arrival_rps` is the de-noised total
/// arrival rate across them (what the forecaster consumes).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeStatus {
    pub node_id: String,
    pub live_replicas: usize,
    pub warm_replicas: usize,
    /// every live replica's engine finished construction
    pub ready: bool,
    pub gpu_memory_total: f64,
    pub gpu_memory_free: f64,
    /// `None` until the first monitoring window flushed
    pub frame: Option<Frame>,
    pub arrival_rps: f64,
    /// mean worker-queue wait across live replicas (seconds)
    pub queue_wait: f64,
    /// share of `arrival_rps` coming from batch-tier tenants; the
    /// coordinator's tier-aware placement uses it to keep latency tenants
    /// away from batch-heavy nodes. Optional on the wire (version skew:
    /// an older node simply reports 0.0).
    pub batch_rps: f64,
}

impl NodeStatus {
    pub fn to_json(&self) -> Json {
        let mut j = obj([
            ("node_id", s(&self.node_id)),
            ("live_replicas", num(self.live_replicas as f64)),
            ("warm_replicas", num(self.warm_replicas as f64)),
            ("ready", Json::Bool(self.ready)),
            ("gpu_memory_total", num(self.gpu_memory_total)),
            ("gpu_memory_free", num(self.gpu_memory_free)),
            ("arrival_rps", num(self.arrival_rps)),
            ("queue_wait", num(self.queue_wait)),
            ("batch_rps", num(self.batch_rps)),
        ]);
        if let (Json::Obj(m), Some(frame)) = (&mut j, &self.frame) {
            m.insert("frame".to_string(), arr_f64(&frame.to_array()));
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<NodeStatus, String> {
        let node_id = j
            .get("node_id")
            .and_then(Json::as_str)
            .ok_or("status needs a string \"node_id\"")?
            .to_string();
        let frame = match j.get("frame").and_then(Json::as_arr) {
            None => None,
            Some(cols) => {
                if cols.len() != 8 {
                    return Err(format!("status \"frame\" must have 8 columns, got {}", cols.len()));
                }
                let mut a = [0.0f64; 8];
                for (slot, col) in a.iter_mut().zip(cols) {
                    *slot = col
                        .as_f64()
                        .filter(|v| v.is_finite())
                        .ok_or("status \"frame\" columns must be finite numbers")?;
                }
                Some(Frame::from_array(a))
            }
        };
        let f = |key: &str| j.get(key).and_then(Json::as_f64).filter(|v| v.is_finite());
        Ok(NodeStatus {
            node_id,
            live_replicas: j
                .get("live_replicas")
                .and_then(Json::as_usize)
                .ok_or("status needs an integer \"live_replicas\"")?,
            warm_replicas: j.get("warm_replicas").and_then(Json::as_usize).unwrap_or(0),
            ready: j.get("ready").and_then(Json::as_bool).unwrap_or(false),
            gpu_memory_total: f("gpu_memory_total").unwrap_or(0.0).max(0.0),
            gpu_memory_free: f("gpu_memory_free").unwrap_or(0.0).max(0.0),
            frame,
            arrival_rps: f("arrival_rps").unwrap_or(0.0).max(0.0),
            queue_wait: f("queue_wait").unwrap_or(0.0).max(0.0),
            batch_rps: f("batch_rps").unwrap_or(0.0).max(0.0),
        })
    }
}

// ---------------------------------------------------------------------------
// The versioned `/v1/admin/*` control API.
//
// Gateway, node, and coordinator all serve the same four operations —
// `GET /v1/admin/status`, `POST /v1/admin/scale` (router weights),
// `POST /v1/admin/scale-up`, `POST /v1/admin/scale-down` — with typed JSON
// requests/responses and structured `{code, message, details}` error
// bodies. The pre-v1 paths (`/admin/scale`, `/cluster/status`,
// `/cluster/scale-{up,down}`) remain as thin deprecated aliases for one
// release.
// ---------------------------------------------------------------------------

/// Path prefix of the unified control API.
pub const ADMIN_API_PREFIX: &str = "/v1/admin";

/// Structured error body of every `/v1/admin/*` failure:
/// `{"code": "...", "message": "...", "details": {...}}`.
#[derive(Debug, Clone, PartialEq)]
pub struct AdminError {
    /// stable machine-readable code, e.g. `invalid_request`, `node_full`
    pub code: String,
    /// human-readable explanation
    pub message: String,
    /// optional string key/value context, e.g. the offending replica id
    pub details: Vec<(String, String)>,
}

impl AdminError {
    pub fn new(code: &str, message: &str) -> AdminError {
        AdminError {
            code: code.to_string(),
            message: message.to_string(),
            details: Vec::new(),
        }
    }

    pub fn with_detail(mut self, key: &str, value: &str) -> AdminError {
        self.details.push((key.to_string(), value.to_string()));
        self
    }

    pub fn to_json(&self) -> Json {
        let details = Json::Obj(
            self.details
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        );
        obj([
            ("code", s(&self.code)),
            ("message", s(&self.message)),
            ("details", details),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AdminError, String> {
        let code = j
            .get("code")
            .and_then(Json::as_str)
            .ok_or("admin error needs a string \"code\"")?
            .to_string();
        let message = j
            .get("message")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let mut details = Vec::new();
        if let Some(Json::Obj(m)) = j.get("details") {
            for (k, v) in m {
                if let Some(v) = v.as_str() {
                    details.push((k.clone(), v.to_string()));
                }
            }
        }
        Ok(AdminError {
            code,
            message,
            details,
        })
    }
}

/// One router weight entry in a scale request/response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaWeight {
    pub id: u64,
    pub weight: f64,
}

/// `POST /v1/admin/scale` body: the full desired router weight set.
#[derive(Debug, Clone, PartialEq)]
pub struct AdminScaleRequest {
    pub replicas: Vec<ReplicaWeight>,
}

impl AdminScaleRequest {
    pub fn to_json(&self) -> Json {
        let entries = self
            .replicas
            .iter()
            .map(|r| obj([("id", num(r.id as f64)), ("weight", num(r.weight))]))
            .collect();
        obj([("replicas", Json::Arr(entries))])
    }

    /// Parse and validate. Errors are ready-to-serve [`AdminError`]s with
    /// code `invalid_request` and the offending entry in `details`.
    pub fn from_json(j: &Json) -> Result<AdminScaleRequest, AdminError> {
        let bad = |msg: &str| AdminError::new("invalid_request", msg);
        let entries = j
            .get("replicas")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("body must be {\"replicas\": [{\"id\": N, \"weight\": W}, ...]}"))?;
        if entries.is_empty() {
            return Err(bad("\"replicas\" must not be empty"));
        }
        let mut replicas: Vec<ReplicaWeight> = Vec::with_capacity(entries.len());
        for e in entries {
            let id = e
                .get("id")
                .and_then(Json::as_f64)
                .filter(|v| v.is_finite() && *v >= 0.0 && v.fract() == 0.0)
                .ok_or_else(|| bad("every entry needs a non-negative integer \"id\""))?
                as u64;
            let weight = e
                .get("weight")
                .and_then(Json::as_f64)
                .filter(|w| w.is_finite() && *w > 0.0)
                .ok_or_else(|| {
                    bad("every entry needs a positive finite \"weight\"")
                        .with_detail("id", &id.to_string())
                })?;
            if replicas.iter().any(|r| r.id == id) {
                return Err(bad("duplicate replica id").with_detail("id", &id.to_string()));
            }
            replicas.push(ReplicaWeight { id, weight });
        }
        Ok(AdminScaleRequest { replicas })
    }
}

/// `POST /v1/admin/scale` success body.
#[derive(Debug, Clone, PartialEq)]
pub struct AdminScaleResponse {
    pub applied: Vec<ReplicaWeight>,
    pub routable_replicas: usize,
}

impl AdminScaleResponse {
    pub fn to_json(&self) -> Json {
        obj([
            (
                "applied",
                Json::Arr(
                    self.applied
                        .iter()
                        .map(|r| obj([("id", num(r.id as f64)), ("weight", num(r.weight))]))
                        .collect(),
                ),
            ),
            ("routable_replicas", num(self.routable_replicas as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AdminScaleResponse, String> {
        let applied = j
            .get("applied")
            .and_then(Json::as_arr)
            .ok_or("scale response needs an array \"applied\"")?
            .iter()
            .map(|e| {
                let id = e
                    .get("id")
                    .and_then(Json::as_usize)
                    .ok_or("applied entries need an integer \"id\"")? as u64;
                let weight = e
                    .get("weight")
                    .and_then(Json::as_f64)
                    .ok_or("applied entries need a numeric \"weight\"")?;
                Ok(ReplicaWeight { id, weight })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(AdminScaleResponse {
            applied,
            routable_replicas: j
                .get("routable_replicas")
                .and_then(Json::as_usize)
                .unwrap_or(0),
        })
    }
}

/// Direction of a node replica-count change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    Up,
    Down,
}

impl ScaleDirection {
    pub fn as_str(self) -> &'static str {
        match self {
            ScaleDirection::Up => "up",
            ScaleDirection::Down => "down",
        }
    }
}

/// `POST /v1/admin/scale-{up,down}` success body. For wire compatibility
/// with the pre-v1 endpoints the JSON also carries the legacy field name
/// (`replica_id` for up, `retired` for down).
#[derive(Debug, Clone, PartialEq)]
pub struct AdminNodeScaleResponse {
    pub node_id: String,
    pub direction: ScaleDirection,
    /// the replica added (up) or retired (down)
    pub replica_id: u64,
    pub live_replicas: usize,
}

impl AdminNodeScaleResponse {
    pub fn to_json(&self) -> Json {
        let legacy_key = match self.direction {
            ScaleDirection::Up => "replica_id",
            ScaleDirection::Down => "retired",
        };
        obj([
            ("node_id", s(&self.node_id)),
            ("action", s(self.direction.as_str())),
            (legacy_key, num(self.replica_id as f64)),
            ("live_replicas", num(self.live_replicas as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AdminNodeScaleResponse, String> {
        let node_id = j
            .get("node_id")
            .and_then(Json::as_str)
            .ok_or("scale response needs a string \"node_id\"")?
            .to_string();
        let (direction, replica_id) = if let Some(id) =
            j.get("retired").and_then(Json::as_usize)
        {
            (ScaleDirection::Down, id as u64)
        } else if let Some(id) = j.get("replica_id").and_then(Json::as_usize) {
            (ScaleDirection::Up, id as u64)
        } else {
            return Err("scale response needs \"replica_id\" or \"retired\"".into());
        };
        Ok(AdminNodeScaleResponse {
            node_id,
            direction,
            replica_id,
            live_replicas: j
                .get("live_replicas")
                .and_then(Json::as_usize)
                .ok_or("scale response needs an integer \"live_replicas\"")?,
        })
    }
}

// ---------------------------------------------------------------------------
// The versioned `/v1/debug/*` observability API and `/v1/admin/chaos`.
//
// PR 8 versioned the control surface; this extends the same pattern to the
// read-only debug exports. `GET /v1/debug/traces` and `GET
// /v1/debug/decisions` answer a typed [`DebugExportResponse`] envelope —
// `{api_version, kind, service, data}` with the recorder's export embedded
// under `data` — while the pre-v1 `/debug/*` paths keep serving the bare
// export for one release as deprecated aliases. `GET|POST /v1/admin/chaos`
// reads/replaces a node's live [`ChaosConfig`] so chaos-smoke toggles
// faults without restarts; failures are structured [`AdminError`]s.
// ---------------------------------------------------------------------------

/// Path prefix of the versioned observability API.
pub const DEBUG_API_PREFIX: &str = "/v1/debug";

/// Version tag served in every `/v1/debug/*` and `/v1/admin/chaos` body.
pub const DEBUG_API_VERSION: &str = "v1";

/// Envelope of `GET /v1/debug/{traces,decisions}`: the recorder's legacy
/// export object wrapped with enough typing that consumers can verify
/// what they are holding (`kind`) and who served it (`service`).
#[derive(Debug, Clone, PartialEq)]
pub struct DebugExportResponse {
    /// `"traces"` or `"decisions"`
    pub kind: String,
    /// serving role: `coordinator`, `gateway`, or `node:<id>`
    pub service: String,
    /// the full recorder export — identical to the deprecated `/debug/*`
    /// alias body, so consumers migrate by unwrapping one level
    pub data: Json,
}

impl DebugExportResponse {
    pub fn new(kind: &str, service: &str, data: Json) -> DebugExportResponse {
        DebugExportResponse {
            kind: kind.to_string(),
            service: service.to_string(),
            data,
        }
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("api_version", s(DEBUG_API_VERSION)),
            ("kind", s(&self.kind)),
            ("service", s(&self.service)),
            ("data", self.data.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DebugExportResponse, String> {
        let version = j
            .get("api_version")
            .and_then(Json::as_str)
            .ok_or("debug export needs a string \"api_version\"")?;
        if version != DEBUG_API_VERSION {
            return Err(format!("unsupported debug api_version {version:?}"));
        }
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("debug export needs a string \"kind\"")?
            .to_string();
        if kind != "traces" && kind != "decisions" {
            return Err(format!("unknown debug export kind {kind:?}"));
        }
        let data = j.get("data").ok_or("debug export needs a \"data\" object")?;
        if !matches!(data, Json::Obj(_)) {
            return Err("debug export \"data\" must be an object".into());
        }
        Ok(DebugExportResponse {
            kind,
            service: j
                .get("service")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            data: data.clone(),
        })
    }
}

/// `POST /v1/admin/chaos` body: the desired injection config. Fields not
/// named keep their [`ChaosConfig`] defaults, so `{"error_rate":0}`
/// disarms everything.
#[derive(Debug, Clone, PartialEq)]
pub struct AdminChaosRequest {
    pub config: ChaosConfig,
}

impl AdminChaosRequest {
    pub fn to_json(&self) -> Json {
        self.config.to_json()
    }

    /// Parse and validate; errors are ready-to-serve [`AdminError`]s
    /// with code `invalid_request`.
    pub fn from_json(j: &Json) -> Result<AdminChaosRequest, AdminError> {
        let config = ChaosConfig::from_json(j)
            .map_err(|msg| AdminError::new("invalid_request", &msg))?;
        Ok(AdminChaosRequest { config })
    }
}

/// `GET|POST /v1/admin/chaos` success body: the live config plus the
/// injector's counters (armed / degraded / injected totals).
#[derive(Debug, Clone, PartialEq)]
pub struct AdminChaosResponse {
    pub service: String,
    pub config: ChaosConfig,
    /// [`crate::chaos::ChaosInjector::stats_json`] output
    pub stats: Json,
}

impl AdminChaosResponse {
    pub fn to_json(&self) -> Json {
        obj([
            ("api_version", s(DEBUG_API_VERSION)),
            ("service", s(&self.service)),
            ("config", self.config.to_json()),
            ("stats", self.stats.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AdminChaosResponse, String> {
        let config = j.get("config").ok_or("chaos response needs a \"config\" object")?;
        Ok(AdminChaosResponse {
            service: j
                .get("service")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            config: ChaosConfig::from_json(config)?,
            stats: j.get("stats").cloned().unwrap_or(Json::Obj(Default::default())),
        })
    }
}

/// Reject payloads carrying keys a request type does not define — a
/// typo'd field fails loudly at the protocol boundary instead of being
/// silently ignored (the contract the new v1 request types share).
fn reject_unknown_keys(j: &Json, allowed: &[&str], what: &str) -> Result<(), AdminError> {
    if let Json::Obj(m) = j {
        for key in m.keys() {
            if !allowed.contains(&key.as_str()) {
                return Err(AdminError::new(
                    "invalid_request",
                    &format!("unknown field {key:?} in {what}"),
                )
                .with_detail("field", key));
            }
        }
    }
    Ok(())
}

/// Metadata describing one engine snapshot — what `GET /v1/admin/snapshots`
/// lists and every capture/restore exchange carries.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotInfo {
    pub engine_kind: String,
    pub version: usize,
    pub max_num_seqs: usize,
    pub gpu_memory: f64,
    /// config fingerprint, hex (restore fails closed on a mismatch)
    pub fingerprint: String,
    pub payload_bytes: usize,
    /// where the checkpoint came from (`node-a` or `replica-3`)
    pub source: String,
    /// wall-clock capture time, unix seconds
    pub taken_unix: f64,
}

impl SnapshotInfo {
    pub fn to_json(&self) -> Json {
        obj([
            ("engine_kind", s(&self.engine_kind)),
            ("version", num(self.version as f64)),
            ("max_num_seqs", num(self.max_num_seqs as f64)),
            ("gpu_memory", num(self.gpu_memory)),
            ("fingerprint", s(&self.fingerprint)),
            ("payload_bytes", num(self.payload_bytes as f64)),
            ("source", s(&self.source)),
            ("taken_unix", num(self.taken_unix)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SnapshotInfo, String> {
        Ok(SnapshotInfo {
            engine_kind: j
                .get("engine_kind")
                .and_then(Json::as_str)
                .ok_or("snapshot info needs a string \"engine_kind\"")?
                .to_string(),
            version: j
                .get("version")
                .and_then(Json::as_usize)
                .ok_or("snapshot info needs an integer \"version\"")?,
            max_num_seqs: j.get("max_num_seqs").and_then(Json::as_usize).unwrap_or(0),
            gpu_memory: j.get("gpu_memory").and_then(Json::as_f64).unwrap_or(0.0),
            fingerprint: j
                .get("fingerprint")
                .and_then(Json::as_str)
                .ok_or("snapshot info needs a string \"fingerprint\"")?
                .to_string(),
            payload_bytes: j.get("payload_bytes").and_then(Json::as_usize).unwrap_or(0),
            source: j
                .get("source")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            taken_unix: j.get("taken_unix").and_then(Json::as_f64).unwrap_or(0.0),
        })
    }
}

/// `POST /v1/admin/snapshots` body: `capture` checkpoints a live replica
/// (node; the coordinator proxies to one), `restore` spawns a replica
/// from a hex-encoded snapshot frame.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotRequest {
    pub action: SnapshotAction,
    /// capture: which replica to checkpoint (default: lowest live)
    pub replica_id: Option<u64>,
    /// coordinator capture: which node to checkpoint from
    pub node: Option<String>,
    /// restore: the encoded snapshot frame, hex
    pub snapshot_hex: Option<String>,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotAction {
    Capture,
    Restore,
}

impl SnapshotAction {
    pub fn as_str(self) -> &'static str {
        match self {
            SnapshotAction::Capture => "capture",
            SnapshotAction::Restore => "restore",
        }
    }
}

impl SnapshotRequest {
    pub fn capture() -> SnapshotRequest {
        SnapshotRequest {
            action: SnapshotAction::Capture,
            replica_id: None,
            node: None,
            snapshot_hex: None,
        }
    }

    pub fn restore(snapshot_hex: &str) -> SnapshotRequest {
        SnapshotRequest {
            action: SnapshotAction::Restore,
            replica_id: None,
            node: None,
            snapshot_hex: Some(snapshot_hex.to_string()),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = obj([("action", s(self.action.as_str()))]);
        if let Json::Obj(m) = &mut j {
            if let Some(id) = self.replica_id {
                m.insert("replica_id".into(), num(id as f64));
            }
            if let Some(node) = &self.node {
                m.insert("node".into(), s(node));
            }
            if let Some(hex) = &self.snapshot_hex {
                m.insert("snapshot_hex".into(), s(hex));
            }
        }
        j
    }

    /// Parse and validate; errors are ready-to-serve [`AdminError`]s with
    /// code `invalid_request`.
    pub fn from_json(j: &Json) -> Result<SnapshotRequest, AdminError> {
        let bad = |msg: &str| AdminError::new("invalid_request", msg);
        reject_unknown_keys(j, &["action", "replica_id", "node", "snapshot_hex"], "snapshot request")?;
        let action = match j.get("action").and_then(Json::as_str) {
            Some("capture") => SnapshotAction::Capture,
            Some("restore") => SnapshotAction::Restore,
            Some(other) => {
                return Err(bad(&format!(
                    "unknown action {other:?}; expected \"capture\" or \"restore\""
                )))
            }
            None => return Err(bad("body must be {\"action\": \"capture\"|\"restore\", ...}")),
        };
        let replica_id = match j.get("replica_id") {
            None => None,
            Some(v) => Some(
                v.as_usize()
                    .ok_or_else(|| bad("\"replica_id\" must be a non-negative integer"))?
                    as u64,
            ),
        };
        let node = match j.get("node") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| bad("\"node\" must be a string"))?
                    .to_string(),
            ),
        };
        let snapshot_hex = match j.get("snapshot_hex") {
            None => None,
            Some(v) => Some(
                v.as_str()
                    .ok_or_else(|| bad("\"snapshot_hex\" must be a string"))?
                    .to_string(),
            ),
        };
        if action == SnapshotAction::Restore && snapshot_hex.is_none() {
            return Err(bad("restore needs a \"snapshot_hex\" frame"));
        }
        if action == SnapshotAction::Capture && snapshot_hex.is_some() {
            return Err(bad("capture does not take a \"snapshot_hex\" frame"));
        }
        Ok(SnapshotRequest {
            action,
            replica_id,
            node,
            snapshot_hex,
        })
    }
}

/// `POST /v1/admin/snapshots` success body.
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotResponse {
    pub service: String,
    pub action: SnapshotAction,
    pub info: SnapshotInfo,
    /// capture: the replica checkpointed; restore: the replica spawned
    pub replica_id: u64,
    /// capture only: the encoded frame, hex
    pub snapshot_hex: Option<String>,
    /// restore only: snapshot-promotion latency (the number that beats
    /// cold spawn)
    pub promote_seconds: Option<f64>,
}

impl SnapshotResponse {
    pub fn to_json(&self) -> Json {
        let mut j = obj([
            ("service", s(&self.service)),
            ("action", s(self.action.as_str())),
            ("info", self.info.to_json()),
            ("replica_id", num(self.replica_id as f64)),
        ]);
        if let Json::Obj(m) = &mut j {
            if let Some(hex) = &self.snapshot_hex {
                m.insert("snapshot_hex".into(), s(hex));
            }
            if let Some(secs) = self.promote_seconds {
                m.insert("promote_seconds".into(), num(secs));
            }
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<SnapshotResponse, String> {
        let action = match j.get("action").and_then(Json::as_str) {
            Some("capture") => SnapshotAction::Capture,
            Some("restore") => SnapshotAction::Restore,
            _ => return Err("snapshot response needs \"action\" capture|restore".into()),
        };
        Ok(SnapshotResponse {
            service: j
                .get("service")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            action,
            info: SnapshotInfo::from_json(
                j.get("info").ok_or("snapshot response needs an \"info\" object")?,
            )?,
            replica_id: j
                .get("replica_id")
                .and_then(Json::as_usize)
                .ok_or("snapshot response needs an integer \"replica_id\"")? as u64,
            snapshot_hex: j
                .get("snapshot_hex")
                .and_then(Json::as_str)
                .map(str::to_string),
            promote_seconds: j.get("promote_seconds").and_then(Json::as_f64),
        })
    }
}

/// `GET /v1/admin/snapshots` body: the snapshots a service is holding
/// (a node's capture ledger; the coordinator's periodic backfill cache).
#[derive(Debug, Clone, PartialEq)]
pub struct SnapshotListResponse {
    pub service: String,
    pub snapshots: Vec<SnapshotInfo>,
}

impl SnapshotListResponse {
    pub fn to_json(&self) -> Json {
        obj([
            ("api_version", s(DEBUG_API_VERSION)),
            ("service", s(&self.service)),
            (
                "snapshots",
                Json::Arr(self.snapshots.iter().map(SnapshotInfo::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<SnapshotListResponse, String> {
        let snapshots = j
            .get("snapshots")
            .and_then(Json::as_arr)
            .ok_or("snapshot list needs an array \"snapshots\"")?
            .iter()
            .map(SnapshotInfo::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(SnapshotListResponse {
            service: j
                .get("service")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            snapshots,
        })
    }
}

/// `POST /v1/admin/migrate` body: move one replica's capacity from
/// `source_node` to `target_node` (or the placement policy's choice) via
/// snapshot → transfer → restore → route flip → drain-retire.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationRequest {
    pub source_node: String,
    /// empty → the coordinator's placement policy chooses
    pub target_node: Option<String>,
}

impl MigrationRequest {
    pub fn to_json(&self) -> Json {
        let mut j = obj([("source_node", s(&self.source_node))]);
        if let (Json::Obj(m), Some(t)) = (&mut j, &self.target_node) {
            m.insert("target_node".into(), s(t));
        }
        j
    }

    /// Parse and validate; errors are ready-to-serve [`AdminError`]s with
    /// code `invalid_request`.
    pub fn from_json(j: &Json) -> Result<MigrationRequest, AdminError> {
        let bad = |msg: &str| AdminError::new("invalid_request", msg);
        reject_unknown_keys(j, &["source_node", "target_node"], "migration request")?;
        let source_node = j
            .get("source_node")
            .and_then(Json::as_str)
            .ok_or_else(|| bad("body must be {\"source_node\": \"...\", \"target_node\"?: \"...\"}"))?
            .to_string();
        if source_node.is_empty() {
            return Err(bad("\"source_node\" must be non-empty"));
        }
        let target_node = match j.get("target_node") {
            None => None,
            Some(v) => {
                let t = v
                    .as_str()
                    .ok_or_else(|| bad("\"target_node\" must be a string"))?
                    .to_string();
                if t == source_node {
                    return Err(bad("\"target_node\" must differ from \"source_node\""));
                }
                Some(t)
            }
        };
        Ok(MigrationRequest {
            source_node,
            target_node,
        })
    }
}

/// Where a migration is in its lifecycle.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MigrationPhase {
    Pending,
    Snapshotting,
    Restoring,
    Retiring,
    Done,
    Failed,
}

impl MigrationPhase {
    pub fn as_str(self) -> &'static str {
        match self {
            MigrationPhase::Pending => "pending",
            MigrationPhase::Snapshotting => "snapshotting",
            MigrationPhase::Restoring => "restoring",
            MigrationPhase::Retiring => "retiring",
            MigrationPhase::Done => "done",
            MigrationPhase::Failed => "failed",
        }
    }

    pub fn from_str(sv: &str) -> Result<MigrationPhase, String> {
        Ok(match sv {
            "pending" => MigrationPhase::Pending,
            "snapshotting" => MigrationPhase::Snapshotting,
            "restoring" => MigrationPhase::Restoring,
            "retiring" => MigrationPhase::Retiring,
            "done" => MigrationPhase::Done,
            "failed" => MigrationPhase::Failed,
            other => return Err(format!("unknown migration phase {other:?}")),
        })
    }
}

/// One migration's full record — returned by `POST /v1/admin/migrate`
/// (synchronously, after the state machine runs) and listed by
/// `GET /v1/admin/migrations`. Phase timings let an operator see where a
/// slow migration spent its time.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationStatus {
    pub id: u64,
    pub source_node: String,
    pub target_node: String,
    /// why it ran: `admin` (API), `backfill` (dead node), `defrag`
    pub reason: String,
    pub phase: MigrationPhase,
    /// replica spawned on the target (phase ≥ restoring)
    pub new_replica_id: Option<u64>,
    /// structured cause when `phase == failed`
    pub error: Option<AdminError>,
    pub started_unix: f64,
    /// source checkpoint RPC, seconds
    pub snapshot_seconds: f64,
    /// transfer + restore on the target, seconds
    pub restore_seconds: f64,
    /// drain-then-retire of the source replica (the route flip's tail)
    pub retire_seconds: f64,
    pub total_seconds: f64,
}

impl MigrationStatus {
    pub fn to_json(&self) -> Json {
        let mut j = obj([
            ("id", num(self.id as f64)),
            ("source_node", s(&self.source_node)),
            ("target_node", s(&self.target_node)),
            ("reason", s(&self.reason)),
            ("phase", s(self.phase.as_str())),
            ("started_unix", num(self.started_unix)),
            (
                "timings",
                obj([
                    ("snapshot_seconds", num(self.snapshot_seconds)),
                    ("restore_seconds", num(self.restore_seconds)),
                    ("retire_seconds", num(self.retire_seconds)),
                    ("total_seconds", num(self.total_seconds)),
                ]),
            ),
        ]);
        if let Json::Obj(m) = &mut j {
            if let Some(id) = self.new_replica_id {
                m.insert("new_replica_id".into(), num(id as f64));
            }
            if let Some(err) = &self.error {
                m.insert("error".into(), err.to_json());
            }
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<MigrationStatus, String> {
        let phase = MigrationPhase::from_str(
            j.get("phase")
                .and_then(Json::as_str)
                .ok_or("migration status needs a string \"phase\"")?,
        )?;
        let timing = |key: &str| j.at(&["timings", key]).and_then(Json::as_f64).unwrap_or(0.0);
        Ok(MigrationStatus {
            id: j
                .get("id")
                .and_then(Json::as_usize)
                .ok_or("migration status needs an integer \"id\"")? as u64,
            source_node: j
                .get("source_node")
                .and_then(Json::as_str)
                .ok_or("migration status needs a string \"source_node\"")?
                .to_string(),
            target_node: j
                .get("target_node")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            reason: j
                .get("reason")
                .and_then(Json::as_str)
                .unwrap_or("admin")
                .to_string(),
            phase,
            new_replica_id: j.get("new_replica_id").and_then(Json::as_usize).map(|v| v as u64),
            error: match j.get("error") {
                Some(e) => Some(AdminError::from_json(e)?),
                None => None,
            },
            started_unix: j.get("started_unix").and_then(Json::as_f64).unwrap_or(0.0),
            snapshot_seconds: timing("snapshot_seconds"),
            restore_seconds: timing("restore_seconds"),
            retire_seconds: timing("retire_seconds"),
            total_seconds: timing("total_seconds"),
        })
    }
}

/// `GET /v1/admin/migrations` body: the coordinator's bounded migration
/// history, newest last.
#[derive(Debug, Clone, PartialEq)]
pub struct MigrationListResponse {
    pub service: String,
    pub migrations: Vec<MigrationStatus>,
}

impl MigrationListResponse {
    pub fn to_json(&self) -> Json {
        obj([
            ("api_version", s(DEBUG_API_VERSION)),
            ("service", s(&self.service)),
            (
                "migrations",
                Json::Arr(self.migrations.iter().map(MigrationStatus::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<MigrationListResponse, String> {
        let migrations = j
            .get("migrations")
            .and_then(Json::as_arr)
            .ok_or("migration list needs an array \"migrations\"")?
            .iter()
            .map(MigrationStatus::from_json)
            .collect::<Result<Vec<_>, _>>()?;
        Ok(MigrationListResponse {
            service: j
                .get("service")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            migrations,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn announce_roundtrips_through_json() {
        let a = NodeAnnounce {
            node_id: "node-a".into(),
            addr: "127.0.0.1:18501".into(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 3,
            replica_capacity_rps: 12.5,
        };
        let wire = a.to_json().to_string_compact();
        let back = NodeAnnounce::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn announce_rejects_malformed_peers() {
        let missing_id = Json::parse(r#"{"addr":"x:1","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&missing_id).is_err());
        let empty_id =
            Json::parse(r#"{"node_id":"","addr":"x:1","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&empty_id).is_err());
        let no_addr = Json::parse(r#"{"node_id":"n","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&no_addr).is_err());
        let no_max = Json::parse(r#"{"node_id":"n","addr":"x:1"}"#).unwrap();
        assert!(NodeAnnounce::from_json(&no_max).is_err());
    }

    #[test]
    fn status_roundtrips_with_and_without_frame() {
        let mut st = NodeStatus {
            node_id: "node-b".into(),
            live_replicas: 2,
            warm_replicas: 1,
            ready: true,
            gpu_memory_total: 24.0,
            gpu_memory_free: 8.0,
            frame: None,
            arrival_rps: 7.5,
            queue_wait: 0.02,
            batch_rps: 2.5,
        };
        let back =
            NodeStatus::from_json(&Json::parse(&st.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, st);

        st.frame = Some(Frame {
            n_finished: 3.0,
            n_arriving: 4.0,
            gpu_util: 0.8,
            ..Default::default()
        });
        let back =
            NodeStatus::from_json(&Json::parse(&st.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, st);
    }

    #[test]
    fn status_without_batch_rps_defaults_to_zero() {
        // version skew: an older node omits the field entirely
        let old = Json::parse(r#"{"node_id":"n","live_replicas":1}"#).unwrap();
        let st = NodeStatus::from_json(&old).unwrap();
        assert_eq!(st.batch_rps, 0.0);
    }

    #[test]
    fn admin_error_roundtrips_with_details() {
        let e = AdminError::new("node_full", "no replica slot free")
            .with_detail("node_id", "node-a")
            .with_detail("live_replicas", "3");
        let wire = e.to_json().to_string_compact();
        assert!(wire.contains("\"code\":\"node_full\""));
        assert!(wire.contains("\"details\""));
        let back = AdminError::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.code, "node_full");
        assert_eq!(back.message, "no replica slot free");
        assert!(back
            .details
            .iter()
            .any(|(k, v)| k == "node_id" && v == "node-a"));
        // a body without a code is not an admin error
        assert!(AdminError::from_json(&Json::parse(r#"{"message":"x"}"#).unwrap()).is_err());
    }

    #[test]
    fn admin_scale_request_validates() {
        let ok = Json::parse(r#"{"replicas":[{"id":0,"weight":1.5},{"id":2,"weight":0.5}]}"#)
            .unwrap();
        let req = AdminScaleRequest::from_json(&ok).unwrap();
        assert_eq!(req.replicas.len(), 2);
        assert_eq!(req.replicas[1].id, 2);
        // roundtrip
        let again = AdminScaleRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(again, req);

        for bad in [
            r#"{"weights":[]}"#,
            r#"{"replicas":[]}"#,
            r#"{"replicas":[{"id":-1,"weight":1}]}"#,
            r#"{"replicas":[{"id":0.5,"weight":1}]}"#,
            r#"{"replicas":[{"id":0,"weight":0}]}"#,
            r#"{"replicas":[{"id":0,"weight":1},{"id":0,"weight":2}]}"#,
        ] {
            let err = AdminScaleRequest::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code, "invalid_request", "body {bad} -> {err:?}");
        }
    }

    #[test]
    fn node_scale_response_keeps_legacy_field_names() {
        let up = AdminNodeScaleResponse {
            node_id: "node-a".into(),
            direction: ScaleDirection::Up,
            replica_id: 7,
            live_replicas: 3,
        };
        let wire = up.to_json().to_string_compact();
        assert!(wire.contains("\"replica_id\":7"), "{wire}");
        assert_eq!(
            AdminNodeScaleResponse::from_json(&Json::parse(&wire).unwrap()).unwrap(),
            up
        );
        let down = AdminNodeScaleResponse {
            direction: ScaleDirection::Down,
            ..up.clone()
        };
        let wire = down.to_json().to_string_compact();
        assert!(wire.contains("\"retired\":7"), "{wire}");
        assert_eq!(
            AdminNodeScaleResponse::from_json(&Json::parse(&wire).unwrap()).unwrap(),
            down
        );
    }

    #[test]
    fn debug_export_roundtrips_and_validates() {
        let data = Json::parse(r#"{"recorded":3,"capacity":512,"traces":[]}"#).unwrap();
        let resp = DebugExportResponse::new("traces", "coordinator", data.clone());
        let wire = resp.to_json().to_string_compact();
        assert!(wire.contains("\"api_version\":\"v1\""), "{wire}");
        let back = DebugExportResponse::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, resp);
        // the embedded data is the legacy alias body, verbatim
        assert_eq!(back.data, data);

        for bad in [
            r#"{"kind":"traces","data":{}}"#,
            r#"{"api_version":"v2","kind":"traces","data":{}}"#,
            r#"{"api_version":"v1","kind":"spans","data":{}}"#,
            r#"{"api_version":"v1","kind":"traces"}"#,
            r#"{"api_version":"v1","kind":"traces","data":[]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(DebugExportResponse::from_json(&v).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn chaos_request_surfaces_structured_errors() {
        let ok = Json::parse(r#"{"seed":9,"error_rate":0.2}"#).unwrap();
        let req = AdminChaosRequest::from_json(&ok).unwrap();
        assert_eq!(req.config.seed, 9);
        assert_eq!(req.config.error_rate, 0.2);
        let again = AdminChaosRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(again, req);

        let bad = Json::parse(r#"{"error_rate":7}"#).unwrap();
        let err = AdminChaosRequest::from_json(&bad).unwrap_err();
        assert_eq!(err.code, "invalid_request");
    }

    #[test]
    fn chaos_response_roundtrips() {
        let resp = AdminChaosResponse {
            service: "node:node-a".into(),
            config: ChaosConfig {
                seed: 5,
                error_rate: 0.1,
                ..ChaosConfig::default()
            },
            stats: Json::parse(r#"{"armed":true,"injected_errors":4}"#).unwrap(),
        };
        let wire = resp.to_json().to_string_compact();
        let back = AdminChaosResponse::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, resp);
    }

    #[test]
    fn status_rejects_short_or_nan_frames() {
        let short = Json::parse(r#"{"node_id":"n","live_replicas":1,"frame":[1,2,3]}"#).unwrap();
        assert!(NodeStatus::from_json(&short).is_err());
        let nan = Json::parse(
            r#"{"node_id":"n","live_replicas":1,"frame":[1,2,3,4,5,6,7,"x"]}"#,
        )
        .unwrap();
        assert!(NodeStatus::from_json(&nan).is_err());
    }

    fn sample_snapshot_info() -> SnapshotInfo {
        SnapshotInfo {
            engine_kind: "sim".into(),
            version: 1,
            max_num_seqs: 4,
            gpu_memory: 0.6,
            fingerprint: "00deadbeef00cafe".into(),
            payload_bytes: 48,
            source: "node-a".into(),
            taken_unix: 1754600000.0,
        }
    }

    fn sample_migration_status() -> MigrationStatus {
        MigrationStatus {
            id: 3,
            source_node: "node-a".into(),
            target_node: "node-b".into(),
            reason: "defrag".into(),
            phase: MigrationPhase::Done,
            new_replica_id: Some(11),
            error: None,
            started_unix: 1754600001.5,
            snapshot_seconds: 0.004,
            restore_seconds: 0.012,
            retire_seconds: 0.25,
            total_seconds: 0.27,
        }
    }

    /// Satellite sweep: every v1 request/response/error type serializes to
    /// the wire and parses back to an identical JSON shape. Each row is
    /// `(label, to_json() output, from_json∘to_json)`; a type whose
    /// re-serialization drifts from its own output is a wire bug.
    #[test]
    fn v1_wire_types_round_trip_sweep() {
        type Reparse = Box<dyn Fn(&Json) -> Result<Json, String>>;
        let rows: Vec<(&str, Json, Reparse)> = vec![
            (
                "node_announce",
                NodeAnnounce {
                    node_id: "node-a".into(),
                    addr: "127.0.0.1:18501".into(),
                    gpu_memory_total: 24.0,
                    replica_gpu_memory: 8.0,
                    max_replicas: 3,
                    replica_capacity_rps: 12.5,
                }
                .to_json(),
                Box::new(|j| NodeAnnounce::from_json(j).map(|v| v.to_json())),
            ),
            (
                "node_status",
                NodeStatus {
                    node_id: "node-b".into(),
                    live_replicas: 2,
                    warm_replicas: 1,
                    ready: true,
                    gpu_memory_total: 24.0,
                    gpu_memory_free: 8.0,
                    frame: Some(Frame {
                        n_finished: 3.0,
                        gpu_util: 0.8,
                        ..Default::default()
                    }),
                    arrival_rps: 7.5,
                    queue_wait: 0.02,
                    batch_rps: 2.5,
                }
                .to_json(),
                Box::new(|j| NodeStatus::from_json(j).map(|v| v.to_json())),
            ),
            (
                "admin_error",
                AdminError::new("node_full", "no slot").with_detail("node_id", "node-a").to_json(),
                Box::new(|j| AdminError::from_json(j).map(|v| v.to_json())),
            ),
            (
                "admin_scale_request",
                AdminScaleRequest {
                    replicas: vec![
                        ReplicaWeight { id: 0, weight: 1.5 },
                        ReplicaWeight { id: 2, weight: 0.5 },
                    ],
                }
                .to_json(),
                Box::new(|j| {
                    AdminScaleRequest::from_json(j)
                        .map(|v| v.to_json())
                        .map_err(|e| e.message)
                }),
            ),
            (
                "admin_scale_response",
                AdminScaleResponse {
                    applied: vec![ReplicaWeight { id: 0, weight: 1.0 }],
                    routable_replicas: 2,
                }
                .to_json(),
                Box::new(|j| AdminScaleResponse::from_json(j).map(|v| v.to_json())),
            ),
            (
                "admin_node_scale_response_up",
                AdminNodeScaleResponse {
                    node_id: "node-a".into(),
                    direction: ScaleDirection::Up,
                    replica_id: 7,
                    live_replicas: 3,
                }
                .to_json(),
                Box::new(|j| AdminNodeScaleResponse::from_json(j).map(|v| v.to_json())),
            ),
            (
                "admin_node_scale_response_down",
                AdminNodeScaleResponse {
                    node_id: "node-a".into(),
                    direction: ScaleDirection::Down,
                    replica_id: 4,
                    live_replicas: 2,
                }
                .to_json(),
                Box::new(|j| AdminNodeScaleResponse::from_json(j).map(|v| v.to_json())),
            ),
            (
                "debug_export_response",
                DebugExportResponse::new(
                    "decisions",
                    "coordinator",
                    Json::parse(r#"{"recorded":2,"decisions":[]}"#).unwrap(),
                )
                .to_json(),
                Box::new(|j| DebugExportResponse::from_json(j).map(|v| v.to_json())),
            ),
            (
                "admin_chaos_request",
                AdminChaosRequest {
                    config: ChaosConfig {
                        seed: 9,
                        error_rate: 0.2,
                        ..ChaosConfig::default()
                    },
                }
                .to_json(),
                Box::new(|j| {
                    AdminChaosRequest::from_json(j)
                        .map(|v| v.to_json())
                        .map_err(|e| e.message)
                }),
            ),
            (
                "admin_chaos_response",
                AdminChaosResponse {
                    service: "node:node-a".into(),
                    config: ChaosConfig::default(),
                    stats: Json::parse(r#"{"armed":false}"#).unwrap(),
                }
                .to_json(),
                Box::new(|j| AdminChaosResponse::from_json(j).map(|v| v.to_json())),
            ),
            (
                "snapshot_info",
                sample_snapshot_info().to_json(),
                Box::new(|j| SnapshotInfo::from_json(j).map(|v| v.to_json())),
            ),
            (
                "snapshot_request_capture",
                SnapshotRequest {
                    action: SnapshotAction::Capture,
                    replica_id: Some(2),
                    node: Some("node-a".into()),
                    snapshot_hex: None,
                }
                .to_json(),
                Box::new(|j| {
                    SnapshotRequest::from_json(j)
                        .map(|v| v.to_json())
                        .map_err(|e| e.message)
                }),
            ),
            (
                "snapshot_request_restore",
                SnapshotRequest::restore("454e534e0001").to_json(),
                Box::new(|j| {
                    SnapshotRequest::from_json(j)
                        .map(|v| v.to_json())
                        .map_err(|e| e.message)
                }),
            ),
            (
                "snapshot_response",
                SnapshotResponse {
                    service: "node:node-a".into(),
                    action: SnapshotAction::Restore,
                    info: sample_snapshot_info(),
                    replica_id: 9,
                    snapshot_hex: None,
                    promote_seconds: Some(0.0021),
                }
                .to_json(),
                Box::new(|j| SnapshotResponse::from_json(j).map(|v| v.to_json())),
            ),
            (
                "snapshot_list_response",
                SnapshotListResponse {
                    service: "coordinator".into(),
                    snapshots: vec![sample_snapshot_info()],
                }
                .to_json(),
                Box::new(|j| SnapshotListResponse::from_json(j).map(|v| v.to_json())),
            ),
            (
                "migration_request",
                MigrationRequest {
                    source_node: "node-a".into(),
                    target_node: Some("node-b".into()),
                }
                .to_json(),
                Box::new(|j| {
                    MigrationRequest::from_json(j)
                        .map(|v| v.to_json())
                        .map_err(|e| e.message)
                }),
            ),
            (
                "migration_status_done",
                sample_migration_status().to_json(),
                Box::new(|j| MigrationStatus::from_json(j).map(|v| v.to_json())),
            ),
            (
                "migration_status_failed",
                MigrationStatus {
                    phase: MigrationPhase::Failed,
                    new_replica_id: None,
                    error: Some(AdminError::new("no_target", "no node has room")),
                    ..sample_migration_status()
                }
                .to_json(),
                Box::new(|j| MigrationStatus::from_json(j).map(|v| v.to_json())),
            ),
            (
                "migration_list_response",
                MigrationListResponse {
                    service: "coordinator".into(),
                    migrations: vec![sample_migration_status()],
                }
                .to_json(),
                Box::new(|j| MigrationListResponse::from_json(j).map(|v| v.to_json())),
            ),
        ];
        for (label, wire, reparse) in rows {
            // through real bytes, not just the in-memory tree
            let text = wire.to_string_compact();
            let parsed = Json::parse(&text).unwrap_or_else(|e| panic!("{label}: {e}"));
            let back = reparse(&parsed).unwrap_or_else(|e| panic!("{label}: {e}"));
            assert_eq!(
                back.to_string_compact(),
                text,
                "{label} drifted through a round trip"
            );
        }
    }

    /// The rejection half of the sweep: malformed or unknown-field payloads
    /// must fail with a structured `invalid_request` (requests) or an error
    /// string (responses) — never parse loosely, never panic.
    #[test]
    fn v1_wire_types_reject_malformed_payloads() {
        // (label, body, parse-attempt) — every row must error
        type Attempt = Box<dyn Fn(&Json) -> Result<(), String>>;
        let rows: Vec<(&str, &str, Attempt)> = vec![
            (
                "snapshot request without action",
                r#"{"replica_id":1}"#,
                Box::new(|j| SnapshotRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "snapshot request with unknown action",
                r#"{"action":"freeze"}"#,
                Box::new(|j| SnapshotRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "snapshot request with unknown field",
                r#"{"action":"capture","replicaid":1}"#,
                Box::new(|j| SnapshotRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "restore without a frame",
                r#"{"action":"restore"}"#,
                Box::new(|j| SnapshotRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "capture with a frame",
                r#"{"action":"capture","snapshot_hex":"00"}"#,
                Box::new(|j| SnapshotRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "snapshot request with non-integer replica_id",
                r#"{"action":"capture","replica_id":"two"}"#,
                Box::new(|j| SnapshotRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "migration request without source",
                r#"{"target_node":"node-b"}"#,
                Box::new(|j| MigrationRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "migration request with empty source",
                r#"{"source_node":""}"#,
                Box::new(|j| MigrationRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "migration onto itself",
                r#"{"source_node":"node-a","target_node":"node-a"}"#,
                Box::new(|j| MigrationRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "migration request with unknown field",
                r#"{"source_node":"node-a","dest":"node-b"}"#,
                Box::new(|j| MigrationRequest::from_json(j).map(|_| ()).map_err(|e| e.code)),
            ),
            (
                "snapshot info without fingerprint",
                r#"{"engine_kind":"sim","version":1}"#,
                Box::new(|j| SnapshotInfo::from_json(j).map(|_| ())),
            ),
            (
                "snapshot response without info",
                r#"{"action":"capture","replica_id":1}"#,
                Box::new(|j| SnapshotResponse::from_json(j).map(|_| ())),
            ),
            (
                "migration status with unknown phase",
                r#"{"id":1,"source_node":"a","phase":"paused"}"#,
                Box::new(|j| MigrationStatus::from_json(j).map(|_| ())),
            ),
            (
                "migration list without array",
                r#"{"service":"coordinator","migrations":{}}"#,
                Box::new(|j| MigrationListResponse::from_json(j).map(|_| ())),
            ),
        ];
        for (label, body, attempt) in rows {
            let parsed = Json::parse(body).unwrap();
            let err = attempt(&parsed).expect_err(label);
            // requests surface the stable machine-readable code
            if label.contains("request") || label.contains("restore") || label.contains("capture")
                || label.contains("onto itself")
            {
                assert_eq!(err, "invalid_request", "{label}");
            }
        }
    }
}
