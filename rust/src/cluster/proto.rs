//! Wire types of the coordinator ↔ node control protocol: plain JSON
//! bodies over the crate's hand-rolled HTTP stack. Every type serializes
//! with [`crate::util::json`] and parses defensively — a malformed peer
//! yields an error string, never a panic — so a version-skewed node and
//! coordinator fail loudly at the protocol boundary.

use super::NodeIdentity;
use crate::chaos::ChaosConfig;
use crate::metrics::Frame;
use crate::util::json::{arr_f64, num, obj, s, Json};

/// What a node POSTs to the coordinator's `/cluster/join`: where its
/// gateway listens plus its capacity advertisement.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeAnnounce {
    pub node_id: String,
    /// `host:port` of the node's gateway (ingress proxy + control target)
    pub addr: String,
    pub gpu_memory_total: f64,
    pub replica_gpu_memory: f64,
    pub max_replicas: usize,
    /// advertised per-replica service rate (requests/second); 0 = unknown
    pub replica_capacity_rps: f64,
}

impl NodeAnnounce {
    pub fn new(identity: &NodeIdentity, addr: &str) -> NodeAnnounce {
        NodeAnnounce {
            node_id: identity.node_id.clone(),
            addr: addr.to_string(),
            gpu_memory_total: identity.gpu_memory_total,
            replica_gpu_memory: identity.replica_gpu_memory,
            max_replicas: identity.max_replicas,
            replica_capacity_rps: identity.replica_capacity_rps,
        }
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("node_id", s(&self.node_id)),
            ("addr", s(&self.addr)),
            ("gpu_memory_total", num(self.gpu_memory_total)),
            ("replica_gpu_memory", num(self.replica_gpu_memory)),
            ("max_replicas", num(self.max_replicas as f64)),
            ("replica_capacity_rps", num(self.replica_capacity_rps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<NodeAnnounce, String> {
        let node_id = j
            .get("node_id")
            .and_then(Json::as_str)
            .ok_or("announce needs a string \"node_id\"")?
            .to_string();
        if node_id.is_empty() {
            return Err("announce \"node_id\" must not be empty".into());
        }
        let addr = j
            .get("addr")
            .and_then(Json::as_str)
            .ok_or("announce needs a string \"addr\"")?
            .to_string();
        if addr.is_empty() {
            return Err("announce \"addr\" must not be empty".into());
        }
        let f = |key: &str| j.get(key).and_then(Json::as_f64).filter(|v| v.is_finite());
        Ok(NodeAnnounce {
            node_id,
            addr,
            gpu_memory_total: f("gpu_memory_total").unwrap_or(0.0).max(0.0),
            replica_gpu_memory: f("replica_gpu_memory").unwrap_or(0.0).max(0.0),
            max_replicas: j
                .get("max_replicas")
                .and_then(Json::as_usize)
                .ok_or("announce needs an integer \"max_replicas\"")?,
            replica_capacity_rps: f("replica_capacity_rps").unwrap_or(0.0).max(0.0),
        })
    }
}

/// What a node answers on `GET /cluster/status`: the heartbeat row the
/// cluster supervisor monitors. `frame` is the mean of the newest Table II
/// frame across the node's live replicas (the same aggregation the
/// single-node supervisor scores); `arrival_rps` is the de-noised total
/// arrival rate across them (what the forecaster consumes).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeStatus {
    pub node_id: String,
    pub live_replicas: usize,
    pub warm_replicas: usize,
    /// every live replica's engine finished construction
    pub ready: bool,
    pub gpu_memory_total: f64,
    pub gpu_memory_free: f64,
    /// `None` until the first monitoring window flushed
    pub frame: Option<Frame>,
    pub arrival_rps: f64,
    /// mean worker-queue wait across live replicas (seconds)
    pub queue_wait: f64,
    /// share of `arrival_rps` coming from batch-tier tenants; the
    /// coordinator's tier-aware placement uses it to keep latency tenants
    /// away from batch-heavy nodes. Optional on the wire (version skew:
    /// an older node simply reports 0.0).
    pub batch_rps: f64,
}

impl NodeStatus {
    pub fn to_json(&self) -> Json {
        let mut j = obj([
            ("node_id", s(&self.node_id)),
            ("live_replicas", num(self.live_replicas as f64)),
            ("warm_replicas", num(self.warm_replicas as f64)),
            ("ready", Json::Bool(self.ready)),
            ("gpu_memory_total", num(self.gpu_memory_total)),
            ("gpu_memory_free", num(self.gpu_memory_free)),
            ("arrival_rps", num(self.arrival_rps)),
            ("queue_wait", num(self.queue_wait)),
            ("batch_rps", num(self.batch_rps)),
        ]);
        if let (Json::Obj(m), Some(frame)) = (&mut j, &self.frame) {
            m.insert("frame".to_string(), arr_f64(&frame.to_array()));
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<NodeStatus, String> {
        let node_id = j
            .get("node_id")
            .and_then(Json::as_str)
            .ok_or("status needs a string \"node_id\"")?
            .to_string();
        let frame = match j.get("frame").and_then(Json::as_arr) {
            None => None,
            Some(cols) => {
                if cols.len() != 8 {
                    return Err(format!("status \"frame\" must have 8 columns, got {}", cols.len()));
                }
                let mut a = [0.0f64; 8];
                for (slot, col) in a.iter_mut().zip(cols) {
                    *slot = col
                        .as_f64()
                        .filter(|v| v.is_finite())
                        .ok_or("status \"frame\" columns must be finite numbers")?;
                }
                Some(Frame::from_array(a))
            }
        };
        let f = |key: &str| j.get(key).and_then(Json::as_f64).filter(|v| v.is_finite());
        Ok(NodeStatus {
            node_id,
            live_replicas: j
                .get("live_replicas")
                .and_then(Json::as_usize)
                .ok_or("status needs an integer \"live_replicas\"")?,
            warm_replicas: j.get("warm_replicas").and_then(Json::as_usize).unwrap_or(0),
            ready: j.get("ready").and_then(Json::as_bool).unwrap_or(false),
            gpu_memory_total: f("gpu_memory_total").unwrap_or(0.0).max(0.0),
            gpu_memory_free: f("gpu_memory_free").unwrap_or(0.0).max(0.0),
            frame,
            arrival_rps: f("arrival_rps").unwrap_or(0.0).max(0.0),
            queue_wait: f("queue_wait").unwrap_or(0.0).max(0.0),
            batch_rps: f("batch_rps").unwrap_or(0.0).max(0.0),
        })
    }
}

// ---------------------------------------------------------------------------
// The versioned `/v1/admin/*` control API.
//
// Gateway, node, and coordinator all serve the same four operations —
// `GET /v1/admin/status`, `POST /v1/admin/scale` (router weights),
// `POST /v1/admin/scale-up`, `POST /v1/admin/scale-down` — with typed JSON
// requests/responses and structured `{code, message, details}` error
// bodies. The pre-v1 paths (`/admin/scale`, `/cluster/status`,
// `/cluster/scale-{up,down}`) remain as thin deprecated aliases for one
// release.
// ---------------------------------------------------------------------------

/// Path prefix of the unified control API.
pub const ADMIN_API_PREFIX: &str = "/v1/admin";

/// Structured error body of every `/v1/admin/*` failure:
/// `{"code": "...", "message": "...", "details": {...}}`.
#[derive(Debug, Clone, PartialEq)]
pub struct AdminError {
    /// stable machine-readable code, e.g. `invalid_request`, `node_full`
    pub code: String,
    /// human-readable explanation
    pub message: String,
    /// optional string key/value context, e.g. the offending replica id
    pub details: Vec<(String, String)>,
}

impl AdminError {
    pub fn new(code: &str, message: &str) -> AdminError {
        AdminError {
            code: code.to_string(),
            message: message.to_string(),
            details: Vec::new(),
        }
    }

    pub fn with_detail(mut self, key: &str, value: &str) -> AdminError {
        self.details.push((key.to_string(), value.to_string()));
        self
    }

    pub fn to_json(&self) -> Json {
        let details = Json::Obj(
            self.details
                .iter()
                .map(|(k, v)| (k.clone(), Json::Str(v.clone())))
                .collect(),
        );
        obj([
            ("code", s(&self.code)),
            ("message", s(&self.message)),
            ("details", details),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AdminError, String> {
        let code = j
            .get("code")
            .and_then(Json::as_str)
            .ok_or("admin error needs a string \"code\"")?
            .to_string();
        let message = j
            .get("message")
            .and_then(Json::as_str)
            .unwrap_or("")
            .to_string();
        let mut details = Vec::new();
        if let Some(Json::Obj(m)) = j.get("details") {
            for (k, v) in m {
                if let Some(v) = v.as_str() {
                    details.push((k.clone(), v.to_string()));
                }
            }
        }
        Ok(AdminError {
            code,
            message,
            details,
        })
    }
}

/// One router weight entry in a scale request/response.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ReplicaWeight {
    pub id: u64,
    pub weight: f64,
}

/// `POST /v1/admin/scale` body: the full desired router weight set.
#[derive(Debug, Clone, PartialEq)]
pub struct AdminScaleRequest {
    pub replicas: Vec<ReplicaWeight>,
}

impl AdminScaleRequest {
    pub fn to_json(&self) -> Json {
        let entries = self
            .replicas
            .iter()
            .map(|r| obj([("id", num(r.id as f64)), ("weight", num(r.weight))]))
            .collect();
        obj([("replicas", Json::Arr(entries))])
    }

    /// Parse and validate. Errors are ready-to-serve [`AdminError`]s with
    /// code `invalid_request` and the offending entry in `details`.
    pub fn from_json(j: &Json) -> Result<AdminScaleRequest, AdminError> {
        let bad = |msg: &str| AdminError::new("invalid_request", msg);
        let entries = j
            .get("replicas")
            .and_then(Json::as_arr)
            .ok_or_else(|| bad("body must be {\"replicas\": [{\"id\": N, \"weight\": W}, ...]}"))?;
        if entries.is_empty() {
            return Err(bad("\"replicas\" must not be empty"));
        }
        let mut replicas: Vec<ReplicaWeight> = Vec::with_capacity(entries.len());
        for e in entries {
            let id = e
                .get("id")
                .and_then(Json::as_f64)
                .filter(|v| v.is_finite() && *v >= 0.0 && v.fract() == 0.0)
                .ok_or_else(|| bad("every entry needs a non-negative integer \"id\""))?
                as u64;
            let weight = e
                .get("weight")
                .and_then(Json::as_f64)
                .filter(|w| w.is_finite() && *w > 0.0)
                .ok_or_else(|| {
                    bad("every entry needs a positive finite \"weight\"")
                        .with_detail("id", &id.to_string())
                })?;
            if replicas.iter().any(|r| r.id == id) {
                return Err(bad("duplicate replica id").with_detail("id", &id.to_string()));
            }
            replicas.push(ReplicaWeight { id, weight });
        }
        Ok(AdminScaleRequest { replicas })
    }
}

/// `POST /v1/admin/scale` success body.
#[derive(Debug, Clone, PartialEq)]
pub struct AdminScaleResponse {
    pub applied: Vec<ReplicaWeight>,
    pub routable_replicas: usize,
}

impl AdminScaleResponse {
    pub fn to_json(&self) -> Json {
        obj([
            (
                "applied",
                Json::Arr(
                    self.applied
                        .iter()
                        .map(|r| obj([("id", num(r.id as f64)), ("weight", num(r.weight))]))
                        .collect(),
                ),
            ),
            ("routable_replicas", num(self.routable_replicas as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AdminScaleResponse, String> {
        let applied = j
            .get("applied")
            .and_then(Json::as_arr)
            .ok_or("scale response needs an array \"applied\"")?
            .iter()
            .map(|e| {
                let id = e
                    .get("id")
                    .and_then(Json::as_usize)
                    .ok_or("applied entries need an integer \"id\"")? as u64;
                let weight = e
                    .get("weight")
                    .and_then(Json::as_f64)
                    .ok_or("applied entries need a numeric \"weight\"")?;
                Ok(ReplicaWeight { id, weight })
            })
            .collect::<Result<Vec<_>, String>>()?;
        Ok(AdminScaleResponse {
            applied,
            routable_replicas: j
                .get("routable_replicas")
                .and_then(Json::as_usize)
                .unwrap_or(0),
        })
    }
}

/// Direction of a node replica-count change.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDirection {
    Up,
    Down,
}

impl ScaleDirection {
    pub fn as_str(self) -> &'static str {
        match self {
            ScaleDirection::Up => "up",
            ScaleDirection::Down => "down",
        }
    }
}

/// `POST /v1/admin/scale-{up,down}` success body. For wire compatibility
/// with the pre-v1 endpoints the JSON also carries the legacy field name
/// (`replica_id` for up, `retired` for down).
#[derive(Debug, Clone, PartialEq)]
pub struct AdminNodeScaleResponse {
    pub node_id: String,
    pub direction: ScaleDirection,
    /// the replica added (up) or retired (down)
    pub replica_id: u64,
    pub live_replicas: usize,
}

impl AdminNodeScaleResponse {
    pub fn to_json(&self) -> Json {
        let legacy_key = match self.direction {
            ScaleDirection::Up => "replica_id",
            ScaleDirection::Down => "retired",
        };
        obj([
            ("node_id", s(&self.node_id)),
            ("action", s(self.direction.as_str())),
            (legacy_key, num(self.replica_id as f64)),
            ("live_replicas", num(self.live_replicas as f64)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AdminNodeScaleResponse, String> {
        let node_id = j
            .get("node_id")
            .and_then(Json::as_str)
            .ok_or("scale response needs a string \"node_id\"")?
            .to_string();
        let (direction, replica_id) = if let Some(id) =
            j.get("retired").and_then(Json::as_usize)
        {
            (ScaleDirection::Down, id as u64)
        } else if let Some(id) = j.get("replica_id").and_then(Json::as_usize) {
            (ScaleDirection::Up, id as u64)
        } else {
            return Err("scale response needs \"replica_id\" or \"retired\"".into());
        };
        Ok(AdminNodeScaleResponse {
            node_id,
            direction,
            replica_id,
            live_replicas: j
                .get("live_replicas")
                .and_then(Json::as_usize)
                .ok_or("scale response needs an integer \"live_replicas\"")?,
        })
    }
}

// ---------------------------------------------------------------------------
// The versioned `/v1/debug/*` observability API and `/v1/admin/chaos`.
//
// PR 8 versioned the control surface; this extends the same pattern to the
// read-only debug exports. `GET /v1/debug/traces` and `GET
// /v1/debug/decisions` answer a typed [`DebugExportResponse`] envelope —
// `{api_version, kind, service, data}` with the recorder's export embedded
// under `data` — while the pre-v1 `/debug/*` paths keep serving the bare
// export for one release as deprecated aliases. `GET|POST /v1/admin/chaos`
// reads/replaces a node's live [`ChaosConfig`] so chaos-smoke toggles
// faults without restarts; failures are structured [`AdminError`]s.
// ---------------------------------------------------------------------------

/// Path prefix of the versioned observability API.
pub const DEBUG_API_PREFIX: &str = "/v1/debug";

/// Version tag served in every `/v1/debug/*` and `/v1/admin/chaos` body.
pub const DEBUG_API_VERSION: &str = "v1";

/// Envelope of `GET /v1/debug/{traces,decisions}`: the recorder's legacy
/// export object wrapped with enough typing that consumers can verify
/// what they are holding (`kind`) and who served it (`service`).
#[derive(Debug, Clone, PartialEq)]
pub struct DebugExportResponse {
    /// `"traces"` or `"decisions"`
    pub kind: String,
    /// serving role: `coordinator`, `gateway`, or `node:<id>`
    pub service: String,
    /// the full recorder export — identical to the deprecated `/debug/*`
    /// alias body, so consumers migrate by unwrapping one level
    pub data: Json,
}

impl DebugExportResponse {
    pub fn new(kind: &str, service: &str, data: Json) -> DebugExportResponse {
        DebugExportResponse {
            kind: kind.to_string(),
            service: service.to_string(),
            data,
        }
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("api_version", s(DEBUG_API_VERSION)),
            ("kind", s(&self.kind)),
            ("service", s(&self.service)),
            ("data", self.data.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<DebugExportResponse, String> {
        let version = j
            .get("api_version")
            .and_then(Json::as_str)
            .ok_or("debug export needs a string \"api_version\"")?;
        if version != DEBUG_API_VERSION {
            return Err(format!("unsupported debug api_version {version:?}"));
        }
        let kind = j
            .get("kind")
            .and_then(Json::as_str)
            .ok_or("debug export needs a string \"kind\"")?
            .to_string();
        if kind != "traces" && kind != "decisions" {
            return Err(format!("unknown debug export kind {kind:?}"));
        }
        let data = j.get("data").ok_or("debug export needs a \"data\" object")?;
        if !matches!(data, Json::Obj(_)) {
            return Err("debug export \"data\" must be an object".into());
        }
        Ok(DebugExportResponse {
            kind,
            service: j
                .get("service")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            data: data.clone(),
        })
    }
}

/// `POST /v1/admin/chaos` body: the desired injection config. Fields not
/// named keep their [`ChaosConfig`] defaults, so `{"error_rate":0}`
/// disarms everything.
#[derive(Debug, Clone, PartialEq)]
pub struct AdminChaosRequest {
    pub config: ChaosConfig,
}

impl AdminChaosRequest {
    pub fn to_json(&self) -> Json {
        self.config.to_json()
    }

    /// Parse and validate; errors are ready-to-serve [`AdminError`]s
    /// with code `invalid_request`.
    pub fn from_json(j: &Json) -> Result<AdminChaosRequest, AdminError> {
        let config = ChaosConfig::from_json(j)
            .map_err(|msg| AdminError::new("invalid_request", &msg))?;
        Ok(AdminChaosRequest { config })
    }
}

/// `GET|POST /v1/admin/chaos` success body: the live config plus the
/// injector's counters (armed / degraded / injected totals).
#[derive(Debug, Clone, PartialEq)]
pub struct AdminChaosResponse {
    pub service: String,
    pub config: ChaosConfig,
    /// [`crate::chaos::ChaosInjector::stats_json`] output
    pub stats: Json,
}

impl AdminChaosResponse {
    pub fn to_json(&self) -> Json {
        obj([
            ("api_version", s(DEBUG_API_VERSION)),
            ("service", s(&self.service)),
            ("config", self.config.to_json()),
            ("stats", self.stats.clone()),
        ])
    }

    pub fn from_json(j: &Json) -> Result<AdminChaosResponse, String> {
        let config = j.get("config").ok_or("chaos response needs a \"config\" object")?;
        Ok(AdminChaosResponse {
            service: j
                .get("service")
                .and_then(Json::as_str)
                .unwrap_or("")
                .to_string(),
            config: ChaosConfig::from_json(config)?,
            stats: j.get("stats").cloned().unwrap_or(Json::Obj(Default::default())),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn announce_roundtrips_through_json() {
        let a = NodeAnnounce {
            node_id: "node-a".into(),
            addr: "127.0.0.1:18501".into(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 3,
            replica_capacity_rps: 12.5,
        };
        let wire = a.to_json().to_string_compact();
        let back = NodeAnnounce::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn announce_rejects_malformed_peers() {
        let missing_id = Json::parse(r#"{"addr":"x:1","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&missing_id).is_err());
        let empty_id =
            Json::parse(r#"{"node_id":"","addr":"x:1","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&empty_id).is_err());
        let no_addr = Json::parse(r#"{"node_id":"n","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&no_addr).is_err());
        let no_max = Json::parse(r#"{"node_id":"n","addr":"x:1"}"#).unwrap();
        assert!(NodeAnnounce::from_json(&no_max).is_err());
    }

    #[test]
    fn status_roundtrips_with_and_without_frame() {
        let mut st = NodeStatus {
            node_id: "node-b".into(),
            live_replicas: 2,
            warm_replicas: 1,
            ready: true,
            gpu_memory_total: 24.0,
            gpu_memory_free: 8.0,
            frame: None,
            arrival_rps: 7.5,
            queue_wait: 0.02,
            batch_rps: 2.5,
        };
        let back =
            NodeStatus::from_json(&Json::parse(&st.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, st);

        st.frame = Some(Frame {
            n_finished: 3.0,
            n_arriving: 4.0,
            gpu_util: 0.8,
            ..Default::default()
        });
        let back =
            NodeStatus::from_json(&Json::parse(&st.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, st);
    }

    #[test]
    fn status_without_batch_rps_defaults_to_zero() {
        // version skew: an older node omits the field entirely
        let old = Json::parse(r#"{"node_id":"n","live_replicas":1}"#).unwrap();
        let st = NodeStatus::from_json(&old).unwrap();
        assert_eq!(st.batch_rps, 0.0);
    }

    #[test]
    fn admin_error_roundtrips_with_details() {
        let e = AdminError::new("node_full", "no replica slot free")
            .with_detail("node_id", "node-a")
            .with_detail("live_replicas", "3");
        let wire = e.to_json().to_string_compact();
        assert!(wire.contains("\"code\":\"node_full\""));
        assert!(wire.contains("\"details\""));
        let back = AdminError::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back.code, "node_full");
        assert_eq!(back.message, "no replica slot free");
        assert!(back
            .details
            .iter()
            .any(|(k, v)| k == "node_id" && v == "node-a"));
        // a body without a code is not an admin error
        assert!(AdminError::from_json(&Json::parse(r#"{"message":"x"}"#).unwrap()).is_err());
    }

    #[test]
    fn admin_scale_request_validates() {
        let ok = Json::parse(r#"{"replicas":[{"id":0,"weight":1.5},{"id":2,"weight":0.5}]}"#)
            .unwrap();
        let req = AdminScaleRequest::from_json(&ok).unwrap();
        assert_eq!(req.replicas.len(), 2);
        assert_eq!(req.replicas[1].id, 2);
        // roundtrip
        let again = AdminScaleRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(again, req);

        for bad in [
            r#"{"weights":[]}"#,
            r#"{"replicas":[]}"#,
            r#"{"replicas":[{"id":-1,"weight":1}]}"#,
            r#"{"replicas":[{"id":0.5,"weight":1}]}"#,
            r#"{"replicas":[{"id":0,"weight":0}]}"#,
            r#"{"replicas":[{"id":0,"weight":1},{"id":0,"weight":2}]}"#,
        ] {
            let err = AdminScaleRequest::from_json(&Json::parse(bad).unwrap()).unwrap_err();
            assert_eq!(err.code, "invalid_request", "body {bad} -> {err:?}");
        }
    }

    #[test]
    fn node_scale_response_keeps_legacy_field_names() {
        let up = AdminNodeScaleResponse {
            node_id: "node-a".into(),
            direction: ScaleDirection::Up,
            replica_id: 7,
            live_replicas: 3,
        };
        let wire = up.to_json().to_string_compact();
        assert!(wire.contains("\"replica_id\":7"), "{wire}");
        assert_eq!(
            AdminNodeScaleResponse::from_json(&Json::parse(&wire).unwrap()).unwrap(),
            up
        );
        let down = AdminNodeScaleResponse {
            direction: ScaleDirection::Down,
            ..up.clone()
        };
        let wire = down.to_json().to_string_compact();
        assert!(wire.contains("\"retired\":7"), "{wire}");
        assert_eq!(
            AdminNodeScaleResponse::from_json(&Json::parse(&wire).unwrap()).unwrap(),
            down
        );
    }

    #[test]
    fn debug_export_roundtrips_and_validates() {
        let data = Json::parse(r#"{"recorded":3,"capacity":512,"traces":[]}"#).unwrap();
        let resp = DebugExportResponse::new("traces", "coordinator", data.clone());
        let wire = resp.to_json().to_string_compact();
        assert!(wire.contains("\"api_version\":\"v1\""), "{wire}");
        let back = DebugExportResponse::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, resp);
        // the embedded data is the legacy alias body, verbatim
        assert_eq!(back.data, data);

        for bad in [
            r#"{"kind":"traces","data":{}}"#,
            r#"{"api_version":"v2","kind":"traces","data":{}}"#,
            r#"{"api_version":"v1","kind":"spans","data":{}}"#,
            r#"{"api_version":"v1","kind":"traces"}"#,
            r#"{"api_version":"v1","kind":"traces","data":[]}"#,
        ] {
            let v = Json::parse(bad).unwrap();
            assert!(DebugExportResponse::from_json(&v).is_err(), "accepted {bad}");
        }
    }

    #[test]
    fn chaos_request_surfaces_structured_errors() {
        let ok = Json::parse(r#"{"seed":9,"error_rate":0.2}"#).unwrap();
        let req = AdminChaosRequest::from_json(&ok).unwrap();
        assert_eq!(req.config.seed, 9);
        assert_eq!(req.config.error_rate, 0.2);
        let again = AdminChaosRequest::from_json(&req.to_json()).unwrap();
        assert_eq!(again, req);

        let bad = Json::parse(r#"{"error_rate":7}"#).unwrap();
        let err = AdminChaosRequest::from_json(&bad).unwrap_err();
        assert_eq!(err.code, "invalid_request");
    }

    #[test]
    fn chaos_response_roundtrips() {
        let resp = AdminChaosResponse {
            service: "node:node-a".into(),
            config: ChaosConfig {
                seed: 5,
                error_rate: 0.1,
                ..ChaosConfig::default()
            },
            stats: Json::parse(r#"{"armed":true,"injected_errors":4}"#).unwrap(),
        };
        let wire = resp.to_json().to_string_compact();
        let back = AdminChaosResponse::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, resp);
    }

    #[test]
    fn status_rejects_short_or_nan_frames() {
        let short = Json::parse(r#"{"node_id":"n","live_replicas":1,"frame":[1,2,3]}"#).unwrap();
        assert!(NodeStatus::from_json(&short).is_err());
        let nan = Json::parse(
            r#"{"node_id":"n","live_replicas":1,"frame":[1,2,3,4,5,6,7,"x"]}"#,
        )
        .unwrap();
        assert!(NodeStatus::from_json(&nan).is_err());
    }
}
