//! Wire types of the coordinator ↔ node control protocol: plain JSON
//! bodies over the crate's hand-rolled HTTP stack. Every type serializes
//! with [`crate::util::json`] and parses defensively — a malformed peer
//! yields an error string, never a panic — so a version-skewed node and
//! coordinator fail loudly at the protocol boundary.

use super::NodeIdentity;
use crate::metrics::Frame;
use crate::util::json::{arr_f64, num, obj, s, Json};

/// What a node POSTs to the coordinator's `/cluster/join`: where its
/// gateway listens plus its capacity advertisement.
#[derive(Debug, Clone, PartialEq)]
pub struct NodeAnnounce {
    pub node_id: String,
    /// `host:port` of the node's gateway (ingress proxy + control target)
    pub addr: String,
    pub gpu_memory_total: f64,
    pub replica_gpu_memory: f64,
    pub max_replicas: usize,
    /// advertised per-replica service rate (requests/second); 0 = unknown
    pub replica_capacity_rps: f64,
}

impl NodeAnnounce {
    pub fn new(identity: &NodeIdentity, addr: &str) -> NodeAnnounce {
        NodeAnnounce {
            node_id: identity.node_id.clone(),
            addr: addr.to_string(),
            gpu_memory_total: identity.gpu_memory_total,
            replica_gpu_memory: identity.replica_gpu_memory,
            max_replicas: identity.max_replicas,
            replica_capacity_rps: identity.replica_capacity_rps,
        }
    }

    pub fn to_json(&self) -> Json {
        obj([
            ("node_id", s(&self.node_id)),
            ("addr", s(&self.addr)),
            ("gpu_memory_total", num(self.gpu_memory_total)),
            ("replica_gpu_memory", num(self.replica_gpu_memory)),
            ("max_replicas", num(self.max_replicas as f64)),
            ("replica_capacity_rps", num(self.replica_capacity_rps)),
        ])
    }

    pub fn from_json(j: &Json) -> Result<NodeAnnounce, String> {
        let node_id = j
            .get("node_id")
            .and_then(Json::as_str)
            .ok_or("announce needs a string \"node_id\"")?
            .to_string();
        if node_id.is_empty() {
            return Err("announce \"node_id\" must not be empty".into());
        }
        let addr = j
            .get("addr")
            .and_then(Json::as_str)
            .ok_or("announce needs a string \"addr\"")?
            .to_string();
        if addr.is_empty() {
            return Err("announce \"addr\" must not be empty".into());
        }
        let f = |key: &str| j.get(key).and_then(Json::as_f64).filter(|v| v.is_finite());
        Ok(NodeAnnounce {
            node_id,
            addr,
            gpu_memory_total: f("gpu_memory_total").unwrap_or(0.0).max(0.0),
            replica_gpu_memory: f("replica_gpu_memory").unwrap_or(0.0).max(0.0),
            max_replicas: j
                .get("max_replicas")
                .and_then(Json::as_usize)
                .ok_or("announce needs an integer \"max_replicas\"")?,
            replica_capacity_rps: f("replica_capacity_rps").unwrap_or(0.0).max(0.0),
        })
    }
}

/// What a node answers on `GET /cluster/status`: the heartbeat row the
/// cluster supervisor monitors. `frame` is the mean of the newest Table II
/// frame across the node's live replicas (the same aggregation the
/// single-node supervisor scores); `arrival_rps` is the de-noised total
/// arrival rate across them (what the forecaster consumes).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeStatus {
    pub node_id: String,
    pub live_replicas: usize,
    pub warm_replicas: usize,
    /// every live replica's engine finished construction
    pub ready: bool,
    pub gpu_memory_total: f64,
    pub gpu_memory_free: f64,
    /// `None` until the first monitoring window flushed
    pub frame: Option<Frame>,
    pub arrival_rps: f64,
    /// mean worker-queue wait across live replicas (seconds)
    pub queue_wait: f64,
}

impl NodeStatus {
    pub fn to_json(&self) -> Json {
        let mut j = obj([
            ("node_id", s(&self.node_id)),
            ("live_replicas", num(self.live_replicas as f64)),
            ("warm_replicas", num(self.warm_replicas as f64)),
            ("ready", Json::Bool(self.ready)),
            ("gpu_memory_total", num(self.gpu_memory_total)),
            ("gpu_memory_free", num(self.gpu_memory_free)),
            ("arrival_rps", num(self.arrival_rps)),
            ("queue_wait", num(self.queue_wait)),
        ]);
        if let (Json::Obj(m), Some(frame)) = (&mut j, &self.frame) {
            m.insert("frame".to_string(), arr_f64(&frame.to_array()));
        }
        j
    }

    pub fn from_json(j: &Json) -> Result<NodeStatus, String> {
        let node_id = j
            .get("node_id")
            .and_then(Json::as_str)
            .ok_or("status needs a string \"node_id\"")?
            .to_string();
        let frame = match j.get("frame").and_then(Json::as_arr) {
            None => None,
            Some(cols) => {
                if cols.len() != 8 {
                    return Err(format!("status \"frame\" must have 8 columns, got {}", cols.len()));
                }
                let mut a = [0.0f64; 8];
                for (slot, col) in a.iter_mut().zip(cols) {
                    *slot = col
                        .as_f64()
                        .filter(|v| v.is_finite())
                        .ok_or("status \"frame\" columns must be finite numbers")?;
                }
                Some(Frame::from_array(a))
            }
        };
        let f = |key: &str| j.get(key).and_then(Json::as_f64).filter(|v| v.is_finite());
        Ok(NodeStatus {
            node_id,
            live_replicas: j
                .get("live_replicas")
                .and_then(Json::as_usize)
                .ok_or("status needs an integer \"live_replicas\"")?,
            warm_replicas: j.get("warm_replicas").and_then(Json::as_usize).unwrap_or(0),
            ready: j.get("ready").and_then(Json::as_bool).unwrap_or(false),
            gpu_memory_total: f("gpu_memory_total").unwrap_or(0.0).max(0.0),
            gpu_memory_free: f("gpu_memory_free").unwrap_or(0.0).max(0.0),
            frame,
            arrival_rps: f("arrival_rps").unwrap_or(0.0).max(0.0),
            queue_wait: f("queue_wait").unwrap_or(0.0).max(0.0),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn announce_roundtrips_through_json() {
        let a = NodeAnnounce {
            node_id: "node-a".into(),
            addr: "127.0.0.1:18501".into(),
            gpu_memory_total: 24.0,
            replica_gpu_memory: 8.0,
            max_replicas: 3,
            replica_capacity_rps: 12.5,
        };
        let wire = a.to_json().to_string_compact();
        let back = NodeAnnounce::from_json(&Json::parse(&wire).unwrap()).unwrap();
        assert_eq!(back, a);
    }

    #[test]
    fn announce_rejects_malformed_peers() {
        let missing_id = Json::parse(r#"{"addr":"x:1","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&missing_id).is_err());
        let empty_id =
            Json::parse(r#"{"node_id":"","addr":"x:1","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&empty_id).is_err());
        let no_addr = Json::parse(r#"{"node_id":"n","max_replicas":2}"#).unwrap();
        assert!(NodeAnnounce::from_json(&no_addr).is_err());
        let no_max = Json::parse(r#"{"node_id":"n","addr":"x:1"}"#).unwrap();
        assert!(NodeAnnounce::from_json(&no_max).is_err());
    }

    #[test]
    fn status_roundtrips_with_and_without_frame() {
        let mut st = NodeStatus {
            node_id: "node-b".into(),
            live_replicas: 2,
            warm_replicas: 1,
            ready: true,
            gpu_memory_total: 24.0,
            gpu_memory_free: 8.0,
            frame: None,
            arrival_rps: 7.5,
            queue_wait: 0.02,
        };
        let back =
            NodeStatus::from_json(&Json::parse(&st.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, st);

        st.frame = Some(Frame {
            n_finished: 3.0,
            n_arriving: 4.0,
            gpu_util: 0.8,
            ..Default::default()
        });
        let back =
            NodeStatus::from_json(&Json::parse(&st.to_json().to_string_compact()).unwrap())
                .unwrap();
        assert_eq!(back, st);
    }

    #[test]
    fn status_rejects_short_or_nan_frames() {
        let short = Json::parse(r#"{"node_id":"n","live_replicas":1,"frame":[1,2,3]}"#).unwrap();
        assert!(NodeStatus::from_json(&short).is_err());
        let nan = Json::parse(
            r#"{"node_id":"n","live_replicas":1,"frame":[1,2,3,4,5,6,7,"x"]}"#,
        )
        .unwrap();
        assert!(NodeStatus::from_json(&nan).is_err());
    }
}
